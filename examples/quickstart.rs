//! Quickstart: the 60-second tour of the AccD engine.
//!
//! Builds a small clustered dataset, runs AccD K-means on the CPU-FPGA
//! engine, and contrasts it with the naive CPU baseline — the same
//! comparison every paper figure is built on.
//!
//! Run with:  cargo run --release --example quickstart
//! (requires `make artifacts` once beforehand)

use accd::baselines::naive;
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;

fn main() -> anyhow::Result<()> {
    // 1. Data: 20k points in 16-D with real cluster structure.
    let dataset = synthetic::clustered(20_000, 16, 70, 0.03, 42);
    println!("dataset: {} ({} x {})", dataset.name, dataset.n(), dataset.d());

    // 2. The AccD engine: loads AOT artifacts, creates the PJRT client.
    let cfg = AccdConfig::new();
    let mut engine = Engine::new(cfg)?;
    println!("accelerator platform: {}", engine.runtime.platform());

    // 3. AccD K-means: GTI filtering on CPU + distance tiles on the
    //    accelerator.
    let k = 64;
    let accd = engine.kmeans(&dataset, k, 15)?;
    println!("\n[AccD CPU-FPGA]\n{}", accd.report.summary());

    // 4. The naive baseline the paper normalizes against.
    let base = naive::kmeans(&dataset, k, 15, 42)?;
    println!("\n[naive baseline]\n{}", base.report.summary());

    // 5. The headline numbers.
    println!(
        "\nspeedup: {:.2}x | energy efficiency: {:.2}x | SSE match: {:.4}% difference",
        accd.report.speedup_vs(&base.report),
        accd.report.energy_eff_vs(&base.report),
        100.0 * (accd.sse - base.sse).abs() / base.sse.max(1e-12),
    );
    Ok(())
}
