//! N-body simulation example: radius-limited particle dynamics with the
//! full hybrid GTI (Two-landmark + Trace-based + Group-level).
//!
//! Shows the trace-based machinery doing its job across time steps:
//! center-pair distances are reused and drift-widened instead of being
//! recomputed, and the filter stats report how many refreshes the run
//! actually needed.
//!
//! Run with:  cargo run --release --example nbody_sim

use accd::baselines::naive;
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;

fn main() -> anyhow::Result<()> {
    let n = 8_192;
    let steps = 8;
    let dt = 1e-3f32;
    let radius = 0.08f32;
    let ds = synthetic::uniform(n, 3, 7);
    let masses = synthetic::equal_masses(n, 1.0);
    println!("N-body: {n} particles, {steps} steps, R={radius}");

    let mut engine = Engine::new(AccdConfig::new())?;
    let accd = engine.nbody(&ds, &masses, steps, dt, radius)?;
    println!("\n[AccD]\n{}", accd.report.summary());

    let base = naive::nbody(&ds, &masses, steps, dt, radius)?;
    println!("\n[naive]\n{}", base.report.summary());

    // Trajectory agreement.
    let mut max_err = 0.0f32;
    for i in 0..n {
        for c in 0..3 {
            max_err =
                max_err.max((accd.positions.row(i)[c] - base.positions.row(i)[c]).abs());
        }
    }
    anyhow::ensure!(max_err <= 2e-3, "trajectories diverged: {max_err}");
    println!(
        "\ntrajectories match (max err {max_err:.2e}) | speedup {:.2}x | pairs pruned {:.1}%",
        accd.report.speedup_vs(&base.report),
        100.0 * accd.report.filter.saving_ratio(),
    );
    Ok(())
}
