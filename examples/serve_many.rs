//! Batched multi-query serving: the `accd::serve` tour.
//!
//! Simulates a serving deployment: many users issue KNN / K-means /
//! N-body queries against a handful of hot datasets.  The batcher
//! coalesces compatible queries into cohorts (shared groupings, shared
//! target slabs, one tagged device pipeline per cohort), deduplicates
//! identical requests, spreads cohorts across its engine shards, and
//! honours per-query deadlines: `poll()` flushes only what is due, so
//! a latency-sensitive query never waits for patient ones — while
//! returning results identical to solo `Engine` calls (see
//! rust/tests/serve_parity.rs).  `next_wakeup()` tells a serving loop
//! when it next has to act (size trigger met -> now; else the
//! earliest deadline), and the always-on `Server` at the end runs
//! that loop on its own scheduler thread.
//!
//! Run with:  cargo run --release --example serve_many

use std::sync::Arc;
use std::time::{Duration, Instant};

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{QueryBatcher, Server, ServeRequest, ServeResponse};

fn main() -> anyhow::Result<()> {
    let mut cfg = AccdConfig::new();
    // ACCD_SERVE_DEVICES=N runs the same tour on N emulated devices
    // (CI smokes the 2-device configuration this way).
    if let Ok(devices) = std::env::var("ACCD_SERVE_DEVICES") {
        cfg.serve.devices =
            devices.parse().expect("ACCD_SERVE_DEVICES must be a positive integer");
    }
    let engine = Engine::new(cfg.clone())?;
    let mut batcher = QueryBatcher::new(engine, cfg.serve.clone());
    println!(
        "serving on {} engine shard(s) across {} emulated device(s)\n",
        batcher.shard_count(),
        batcher.device_count()
    );

    // Two hot datasets every user queries against.
    let catalog = Arc::new(synthetic::clustered(8_000, 8, 40, 0.02, 7));
    let particles = Arc::new(synthetic::uniform(400, 3, 8));
    let masses = Arc::new(synthetic::equal_masses(400, 1.0));

    // A latency-sensitive query, already due: the next poll() serves
    // it alone instead of waiting for the rest of the burst.
    let urgent_src = Arc::new(synthetic::clustered(200, 8, 4, 0.03, 99));
    let urgent_req = ServeRequest::knn(urgent_src, catalog.clone(), 5);
    let urgent = batcher.submit_with_deadline(urgent_req, Duration::ZERO);

    // A burst of patient traffic: 8 users, some asking the same thing.
    for user in 0..8u64 {
        // 4 unique query vectors, each asked twice.
        let src = Arc::new(synthetic::clustered(300, 8, 6, 0.03, 50 + user % 4));
        batcher.submit_with_deadline(
            ServeRequest::knn(src, catalog.clone(), 10),
            Duration::from_secs(3600),
        );
    }
    batcher.submit(ServeRequest::kmeans(catalog.clone(), 32, 8));
    batcher.submit(ServeRequest::nbody(particles, masses, 3, 1e-3, 0.12));

    // next_wakeup() is what a serving loop sleeps until: the urgent
    // query is already due, so it reads "act now", not the patient
    // burst's one-hour deadline.
    let wake = batcher.next_wakeup().expect("pending queries imply a wake-up");
    let now = batcher.now();
    println!(
        "submitted {} queries; next_wakeup() is {} -> polling...",
        batcher.pending_len(),
        if wake <= now { "already due".to_string() } else { format!("in {} ns", wake - now) }
    );

    let polled = batcher.poll()?;
    println!(
        "poll served {} due query(ies) (urgent id {urgent}), {} still pending\n",
        polled.len(),
        batcher.pending_len()
    );
    anyhow::ensure!(polled.iter().any(|(id, _)| *id == urgent), "urgent query must be served");

    let t = Instant::now();
    let responses = batcher.flush()?;
    let secs = t.elapsed().as_secs_f64();

    for (id, resp) in polled.iter().chain(responses.iter()) {
        match resp {
            ServeResponse::Knn(r) => println!(
                "  query {id}: knn k={} -> {} result rows (mean k-th d^2 {:.4})",
                r.k,
                r.neighbors.len(),
                r.report.quality
            ),
            ServeResponse::Kmeans(r) => println!(
                "  query {id}: kmeans -> sse {:.3} in {} iters",
                r.sse, r.iterations
            ),
            ServeResponse::Nbody(r) => println!(
                "  query {id}: nbody -> {} steps, kinetic energy {:.6}",
                r.steps, r.report.quality
            ),
        }
    }

    println!("\nburst flush took {secs:.3}s\n");
    println!("{}", batcher.stats().summary());
    println!();
    for (i, shard) in batcher.shard_stats().iter().enumerate() {
        println!(
            "  shard {i}: {} queries in {} flushes | {} tiles | slab cache {} hits / {} misses \
             | {} lockstep rounds, {} stolen | p95 {:.3} ms, {} met / {} missed",
            shard.queries,
            shard.flushes,
            shard.tiles_total,
            shard.slab_cache_hits,
            shard.slab_cache_misses,
            shard.lockstep_rounds,
            shard.steals,
            shard.latency_p95_ms(),
            shard.deadline_met,
            shard.deadline_misses,
        );
    }
    // Per-device modeled timeline: shard counters folded onto the
    // device each shard is pinned to.
    let device_count = batcher.device_count();
    let mut dev_ns = vec![[0u64; 3]; device_count];
    for (s, shard) in batcher.shard_stats().iter().enumerate() {
        let d = batcher.device_of(s);
        dev_ns[d][0] += shard.transfer_ns;
        dev_ns[d][1] += shard.compute_ns;
        dev_ns[d][2] += shard.overlap_ns;
    }
    for (d, [transfer, compute, overlap]) in dev_ns.iter().enumerate() {
        println!(
            "  device {d}: modeled {:.3} ms transfer / {:.3} ms compute, {:.3} ms overlapped",
            *transfer as f64 / 1e6,
            *compute as f64 / 1e6,
            *overlap as f64 / 1e6,
        );
    }
    anyhow::ensure!(
        batcher.stats().transfer_ns > 0,
        "cold slab uploads must appear in the modeled device timeline"
    );
    anyhow::ensure!(
        batcher.stats().tiles_shared > 0,
        "coalescible burst shared no tiles"
    );
    anyhow::ensure!(batcher.stats().deadline_flushes == 1, "poll must have served the deadline");
    anyhow::ensure!(
        batcher.stats().lockstep_rounds > 0,
        "the lockstep scheduler must have run rounds"
    );
    let stats = batcher.stats();
    anyhow::ensure!(
        stats.latency_ns.len() == stats.queries as usize,
        "every answered query must contribute a latency sample"
    );
    anyhow::ensure!(
        stats.deadline_met + stats.deadline_misses > 0,
        "deadline queries must be accounted met or missed"
    );
    // Calibration telemetry: every retired unit compares the cost
    // calibrator's predicted service time against the modeled actual,
    // whether or not predictive scheduling is enabled.
    println!(
        "  calibration: predict error p50 {}\u{2030} / p95 {}\u{2030} over {} sample(s), \
         {} predictive sheds",
        stats.predict_err_p50_permille(),
        stats.predict_err_p95_permille(),
        stats.predict_err_permille.len(),
        stats.predicted_sheds,
    );
    anyhow::ensure!(
        !stats.predict_err_permille.is_empty(),
        "every flush must record predicted-vs-actual error samples"
    );
    anyhow::ensure!(
        stats.predicted_sheds == 0,
        "predictive shedding is off by default; nothing may be shed"
    );

    // --- The always-on Server: same runtime, no manual polling ------------
    // `serve::Server` owns the loop the code above drove by hand: a
    // scheduler thread sleeps until `next_wakeup()`, producers submit
    // from any thread and block on their own `ResponseHandle`, and
    // shutdown drains every accepted query before returning the
    // merged stats.
    let server = Server::new(Engine::new(cfg.clone())?, cfg.serve.clone());
    let mut handles = Vec::new();
    for user in 0..4u64 {
        let src = Arc::new(synthetic::clustered(300, 8, 6, 0.03, 150 + user));
        handles.push(server.submit_with_deadline(
            ServeRequest::knn(src, catalog.clone(), 10),
            Duration::from_millis(5),
        )?);
    }
    println!("\nserver: submitted 4 queries; waiting on their handles...");
    for (i, handle) in handles.into_iter().enumerate() {
        let resp = handle.wait()?;
        let r = resp.as_knn().expect("knn response");
        println!("  server query {i}: knn k={} -> {} result rows", r.k, r.neighbors.len());
    }
    let sstats = server.shutdown();
    println!(
        "server: {} queries in {} flushes | {} shed (intake high-water {})",
        sstats.queries, sstats.flushes, sstats.shed, sstats.queue_depth_watermark
    );
    anyhow::ensure!(
        sstats.latency_ns.len() == 4 && sstats.shed == 0,
        "the server must answer every accepted query"
    );
    Ok(())
}
