//! Batched multi-query serving: the `accd::serve` tour.
//!
//! Simulates a serving deployment: many users issue KNN / K-means /
//! N-body queries against a handful of hot datasets.  The batcher
//! coalesces compatible queries into cohorts (shared groupings, shared
//! target slabs, one tagged device pipeline), deduplicates identical
//! requests, and reports what it amortized — while returning results
//! identical to solo `Engine` calls (see rust/tests/serve_parity.rs).
//!
//! Run with:  cargo run --release --example serve_many

use std::sync::Arc;
use std::time::Instant;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{QueryBatcher, ServeRequest, ServeResponse};

fn main() -> anyhow::Result<()> {
    let cfg = AccdConfig::new();
    let engine = Engine::new(cfg.clone())?;
    let mut batcher = QueryBatcher::new(engine, cfg.serve.clone());

    // Two hot datasets every user queries against.
    let catalog = Arc::new(synthetic::clustered(8_000, 8, 40, 0.02, 7));
    let particles = Arc::new(synthetic::uniform(400, 3, 8));
    let masses = Arc::new(synthetic::equal_masses(400, 1.0));

    // A burst of traffic: 10 users, some asking the same thing.
    for user in 0..8u64 {
        // 4 unique query vectors, each asked twice.
        let src = Arc::new(synthetic::clustered(300, 8, 6, 0.03, 50 + user % 4));
        batcher.submit(ServeRequest::knn(src, catalog.clone(), 10));
    }
    batcher.submit(ServeRequest::kmeans(catalog.clone(), 32, 8));
    batcher.submit(ServeRequest::nbody(particles, masses, 3, 1e-3, 0.12));
    println!("submitted {} queries; flushing...", batcher.pending_len());

    let t = Instant::now();
    let responses = batcher.flush()?;
    let secs = t.elapsed().as_secs_f64();

    for (id, resp) in &responses {
        match resp {
            ServeResponse::Knn(r) => println!(
                "  query {id}: knn k={} -> {} result rows (mean k-th d^2 {:.4})",
                r.k,
                r.neighbors.len(),
                r.report.quality
            ),
            ServeResponse::Kmeans(r) => println!(
                "  query {id}: kmeans -> sse {:.3} in {} iters",
                r.sse, r.iterations
            ),
            ServeResponse::Nbody(r) => println!(
                "  query {id}: nbody -> {} steps, kinetic energy {:.6}",
                r.steps, r.report.quality
            ),
        }
    }

    println!("\nflush took {secs:.3}s\n");
    println!("{}", batcher.stats().summary());
    anyhow::ensure!(
        batcher.stats().tiles_shared > 0,
        "coalescible burst shared no tiles"
    );
    Ok(())
}
