//! DDSL compiler example: parse each sample program under
//! `examples/ddsl/`, print the recognized algorithm family, the GTI
//! strategy the planner selected (the paper's strategy table), and the
//! dataset bindings a runner would attach.
//!
//! Run with:  cargo run --release --example ddsl_compile

use accd::ddsl;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("examples/ddsl");
    let mut paths: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|e| e == "dd"))
        .collect();
    paths.sort();
    anyhow::ensure!(!paths.is_empty(), "no .dd programs in {}", dir.display());

    for path in paths {
        let src = std::fs::read_to_string(&path)?;
        println!("== {} ==", path.display());
        match ddsl::compile_program(&src) {
            Ok(plan) => {
                println!("  kind:     {:?}", plan.kind);
                println!("  strategy: {}", plan.strategy);
                println!(
                    "  metric:   {}{}",
                    if plan.metric.weighted { "weighted " } else { "" },
                    plan.metric.norm
                );
                for (name, size, dim) in &plan.bindings {
                    println!("  bind:     {name} ({size} x {dim})");
                }
            }
            Err(e) => println!("  compile error: {e}"),
        }
        println!();
    }
    Ok(())
}
