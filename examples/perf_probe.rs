//! Perf probe: per-call cost and throughput of the accelerator tiles
//! across the shipped size variants.  This is the measurement tool the
//! §Perf iteration log in EXPERIMENTS.md is built from — run it after
//! kernel or runtime changes to see where the dispatch/compute
//! crossover sits.
//!
//! Run with:  cargo run --release --example perf_probe

use accd::runtime::Runtime;
use accd::util::rng::Rng;
use std::time::Instant;

fn main() {
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let mut rng = Rng::new(1);
    let d = 16usize;
    let iters = 20;
    println!("-- distance tiles (l2sq, d={d}) --");
    for (tm, tn) in [(64usize, 64usize), (512, 512), (512, 64), (64, 512)] {
        let a: Vec<f32> = (0..tm * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..tn * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let _ = rt.distance_tile_sized("l2sq", tm, tn, d, &a, &b).unwrap(); // compile
        let t = Instant::now();
        for _ in 0..iters {
            let _ = rt.distance_tile_sized("l2sq", tm, tn, d, &a, &b).unwrap();
        }
        let per = t.elapsed().as_secs_f64() / iters as f64;
        let macs = (tm * tn * d) as f64;
        println!(
            "distance {tm}x{tn}x{d}: {:.1}us/call, {:.2} GMAC/s",
            per * 1e6,
            macs / per / 1e9
        );
    }
    println!("-- fused kmeans-assign tiles (d={d}) --");
    for tm in [64usize, 512] {
        for kp in [64usize, 512] {
            let a: Vec<f32> = (0..tm * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let c: Vec<f32> = (0..kp * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let _ = rt.kmeans_assign_tile_sized(tm, kp, d, &a, &c).unwrap();
            let t = Instant::now();
            for _ in 0..iters {
                let _ = rt.kmeans_assign_tile_sized(tm, kp, d, &a, &c).unwrap();
            }
            let per = t.elapsed().as_secs_f64() / iters as f64;
            println!(
                "kmeans m{tm} k{kp} d{d}: {:.1}us/call, {:.2} GMAC/s",
                per * 1e6,
                (tm * kp * d) as f64 / per / 1e9
            );
        }
    }
    println!("-- nbody force tiles --");
    for (tm, tn) in [(64usize, 64usize), (512, 512)] {
        let pi: Vec<f32> = (0..tm * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let pj: Vec<f32> = (0..tn * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let m: Vec<f32> = (0..tn).map(|_| rng.range_f32(0.1, 1.0)).collect();
        let _ = rt.nbody_accel_sized(tm, tn, &pi, &pj, &m, 1e-4, 0.5).unwrap();
        let t = Instant::now();
        for _ in 0..iters {
            let _ = rt.nbody_accel_sized(tm, tn, &pi, &pj, &m, 1e-4, 0.5).unwrap();
        }
        let per = t.elapsed().as_secs_f64() / iters as f64;
        println!(
            "nbody {tm}x{tn}: {:.1}us/call, {:.2} Gpair/s",
            per * 1e6,
            (tm * tn) as f64 / per / 1e9
        );
    }
}
