//! KNN-join example: nearest-neighbor search over a spatial dataset,
//! the paper's second benchmark (Two-landmark + Group-level GTI).
//!
//! Mirrors the "3D Spatial Network" Table V scenario at reduced scale
//! and shows how the inter-group layout schedule drives target-slab
//! reuse on the accelerator.
//!
//! Run with:  cargo run --release --example knn_search

use accd::baselines::naive;
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::tablev;

fn main() -> anyhow::Result<()> {
    // The "3D Spatial Network" row of Table V, scaled to laptop size.
    let spec = tablev::knn_datasets()
        .into_iter()
        .find(|s| s.name == "3D Spatial Network")
        .unwrap()
        .scaled(0.03); // ~13k points
    let trg = spec.generate();
    // Query set: a disjoint sample of the same distribution.
    let mut src_spec = spec.clone();
    src_spec.size /= 4;
    src_spec.seed ^= 0x51;
    let src = src_spec.generate();
    let k = spec.k.min(200); // scaled-down Top-K

    println!(
        "KNN-join: {} queries x {} targets, d={}, K={k}",
        src.n(),
        trg.n(),
        trg.d()
    );

    let mut engine = Engine::new(AccdConfig::new())?;
    let accd = engine.knn_join(&src, &trg, k)?;
    println!("\n[AccD]\n{}", accd.report.summary());

    let base = naive::knn_join(&src, &trg, k)?;
    println!("\n[naive]\n{}", base.report.summary());

    // Verify: every query's K-th neighbor distance matches.
    for i in 0..src.n() {
        let (da, _) = accd.neighbors[i][k - 1];
        let (db, _) = base.neighbors[i][k - 1];
        anyhow::ensure!(
            (da - db).abs() <= 1e-3 * (1.0 + db),
            "query {i}: K-th neighbor diverged ({da} vs {db})"
        );
    }
    println!(
        "\nresults verified | speedup {:.2}x | filter saved {:.1}% | slab reuse {:.1}%",
        accd.report.speedup_vs(&base.report),
        100.0 * accd.report.filter.saving_ratio(),
        100.0 * accd.report.layout.reuse_ratio(),
    );
    Ok(())
}
