//! End-to-end driver: exercises the **full** system on one real small
//! workload, proving all layers compose (this is the repo's headline
//! validation run, recorded in EXPERIMENTS.md §End-to-end):
//!
//!   DDSL source → lexer/parser/typecheck → GTI strategy selection →
//!   DSE explorer picks the hardware design point → engine executes
//!   the plan (CPU GTI filter + PJRT-loaded Pallas distance tiles) →
//!   result cross-checked against naive + TOP + CBLAS baselines →
//!   paper-style speedup/energy table printed.
//!
//! Run with:  cargo run --release --example end_to_end

use accd::baselines::{cblas, naive, top};
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::ddsl;
use accd::dse::{explorer::Workload, Explorer};
use accd::util::bench::{fmt_x, Table};

/// K-means over a 12k x 24-D set with 96 clusters, expressed in DDSL.
const PROGRAM: &str = r#"
    DVar K int 96;
    DVar D int 24;
    DVar psize int 12000;
    DVar csize int 96;
    DSet pSet float psize D;
    DSet cSet float csize D;
    DSet distMat float psize csize;
    DSet idMat int psize csize;
    DSet pkMat int psize K;
    DVar S int;
    AccD_Iter(12) {
        S = false;
        AccD_Comp_Dist(pSet, cSet, distMat, idMat, D, "L2", 0);
        AccD_Dist_Select(distMat, idMat, K, "smallest", pkMat);
        AccD_Update(cSet, pSet, pkMat, S)
    }
"#;

fn main() -> anyhow::Result<()> {
    // --- Stage 1: DDSL compilation --------------------------------------
    let plan = ddsl::compile_program(PROGRAM)?;
    println!("[1/5] DDSL compiled: strategy = {}", plan.strategy);
    let ddsl::plan::PlanKind::KmeansLike { k, max_iters, .. } = plan.kind else {
        anyhow::bail!("planner mis-classified the program");
    };
    let (_, psize, pdim) = plan.bindings[0].clone();

    // --- Stage 2: DSE ----------------------------------------------------
    let workload =
        Workload { src_size: psize, trg_size: k, d: pdim, n_iteration: 3, alpha: 10.0 };
    let dse = Explorer::default().explore(&workload)?;
    println!(
        "[2/5] DSE: {} configs -> block={} simd={} unroll={} src_groups={} (modeled {:.4}s)",
        dse.evaluated, dse.best.block, dse.best.simd, dse.best.unroll, dse.best.n_src_grp,
        dse.best_latency
    );

    // --- Stage 3: engine with the DSE-selected design --------------------
    let mut cfg = AccdConfig::new();
    cfg.hw = dse.best.to_hw(cfg.hw.freq_mhz);
    cfg.gti.src_groups = dse.best.n_src_grp;
    cfg.gti.trg_groups = dse.best.n_trg_grp.min(k);
    let seed = cfg.seed;
    let dataset = synthetic::clustered(psize, pdim, 110, 0.025, seed);
    let mut engine = Engine::new(cfg)?;
    let accd_run = engine.kmeans(&dataset, k, max_iters)?;
    println!("[3/5] AccD run: {}", accd_run.report.summary());

    // --- Stage 4: baselines ----------------------------------------------
    let base = naive::kmeans(&dataset, k, max_iters, seed)?;
    let top_run = top::kmeans(&dataset, k, max_iters, seed)?;
    let cblas_run = cblas::kmeans(&dataset, k, max_iters, seed)?;
    println!("[4/5] baselines done");

    // --- Stage 5: cross-check + table ------------------------------------
    let tol = 1e-3 * (1.0 + base.sse);
    anyhow::ensure!(
        (accd_run.sse - base.sse).abs() <= tol,
        "AccD SSE {} diverged from naive {}",
        accd_run.sse,
        base.sse
    );
    let mut table = Table::new(&["impl", "wall (s)", "speedup", "energy (J)", "energy-eff"]);
    for (name, report) in [
        ("Baseline", &base.report),
        ("TOP", &top_run.report),
        ("CBLAS", &cblas_run.report),
        ("AccD", &accd_run.report),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{:.3}", report.wall_secs),
            fmt_x(base.report.wall_secs / report.wall_secs),
            format!("{:.1}", report.energy_j),
            fmt_x(base.report.energy_j / report.energy_j),
        ]);
    }
    table.print("end-to-end: K-means 12k x 24-D, k=96 (results verified equal)");
    println!("\n[5/5] all layers verified: DDSL -> DSE -> GTI filter -> PJRT tiles -> results");
    Ok(())
}
