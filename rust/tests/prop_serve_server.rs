//! Property test for the always-on server: for ANY seeded open-loop
//! arrival schedule — random request mixes, random arrival ticks,
//! random deadline assignments — every query accepted by
//! `serve::Server` resolves to a response **bit-for-bit equal** to the
//! solo engine, across shard counts 1 / 2 / 4.  The schedule runs on a
//! `VirtualClock` (the scheduler wakes via the registered clock waker),
//! so arbitrary arrival interleavings are exercised with zero
//! wall-clock sleeps.  This is the server-level extension of the serve
//! parity contract: concurrency, intake transfer, wake-up scheduling
//! and drain-on-shutdown may change *when* queries run, never *what*
//! they compute.

use std::sync::Arc;
use std::time::Duration;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{Server, ServeRequest, ServeResponse, VirtualClock};
use accd::util::prop::{self, Config};

/// One scheduled arrival: the request, its arrival tick (ms from
/// scenario start) and an optional deadline span (ms from arrival).
#[derive(Debug)]
struct Arrival {
    req: ServeRequest,
    at_ms: u64,
    deadline_ms: Option<u64>,
}

/// Exact comparison of one served response against the solo run.
fn check_against_solo(
    resp: &ServeResponse,
    req: &ServeRequest,
    solo: &mut Engine,
    what: &str,
) -> Result<(), String> {
    match req {
        ServeRequest::Knn { src, trg, k, metric } => {
            let want =
                solo.knn_join_metric(src, trg, *k, *metric).map_err(|e| e.to_string())?;
            let got = resp.as_knn().ok_or_else(|| format!("{what}: wrong kind"))?;
            if got.k != want.k || got.neighbors != want.neighbors {
                return Err(format!("{what}: knn diverged"));
            }
        }
        ServeRequest::Kmeans { ds, k, max_iters } => {
            let want = solo.kmeans(ds, *k, *max_iters).map_err(|e| e.to_string())?;
            let got = resp.as_kmeans().ok_or_else(|| format!("{what}: wrong kind"))?;
            if got.assign != want.assign {
                return Err(format!("{what}: kmeans assignment diverged"));
            }
            if got.sse != want.sse {
                return Err(format!("{what}: kmeans sse {} != {}", got.sse, want.sse));
            }
            if got.iterations != want.iterations {
                return Err(format!("{what}: iterations {} != {}", got.iterations, want.iterations));
            }
            if got.centers.as_slice() != want.centers.as_slice() {
                return Err(format!("{what}: kmeans centers diverged"));
            }
        }
        ServeRequest::RangeJoin { src, trg, threshold, metric } => {
            let want = solo
                .range_join_metric(src, trg, *threshold, *metric)
                .map_err(|e| e.to_string())?;
            let got = resp.as_rangejoin().ok_or_else(|| format!("{what}: wrong kind"))?;
            if got.neighbors != want.neighbors {
                return Err(format!("{what}: rangejoin diverged"));
            }
        }
        ServeRequest::Nbody { .. } => unreachable!("schedule has no N-body queries"),
    }
    Ok(())
}

#[test]
fn prop_server_matches_solo_for_any_arrival_schedule() {
    prop::check(
        &Config { cases: 4, max_size: 60, seed: 0x5E12_4E12, ..Default::default() },
        |rng, size| {
            // Shared content pool: one KNN target cohort, two K-means
            // datasets, a handful of sources (reused, so dedup and the
            // fingerprint memo stay in play under arrival races).
            let trg = Arc::new(synthetic::clustered(160 + size, 4, 5, 0.03, 500 + size as u64));
            let km_a = Arc::new(synthetic::clustered(110 + size, 4, 4, 0.04, 600 + size as u64));
            let km_b = Arc::new(synthetic::clustered(90 + size / 2, 4, 4, 0.04, 700));
            let srcs: Vec<_> = (0..3)
                .map(|s| Arc::new(synthetic::clustered(40 + 10 * s, 4, 3, 0.05, 800 + s as u64)))
                .collect();
            let n_queries = 5 + rng.below(5);
            let mut schedule: Vec<Arrival> = (0..n_queries)
                .map(|_| {
                    let req = match rng.below(3) {
                        0 => ServeRequest::knn(srcs[rng.below(srcs.len())].clone(), trg.clone(), 3),
                        1 => ServeRequest::kmeans(km_a.clone(), 2 + rng.below(6), rng.below(4)),
                        _ => ServeRequest::kmeans(km_b.clone(), 2 + rng.below(4), 1 + rng.below(3)),
                    };
                    Arrival {
                        req,
                        at_ms: rng.below(50) as u64,
                        deadline_ms: (rng.below(3) != 0).then(|| 1 + rng.below(40) as u64),
                    }
                })
                .collect();
            schedule.sort_by_key(|a| a.at_ms);
            schedule
        },
        |schedule| {
            let mut solo = Engine::new(AccdConfig::new()).map_err(|e| e.to_string())?;
            for shards in [1usize, 2, 4] {
                let mut cfg = AccdConfig::new();
                cfg.serve.shards = shards;
                let engine = Engine::new(cfg.clone()).map_err(|e| e.to_string())?;
                let clock = VirtualClock::new();
                let server =
                    Server::with_clock(engine, cfg.serve.clone(), Arc::new(clock.clone()));
                let mut handles = Vec::new();
                for a in schedule {
                    // Open loop: jump the clock to the arrival tick and
                    // submit without waiting on any earlier response.
                    clock.set(a.at_ms * 1_000_000);
                    let handle = match a.deadline_ms {
                        Some(ms) => server
                            .submit_with_deadline(a.req.clone(), Duration::from_millis(ms)),
                        None => server.submit(a.req.clone()),
                    };
                    handles.push(handle.map_err(|e| e.to_string())?);
                }
                // Let every deadline expire, then drain the rest.
                clock.advance(Duration::from_millis(100));
                let stats = server.shutdown();
                if stats.latency_ns.len() != schedule.len() {
                    return Err(format!(
                        "{shards} shards: {} answered of {}",
                        stats.latency_ns.len(),
                        schedule.len()
                    ));
                }
                if stats.shed != 0 {
                    return Err(format!("{shards} shards: {} shed under default cap", stats.shed));
                }
                let with_deadline =
                    schedule.iter().filter(|a| a.deadline_ms.is_some()).count() as u64;
                if stats.deadline_met + stats.deadline_misses != with_deadline {
                    return Err(format!("{shards} shards: deadline accounting: {stats:?}"));
                }
                for (i, handle) in handles.into_iter().enumerate() {
                    let resp = handle.wait().map_err(|e| e.to_string())?;
                    let what = format!("{shards} shards, arrival {i}");
                    check_against_solo(&resp, &schedule[i].req, &mut solo, &what)?;
                }
            }
            Ok(())
        },
    );
}
