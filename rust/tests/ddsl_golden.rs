//! Golden-file tests for the DDSL front end.
//!
//! Each `rust/tests/ddsl_golden/<name>.dd` program is compiled through
//! the full pipeline (lexer → parser → typecheck → planner) and the
//! resulting `ExecutionPlan` is rendered to a stable textual snapshot,
//! compared byte-for-byte against `<name>.golden`.  Any parser,
//! typechecker or planner refactor that silently changes program
//! semantics fails here with a readable diff.
//!
//! Programs the compiler *rejects by design* (e.g. weighted metrics,
//! which no execution path implements) are part of the corpus too:
//! their snapshot is the rejection itself, rendered as `rejected: <msg>`.
//! That locks the refusal — a regression that starts accepting such a
//! program (or rewords the diagnostic) diffs here.
//!
//! Regenerate snapshots after an *intentional* semantic change with:
//! `ACCD_UPDATE_GOLDEN=1 cargo test --test ddsl_golden`

use accd::ddsl::{self, plan::PlanKind, ExecutionPlan};
use std::path::{Path, PathBuf};

/// Stable, human-auditable rendering of a plan.  Deliberately not
/// `{:#?}` so incidental `derive(Debug)` layout changes don't churn
/// every snapshot — only semantic fields appear.
fn render(plan: &ExecutionPlan) -> String {
    let kind = match &plan.kind {
        PlanKind::KmeansLike { points, centers, k, max_iters } => {
            format!("KmeansLike {{ points: {points}, centers: {centers}, k: {k}, max_iters: {max_iters} }}")
        }
        PlanKind::KnnJoinLike { src, trg, k } => {
            format!("KnnJoinLike {{ src: {src}, trg: {trg}, k: {k} }}")
        }
        PlanKind::RangeJoinLike { src, trg, threshold } => {
            format!("RangeJoinLike {{ src: {src}, trg: {trg}, threshold: {threshold} }}")
        }
        PlanKind::NbodyLike { particles, radius_expr, max_iters } => {
            format!("NbodyLike {{ particles: {particles}, radius: {radius_expr}, max_iters: {max_iters} }}")
        }
    };
    let mut out = String::new();
    out.push_str(&format!("kind: {kind}\n"));
    out.push_str(&format!("strategy: {}\n", plan.strategy));
    out.push_str(&format!(
        "metric: {} {}\n",
        if plan.metric.weighted { "weighted" } else { "unweighted" },
        plan.metric.norm
    ));
    for (name, size, dim) in &plan.bindings {
        out.push_str(&format!("bind: {name} {size}x{dim}\n"));
    }
    out
}

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/ddsl_golden")
}

#[test]
fn golden_corpus_matches_snapshots() {
    let dir = golden_dir();
    let mut programs: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "dd"))
        .collect();
    programs.sort();
    assert!(
        programs.len() >= 4,
        "golden corpus unexpectedly small: {} programs in {}",
        programs.len(),
        dir.display()
    );

    let update = std::env::var_os("ACCD_UPDATE_GOLDEN").is_some();
    let mut failures = Vec::new();
    for program in &programs {
        let name = program.file_stem().unwrap().to_string_lossy().to_string();
        let src = std::fs::read_to_string(program).expect("read .dd");
        let got = match ddsl::compile_program(&src) {
            Ok(plan) => render(&plan),
            // Intentionally-rejected programs snapshot their diagnostic.
            Err(e) => format!("rejected: {e}\n"),
        };
        let golden_path = dir.join(format!("{name}.golden"));
        if update {
            std::fs::write(&golden_path, &got).expect("write golden");
            continue;
        }
        let want = std::fs::read_to_string(&golden_path).unwrap_or_else(|e| {
            panic!(
                "{}: missing snapshot ({e}); run with ACCD_UPDATE_GOLDEN=1 to create",
                golden_path.display()
            )
        });
        if got.trim_end() != want.trim_end() {
            failures.push(format!(
                "== {name} ==\n--- expected ---\n{want}\n--- got ---\n{got}"
            ));
        }
    }
    assert!(
        failures.is_empty(),
        "DDSL golden snapshots diverged (semantic change?):\n{}",
        failures.join("\n")
    );
}

/// The goldens themselves are also sanity-locked in code for the four
/// strategy families, so a wholesale regeneration of wrong snapshots
/// (e.g. blindly re-blessing after a planner bug) still gets caught.
/// Rejected programs don't contribute a family, but at least one must
/// exist so the error-snapshot path stays exercised.
#[test]
fn golden_corpus_covers_all_four_strategy_families() {
    let dir = golden_dir();
    let mut kinds = std::collections::BTreeSet::new();
    let mut rejected = 0usize;
    for entry in std::fs::read_dir(&dir).expect("read golden dir") {
        let p = entry.expect("dir entry").path();
        if p.extension().is_some_and(|x| x == "dd") {
            match ddsl::compile_program(&std::fs::read_to_string(&p).unwrap()) {
                Ok(plan) => {
                    kinds.insert(match plan.kind {
                        PlanKind::KmeansLike { .. } => "kmeans",
                        PlanKind::KnnJoinLike { .. } => "knn",
                        PlanKind::RangeJoinLike { .. } => "rangejoin",
                        PlanKind::NbodyLike { .. } => "nbody",
                    });
                }
                // The exact diagnostic is locked by the snapshot test.
                Err(_) => rejected += 1,
            }
        }
    }
    assert_eq!(
        kinds.into_iter().collect::<Vec<_>>(),
        vec!["kmeans", "knn", "nbody", "rangejoin"],
        "corpus must exercise every planner family"
    );
    assert!(rejected >= 1, "corpus must include at least one rejected program");
}
