//! Property test for the lockstep serving schedule: batched K-means /
//! N-body cohorts through `serve::QueryBatcher` must equal sequential
//! solo runs **bit-for-bit** across random iteration caps, random
//! cohort mixes, random *deadline permutations*, both placement modes
//! (`lpt` / `edf-lpt`) and shard counts 1 / 2 / 4 — with lockstep
//! stepping and work stealing at their defaults (on).  Each shard
//! count is paired with a different emulated-device count (with a
//! tiny per-device memory budget and the transfer/compute overlap
//! knob alternating), so device pinning, per-device slab budgets,
//! movement-aware placement and the overlap accounting all run under
//! the property without growing the sweep.  Deadlines run
//! on a `VirtualClock` the property advances in waves, so the
//! deadline-driven flush order, EDF placement tiers, urgent-first
//! claims and step priority are all exercised without a single sleep
//! — and none of them may perturb a single bit.  This is the
//! executable form of the stepwise-program safety argument: programs
//! own all their iteration state, so no step schedule, placement or
//! migration can perturb a result.

use std::sync::Arc;
use std::time::Duration;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{QueryBatcher, ServeRequest, ServeResponse, VirtualClock};
use accd::util::prop::{self, Config};

/// Exact comparison of one served response against the solo run.
fn check_against_solo(
    resp: &ServeResponse,
    req: &ServeRequest,
    solo: &mut Engine,
    what: &str,
) -> Result<(), String> {
    match req {
        ServeRequest::Kmeans { ds, k, max_iters } => {
            let want = solo.kmeans(ds, *k, *max_iters).map_err(|e| e.to_string())?;
            let got = resp.as_kmeans().ok_or_else(|| format!("{what}: wrong kind"))?;
            if got.assign != want.assign {
                return Err(format!("{what}: kmeans assignment diverged"));
            }
            if got.sse != want.sse {
                return Err(format!("{what}: kmeans sse {} != {}", got.sse, want.sse));
            }
            if got.iterations != want.iterations {
                return Err(format!(
                    "{what}: iterations {} != {}",
                    got.iterations, want.iterations
                ));
            }
            if got.centers.as_slice() != want.centers.as_slice() {
                return Err(format!("{what}: kmeans centers diverged"));
            }
        }
        ServeRequest::Nbody { ds, masses, steps, dt, radius } => {
            let want = solo
                .nbody(ds, masses.as_slice(), *steps, *dt, *radius)
                .map_err(|e| e.to_string())?;
            let got = resp.as_nbody().ok_or_else(|| format!("{what}: wrong kind"))?;
            if got.positions.as_slice() != want.positions.as_slice() {
                return Err(format!("{what}: nbody positions diverged"));
            }
            if got.velocities.as_slice() != want.velocities.as_slice() {
                return Err(format!("{what}: nbody velocities diverged"));
            }
        }
        ServeRequest::Knn { .. } | ServeRequest::RangeJoin { .. } => {
            unreachable!("workload has no KNN / range-join queries")
        }
    }
    Ok(())
}

#[test]
fn prop_lockstep_batched_iterative_cohorts_equal_sequential() {
    prop::check(
        &Config { cases: 4, max_size: 70, seed: 0x10C5, ..Default::default() },
        |rng, size| {
            let n_km = 80 + size; // 80..150 points
            let n_nb = 60 + size / 2;
            let km_ds = Arc::new(synthetic::clustered(n_km, 4, 4, 0.05, 1000 + size as u64));
            let nb_ds = Arc::new(synthetic::uniform(n_nb, 3, 2000 + size as u64));
            let masses = Arc::new(synthetic::equal_masses(n_nb, 1.0));
            let mut reqs: Vec<ServeRequest> = Vec::new();
            // Cohort mix: 2-4 K-means on ONE dataset with random k and
            // random iteration caps (including a 0-iteration cap, the
            // plan-then-finish edge), plus 1-2 N-body with random step
            // counts — co-resident iterative programs of every shape.
            for _ in 0..(2 + rng.below(3)) {
                let k = 2 + rng.below(6);
                let iters = rng.below(5);
                reqs.push(ServeRequest::kmeans(km_ds.clone(), k, iters));
            }
            for _ in 0..(1 + rng.below(2)) {
                let steps = 1 + rng.below(3);
                reqs.push(ServeRequest::nbody(
                    nb_ds.clone(),
                    masses.clone(),
                    steps,
                    1e-3,
                    0.2,
                ));
            }
            // Random deadline permutation: each query is patient
            // (None) or due at a random millisecond within the two
            // poll waves — duplicates may straddle waves, exercising
            // deadline inheritance across the identity class.
            let deadlines: Vec<Option<u64>> = reqs
                .iter()
                .map(|_| {
                    if rng.below(4) == 0 {
                        None
                    } else {
                        Some(1 + rng.below(50) as u64)
                    }
                })
                .collect();
            reqs.into_iter().zip(deadlines).collect::<Vec<_>>()
        },
        |cases| {
            let mut solo = Engine::new(AccdConfig::new()).map_err(|e| e.to_string())?;
            for placement in ["lpt", "edf-lpt"] {
                for (shards, devices) in [(1usize, 1usize), (2, 2), (4, 3)] {
                    let mut cfg = AccdConfig::new();
                    cfg.serve.shards = shards;
                    cfg.serve.placement = placement.to_string();
                    cfg.serve.devices = devices;
                    cfg.serve.overlap = shards % 2 == 0;
                    if devices > 1 {
                        cfg.serve.device_mem_bytes = 1 << 16;
                    }
                    if !cfg.serve.lockstep || cfg.serve.steal_threshold == 0 {
                        return Err("lockstep + stealing must default on".into());
                    }
                    let engine = Engine::new(cfg.clone()).map_err(|e| e.to_string())?;
                    let clock = VirtualClock::new();
                    let mut batcher = QueryBatcher::with_clock(
                        engine,
                        cfg.serve.clone(),
                        Arc::new(clock.clone()),
                    );
                    for (req, deadline) in cases {
                        match deadline {
                            Some(ms) => batcher.submit_with_deadline(
                                req.clone(),
                                Duration::from_millis(*ms),
                            ),
                            None => batcher.submit(req.clone()),
                        };
                    }
                    // Two deadline waves, then the patient remainder:
                    // three different batch compositions per config.
                    let mut out: Vec<(u64, ServeResponse)> = Vec::new();
                    clock.advance(Duration::from_millis(25));
                    out.extend(batcher.poll().map_err(|e| e.to_string())?);
                    clock.advance(Duration::from_millis(35));
                    out.extend(batcher.poll().map_err(|e| e.to_string())?);
                    out.extend(batcher.flush().map_err(|e| e.to_string())?);
                    if out.len() != cases.len() {
                        return Err(format!(
                            "{} responses for {} queries",
                            out.len(),
                            cases.len()
                        ));
                    }
                    if batcher.stats().deadline_misses + batcher.stats().deadline_met
                        != cases.iter().filter(|(_, d)| d.is_some()).count() as u64
                    {
                        return Err("every deadline query must be met or missed".into());
                    }
                    for (id, resp) in &out {
                        let qi = *id as usize;
                        let what = format!(
                            "{placement}, {shards} shards, {devices} devices, query {qi}"
                        );
                        check_against_solo(resp, &cases[qi].0, &mut solo, &what)?;
                    }
                }
            }
            Ok(())
        },
    );
}
