//! Incremental TI pruning: stats plumbing and the A/B config lever.
//!
//! Covers the counters' full path — `gti::FilterStats` inside a
//! K-means program, folded into the shard delta at retirement
//! (`serve::exec::retire_job`), summed into the merged and per-shard
//! `ServeStats` views through `absorb_exec` — plus the
//! `kmeans.incremental_ti = false` escape hatch (counters must stay
//! exactly zero and results must be unchanged).

use std::sync::Arc;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset};
use accd::serve::{QueryBatcher, ServeRequest};

fn km_dataset(seed: u64) -> Arc<Dataset> {
    // Tight clusters: after the first couple of Lloyd iterations the
    // centers barely move, so the carried bounds certify most points.
    Arc::new(synthetic::clustered(600, 5, 8, 0.02, seed))
}

fn sharded_batcher(cfg: &AccdConfig) -> QueryBatcher {
    QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone())
}

/// Default config (incremental on): repeated-iteration K-means prunes
/// points, and the counters agree between the merged view and the sum
/// of the per-shard views.
#[test]
fn incremental_counters_flow_to_merged_and_shard_views() {
    let mut cfg = AccdConfig::new();
    cfg.serve.shards = 2;
    assert!(cfg.kmeans.incremental_ti, "incremental TI must default on");
    let mut batcher = sharded_batcher(&cfg);
    for i in 0..4u64 {
        batcher.submit(ServeRequest::kmeans(km_dataset(900 + i), 8, 6));
    }
    let responses = batcher.flush().expect("flush");
    assert_eq!(responses.len(), 4);

    let merged = batcher.stats().clone();
    assert!(
        merged.points_pruned > 0,
        "multi-iteration clustered K-means must prune points: {merged:?}"
    );
    assert!(
        merged.bound_recomputes > 0,
        "pruning implies cheap ub-tightens were spent: {merged:?}"
    );

    let shard_points: u64 = batcher.shard_stats().iter().map(|s| s.points_pruned).sum();
    let shard_tiles: u64 = batcher.shard_stats().iter().map(|s| s.tiles_skipped).sum();
    let shard_recomp: u64 = batcher.shard_stats().iter().map(|s| s.bound_recomputes).sum();
    assert_eq!(shard_points, merged.points_pruned, "shard views must sum to merged");
    assert_eq!(shard_tiles, merged.tiles_skipped, "shard views must sum to merged");
    assert_eq!(shard_recomp, merged.bound_recomputes, "shard views must sum to merged");
}

/// `kmeans.incremental_ti = false` restores the recompute-every-
/// iteration path: all three counters stay exactly zero, merged and
/// per shard.
#[test]
fn incremental_off_keeps_counters_zero() {
    let mut cfg = AccdConfig::new();
    cfg.serve.shards = 2;
    cfg.kmeans.incremental_ti = false;
    let mut batcher = sharded_batcher(&cfg);
    for i in 0..3u64 {
        batcher.submit(ServeRequest::kmeans(km_dataset(950 + i), 8, 6));
    }
    batcher.flush().expect("flush");

    let merged = batcher.stats().clone();
    assert_eq!(merged.points_pruned, 0, "legacy path must not prune: {merged:?}");
    assert_eq!(merged.tiles_skipped, 0, "legacy path must not skip tiles: {merged:?}");
    assert_eq!(merged.bound_recomputes, 0, "legacy path spends no ub-tightens: {merged:?}");
    for (i, s) in batcher.shard_stats().iter().enumerate() {
        assert_eq!(s.points_pruned, 0, "shard {i}");
        assert_eq!(s.tiles_skipped, 0, "shard {i}");
        assert_eq!(s.bound_recomputes, 0, "shard {i}");
    }
}

/// The pruning is an optimization, not an approximation: solo runs
/// with incremental TI on and off produce identical assignments,
/// centers, SSE and iteration counts, and only the incremental run
/// reports prune counters.
#[test]
fn incremental_and_legacy_paths_agree_exactly() {
    let ds = km_dataset(971);
    let mut cfg_on = AccdConfig::new();
    cfg_on.kmeans.incremental_ti = true;
    let mut cfg_off = cfg_on.clone();
    cfg_off.kmeans.incremental_ti = false;

    let on = Engine::new(cfg_on).unwrap().kmeans(&ds, 8, 6).expect("incremental run");
    let off = Engine::new(cfg_off).unwrap().kmeans(&ds, 8, 6).expect("legacy run");

    assert_eq!(on.assign, off.assign, "assignments must agree");
    assert_eq!(on.sse, off.sse, "SSE must agree exactly");
    assert_eq!(on.iterations, off.iterations, "iteration counts must agree");
    assert_eq!(on.centers.as_slice(), off.centers.as_slice(), "centers must agree");

    assert!(
        on.report.filter.points_pruned > 0,
        "incremental run must prune: {:?}",
        on.report.filter
    );
    assert_eq!(off.report.filter.points_pruned, 0);
    assert_eq!(off.report.filter.tiles_skipped, 0);
    assert_eq!(off.report.filter.bound_recomputes, 0);
}
