//! Deadline-aware serving, end to end, on a virtual clock.
//!
//! A seeded scenario harness submits mixed KNN / K-means cohorts with
//! staggered deadlines against a `QueryBatcher` whose time source is a
//! test-controlled `VirtualClock`, then drives the clock wave by wave
//! and asserts the deadline contract:
//!
//! (a) urgent cohorts place onto lightly-loaded shards (EDF-LPT
//!     spreads same-tier urgent units while pure LPT piles them
//!     behind the heavy unit's counterweight),
//! (b) `deadline_misses == 0` when capacity suffices (every wave is
//!     served at exactly its deadline tick),
//! (c) when capacity does NOT suffice, misses are *counted* — never
//!     silently dropped: every query is still answered, correctly.
//!
//! No sleeps anywhere: every deadline expiry is a clock advance.

use std::sync::Arc;
use std::time::Duration;

use accd::config::{AccdConfig, PlacementMode};
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{QueryBatcher, ServeRequest, ShardPlanner, VirtualClock};

const MS: u64 = 1_000_000; // ticks per millisecond

fn clocked_batcher(
    clock: &VirtualClock,
    tweak: impl FnOnce(&mut AccdConfig),
) -> QueryBatcher {
    let mut cfg = AccdConfig::new();
    tweak(&mut cfg);
    let engine = Engine::new(cfg.clone()).unwrap();
    QueryBatcher::with_clock(engine, cfg.serve.clone(), Arc::new(clock.clone()))
}

/// One scenario query: the request, its deadline (from scenario start)
/// and the wave (poll round) that must serve it.
struct Planned {
    req: ServeRequest,
    deadline: Option<Duration>,
    wave: usize,
}

/// The seeded staggered-deadline workload: three 10 ms waves of mixed
/// KNN / K-means cohorts (wave 3 includes a patient duplicate that
/// must ride along via deadline inheritance), plus a deadline-free
/// straggler served only by the final explicit flush (wave 3).
fn staggered_scenario(seed: u64) -> Vec<Planned> {
    let trg_a = Arc::new(synthetic::clustered(300, 4, 6, 0.03, seed));
    let trg_b = Arc::new(synthetic::clustered(220, 4, 5, 0.03, seed + 1));
    let km_ds = Arc::new(synthetic::clustered(260, 5, 6, 0.03, seed + 2));
    let src = |s: u64, n: usize| Arc::new(synthetic::clustered(n, 4, 4, 0.04, seed + 10 + s));
    let wave3_src = src(4, 70);
    let ms = Duration::from_millis;
    let planned = |req: ServeRequest, deadline: Option<Duration>, wave: usize| Planned {
        req,
        deadline,
        wave,
    };
    vec![
        // Wave 0 (10 ms): one KNN + one K-means.
        planned(ServeRequest::knn(src(0, 60), trg_a.clone(), 5), Some(ms(10)), 0),
        planned(ServeRequest::kmeans(km_ds.clone(), 6, 3), Some(ms(10)), 0),
        // Wave 1 (20 ms): same KNN cohort target, new source; another
        // K-means on the same dataset (different k: not a duplicate).
        planned(ServeRequest::knn(src(1, 80), trg_a.clone(), 5), Some(ms(20)), 1),
        planned(ServeRequest::kmeans(km_ds.clone(), 9, 3), Some(ms(20)), 1),
        // Wave 2 (30 ms): a second cohort + a patient duplicate that
        // inherits the 30 ms deadline from its identical twin.
        planned(ServeRequest::knn(wave3_src.clone(), trg_b.clone(), 4), Some(ms(30)), 2),
        planned(ServeRequest::knn(wave3_src, trg_b, 4), Some(ms(3_600_000)), 2),
        // Deadline-free straggler: only the explicit flush serves it.
        planned(ServeRequest::kmeans(km_ds, 4, 2), None, 3),
    ]
}

/// Exact parity of one response against the solo engine — every
/// result field, same rigor as `serve_parity.rs`'s comparisons (a
/// deadline-scheduling regression must not hide in an unchecked
/// field).
fn assert_solo_parity(
    resp: &accd::serve::ServeResponse,
    req: &ServeRequest,
    solo: &mut Engine,
    what: &str,
) {
    match req {
        ServeRequest::Knn { src, trg, k, metric } => {
            let want = solo.knn_join_metric(src, trg, *k, *metric).expect("solo knn");
            let got = resp.as_knn().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.k, want.k, "{what}: k");
            assert_eq!(got.neighbors, want.neighbors, "{what}: knn diverged");
        }
        ServeRequest::Kmeans { ds, k, max_iters } => {
            let want = solo.kmeans(ds, *k, *max_iters).expect("solo kmeans");
            let got = resp.as_kmeans().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.assign, want.assign, "{what}: kmeans diverged");
            assert_eq!(got.sse, want.sse, "{what}: kmeans sse diverged");
            assert_eq!(got.iterations, want.iterations, "{what}: kmeans iterations diverged");
            assert_eq!(
                got.centers.as_slice(),
                want.centers.as_slice(),
                "{what}: kmeans centers diverged"
            );
        }
        ServeRequest::RangeJoin { src, trg, threshold, metric } => {
            let want =
                solo.range_join_metric(src, trg, *threshold, *metric).expect("solo rangejoin");
            let got = resp.as_rangejoin().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.neighbors, want.neighbors, "{what}: rangejoin diverged");
        }
        ServeRequest::Nbody { .. } => unreachable!("scenario has no N-body queries"),
    }
}

/// (b) Capacity suffices: the harness polls at exactly each wave's
/// deadline tick, so every deadline is met, nothing is missed, and
/// every response equals the solo run — across shard counts and both
/// placement modes.
#[test]
fn staggered_waves_meet_every_deadline_when_capacity_suffices() {
    let scenario_seed = 0xD0_5E;
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for placement in ["edf-lpt", "lpt"] {
        for shards in [1usize, 2, 4] {
            let clock = VirtualClock::new();
            let mut b = clocked_batcher(&clock, |c| {
                c.serve.shards = shards;
                c.serve.placement = placement.to_string();
            });
            let plan = staggered_scenario(scenario_seed);
            let ids: Vec<_> = plan
                .iter()
                .map(|p| match p.deadline {
                    Some(d) => b.submit_with_deadline(p.req.clone(), d),
                    None => b.submit(p.req.clone()),
                })
                .collect();

            // Wave polls at deadline ticks 10/20/30 ms, then the
            // explicit flush for the deadline-free straggler.
            let mut served: Vec<(u64, accd::serve::ServeResponse)> = Vec::new();
            for wave in 0..3usize {
                clock.advance(Duration::from_millis(10));
                let out = b.poll().expect("wave poll");
                let want: Vec<u64> = plan
                    .iter()
                    .zip(&ids)
                    .filter(|(p, _)| p.wave == wave)
                    .map(|(_, id)| *id)
                    .collect();
                let got: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
                assert_eq!(got, want, "{placement}/{shards}: wave {wave} membership");
                served.extend(out);
            }
            served.extend(b.flush().expect("final flush"));
            assert_eq!(served.len(), plan.len(), "every query answered");

            let stats = b.stats();
            assert_eq!(stats.deadline_misses, 0, "{placement}/{shards}: {stats:?}");
            // Six queries carried a deadline (incl. the inheriting
            // duplicate); the straggler had none.
            assert_eq!(stats.deadline_met, 6, "{placement}/{shards}: {stats:?}");
            assert_eq!(stats.latency_ns.len(), plan.len());
            assert!(stats.latency_p50_ms() > 0.0, "virtual latency must be visible");
            // Per-shard accounting folds up to the merged view.
            let met: u64 = b.shard_stats().iter().map(|s| s.deadline_met).sum();
            let missed: u64 = b.shard_stats().iter().map(|s| s.deadline_misses).sum();
            let samples: usize = b.shard_stats().iter().map(|s| s.latency_ns.len()).sum();
            assert_eq!((met, missed, samples), (6, 0, plan.len()));

            for (id, resp) in &served {
                let qi = ids.iter().position(|x| x == id).expect("known id");
                let what = format!("{placement}/{shards}: query {qi}");
                assert_solo_parity(resp, &plan[qi].req, &mut solo, &what);
            }
        }
    }
}

/// (c) Capacity does NOT suffice: the clock jumps far past every
/// deadline before service happens (the virtual-clock stand-in for an
/// overloaded pool).  Every miss is counted, every query is still
/// answered — late, correct, never dropped.
#[test]
fn overload_counts_misses_and_drops_nothing() {
    let clock = VirtualClock::new();
    let mut b = clocked_batcher(&clock, |c| c.serve.shards = 2);
    let plan = staggered_scenario(0xBEEF);
    let with_deadline =
        plan.iter().filter(|p| p.deadline.is_some()).count() as u64;
    let ids: Vec<_> = plan
        .iter()
        .map(|p| match p.deadline {
            Some(d) => b.submit_with_deadline(p.req.clone(), d),
            None => b.submit(p.req.clone()),
        })
        .collect();
    // 10 virtual minutes late: every wave deadline expires; only the
    // patient duplicate's hour-long deadline survives.
    clock.advance(Duration::from_secs(600));
    let mut served = b.poll().expect("overload poll");
    served.extend(b.flush().expect("final flush"));
    assert_eq!(served.len(), ids.len(), "late queries are answered, not dropped");
    let stats = b.stats();
    // The 3600-second duplicate is still within its own deadline at
    // t=600 s — it rides along via inheritance and is MET; the other
    // five deadline queries all missed.
    assert_eq!(stats.deadline_misses, with_deadline - 1, "{stats:?}");
    assert_eq!(stats.deadline_met, 1, "{stats:?}");
    assert_eq!(stats.latency_ns.len(), plan.len());
    // Latency tells the true story: ~600 s p50, not a rosy zero.
    assert!(stats.latency_p50_ms() >= 600_000.0, "{}", stats.latency_p50_ms());

    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for (id, resp) in &served {
        let qi = ids.iter().position(|x| x == id).expect("known id");
        assert_solo_parity(resp, &plan[qi].req, &mut solo, &format!("late query {qi}"));
    }
}

/// (a) Urgent cohorts place onto lightly-loaded shards.  Two equal
/// urgent units and one heavy patient unit over two shards: EDF-LPT
/// assigns the urgent tier first, spreading one urgent unit per
/// shard; pure LPT assigns the heavy unit first and parks BOTH urgent
/// units behind it on the other shard.  Asserted at the planner level
/// and end to end via per-shard deadline accounting.
#[test]
fn urgent_units_spread_across_lightly_loaded_shards() {
    // Planner level: the same-tier urgent units 1 and 2 must not share
    // a shard under EDF-LPT.
    let costs = [100_000u64, 1_000, 1_000];
    let deadlines = [None, Some(5 * MS), Some(5 * MS)];
    let edf = ShardPlanner::plan(&costs, &deadlines, 2, PlacementMode::EdfLpt);
    let shard_of = |parts: &Vec<Vec<usize>>, unit: usize| {
        parts.iter().position(|p| p.contains(&unit)).expect("placed")
    };
    assert_ne!(
        shard_of(&edf, 1),
        shard_of(&edf, 2),
        "EDF must spread the urgent tier across shards: {edf:?}"
    );
    let lpt = ShardPlanner::plan(&costs, &deadlines, 2, PlacementMode::Lpt);
    assert_eq!(
        shard_of(&lpt, 1),
        shard_of(&lpt, 2),
        "pure LPT counterweights the heavy unit with both urgent ones: {lpt:?}"
    );

    // End to end: one heavy patient K-means + two small urgent ones on
    // distinct datasets, flushed together at t=0 (stealing off so the
    // plan IS the execution).  Per-shard deadline_met shows where the
    // urgent queries ran: [1, 1] under EDF-LPT, [0, 2] under LPT.
    let heavy = Arc::new(synthetic::clustered(600, 5, 8, 0.03, 21));
    let fast_a = Arc::new(synthetic::clustered(120, 5, 4, 0.04, 22));
    let fast_b = Arc::new(synthetic::clustered(120, 5, 4, 0.04, 23));
    let rush = Duration::from_millis(5);
    let submit = |b: &mut QueryBatcher| {
        b.submit(ServeRequest::kmeans(heavy.clone(), 12, 6));
        b.submit_with_deadline(ServeRequest::kmeans(fast_a.clone(), 4, 2), rush);
        b.submit_with_deadline(ServeRequest::kmeans(fast_b.clone(), 4, 2), rush);
    };
    let mut met_by_mode = Vec::new();
    for placement in ["edf-lpt", "lpt"] {
        let clock = VirtualClock::new();
        let mut b = clocked_batcher(&clock, |c| {
            c.serve.shards = 2;
            c.serve.steal_threshold = 0;
            c.serve.placement = placement.to_string();
        });
        submit(&mut b);
        let out = b.flush().expect("flush");
        assert_eq!(out.len(), 3);
        assert_eq!(b.stats().deadline_met, 2, "urgent pair served within deadline");
        let mut met: Vec<u64> = b.shard_stats().iter().map(|s| s.deadline_met).collect();
        met.sort_unstable();
        met_by_mode.push((placement, met));
    }
    assert_eq!(met_by_mode[0], ("edf-lpt", vec![1, 1]), "EDF spreads urgency");
    assert_eq!(met_by_mode[1], ("lpt", vec![0, 2]), "LPT piles urgency on one shard");
}
