//! Cross-implementation agreement tests: the AccD coordinator (GTI
//! filter + accelerator tiles) must produce the same answers as the
//! naive CPU baseline on every algorithm — GTI prunes *computations*,
//! never *results*.
//!
//! Skips gracefully when artifacts are missing (run `make artifacts`).

use accd::baselines::{naive, top};
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;

fn engine() -> Option<Engine> {
    let mut cfg = AccdConfig::new();
    cfg.seed = 42;
    match Engine::new(cfg) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping integration tests (no artifacts): {e}");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// KNN-join: exact agreement (deterministic, no iteration)
// ---------------------------------------------------------------------------

#[test]
fn knn_join_matches_naive_on_clustered_data() {
    let Some(mut eng) = engine() else { return };
    // Enough groups that radii are tight relative to cluster spacing —
    // at bench scale the auto heuristic (~sqrt(n)/2) provides this.
    eng.config.gti.src_groups = 32;
    eng.config.gti.trg_groups = 48;
    let src = synthetic::clustered(400, 6, 12, 0.01, 1);
    let trg = synthetic::clustered(700, 6, 12, 0.01, 2);
    let k = 10;
    let accd = eng.knn_join(&src, &trg, k).unwrap();
    let base = naive::knn_join(&src, &trg, k).unwrap();
    for i in 0..src.n() {
        assert_eq!(accd.neighbors[i].len(), k, "point {i}: wrong k");
        for r in 0..k {
            let (da, _) = accd.neighbors[i][r];
            let (db, _) = base.neighbors[i][r];
            assert!(
                (da - db).abs() <= 1e-3 * (1.0 + db.abs()),
                "point {i} rank {r}: accd {da} vs naive {db}"
            );
        }
    }
    // The filter must have pruned something on clustered data.
    assert!(
        accd.report.filter.saving_ratio() > 0.1,
        "no pruning happened: {:?}",
        accd.report.filter
    );
}

#[test]
fn knn_join_matches_naive_on_uniform_data() {
    // Uniform data = worst case for TI; correctness must still hold.
    let Some(mut eng) = engine() else { return };
    let src = synthetic::uniform(300, 4, 3);
    let trg = synthetic::uniform(500, 4, 4);
    let k = 7;
    let accd = eng.knn_join(&src, &trg, k).unwrap();
    let base = naive::knn_join(&src, &trg, k).unwrap();
    for i in 0..src.n() {
        for r in 0..k {
            let (da, _) = accd.neighbors[i][r];
            let (db, _) = base.neighbors[i][r];
            assert!((da - db).abs() <= 1e-3 * (1.0 + db.abs()), "point {i} rank {r}");
        }
    }
}

#[test]
fn knn_join_k_larger_than_groups() {
    let Some(mut eng) = engine() else { return };
    let src = synthetic::clustered(150, 3, 4, 0.05, 5);
    let trg = synthetic::clustered(200, 3, 4, 0.05, 6);
    let k = 150; // bigger than any single group
    let accd = eng.knn_join(&src, &trg, k).unwrap();
    let base = naive::knn_join(&src, &trg, k).unwrap();
    for i in (0..src.n()).step_by(17) {
        for r in (0..k).step_by(13) {
            let (da, _) = accd.neighbors[i][r];
            let (db, _) = base.neighbors[i][r];
            assert!((da - db).abs() <= 1e-3 * (1.0 + db.abs()), "point {i} rank {r}");
        }
    }
}

// ---------------------------------------------------------------------------
// K-means: same trajectory as naive Lloyd from the same seed
// ---------------------------------------------------------------------------

#[test]
fn kmeans_reaches_naive_sse() {
    let Some(mut eng) = engine() else { return };
    let ds = synthetic::clustered(600, 8, 10, 0.03, 7);
    let k = 16;
    let iters = 15;
    let accd = eng.kmeans(&ds, k, iters).unwrap();
    let base = naive::kmeans(&ds, k, iters, eng.config.seed).unwrap();
    // Same seed => same initial centers => identical Lloyd trajectory
    // (GTI only skips provably-unchanged work).
    let rel = (accd.sse - base.sse).abs() / (1.0 + base.sse);
    assert!(rel <= 1e-3, "SSE diverged: accd {} vs naive {}", accd.sse, base.sse);
    // Assignment agreement (allow tie-break slack).
    let mut diff = 0usize;
    for i in 0..ds.n() {
        if accd.assign[i] != base.assign[i] {
            diff += 1;
        }
    }
    assert!(diff <= ds.n() / 100, "assignments diverged on {diff}/{} points", ds.n());
}

#[test]
fn kmeans_with_tiny_k_and_k_above_pad_boundary() {
    let Some(mut eng) = engine() else { return };
    let ds = synthetic::clustered(400, 5, 6, 0.04, 8);
    for k in [2usize, 65] {
        // 2 << first pad (64); 65 crosses into the 128 pad
        let accd = eng.kmeans(&ds, k, 8).unwrap();
        let base = naive::kmeans(&ds, k, 8, eng.config.seed).unwrap();
        let rel = (accd.sse - base.sse).abs() / (1.0 + base.sse);
        assert!(rel <= 1e-3, "k={k}: accd {} vs naive {}", accd.sse, base.sse);
    }
}

// ---------------------------------------------------------------------------
// N-body: trajectories match the naive integrator
// ---------------------------------------------------------------------------

#[test]
fn nbody_positions_track_naive() {
    let Some(mut eng) = engine() else { return };
    // Uniform box + small interaction radius: the regime where the
    // radius filter has real work to do (a condensed Plummer core with
    // a large radius degenerates to all-pairs, tested separately).
    eng.config.gti.src_groups = 64;
    let ds = synthetic::uniform(500, 3, 9);
    let masses = synthetic::equal_masses(500, 1.0);
    let (steps, dt, r) = (5usize, 1e-3f32, 0.1f32);
    let accd = eng.nbody(&ds, &masses, steps, dt, r).unwrap();
    let base = naive::nbody(&ds, &masses, steps, dt, r).unwrap();
    let mut max_err = 0.0f32;
    for i in 0..ds.n() {
        for c in 0..3 {
            let (xa, xb) = (accd.positions.row(i)[c], base.positions.row(i)[c]);
            max_err = max_err.max((xa - xb).abs());
        }
    }
    assert!(max_err <= 2e-3, "trajectory divergence {max_err}");
    assert!(
        accd.report.filter.saving_ratio() > 0.1,
        "radius filter pruned nothing: {:?}",
        accd.report.filter
    );
}

#[test]
fn nbody_huge_radius_consistency() {
    // Huge radius: every pair interacts; AccD must not drop any.
    let Some(mut eng) = engine() else { return };
    let ds = synthetic::plummer(150, 1.0, 10);
    let masses = synthetic::equal_masses(150, 1.0);
    let accd = eng.nbody(&ds, &masses, 2, 1e-3, 50.0).unwrap();
    let base = naive::nbody(&ds, &masses, 2, 1e-3, 50.0).unwrap();
    for i in (0..ds.n()).step_by(7) {
        for c in 0..3 {
            let (xa, xb) = (accd.positions.row(i)[c], base.positions.row(i)[c]);
            assert!((xa - xb).abs() <= 1e-3 * (1.0 + xb.abs()), "particle {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// TOP hybrid (Fig. 10 path) stays correct too
// ---------------------------------------------------------------------------

#[test]
fn top_fpga_kmeans_matches_naive() {
    let Some(mut eng) = engine() else { return };
    let ds = synthetic::clustered(350, 5, 6, 0.04, 11);
    let k = 12;
    let seed = eng.config.seed;
    let hybrid = top::kmeans_fpga(&mut eng, &ds, k, 10, seed).unwrap();
    let base = naive::kmeans(&ds, k, 10, eng.config.seed).unwrap();
    let rel = (hybrid.sse - base.sse).abs() / (1.0 + base.sse);
    assert!(rel <= 1e-3, "hybrid {} vs naive {}", hybrid.sse, base.sse);
}

// ---------------------------------------------------------------------------
// Error paths
// ---------------------------------------------------------------------------

#[test]
fn invalid_arguments_are_rejected() {
    let Some(mut eng) = engine() else { return };
    let ds = synthetic::uniform(50, 4, 12);
    assert!(eng.kmeans(&ds, 0, 5).is_err());
    assert!(eng.kmeans(&ds, 51, 5).is_err());
    let trg = synthetic::uniform(50, 5, 13); // dim mismatch
    assert!(eng.knn_join(&ds, &trg, 5).is_err());
    assert!(eng.range_join(&ds, &trg, 0.5).is_err()); // dim mismatch
    let trg4 = synthetic::uniform(40, 4, 14);
    assert!(eng.range_join(&ds, &trg4, 0.0).is_err()); // zero threshold
    assert!(eng.range_join(&ds, &trg4, -1.0).is_err()); // negative
    assert!(eng.range_join(&ds, &trg4, f32::NAN).is_err()); // non-finite
    assert!(eng.range_join(&ds, &trg4, f32::INFINITY).is_err());
    let masses = vec![1.0f32; 50];
    assert!(eng.nbody(&ds, &masses, 1, 1e-3, 0.5).is_err()); // d != 3
}

// ---------------------------------------------------------------------------
// Metric generality: L1 KNN-join (the DDSL's "Unweighted L1" metric)
// ---------------------------------------------------------------------------

#[test]
fn knn_join_l1_matches_scalar_reference() {
    let Some(mut eng) = engine() else { return };
    let src = synthetic::clustered(250, 5, 8, 0.03, 21);
    let trg = synthetic::clustered(400, 5, 8, 0.03, 22);
    let k = 8;
    let accd = eng
        .knn_join_metric(&src, &trg, k, accd::gti::Metric::L1)
        .unwrap();
    // Scalar L1 reference.
    for i in (0..src.n()).step_by(11) {
        let mut all: Vec<(f32, u32)> = (0..trg.n())
            .map(|j| {
                let d: f32 = src
                    .points
                    .row(i)
                    .iter()
                    .zip(trg.points.row(j))
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                (d, j as u32)
            })
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        for r in 0..k {
            let (da, _) = accd.neighbors[i][r];
            assert!(
                (da - all[r].0).abs() <= 1e-3 * (1.0 + all[r].0),
                "L1 point {i} rank {r}: accd {da} vs ref {}",
                all[r].0
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Range join (radius query): EXACT agreement with a brute-force scan
// ---------------------------------------------------------------------------

/// Brute-force oracle: for every source point, every target point with
/// device-space distance `<= to_device(threshold)`, sorted ascending by
/// `(value, id)`.  Uses `Metric::device_dist` (the tile's accumulation
/// order), so the comparison below can demand bit-for-bit equality —
/// the GTI classification (pruned / sure-within / straddling) must
/// never change a result, only where it is computed.
fn brute_range_join(
    src: &accd::data::Dataset,
    trg: &accd::data::Dataset,
    threshold: f32,
    metric: accd::gti::Metric,
) -> Vec<Vec<(f32, u32)>> {
    let t_dev = metric.to_device(threshold);
    (0..src.n())
        .map(|i| {
            let mut nb: Vec<(f32, u32)> = (0..trg.n())
                .filter_map(|j| {
                    let v = metric.device_dist(src.points.row(i), trg.points.row(j));
                    (v <= t_dev).then_some((v, j as u32))
                })
                .collect();
            nb.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            nb
        })
        .collect()
}

#[test]
fn range_join_matches_brute_force_exactly_on_clustered_data() {
    let Some(mut eng) = engine() else { return };
    eng.config.gti.src_groups = 32;
    eng.config.gti.trg_groups = 48;
    let src = synthetic::clustered(400, 6, 12, 0.01, 1);
    let trg = synthetic::clustered(700, 6, 12, 0.01, 2);
    let threshold = 0.25f32;
    let accd = eng.range_join(&src, &trg, threshold).unwrap();
    let base = brute_range_join(&src, &trg, threshold, accd::gti::Metric::L2);
    assert_eq!(accd.neighbors.len(), base.len());
    for i in 0..src.n() {
        assert_eq!(accd.neighbors[i], base[i], "point {i}: within-set differs from oracle");
    }
    // The result set must be non-trivial (tight clusters => neighbors
    // exist) and the group filter must have pruned pairs on this data.
    assert!(accd.neighbors.iter().any(|nb| !nb.is_empty()), "degenerate workload");
    let f = &accd.report.filter;
    assert!(
        f.surviving_group_pairs < f.group_pairs,
        "no group pair was pruned: {f:?}"
    );
}

#[test]
fn range_join_matches_brute_force_exactly_on_uniform_data() {
    // Uniform data = worst case for TI; exactness must still hold.
    let Some(mut eng) = engine() else { return };
    let src = synthetic::uniform(300, 4, 3);
    let trg = synthetic::uniform(500, 4, 4);
    for threshold in [0.2f32, 0.6, 2.0] {
        let accd = eng.range_join(&src, &trg, threshold).unwrap();
        let base = brute_range_join(&src, &trg, threshold, accd::gti::Metric::L2);
        for i in 0..src.n() {
            assert_eq!(accd.neighbors[i], base[i], "T={threshold}, point {i}");
        }
    }
}

#[test]
fn range_join_l1_matches_brute_force_exactly() {
    let Some(mut eng) = engine() else { return };
    let src = synthetic::clustered(250, 5, 8, 0.03, 21);
    let trg = synthetic::clustered(400, 5, 8, 0.03, 22);
    let threshold = 0.5f32;
    let accd = eng.range_join_metric(&src, &trg, threshold, accd::gti::Metric::L1).unwrap();
    let base = brute_range_join(&src, &trg, threshold, accd::gti::Metric::L1);
    for i in 0..src.n() {
        assert_eq!(accd.neighbors[i], base[i], "L1 point {i}");
    }
    assert_eq!(accd.threshold, threshold);
}
