//! DDSL-to-execution integration: compile the shipped example programs
//! and run the resulting plans end-to-end through the engine.

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::ddsl::{self, plan::PlanKind};

fn engine() -> Option<Engine> {
    match Engine::new(AccdConfig::new()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping ddsl integration (no artifacts): {e}");
            None
        }
    }
}

fn read(path: &str) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("cannot read {path}: {e}"))
}

#[test]
fn example_programs_compile_with_expected_strategies() {
    let km = ddsl::compile_program(&read("examples/ddsl/kmeans.dd")).unwrap();
    assert!(matches!(km.kind, PlanKind::KmeansLike { .. }));
    assert!(km.strategy.trace_based && km.strategy.group_level && !km.strategy.two_landmark);

    let knn = ddsl::compile_program(&read("examples/ddsl/knn_join.dd")).unwrap();
    assert!(matches!(knn.kind, PlanKind::KnnJoinLike { k: 50, .. }));
    assert!(knn.strategy.two_landmark && !knn.strategy.trace_based);

    let nb = ddsl::compile_program(&read("examples/ddsl/nbody.dd")).unwrap();
    assert!(matches!(nb.kind, PlanKind::NbodyLike { .. }));
    assert!(nb.strategy.two_landmark && nb.strategy.trace_based && nb.strategy.group_level);
}

#[test]
fn compiled_kmeans_plan_executes() {
    let Some(mut eng) = engine() else { return };
    // Shrunk copy of the paper's program (small sizes for CI).
    let src = r#"
        DVar K int 12;
        DVar D int 6;
        DVar psize int 900;
        DVar csize int 12;
        DSet pSet float psize D;
        DSet cSet float csize D;
        DSet distMat float psize csize;
        DSet idMat int psize csize;
        DSet pkMat int psize K;
        DVar S int;
        AccD_Iter(6) {
            S = false;
            AccD_Comp_Dist(pSet, cSet, distMat, idMat, D, "L2", 0);
            AccD_Dist_Select(distMat, idMat, K, "smallest", pkMat);
            AccD_Update(cSet, pSet, pkMat, S)
        }
    "#;
    let plan = ddsl::compile_program(src).unwrap();
    let PlanKind::KmeansLike { k, max_iters, .. } = plan.kind else {
        panic!("wrong plan kind")
    };
    let (_, psize, pdim) = plan.bindings[0];
    let ds = synthetic::clustered(psize, pdim, 12, 0.03, 5);
    let out = eng.kmeans(&ds, k, max_iters).unwrap();
    assert_eq!(out.assign.len(), psize);
    assert!(out.sse.is_finite() && out.sse > 0.0);
    assert!(out.iterations <= max_iters);
}

#[test]
fn compiled_knn_plan_executes() {
    let Some(mut eng) = engine() else { return };
    let src = r#"
        DVar K int 9;
        DVar D int 4;
        DSet qSet float 300 D;
        DSet tSet float 800 D;
        DSet distMat float 300 800;
        DSet idMat int 300 800;
        DSet knnMat int 300 K;
        AccD_Comp_Dist(qSet, tSet, distMat, idMat, D, "L2", 0);
        AccD_Dist_Select(distMat, idMat, K, "smallest", knnMat);
    "#;
    let plan = ddsl::compile_program(src).unwrap();
    let PlanKind::KnnJoinLike { k, .. } = plan.kind else { panic!("wrong kind") };
    let (_, ssize, sdim) = plan.bindings[0];
    let (_, tsize, tdim) = plan.bindings[1];
    assert_eq!(sdim, tdim);
    let q = synthetic::clustered(ssize, sdim, 8, 0.04, 6);
    let t = synthetic::clustered(tsize, tdim, 8, 0.04, 7);
    let out = eng.knn_join(&q, &t, k).unwrap();
    assert_eq!(out.neighbors.len(), ssize);
    assert!(out.neighbors.iter().all(|nb| nb.len() == k));
    // Results sorted ascending.
    for nb in &out.neighbors {
        for w in nb.windows(2) {
            assert!(w[0].0 <= w[1].0 + 1e-6);
        }
    }
}

#[test]
fn malformed_programs_fail_with_diagnostics() {
    // Lexer error.
    assert!(ddsl::compile_program("DVar $ int;").is_err());
    // Parser error.
    assert!(ddsl::compile_program("DVar x int").is_err());
    // Type error.
    assert!(ddsl::compile_program("DSet a float 0 2;").is_err());
    // Planner error (no distance computation).
    let err = ddsl::compile_program("DVar x int 1; x = 2;").unwrap_err();
    assert!(err.to_string().contains("AccD_Comp_Dist"));
}
