//! Integration tests pinning the tile-kernel semantics: every runtime
//! tile entry point is checked against rust-side scalar oracles.
//!
//! These are the semantics the AOT-lowered Pallas/HLO kernels were
//! validated against; the in-tree reference backend must honour them
//! bit-for-bit.  With a deployed `artifacts/` directory the runtime
//! resolves kernels through the manifest; otherwise the built-in
//! catalogue is used — either way this suite runs.

use accd::data::Matrix;
use accd::runtime::Runtime;
use accd::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::load_or_builtin("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime tests (broken artifacts dir): {e}");
            None
        }
    }
}

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    Matrix::from_vec(data, rows, cols).unwrap()
}

/// Scalar reference for the squared-L2 distance tile.
fn ref_l2sq(a: &Matrix, b: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; a.rows() * b.rows()];
    for i in 0..a.rows() {
        for j in 0..b.rows() {
            out[i * b.rows() + j] = a.dist2(i, b, j);
        }
    }
    out
}

fn ref_l1(a: &Matrix, b: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; a.rows() * b.rows()];
    for i in 0..a.rows() {
        for j in 0..b.rows() {
            out[i * b.rows() + j] =
                a.row(i).iter().zip(b.row(j)).map(|(x, y)| (x - y).abs()).sum();
        }
    }
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0 + w.abs();
        assert!(
            (g - w).abs() <= tol * scale,
            "{what}: idx {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[test]
fn distance_tile_l2sq_matches_scalar_reference() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let mut rng = Rng::new(1);
    for &d in &[4usize, 16, 64] {
        let a = rand_mat(&mut rng, t.m, d);
        let b = rand_mat(&mut rng, t.n, d);
        let got = rt.distance_tile("l2sq", d, a.as_slice(), b.as_slice()).unwrap();
        assert_close(&got, &ref_l2sq(&a, &b), 1e-4, &format!("l2sq d={d}"));
    }
}

#[test]
fn distance_tile_l1_matches_scalar_reference() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let mut rng = Rng::new(2);
    let d = 8;
    let a = rand_mat(&mut rng, t.m, d);
    let b = rand_mat(&mut rng, t.n, d);
    let got = rt.distance_tile("l1", d, a.as_slice(), b.as_slice()).unwrap();
    assert_close(&got, &ref_l1(&a, &b), 1e-4, "l1");
}

#[test]
fn zero_padding_on_feature_axis_is_distance_neutral() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let mut rng = Rng::new(3);
    let d = 5; // pads to 8
    let d_pad = t.pad_d(d).unwrap();
    assert_eq!(d_pad, 8);
    let a = rand_mat(&mut rng, t.m, d);
    let b = rand_mat(&mut rng, t.n, d);
    let ap = a.padded(t.m, d_pad).unwrap();
    let bp = b.padded(t.n, d_pad).unwrap();
    let got = rt.distance_tile("l2sq", d_pad, &ap, &bp).unwrap();
    assert_close(&got, &ref_l2sq(&a, &b), 1e-4, "padded l2sq");
}

#[test]
fn kmeans_assign_tile_matches_scalar_argmin() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let mut rng = Rng::new(4);
    let d = 16;
    let k_pad = t.kmeans_k_pad[0];
    let k = k_pad - 7; // real centers fewer than the padded slot count
    let pts = rand_mat(&mut rng, t.m, d);
    let mut centers_slab = vec![0.0f32; k_pad * d];
    for c in 0..k {
        for x in 0..d {
            centers_slab[c * d + x] = rng.range_f32(-2.0, 2.0);
        }
    }
    for c in k..k_pad {
        centers_slab[c * d] = 1.0e15; // sentinel
    }
    let (idx, dist) = rt.kmeans_assign_tile(k_pad, d, pts.as_slice(), &centers_slab).unwrap();
    let centers = Matrix::from_vec(centers_slab[..k * d].to_vec(), k, d).unwrap();
    for i in 0..t.m {
        let mut best = (0usize, f32::INFINITY);
        for c in 0..k {
            let d2 = pts.dist2(i, &centers, c);
            if d2 < best.1 {
                best = (c, d2);
            }
        }
        assert!((idx[i] as usize) < k, "row {i} assigned to padded slot {}", idx[i]);
        let scale = 1.0 + best.1.abs();
        assert!(
            (dist[i] - best.1).abs() <= 1e-4 * scale,
            "row {i}: dist {} vs ref {}",
            dist[i],
            best.1
        );
        // Index must achieve (near-)minimal distance even under ties.
        let d_at_idx = pts.dist2(i, &centers, idx[i] as usize);
        assert!((d_at_idx - best.1).abs() <= 1e-4 * scale);
    }
}

#[test]
fn knn_tile_returns_sorted_topk_consistent_with_distances() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let mut rng = Rng::new(5);
    let d = 16;
    let a = rand_mat(&mut rng, t.m, d);
    let b = rand_mat(&mut rng, t.n, d);
    let out = rt.knn_tile(d, a.as_slice(), b.as_slice()).unwrap();
    assert_eq!(out.rows, t.m);
    assert_eq!(out.k, t.knn_k);
    let full = ref_l2sq(&a, &b);
    for r in 0..out.rows {
        let mut row: Vec<f32> = full[r * t.n..(r + 1) * t.n].to_vec();
        row.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for j in 0..out.k {
            let got = out.vals[r * out.k + j];
            let want = row[j];
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "row {r} rank {j}: got {got}, want {want}"
            );
            // Index consistency: vals[j] equals the distance at idx[j].
            let at = full[r * t.n + out.idx[r * out.k + j] as usize];
            assert!((got - at).abs() <= 1e-4 * (1.0 + at.abs()));
        }
    }
}

#[test]
fn nbody_tile_matches_scalar_force_and_respects_radius() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let bt = t.nbody;
    let mut rng = Rng::new(6);
    let pos_i: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let pos_j: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mass: Vec<f32> = (0..bt).map(|_| rng.range_f32(0.1, 1.0)).collect();
    let (eps2, rmax2) = (1e-4f32, 0.8f32);
    let got = rt.nbody_accel_tile_masked(&pos_i, &pos_j, &mass, eps2, rmax2).unwrap();
    for i in 0..bt {
        let mut want = [0.0f64; 3];
        for j in 0..bt {
            let dx = (pos_i[i * 3] - pos_j[j * 3]) as f64;
            let dy = (pos_i[i * 3 + 1] - pos_j[j * 3 + 1]) as f64;
            let dz = (pos_i[i * 3 + 2] - pos_j[j * 3 + 2]) as f64;
            let r2 = dx * dx + dy * dy + dz * dz;
            if r2 > rmax2 as f64 {
                continue; // outside interaction radius
            }
            let r2s = r2 + eps2 as f64;
            let inv_r3 = 1.0 / (r2s.sqrt() * r2s);
            let w = mass[j] as f64 * inv_r3;
            want[0] -= dx * w;
            want[1] -= dy * w;
            want[2] -= dz * w;
        }
        for c in 0..3 {
            let g = got[i * 3 + c] as f64;
            assert!(
                (g - want[c]).abs() <= 1e-3 * (1.0 + want[c].abs()),
                "particle {i} comp {c}: got {g}, want {}",
                want[c]
            );
        }
    }
}

#[test]
fn zero_mass_padding_contributes_nothing() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    let bt = t.nbody;
    let mut rng = Rng::new(7);
    let pos_i: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut pos_j: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut mass: Vec<f32> = (0..bt).map(|_| rng.range_f32(0.1, 1.0)).collect();
    // Zero the second half's masses and scramble their positions: the
    // result must not change (padding-row correctness).
    for j in bt / 2..bt {
        mass[j] = 0.0;
    }
    let a1 = rt.nbody_accel_tile_masked(&pos_i, &pos_j, &mass, 1e-4, 10.0).unwrap();
    for j in bt / 2..bt {
        pos_j[j * 3] += 5.0;
    }
    let a2 = rt.nbody_accel_tile_masked(&pos_i, &pos_j, &mass, 1e-4, 10.0).unwrap();
    for (x, y) in a1.iter().zip(&a2) {
        assert!((x - y).abs() <= 1e-5 * (1.0 + x.abs()));
    }
}

#[test]
fn catalogue_covers_all_padded_dims() {
    let Some(rt) = runtime() else { return };
    let t = rt.manifest().tile.clone();
    // Every advertised padded dimension / center count must resolve to
    // a usable kernel (manifest entry or built-in catalogue member).
    let mut names = Vec::new();
    for &d in &t.d_pad {
        names.push(rt.manifest().distance_name("l2sq", d));
        names.push(rt.manifest().distance_name("l1", d));
        names.push(rt.manifest().knn_name(d));
    }
    for &kp in &t.kmeans_k_pad {
        names.push(rt.manifest().kmeans_name(kp, t.d_pad[0]));
    }
    names.push(rt.manifest().nbody_name());
    rt.warmup(&names).expect("catalogue gap");
}

#[test]
fn executables_are_cached_not_recompiled() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(8);
    let t = rt.manifest().tile.clone();
    let a = rand_mat(&mut rng, t.m, 4);
    let b = rand_mat(&mut rng, t.n, 4);
    let _ = rt.distance_tile("l2sq", 4, a.as_slice(), b.as_slice()).unwrap();
    let after_first = rt.compiled_count();
    let _ = rt.distance_tile("l2sq", 4, a.as_slice(), b.as_slice()).unwrap();
    assert_eq!(rt.compiled_count(), after_first, "second call recompiled");
}
