//! The serving runtime's correctness contract, end to end: a batch of
//! mixed queries through `serve::QueryBatcher` must produce results
//! **identical** to running each query alone through `Engine` — not
//! merely close: grouping reuse, slab sharing, deduplication, the
//! shared tagged pipeline, shard placement and deadline-driven flush
//! order are all engineered to be bit-transparent, so every comparison
//! below is exact (`assert_eq!` on floats), for every shard count.

use std::sync::Arc;
use std::time::Duration;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset, Matrix};
use accd::gti::Metric;
use accd::serve::{AlgoKind, QueryBatcher, ServeRequest, ServeResponse, VirtualClock};
use accd::util::rng::Rng;

fn fresh_engine() -> Engine {
    Engine::new(AccdConfig::new()).expect("engine")
}

fn fresh_batcher() -> QueryBatcher {
    let cfg = AccdConfig::new();
    QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone())
}

fn sharded_batcher(shards: usize) -> QueryBatcher {
    let mut cfg = AccdConfig::new();
    cfg.serve.shards = shards;
    QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone())
}

/// Exact comparison of a served response against the solo engine run
/// of the same request.
fn assert_matches_solo(
    resp: &ServeResponse,
    req: &ServeRequest,
    solo: &mut Engine,
    what: &str,
) {
    match req {
        ServeRequest::Knn { src, trg, k, metric } => {
            let want = solo.knn_join_metric(src, trg, *k, *metric).expect("solo knn");
            assert_knn_identical(resp, &want, what);
        }
        ServeRequest::RangeJoin { src, trg, threshold, metric } => {
            let want =
                solo.range_join_metric(src, trg, *threshold, *metric).expect("solo rangejoin");
            assert_rangejoin_identical(resp, &want, what);
        }
        ServeRequest::Kmeans { ds, k, max_iters } => {
            let want = solo.kmeans(ds, *k, *max_iters).expect("solo kmeans");
            let got = resp.as_kmeans().unwrap_or_else(|| panic!("{what}: wrong response kind"));
            assert_eq!(got.assign, want.assign, "{what}: assignment");
            assert_eq!(got.sse, want.sse, "{what}: sse (exact)");
            assert_eq!(got.iterations, want.iterations, "{what}: iterations");
            assert_eq!(got.centers.as_slice(), want.centers.as_slice(), "{what}: centers");
        }
        ServeRequest::Nbody { ds, masses, steps, dt, radius } => {
            let want = solo.nbody(ds, masses.as_slice(), *steps, *dt, *radius).expect("solo nbody");
            let got = resp.as_nbody().unwrap_or_else(|| panic!("{what}: wrong response kind"));
            assert_eq!(got.positions.as_slice(), want.positions.as_slice(), "{what}: positions");
            assert_eq!(
                got.velocities.as_slice(),
                want.velocities.as_slice(),
                "{what}: velocities"
            );
        }
    }
}

fn assert_knn_identical(got: &ServeResponse, want: &accd::coordinator::KnnResult, what: &str) {
    let got = got.as_knn().unwrap_or_else(|| panic!("{what}: wrong response kind"));
    assert_eq!(got.k, want.k, "{what}: k");
    assert_eq!(got.neighbors.len(), want.neighbors.len(), "{what}: result size");
    for (i, (g, w)) in got.neighbors.iter().zip(&want.neighbors).enumerate() {
        assert_eq!(g, w, "{what}: neighbors of source point {i} differ");
    }
}

fn assert_rangejoin_identical(
    got: &ServeResponse,
    want: &accd::coordinator::RangeJoinResult,
    what: &str,
) {
    let got = got.as_rangejoin().unwrap_or_else(|| panic!("{what}: wrong response kind"));
    assert_eq!(got.threshold, want.threshold, "{what}: threshold");
    assert_eq!(got.neighbors.len(), want.neighbors.len(), "{what}: result size");
    for (i, (g, w)) in got.neighbors.iter().zip(&want.neighbors).enumerate() {
        assert_eq!(g, w, "{what}: within-set of source point {i} differs");
    }
}

#[test]
fn batched_knn_cohort_is_identical_to_sequential() {
    // 8 coalescible queries: one hot target dataset, several distinct
    // sources, duplicated queries, and two different k values.
    let trg = Arc::new(synthetic::clustered(900, 6, 10, 0.03, 100));
    let srcs: Vec<Arc<Dataset>> = (0..4)
        .map(|i| Arc::new(synthetic::clustered(120 + 30 * i, 6, 5, 0.04, 200 + i as u64)))
        .collect();
    let queries: Vec<(Arc<Dataset>, usize)> = vec![
        (srcs[0].clone(), 5),
        (srcs[1].clone(), 5),
        (srcs[0].clone(), 5), // duplicate of query 0 (dedup path)
        (srcs[2].clone(), 9),
        (srcs[1].clone(), 9), // same source, different k (no dedup)
        (srcs[3].clone(), 5),
        (srcs[2].clone(), 9), // duplicate of query 3
        (srcs[3].clone(), 17),
    ];

    let mut batcher = fresh_batcher();
    for (src, k) in &queries {
        batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), *k));
    }
    let batched = batcher.flush().expect("flush");
    assert_eq!(batched.len(), queries.len());

    let mut solo = fresh_engine();
    for (i, (src, k)) in queries.iter().enumerate() {
        let want = solo.knn_join(src, &trg, *k).expect("solo knn");
        assert_knn_identical(&batched[i].1, &want, &format!("query {i}"));
    }

    // The coalescing actually happened: shared tiles were reported.
    let stats = batcher.stats();
    assert_eq!(stats.queries, 8);
    assert!(stats.tiles_total > 0);
    assert!(
        stats.tiles_shared > 0,
        "8 coalescible queries must share tiles: {stats:?}"
    );
    assert!(stats.dedup_hits >= 2, "{stats:?}");
}

#[test]
fn batched_mixed_workload_is_identical_to_sequential() {
    let trg = Arc::new(synthetic::clustered(600, 5, 8, 0.03, 1));
    let knn_src = Arc::new(synthetic::clustered(150, 5, 5, 0.04, 2));
    let l1_src = Arc::new(synthetic::clustered(100, 5, 5, 0.04, 3));
    let km_ds = Arc::new(synthetic::clustered(500, 6, 8, 0.03, 4));
    let nb_ds = Arc::new(synthetic::uniform(220, 3, 5));
    let masses = Arc::new(synthetic::equal_masses(220, 1.0));

    let mut batcher = fresh_batcher();
    batcher.submit(ServeRequest::knn(knn_src.clone(), trg.clone(), 7));
    batcher.submit(ServeRequest::kmeans(km_ds.clone(), 12, 6));
    batcher.submit(ServeRequest::knn_metric(l1_src.clone(), trg.clone(), 4, Metric::L1));
    batcher.submit(ServeRequest::nbody(nb_ds.clone(), masses.clone(), 3, 1e-3, 0.15));
    batcher.submit(ServeRequest::kmeans(km_ds.clone(), 12, 6)); // duplicate
    let batched = batcher.flush().expect("flush");
    assert_eq!(batched.len(), 5);

    let mut solo = fresh_engine();

    let want_knn = solo.knn_join(&knn_src, &trg, 7).unwrap();
    assert_knn_identical(&batched[0].1, &want_knn, "L2 knn");

    let want_km = solo.kmeans(&km_ds, 12, 6).unwrap();
    for idx in [1usize, 4] {
        let got = batched[idx].1.as_kmeans().expect("kmeans response");
        assert_eq!(got.assign, want_km.assign, "kmeans assignment");
        assert_eq!(got.sse, want_km.sse, "kmeans sse (exact)");
        assert_eq!(got.iterations, want_km.iterations);
        assert_eq!(got.centers.as_slice(), want_km.centers.as_slice(), "kmeans centers");
    }

    let want_l1 = solo.knn_join_metric(&l1_src, &trg, 4, Metric::L1).unwrap();
    assert_knn_identical(&batched[2].1, &want_l1, "L1 knn");

    let want_nb = solo.nbody(&nb_ds, &masses, 3, 1e-3, 0.15).unwrap();
    let got_nb = batched[3].1.as_nbody().expect("nbody response");
    assert_eq!(got_nb.steps, want_nb.steps);
    assert_eq!(
        got_nb.positions.as_slice(),
        want_nb.positions.as_slice(),
        "nbody positions (exact)"
    );
    assert_eq!(
        got_nb.velocities.as_slice(),
        want_nb.velocities.as_slice(),
        "nbody velocities (exact)"
    );
}

#[test]
fn parity_survives_a_warm_cache_and_multiple_flushes() {
    let trg = Arc::new(synthetic::clustered(500, 4, 6, 0.03, 11));
    let src_a = Arc::new(synthetic::clustered(90, 4, 4, 0.04, 12));
    let src_b = Arc::new(synthetic::clustered(110, 4, 4, 0.04, 13));

    let mut batcher = fresh_batcher();
    // Flush 1 warms the grouping cache.
    batcher.submit(ServeRequest::knn(src_a.clone(), trg.clone(), 6));
    let first = batcher.flush().expect("flush 1");
    // Flush 2 reuses the cached target grouping for a different source
    // and re-runs the same query (full cache hits).
    batcher.submit(ServeRequest::knn(src_b.clone(), trg.clone(), 6));
    batcher.submit(ServeRequest::knn(src_a.clone(), trg.clone(), 6));
    let second = batcher.flush().expect("flush 2");

    let mut solo = fresh_engine();
    let want_a = solo.knn_join(&src_a, &trg, 6).unwrap();
    let want_b = solo.knn_join(&src_b, &trg, 6).unwrap();
    assert_knn_identical(&first[0].1, &want_a, "flush1/src_a");
    assert_knn_identical(&second[0].1, &want_b, "flush2/src_b");
    assert_knn_identical(&second[1].1, &want_a, "flush2/src_a (warm)");

    let stats = batcher.stats();
    assert!(
        stats.grouping_cache_hits >= 2,
        "warm flush must hit the grouping cache: {stats:?}"
    );
    assert_eq!(stats.flushes, 2);
}

#[test]
fn parity_holds_with_dedup_disabled() {
    let trg = Arc::new(synthetic::clustered(400, 4, 6, 0.03, 21));
    let src = Arc::new(synthetic::clustered(80, 4, 4, 0.04, 22));

    let cfg = AccdConfig::new();
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.dedup = false;
    let mut batcher = QueryBatcher::new(Engine::new(cfg).unwrap(), serve_cfg);
    batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
    batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
    let out = batcher.flush().expect("flush");

    let mut solo = fresh_engine();
    let want = solo.knn_join(&src, &trg, 5).unwrap();
    assert_knn_identical(&out[0].1, &want, "copy 1");
    assert_knn_identical(&out[1].1, &want, "copy 2");
    assert_eq!(batcher.stats().dedup_hits, 0);
    // Without dedup the second copy re-dispatches against fully shared
    // slabs, so sharing is still visible.
    assert!(batcher.stats().tiles_shared > 0, "{:?}", batcher.stats());
}

/// A mixed KNN / range-join / K-means / N-body workload with two KNN
/// cohorts, duplicates and L1 queries — the same query set,
/// bit-for-bit, for shard counts 1, 2 and 4.  The range-join queries
/// hit the same target set as a KNN cohort, so their slab scopes
/// coincide and the two workloads share packed slabs.
fn mixed_workload() -> Vec<ServeRequest> {
    let trg_a = Arc::new(synthetic::clustered(500, 5, 8, 0.03, 31));
    let trg_b = Arc::new(synthetic::clustered(350, 5, 6, 0.03, 32));
    let km_ds = Arc::new(synthetic::clustered(400, 6, 8, 0.03, 33));
    let nb_ds = Arc::new(synthetic::uniform(180, 3, 34));
    let masses = Arc::new(synthetic::equal_masses(180, 1.0));
    let src_a = Arc::new(synthetic::clustered(110, 5, 5, 0.04, 35));
    let src_b = Arc::new(synthetic::clustered(90, 5, 5, 0.04, 36));
    let src_c = Arc::new(synthetic::clustered(70, 5, 5, 0.04, 37));
    vec![
        ServeRequest::knn(src_a.clone(), trg_a.clone(), 6),
        ServeRequest::kmeans(km_ds.clone(), 10, 5),
        ServeRequest::knn(src_b.clone(), trg_b.clone(), 4),
        ServeRequest::knn(src_a.clone(), trg_a.clone(), 6), // duplicate of 0
        ServeRequest::nbody(nb_ds, masses, 3, 1e-3, 0.15),
        ServeRequest::knn_metric(src_c.clone(), trg_a.clone(), 5, Metric::L1),
        ServeRequest::kmeans(km_ds, 10, 5), // duplicate of 1
        ServeRequest::knn(src_b.clone(), trg_a.clone(), 9), // same src, other cohort
        ServeRequest::rangejoin(src_a.clone(), trg_a.clone(), 0.6),
        ServeRequest::rangejoin(src_a, trg_a, 0.6), // duplicate of 8
        ServeRequest::rangejoin_metric(src_c, trg_b, 1.1, Metric::L1),
    ]
}

#[test]
fn sharded_mixed_workload_is_identical_for_1_2_and_4_shards() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    for shards in [1usize, 2, 4] {
        let mut batcher = sharded_batcher(shards);
        assert_eq!(batcher.shard_count(), shards);
        for q in &queries {
            batcher.submit(q.clone());
        }
        let out = batcher.flush().expect("flush");
        assert_eq!(out.len(), queries.len());
        for (i, (_, resp)) in out.iter().enumerate() {
            let what = format!("{shards} shards, query {i}");
            assert_matches_solo(resp, &queries[i], &mut solo, &what);
        }
        // The shards actually shared the work and the stats merged.
        let stats = batcher.stats();
        assert_eq!(stats.queries, queries.len() as u64);
        assert_eq!(stats.dedup_hits, 3, "{stats:?}");
        let shard_sum: u64 = batcher.shard_stats().iter().map(|s| s.queries).sum();
        assert_eq!(shard_sum, stats.queries);
        if shards > 1 {
            let busy = batcher.shard_stats().iter().filter(|s| s.queries > 0).count();
            assert!(busy > 1, "work must spread across shards: {stats:?}");
        }
    }
}

/// The tentpole contract: lockstep step scheduling × shard counts ×
/// work stealing, over a mixed K-means + N-body + KNN workload with a
/// same-dataset K-means cohort (different k — NOT deduplicable, so
/// the programs genuinely co-reside and share packed assignment
/// tiles).  Bit-for-bit against solo runs for 1, 2 and 4 shards.
#[test]
fn lockstep_with_stealing_is_identical_for_1_2_and_4_shards() {
    let km_ds = Arc::new(synthetic::clustered(350, 6, 8, 0.03, 41));
    let nb_ds = Arc::new(synthetic::uniform(160, 3, 42));
    let masses = Arc::new(synthetic::equal_masses(160, 1.0));
    let trg = Arc::new(synthetic::clustered(400, 5, 6, 0.03, 43));
    let src = Arc::new(synthetic::clustered(90, 5, 4, 0.04, 44));
    let queries = vec![
        ServeRequest::kmeans(km_ds.clone(), 8, 6),
        ServeRequest::kmeans(km_ds.clone(), 12, 6), // same dataset, other k
        ServeRequest::nbody(nb_ds, masses, 4, 1e-3, 0.15),
        ServeRequest::knn(src, trg, 6),
        ServeRequest::kmeans(km_ds, 8, 3), // same dataset, other cap
    ];
    let mut solo = fresh_engine();
    for shards in [1usize, 2, 4] {
        let mut cfg = AccdConfig::new();
        cfg.serve.shards = shards;
        assert!(cfg.serve.lockstep, "lockstep is the default");
        assert!(cfg.serve.steal_threshold > 0, "stealing is the default");
        let mut batcher =
            QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone());
        for q in &queries {
            batcher.submit(q.clone());
        }
        let out = batcher.flush().expect("flush");
        assert_eq!(out.len(), queries.len());
        for (i, (_, resp)) in out.iter().enumerate() {
            let what = format!("lockstep, {shards} shards, query {i}");
            assert_matches_solo(resp, &queries[i], &mut solo, &what);
        }
        let stats = batcher.stats();
        assert!(stats.lockstep_rounds > 0, "lockstep must have run rounds: {stats:?}");
        assert_eq!(stats.queries, queries.len() as u64);
    }
}

/// Lockstep off must reproduce the same bits through the serial
/// schedule (the step refactor cannot have changed the algorithms).
#[test]
fn serial_schedule_matches_lockstep_and_solo() {
    let km_ds = Arc::new(synthetic::clustered(300, 5, 6, 0.03, 51));
    let nb_ds = Arc::new(synthetic::uniform(140, 3, 52));
    let masses = Arc::new(synthetic::equal_masses(140, 1.0));
    let queries = vec![
        ServeRequest::kmeans(km_ds.clone(), 9, 5),
        ServeRequest::nbody(nb_ds, masses, 3, 1e-3, 0.15),
        ServeRequest::kmeans(km_ds, 5, 5),
    ];
    let mut solo = fresh_engine();
    let mut cfg = AccdConfig::new();
    cfg.serve.lockstep = false;
    cfg.serve.shards = 2;
    let mut batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone());
    for q in &queries {
        batcher.submit(q.clone());
    }
    let out = batcher.flush().expect("flush");
    for (i, (_, resp)) in out.iter().enumerate() {
        assert_matches_solo(resp, &queries[i], &mut solo, &format!("serial, query {i}"));
    }
    assert_eq!(batcher.stats().lockstep_rounds, 0, "serial mode counts no rounds");
}

/// K-means empty-cluster regression.  The dataset is 10 distinct
/// point values × 12 exact copies; with k = 32 > 10, pigeonhole forces
/// at least two initial centers onto the same position, and argmin
/// tie-breaking sends every member to one of them — the other is
/// empty from iteration 0 on (keeping its position, per the
/// empty-cluster rule).  The batched (lockstep, sharded) result must
/// still equal the sequential one bit-for-bit, and re-running solo
/// must be deterministic.
#[test]
fn kmeans_empty_clusters_keep_batched_equal_to_sequential() {
    let mut vals = Vec::with_capacity(120 * 4);
    for v in 0..10 {
        for _copy in 0..12 {
            for x in 0..4 {
                vals.push(v as f32 * 1.7 + x as f32 * 0.3);
            }
        }
    }
    let ds = Arc::new(Dataset::new(
        "dup-points",
        Matrix::from_vec(vals, 120, 4).expect("matrix"),
        61,
    ));
    let (k, iters) = (32, 8);

    let mut solo_a = fresh_engine();
    let want = solo_a.kmeans(&ds, k, iters).expect("solo kmeans");
    let mut solo_b = fresh_engine();
    let again = solo_b.kmeans(&ds, k, iters).expect("solo kmeans repeat");
    assert_eq!(want.assign, again.assign, "solo kmeans must be deterministic");
    assert_eq!(want.sse, again.sse);

    // Some cluster must actually have died for this regression test to
    // test anything: with 32 centers over 10 distinct point values, at
    // least one center ends memberless (keeping its initial position).
    let mut counts = vec![0u32; k];
    for &a in &want.assign {
        counts[a as usize] += 1;
    }
    assert!(
        counts.iter().any(|&c| c == 0),
        "workload no longer produces an empty cluster; tighten it: {counts:?}"
    );

    let mut batcher = sharded_batcher(2);
    batcher.submit(ServeRequest::kmeans(ds.clone(), k, iters));
    batcher.submit(ServeRequest::kmeans(ds, k, iters)); // dedup path too
    let out = batcher.flush().expect("flush");
    for (_, resp) in &out {
        let got = resp.as_kmeans().expect("kmeans response");
        assert_eq!(got.assign, want.assign, "empty-cluster assignment drifted");
        assert_eq!(got.sse, want.sse, "empty-cluster sse drifted");
        assert_eq!(got.centers.as_slice(), want.centers.as_slice(), "centers drifted");
        assert_eq!(got.iterations, want.iterations);
    }
}

/// Same-dataset K-means cohort under lockstep: the padded full
/// packed-points slab (the assignment tile's row input) is built once
/// and served from the slab cache to every later program — the
/// "shared tile" hits the stats must report.
#[test]
fn lockstep_kmeans_cohort_shares_assignment_tiles() {
    let ds = Arc::new(synthetic::clustered(400, 6, 8, 0.03, 71));
    let mut batcher = sharded_batcher(1); // one shard: deterministic counts
    batcher.submit(ServeRequest::kmeans(ds.clone(), 6, 4));
    batcher.submit(ServeRequest::kmeans(ds.clone(), 10, 4));
    batcher.submit(ServeRequest::kmeans(ds, 14, 4));
    let out = batcher.flush().expect("flush");
    assert_eq!(out.len(), 3);
    let stats = batcher.stats();
    assert!(
        stats.lockstep_shared_tiles >= 2,
        "2nd and 3rd same-dataset programs must hit the cached assignment slab: {stats:?}"
    );
    assert!(stats.lockstep_rounds >= 3, "one admission per round: {stats:?}");
    assert!(stats.grouping_cache_hits >= 2, "grouping shared too: {stats:?}");
}

/// Run one request through a solo engine, wrapped as a `ServeResponse`
/// for exact comparison.
fn solo_response(solo: &mut Engine, req: &ServeRequest) -> ServeResponse {
    match req {
        ServeRequest::Knn { src, trg, k, metric } => {
            ServeResponse::Knn(solo.knn_join_metric(src, trg, *k, *metric).expect("solo knn"))
        }
        ServeRequest::RangeJoin { src, trg, threshold, metric } => ServeResponse::RangeJoin(
            solo.range_join_metric(src, trg, *threshold, *metric).expect("solo rangejoin"),
        ),
        ServeRequest::Kmeans { ds, k, max_iters } => {
            ServeResponse::Kmeans(solo.kmeans(ds, *k, *max_iters).expect("solo kmeans"))
        }
        ServeRequest::Nbody { ds, masses, steps, dt, radius } => ServeResponse::Nbody(
            solo.nbody(ds, masses.as_slice(), *steps, *dt, *radius).expect("solo nbody"),
        ),
    }
}

fn assert_same_response(got: &ServeResponse, want: &ServeResponse, what: &str) {
    match (got, want) {
        (ServeResponse::Knn(g), ServeResponse::Knn(w)) => {
            assert_eq!(g.k, w.k, "{what}: k");
            assert_eq!(g.neighbors, w.neighbors, "{what}: neighbors");
        }
        (ServeResponse::RangeJoin(g), ServeResponse::RangeJoin(w)) => {
            assert_eq!(g.threshold, w.threshold, "{what}: threshold");
            assert_eq!(g.neighbors, w.neighbors, "{what}: within-sets");
        }
        (ServeResponse::Kmeans(g), ServeResponse::Kmeans(w)) => {
            assert_eq!(g.assign, w.assign, "{what}: assignment");
            assert_eq!(g.sse, w.sse, "{what}: sse (exact)");
            assert_eq!(g.iterations, w.iterations, "{what}: iterations");
            assert_eq!(g.centers.as_slice(), w.centers.as_slice(), "{what}: centers");
        }
        (ServeResponse::Nbody(g), ServeResponse::Nbody(w)) => {
            assert_eq!(g.positions.as_slice(), w.positions.as_slice(), "{what}: positions");
            assert_eq!(g.velocities.as_slice(), w.velocities.as_slice(), "{what}: velocities");
        }
        _ => panic!("{what}: response kind mismatch"),
    }
}

/// The deadline-aware acceptance sweep: the mixed workload with
/// staggered urgency, bit-for-bit under BOTH placement modes, with
/// stealing off and on, for shard counts 1 / 2 / 4.  Deadlines steer
/// EDF tiers, urgent-first claims, step priority and at-risk steals —
/// none of which may change a single bit.
#[test]
fn placement_modes_and_stealing_are_bit_transparent() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let want: Vec<ServeResponse> =
        queries.iter().map(|q| solo_response(&mut solo, q)).collect();
    for placement in ["lpt", "edf-lpt"] {
        for steal in [0u64, 1] {
            for shards in [1usize, 2, 4] {
                let mut cfg = AccdConfig::new();
                cfg.serve.shards = shards;
                cfg.serve.steal_threshold = steal;
                cfg.serve.placement = placement.to_string();
                let mut batcher =
                    QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve);
                for (i, q) in queries.iter().enumerate() {
                    // Every other query urgent (already due), the rest
                    // patient: units span EDF tiers.
                    if i % 2 == 0 {
                        batcher.submit_with_deadline(q.clone(), Duration::ZERO);
                    } else {
                        batcher.submit_with_deadline(q.clone(), Duration::from_secs(3600));
                    }
                }
                let out = batcher.flush().expect("flush");
                assert_eq!(out.len(), queries.len());
                for (i, (_, resp)) in out.iter().enumerate() {
                    let what =
                        format!("{placement}, steal={steal}, {shards} shards, query {i}");
                    assert_same_response(resp, &want[i], &what);
                }
                // Every deadline resolved to met or missed, none lost.
                let stats = batcher.stats();
                assert_eq!(
                    stats.deadline_met + stats.deadline_misses,
                    queries.len() as u64,
                    "{stats:?}"
                );
            }
        }
    }
}

/// The emulated multi-device contract: device pinning, per-device slab
/// budgets, movement-aware placement, warmth-discounted stealing and
/// double-buffered transfer/compute overlap are modeled ACCOUNTING
/// layered over the same shared CPU runtime — results must stay
/// bit-identical to solo runs across device counts 1 / 2 / 4, shard
/// counts 1 / 2 / 4, stealing off/on and overlap off/on.  Multi-device
/// configs get a deliberately tiny per-device memory budget so the
/// slab-budget clamp and LRU evictions are exercised under the sweep.
#[test]
fn multi_device_sweep_is_bit_transparent() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let want: Vec<ServeResponse> =
        queries.iter().map(|q| solo_response(&mut solo, q)).collect();
    for devices in [1usize, 2, 4] {
        for shards in [1usize, 2, 4] {
            for steal in [0u64, 1] {
                let overlap = (devices + shards + steal as usize) % 2 == 0;
                let mut cfg = AccdConfig::new();
                cfg.serve.shards = shards;
                cfg.serve.devices = devices;
                cfg.serve.steal_threshold = steal;
                cfg.serve.overlap = overlap;
                cfg.serve.device_mem_bytes = if devices > 1 { 1 << 16 } else { 0 };
                let mut batcher =
                    QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve);
                assert_eq!(batcher.device_count(), devices);
                for s in 0..batcher.shard_count() {
                    assert_eq!(batcher.device_of(s), s % devices, "round-robin pinning");
                }
                for q in &queries {
                    batcher.submit(q.clone());
                }
                let out = batcher.flush().expect("flush");
                assert_eq!(out.len(), queries.len());
                for (i, (_, resp)) in out.iter().enumerate() {
                    let what = format!(
                        "{devices} devices, {shards} shards, steal={steal}, \
                         overlap={overlap}, query {i}"
                    );
                    assert_same_response(resp, &want[i], &what);
                }
                let stats = batcher.stats();
                assert_eq!(stats.queries, queries.len() as u64);
                if !overlap {
                    assert_eq!(
                        stats.overlap_ns, 0,
                        "overlap accounting must be zero when the knob is off: {stats:?}"
                    );
                }
            }
        }
    }
}

/// `serve.overlap` and `serve.movement_aware` are modeling knobs: they
/// may change the modeled device-timeline counters, never a result
/// bit.  All four toggle combinations answer identically, the overlap
/// accounting is zero exactly when the knob is off and never claims to
/// hide more than the total modeled transfer time, and flipping the
/// overlap knob alone must not change placement (the modeled upload
/// bytes, hence `transfer_ns`, stay the same).
#[test]
fn overlap_and_movement_knobs_change_only_counters() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let want: Vec<ServeResponse> =
        queries.iter().map(|q| solo_response(&mut solo, q)).collect();
    let mut transfer_by_movement: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
    for movement_aware in [false, true] {
        for overlap in [false, true] {
            let mut cfg = AccdConfig::new();
            cfg.serve.shards = 2;
            cfg.serve.devices = 2;
            cfg.serve.steal_threshold = 0; // deterministic placement
            cfg.serve.movement_aware = movement_aware;
            cfg.serve.overlap = overlap;
            let mut batcher =
                QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve);
            for q in &queries {
                batcher.submit(q.clone());
            }
            let out = batcher.flush().expect("flush");
            for (i, (_, resp)) in out.iter().enumerate() {
                let what = format!(
                    "movement_aware={movement_aware}, overlap={overlap}, query {i}"
                );
                assert_same_response(resp, &want[i], &what);
            }
            let stats = batcher.stats();
            assert!(stats.transfer_ns > 0, "cold slabs must model uploads: {stats:?}");
            if overlap {
                assert!(
                    stats.overlap_ns <= stats.transfer_ns,
                    "cannot hide more than the total transfer: {stats:?}"
                );
            } else {
                assert_eq!(stats.overlap_ns, 0, "overlap off must record zero: {stats:?}");
            }
            transfer_by_movement[movement_aware as usize].push(stats.transfer_ns);
        }
    }
    for pair in &transfer_by_movement {
        assert_eq!(
            pair[0], pair[1],
            "the overlap knob must not change placement or upload bytes"
        );
    }
}

/// The calibration acceptance sweep: `predictive_shed` and the
/// `predicted-p99` placement mode are order-only knobs — bit-for-bit
/// against solo runs across devices × shards × stealing × placement.
/// The clock is a frozen `VirtualClock`, so no deadline ever expires:
/// predictive admission must shed nothing and full parity must hold
/// even while the calibrated predictions steer placement and steals.
#[test]
fn predictive_scheduling_sweep_is_bit_transparent() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let want: Vec<ServeResponse> =
        queries.iter().map(|q| solo_response(&mut solo, q)).collect();
    for placement in ["edf-lpt", "predicted-p99"] {
        for devices in [1usize, 2] {
            for shards in [1usize, 2] {
                for steal in [0u64, 1] {
                    let mut cfg = AccdConfig::new();
                    cfg.serve.shards = shards;
                    cfg.serve.devices = devices;
                    cfg.serve.steal_threshold = steal;
                    cfg.serve.placement = placement.to_string();
                    cfg.serve.predictive_shed = true;
                    cfg.serve.device_mem_bytes = if devices > 1 { 1 << 16 } else { 0 };
                    let mut batcher = QueryBatcher::with_clock(
                        Engine::new(cfg.clone()).expect("engine"),
                        cfg.serve,
                        Arc::new(VirtualClock::new()),
                    );
                    for (i, q) in queries.iter().enumerate() {
                        if i % 2 == 0 {
                            batcher.submit_with_deadline(q.clone(), Duration::ZERO);
                        } else {
                            batcher
                                .submit_with_deadline(q.clone(), Duration::from_secs(3600));
                        }
                    }
                    let out = batcher.flush().expect("flush");
                    assert_eq!(out.len(), queries.len());
                    for (i, (_, resp)) in out.iter().enumerate() {
                        let what = format!(
                            "{placement}, predictive, {devices} devices, {shards} shards, \
                             steal={steal}, query {i}"
                        );
                        assert_same_response(resp, &want[i], &what);
                    }
                    assert!(
                        batcher.take_predicted_sheds().is_empty(),
                        "frozen clock: no deadline expired, nothing may shed"
                    );
                    let stats = batcher.stats();
                    assert_eq!(stats.predicted_sheds, 0, "{stats:?}");
                    assert_eq!(
                        stats.deadline_met + stats.deadline_misses,
                        queries.len() as u64,
                        "{stats:?}"
                    );
                }
            }
        }
    }
}

/// Early deadline shedding, the accounting contract: exactly the
/// expired query is shed (reported via `take_predicted_sheds`, counted
/// in `predicted_sheds`, NOT in `deadline_misses`), every survivor is
/// served bit-identically, and the shed id never appears in the
/// response stream.
#[test]
fn predictive_shed_drops_only_expired_queries_and_reports_them() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let mut cfg = AccdConfig::new();
    cfg.serve.shards = 2;
    cfg.serve.predictive_shed = true;
    let clock = VirtualClock::new();
    let mut batcher = QueryBatcher::with_clock(
        Engine::new(cfg.clone()).expect("engine"),
        cfg.serve,
        Arc::new(clock.clone()),
    );
    // The first query's deadline expires before the flush; the rest
    // stay serviceable (including query 3, a duplicate of the doomed
    // request under its own generous deadline — it must still run).
    let doomed = batcher.submit_with_deadline(queries[0].clone(), Duration::from_millis(1));
    for q in &queries[1..] {
        batcher.submit_with_deadline(q.clone(), Duration::from_secs(3600));
    }
    clock.advance(Duration::from_millis(5));
    let out = batcher.flush().expect("flush");
    let sheds = batcher.take_predicted_sheds();
    assert_eq!(sheds, vec![doomed], "exactly the expired query is shed");
    assert_eq!(out.len(), queries.len() - 1);
    for (j, (id, resp)) in out.iter().enumerate() {
        assert_ne!(*id, doomed, "shed query must produce no response");
        assert_matches_solo(resp, &queries[j + 1], &mut solo, &format!("survivor {}", j + 1));
    }
    let stats = batcher.stats();
    assert_eq!(stats.predicted_sheds, 1, "{stats:?}");
    assert_eq!(stats.deadline_misses, 0, "a shed query is not a miss: {stats:?}");
    assert_eq!(stats.deadline_met, (queries.len() - 1) as u64, "{stats:?}");
}

/// The shedding safety property, end to end: across seeded arrival /
/// deadline traces, a query the reactive path would have served
/// within its deadline (service start <= deadline) is NEVER
/// predictively shed — shedding only converts certain reactive misses
/// into early rejections, never creates a new one.
#[test]
fn predictive_shedding_never_drops_a_reactively_met_query() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let want: Vec<ServeResponse> =
        queries.iter().map(|q| solo_response(&mut solo, q)).collect();
    for seed in 0..6u64 {
        // One deterministic trace per seed: arrival gap + deadline
        // budget per query, shared verbatim by both runs.
        let mut rng = Rng::new(0x5EED_0000 + seed);
        let trace: Vec<(u64, u64)> = queries
            .iter()
            .map(|_| (rng.below(2_000_000) as u64 + 1, rng.below(4_000_000) as u64 + 1))
            .collect();
        let mut reactive_misses = 0u64;
        for predictive in [false, true] {
            let mut cfg = AccdConfig::new();
            cfg.serve.shards = 2;
            cfg.serve.predictive_shed = predictive;
            let clock = VirtualClock::new();
            let mut batcher = QueryBatcher::with_clock(
                Engine::new(cfg.clone()).expect("engine"),
                cfg.serve,
                Arc::new(clock.clone()),
            );
            let mut now = 0u64;
            let mut ids = Vec::new();
            let mut deadline_at = Vec::new();
            for (q, &(gap, budget)) in queries.iter().zip(&trace) {
                clock.advance(Duration::from_nanos(gap));
                now += gap;
                ids.push(
                    batcher.submit_with_deadline(q.clone(), Duration::from_nanos(budget)),
                );
                deadline_at.push(now + budget);
            }
            clock.advance(Duration::from_millis(1));
            let flush_at = now + 1_000_000;
            let out = batcher.flush().expect("flush");
            let sheds = batcher.take_predicted_sheds();
            assert_eq!(out.len() + sheds.len(), queries.len(), "seed {seed}: lost queries");
            for id in &sheds {
                let qi = ids.iter().position(|x| x == id).expect("known id");
                assert!(
                    deadline_at[qi] < flush_at,
                    "seed {seed}: query {qi} was shed although the reactive path would \
                     have started serving it before its deadline"
                );
            }
            for (id, resp) in &out {
                let qi = ids.iter().position(|x| x == id).expect("known id");
                assert_same_response(resp, &want[qi], &format!("seed {seed}, query {qi}"));
            }
            let stats = batcher.stats();
            if predictive {
                assert_eq!(
                    stats.deadline_misses + stats.predicted_sheds,
                    reactive_misses,
                    "seed {seed}: shedding must only reclassify reactive misses"
                );
            } else {
                assert!(sheds.is_empty(), "seed {seed}: reactive run must never shed");
                reactive_misses = stats.deadline_misses;
            }
        }
    }
}

/// The calibrator is a pure fold over the flush sequence: two
/// batchers fed the identical workload in the identical order learn
/// bit-identical rates, and every algorithm kind in the workload
/// warms at least one (shard, kind) cell.
#[test]
fn calibrator_warms_deterministically_across_identical_runs() {
    let queries = mixed_workload();
    let kinds = [AlgoKind::Knn, AlgoKind::RangeJoin, AlgoKind::Kmeans, AlgoKind::Nbody];
    let run = || {
        let mut cfg = AccdConfig::new();
        cfg.serve.shards = 2;
        let mut batcher = QueryBatcher::with_clock(
            Engine::new(cfg.clone()).expect("engine"),
            cfg.serve,
            Arc::new(VirtualClock::new()),
        );
        for _round in 0..2 {
            for q in &queries {
                batcher.submit(q.clone());
            }
            batcher.flush().expect("flush");
        }
        let calib = batcher.calibrator();
        assert!(calib.observations() > 0, "flushes must feed the calibrator");
        for kind in kinds {
            assert!(
                (0..2).any(|s| calib.is_warm(s, kind)),
                "every kind in the workload must warm some shard cell"
            );
        }
        let mut probes = Vec::new();
        for shard in 0..2 {
            for kind in kinds {
                for units in [1_000u64, 50_000, 2_000_000] {
                    probes.push(calib.predict_ns(shard, kind, units, 6));
                }
            }
        }
        probes
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "same workload, same flush order => identical learned rates");
}

#[test]
fn deadline_driven_flush_order_preserves_parity() {
    let queries = mixed_workload();
    let mut solo = fresh_engine();
    let mut batcher = sharded_batcher(2);
    // Half the workload is latency-sensitive (already due), the rest
    // patient; a poll answers the first half alone, an explicit flush
    // the remainder — two different cohort compositions than the
    // all-at-once test, same bit-for-bit results.
    let mut ids = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        let deadline =
            if i % 2 == 0 { Duration::ZERO } else { Duration::from_secs(3600) };
        ids.push(batcher.submit_with_deadline(q.clone(), deadline));
    }
    let first = batcher.poll().expect("poll");
    assert!(!first.is_empty(), "expired deadlines must flush");
    assert!(batcher.pending_len() > 0, "patient queries must wait");
    let second = batcher.flush().expect("flush");
    assert_eq!(first.len() + second.len(), queries.len());
    assert_eq!(batcher.stats().deadline_flushes, 1);
    for (id, resp) in first.iter().chain(second.iter()) {
        let qi = ids.iter().position(|x| x == id).expect("known id");
        assert_matches_solo(resp, &queries[qi], &mut solo, &format!("deadline query {qi}"));
    }
}
