//! Property tests for the GTI bound algebra (`gti::bounds`): the
//! soundness arguments the whole optimization rests on, as executable
//! checks over random geometry.
//!
//! The invariant in every test: a bound may be loose, but it must NEVER
//! exclude the true answer — no true nearest neighbor may live in a
//! pruned target group, and no true closest center may live in a pruned
//! center group, under either supported metric and after trace-based
//! drift widening.

use accd::data::Matrix;
use accd::gti::{bounds, Grouping, KnnFilter, Metric};
use accd::util::prop::{self, Config};
use accd::util::rng::Rng;

fn rand_points(rng: &mut Rng, n: usize, d: usize) -> Matrix {
    Matrix::from_vec(prop::gen_points(rng, n, d, 4.0), n, d).unwrap()
}

/// Eq. 2 group-pair bounds contain every member-pair distance, for both
/// triangle-inequality metrics (groupings and bounds share the metric).
#[test]
fn prop_group_pair_bounds_contain_all_pair_distances() {
    prop::check(
        &Config { cases: 20, max_size: 160, seed: 0xB0021, ..Default::default() },
        |rng, size| {
            let n_src = 15 + size / 2;
            let n_trg = 20 + size / 2;
            let d = 1 + rng.below(6);
            let zs = 2 + rng.below(6);
            let zt = 2 + rng.below(6);
            let metric = if rng.below(2) == 0 { Metric::L2 } else { Metric::L1 };
            (rand_points(rng, n_src, d), rand_points(rng, n_trg, d), zs, zt, metric)
        },
        |(src, trg, zs, zt, metric)| {
            let gs = Grouping::build_with_metric(src, *zs, 2, 4096, 1, *metric)
                .map_err(|e| e.to_string())?;
            let gt = Grouping::build_with_metric(trg, *zt, 2, 4096, 2, *metric)
                .map_err(|e| e.to_string())?;
            let bnds = bounds::group_pair_bounds_metric(&gs, &gt, *metric);
            for i in 0..src.rows() {
                for j in 0..trg.rows() {
                    let d_true = metric.dist_rows(src, i, trg, j);
                    let b = bnds[gs.assign[i] as usize][gt.assign[j] as usize];
                    if d_true < b.lb - 1e-3 || d_true > b.ub + 1e-3 {
                        return Err(format!(
                            "{metric:?}: pair ({i},{j}) d={d_true} escapes [{}, {}]",
                            b.lb, b.ub
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// KNN soundness: for every source point, ALL of its true K nearest
/// targets live inside the candidate groups of its source group — the
/// filter may keep too much, never too little.  Metric-generic.
#[test]
fn prop_knn_filter_never_excludes_true_neighbors_any_metric() {
    prop::check(
        &Config { cases: 16, max_size: 150, seed: 0xB0022, ..Default::default() },
        |rng, size| {
            let n_src = 10 + size / 2;
            let n_trg = 30 + size;
            let d = 1 + rng.below(5);
            let k = 1 + rng.below(8);
            let zs = 2 + rng.below(6);
            let zt = 2 + rng.below(8);
            let metric = if rng.below(2) == 0 { Metric::L2 } else { Metric::L1 };
            (rand_points(rng, n_src, d), rand_points(rng, n_trg, d), k, zs, zt, metric)
        },
        |(src, trg, k, zs, zt, metric)| {
            let gs = Grouping::build_with_metric(src, *zs, 2, 4096, 3, *metric)
                .map_err(|e| e.to_string())?;
            let gt = Grouping::build_with_metric(trg, *zt, 2, 4096, 4, *metric)
                .map_err(|e| e.to_string())?;
            let mut filter = KnnFilter::new();
            let (cands, _) = filter.candidates_metric(&gs, &gt, *k, *metric);
            for i in 0..src.rows() {
                let cand = &cands[gs.assign[i] as usize];
                // True top-k by exhaustive metric scan.
                let mut dists: Vec<(f32, usize)> =
                    (0..trg.rows()).map(|j| (metric.dist_rows(src, i, trg, j), j)).collect();
                dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                for &(d_true, j) in dists.iter().take(*k) {
                    let tg = gt.assign[j];
                    if !cand.contains(&tg) {
                        return Err(format!(
                            "{metric:?}: point {i}: true neighbor {j} (d={d_true}) \
                             lives in pruned group {tg}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// K-means prune-rule soundness: with per-group upper bounds derived
/// from exact assigned distances (the engine's invariant), the rule
/// `lb[group][center_group] <= max member ub` never prunes the center
/// group holding a point's true closest center.
#[test]
fn prop_kmeans_rule_never_excludes_true_closest_center() {
    prop::check(
        &Config { cases: 16, max_size: 150, seed: 0xB0023, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(5);
            let k = 2 + rng.below(20);
            let zs = 2 + rng.below(6);
            let zt = 1 + rng.below(4);
            (rand_points(rng, n, d), rand_points(rng, k, d), zs, zt)
        },
        |(points, centers, zs, zt)| {
            let gs =
                Grouping::build(points, *zs, 2, 4096, 5).map_err(|e| e.to_string())?;
            let gc =
                Grouping::build(centers, (*zt).min(centers.rows()), 2, 4096, 6)
                    .map_err(|e| e.to_string())?;
            let pair = bounds::group_pair_bounds(&gs, &gc);

            // Exact nearest center per point (the engine's ub source).
            let nearest: Vec<(usize, f32)> = (0..points.rows())
                .map(|i| {
                    let mut best = (0usize, f32::INFINITY);
                    for c in 0..centers.rows() {
                        let d2 = points.dist2(i, centers, c);
                        if d2 < best.1 {
                            best = (c, d2);
                        }
                    }
                    (best.0, best.1.max(0.0).sqrt())
                })
                .collect();

            // Per source group: ub = max member distance-to-assigned.
            let mut grp_ub = vec![0.0f32; gs.num_groups()];
            for (i, &(_, d)) in nearest.iter().enumerate() {
                let g = gs.assign[i] as usize;
                if d > grp_ub[g] {
                    grp_ub[g] = d;
                }
            }

            for (i, &(c_true, _)) in nearest.iter().enumerate() {
                let g = gs.assign[i] as usize;
                let b = gc.assign[c_true] as usize;
                // The engine prunes (g, b) iff lb > grp_ub[g]; that must
                // never happen for the group holding the true closest
                // center (allow float-noise slack).
                if pair[g][b].lb > grp_ub[g] + 1e-4 {
                    return Err(format!(
                        "point {i}: closest center {c_true} in pruned center-group {b} \
                         (lb {} > group ub {})",
                        pair[g][b].lb, grp_ub[g]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Trace-based widening stays sound: bounds computed from *stale*
/// center distances, widened by the per-group drifts that recentering
/// reports, still contain every true pair distance of the *moved*
/// points (the N-body filter's reuse invariant).
#[test]
fn prop_drift_widened_bounds_stay_sound() {
    prop::check(
        &Config { cases: 14, max_size: 120, seed: 0xB0024, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let z = 2 + rng.below(6);
            let step = 0.02 + rng.f32() * 0.15;
            (rand_points(rng, n, 3), z, step)
        },
        |(points, z, step)| {
            let mut grouping =
                Grouping::build(points, *z, 2, 4096, 7).map_err(|e| e.to_string())?;
            // Stale center distances, captured before any motion.
            let stale = bounds::center_distances(&grouping.centers, &grouping.centers);
            let zg = grouping.num_groups();

            // Move the points, then recenter (drift per group, fresh radii).
            let mut moved = points.clone();
            let mut rng = Rng::new(0xD01F7);
            for i in 0..moved.rows() {
                for v in moved.row_mut(i) {
                    *v += rng.range_f32(-*step, *step);
                }
            }
            let drifts = grouping.recenter(&moved);

            for i in 0..moved.rows() {
                for j in 0..moved.rows() {
                    let (a, b) =
                        (grouping.assign[i] as usize, grouping.assign[j] as usize);
                    let bound = bounds::GroupPairBound::from_center_dist(
                        stale[a * zg + b],
                        grouping.radii[a],
                        grouping.radii[b],
                    )
                    .widened(drifts[a], drifts[b]);
                    let d_true = moved.dist2(i, &moved, j).sqrt();
                    if d_true < bound.lb - 1e-3 {
                        return Err(format!(
                            "pair ({i},{j}): d={d_true} below widened lb {} \
                             (groups {a},{b}, drifts {}/{})",
                            bound.lb, drifts[a], drifts[b]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
