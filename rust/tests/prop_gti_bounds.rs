//! Property tests for the GTI bound algebra (`gti::bounds`): the
//! soundness arguments the whole optimization rests on, as executable
//! checks over random geometry.
//!
//! The invariant in every test: a bound may be loose, but it must NEVER
//! exclude the true answer — no true nearest neighbor may live in a
//! pruned target group, and no true closest center may live in a pruned
//! center group, under either supported metric and after trace-based
//! drift widening.

use accd::data::Matrix;
use accd::gti::{bounds, Grouping, KnnFilter, Metric};
use accd::util::prop::{self, Config};
use accd::util::rng::Rng;

fn rand_points(rng: &mut Rng, n: usize, d: usize) -> Matrix {
    Matrix::from_vec(prop::gen_points(rng, n, d, 4.0), n, d).unwrap()
}

/// Eq. 2 group-pair bounds contain every member-pair distance, for both
/// triangle-inequality metrics (groupings and bounds share the metric).
#[test]
fn prop_group_pair_bounds_contain_all_pair_distances() {
    prop::check(
        &Config { cases: 20, max_size: 160, seed: 0xB0021, ..Default::default() },
        |rng, size| {
            let n_src = 15 + size / 2;
            let n_trg = 20 + size / 2;
            let d = 1 + rng.below(6);
            let zs = 2 + rng.below(6);
            let zt = 2 + rng.below(6);
            let metric = if rng.below(2) == 0 { Metric::L2 } else { Metric::L1 };
            (rand_points(rng, n_src, d), rand_points(rng, n_trg, d), zs, zt, metric)
        },
        |(src, trg, zs, zt, metric)| {
            let gs = Grouping::build_with_metric(src, *zs, 2, 4096, 1, *metric)
                .map_err(|e| e.to_string())?;
            let gt = Grouping::build_with_metric(trg, *zt, 2, 4096, 2, *metric)
                .map_err(|e| e.to_string())?;
            let bnds = bounds::group_pair_bounds_metric(&gs, &gt, *metric);
            for i in 0..src.rows() {
                for j in 0..trg.rows() {
                    let d_true = metric.dist_rows(src, i, trg, j);
                    let b = bnds[gs.assign[i] as usize][gt.assign[j] as usize];
                    if d_true < b.lb - 1e-3 || d_true > b.ub + 1e-3 {
                        return Err(format!(
                            "{metric:?}: pair ({i},{j}) d={d_true} escapes [{}, {}]",
                            b.lb, b.ub
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// KNN soundness: for every source point, ALL of its true K nearest
/// targets live inside the candidate groups of its source group — the
/// filter may keep too much, never too little.  Metric-generic.
#[test]
fn prop_knn_filter_never_excludes_true_neighbors_any_metric() {
    prop::check(
        &Config { cases: 16, max_size: 150, seed: 0xB0022, ..Default::default() },
        |rng, size| {
            let n_src = 10 + size / 2;
            let n_trg = 30 + size;
            let d = 1 + rng.below(5);
            let k = 1 + rng.below(8);
            let zs = 2 + rng.below(6);
            let zt = 2 + rng.below(8);
            let metric = if rng.below(2) == 0 { Metric::L2 } else { Metric::L1 };
            (rand_points(rng, n_src, d), rand_points(rng, n_trg, d), k, zs, zt, metric)
        },
        |(src, trg, k, zs, zt, metric)| {
            let gs = Grouping::build_with_metric(src, *zs, 2, 4096, 3, *metric)
                .map_err(|e| e.to_string())?;
            let gt = Grouping::build_with_metric(trg, *zt, 2, 4096, 4, *metric)
                .map_err(|e| e.to_string())?;
            let mut filter = KnnFilter::new();
            let (cands, _) = filter.candidates_metric(&gs, &gt, *k, *metric);
            for i in 0..src.rows() {
                let cand = &cands[gs.assign[i] as usize];
                // True top-k by exhaustive metric scan.
                let mut dists: Vec<(f32, usize)> =
                    (0..trg.rows()).map(|j| (metric.dist_rows(src, i, trg, j), j)).collect();
                dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                for &(d_true, j) in dists.iter().take(*k) {
                    let tg = gt.assign[j];
                    if !cand.contains(&tg) {
                        return Err(format!(
                            "{metric:?}: point {i}: true neighbor {j} (d={d_true}) \
                             lives in pruned group {tg}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// K-means prune-rule soundness: with per-group upper bounds derived
/// from exact assigned distances (the engine's invariant), the rule
/// `lb[group][center_group] <= max member ub` never prunes the center
/// group holding a point's true closest center.
#[test]
fn prop_kmeans_rule_never_excludes_true_closest_center() {
    prop::check(
        &Config { cases: 16, max_size: 150, seed: 0xB0023, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(5);
            let k = 2 + rng.below(20);
            let zs = 2 + rng.below(6);
            let zt = 1 + rng.below(4);
            (rand_points(rng, n, d), rand_points(rng, k, d), zs, zt)
        },
        |(points, centers, zs, zt)| {
            let gs =
                Grouping::build(points, *zs, 2, 4096, 5).map_err(|e| e.to_string())?;
            let gc =
                Grouping::build(centers, (*zt).min(centers.rows()), 2, 4096, 6)
                    .map_err(|e| e.to_string())?;
            let pair = bounds::group_pair_bounds(&gs, &gc);

            // Exact nearest center per point (the engine's ub source).
            let nearest: Vec<(usize, f32)> = (0..points.rows())
                .map(|i| {
                    let mut best = (0usize, f32::INFINITY);
                    for c in 0..centers.rows() {
                        let d2 = points.dist2(i, centers, c);
                        if d2 < best.1 {
                            best = (c, d2);
                        }
                    }
                    (best.0, best.1.max(0.0).sqrt())
                })
                .collect();

            // Per source group: ub = max member distance-to-assigned.
            let mut grp_ub = vec![0.0f32; gs.num_groups()];
            for (i, &(_, d)) in nearest.iter().enumerate() {
                let g = gs.assign[i] as usize;
                if d > grp_ub[g] {
                    grp_ub[g] = d;
                }
            }

            for (i, &(c_true, _)) in nearest.iter().enumerate() {
                let g = gs.assign[i] as usize;
                let b = gc.assign[c_true] as usize;
                // The engine prunes (g, b) iff lb > grp_ub[g]; that must
                // never happen for the group holding the true closest
                // center (allow float-noise slack).
                if pair[g][b].lb > grp_ub[g] + 1e-4 {
                    return Err(format!(
                        "point {i}: closest center {c_true} in pruned center-group {b} \
                         (lb {} > group ub {})",
                        pair[g][b].lb, grp_ub[g]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Incremental (Elkan/Hamerly) point-bound widening stays sound over
/// whole *sequences* of center motion with no recomputation: ub keeps
/// upper-bounding the distance to the (stale) assigned center and lb
/// keeps lower-bounding the distance to every other center.
#[test]
fn prop_incremental_point_bounds_sound_under_drift_sequences() {
    prop::check(
        &Config { cases: 14, max_size: 120, seed: 0xB0025, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(5);
            let k = 2 + rng.below(12);
            let rounds = 2 + rng.below(3);
            let step = 0.02 + rng.f32() * 0.2;
            (rand_points(rng, n, d), rand_points(rng, k, d), rounds, step)
        },
        |(points, centers, rounds, step)| {
            let n = points.rows();
            let k = centers.rows();
            let mut centers = centers.clone();
            // Exact seeds: assignment, ub = d to assigned, lb = d to
            // second-closest (the plan-time assign2 pass).
            let mut assign = vec![0u32; n];
            let mut ub = vec![0.0f32; n];
            let mut lb = vec![0.0f32; n];
            for i in 0..n {
                let (mut best, mut second, mut bi) = (f32::INFINITY, f32::INFINITY, 0);
                for c in 0..k {
                    let d2 = points.dist2(i, &centers, c);
                    if d2 < best {
                        second = best;
                        best = d2;
                        bi = c;
                    } else if d2 < second {
                        second = d2;
                    }
                }
                assign[i] = bi as u32;
                ub[i] = best.max(0.0).sqrt();
                lb[i] = second.max(0.0).sqrt();
            }
            let mut rng = Rng::new(0xD01F8);
            for round in 0..*rounds {
                // Move every center; record its true displacement.
                let mut drift = vec![0.0f32; k];
                for c in 0..k {
                    let mut d2 = 0.0f32;
                    for v in centers.row_mut(c) {
                        let delta = rng.range_f32(-*step, *step);
                        *v += delta;
                        d2 += delta * delta;
                    }
                    drift[c] = d2.sqrt();
                }
                let w = bounds::DriftWidening::from_drifts(&drift);
                bounds::widen_point_bounds(&mut ub, &mut lb, &assign, &drift, &w);
                for i in 0..n {
                    let a = assign[i] as usize;
                    let d_assigned = points.dist2(i, &centers, a).max(0.0).sqrt();
                    if d_assigned > ub[i] + 1e-3 {
                        return Err(format!(
                            "round {round}: point {i}: d(assigned)={d_assigned} \
                             above widened ub {}",
                            ub[i]
                        ));
                    }
                    let mut d_other = f32::INFINITY;
                    for c in 0..k {
                        if c != a {
                            d_other = d_other.min(points.dist2(i, &centers, c));
                        }
                    }
                    let d_other = d_other.max(0.0).sqrt();
                    if lb[i] > d_other + 1e-3 {
                        return Err(format!(
                            "round {round}: point {i}: widened lb {} above \
                             closest-other distance {d_other}",
                            lb[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The stability rule `ub[i] <= lb[i]` (after the engine's cheap exact
/// ub-tighten) never certifies a point whose closest center actually
/// changed — across rounds with the real carry discipline: certified
/// points keep widened bounds, unstable points get the device-style
/// exact refresh.
#[test]
fn prop_stability_rule_never_changes_assignment() {
    prop::check(
        &Config { cases: 14, max_size: 100, seed: 0xB0026, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(4);
            let k = 2 + rng.below(10);
            let rounds = 2 + rng.below(3);
            let step = 0.01 + rng.f32() * 0.15;
            (rand_points(rng, n, d), rand_points(rng, k, d), rounds, step)
        },
        |(points, centers, rounds, step)| {
            let n = points.rows();
            let k = centers.rows();
            let mut centers = centers.clone();
            // (closest center, d to it, d to second-closest) by scan.
            let exact = |centers: &Matrix, i: usize| {
                let (mut best, mut second, mut bi) = (f32::INFINITY, f32::INFINITY, 0usize);
                for c in 0..k {
                    let d2 = points.dist2(i, centers, c);
                    if d2 < best {
                        second = best;
                        best = d2;
                        bi = c;
                    } else if d2 < second {
                        second = d2;
                    }
                }
                (bi, best.max(0.0).sqrt(), second.max(0.0).sqrt())
            };
            let mut assign = vec![0u32; n];
            let mut ub = vec![0.0f32; n];
            let mut lb = vec![0.0f32; n];
            for i in 0..n {
                let (bi, b, s) = exact(&centers, i);
                assign[i] = bi as u32;
                ub[i] = b;
                lb[i] = s;
            }
            let mut rng = Rng::new(0xD01F9);
            for round in 0..*rounds {
                let mut drift = vec![0.0f32; k];
                for c in 0..k {
                    let mut d2 = 0.0f32;
                    for v in centers.row_mut(c) {
                        let delta = rng.range_f32(-*step, *step);
                        *v += delta;
                        d2 += delta * delta;
                    }
                    drift[c] = d2.sqrt();
                }
                let w = bounds::DriftWidening::from_drifts(&drift);
                bounds::widen_point_bounds(&mut ub, &mut lb, &assign, &drift, &w);
                for i in 0..n {
                    let a = assign[i] as usize;
                    if ub[i] > lb[i] {
                        // Cheap exact ub-tighten before deciding.
                        ub[i] = points.dist2(i, &centers, a).max(0.0).sqrt();
                    }
                    let (bi, b, s) = exact(&centers, i);
                    if ub[i] <= lb[i] {
                        // Certified stable: the stale assignment must
                        // still be a true closest center (ties allowed).
                        let d_assigned = points.dist2(i, &centers, a).max(0.0).sqrt();
                        if d_assigned > b + 1e-4 {
                            return Err(format!(
                                "round {round}: point {i} certified stable on \
                                 center {a} (d={d_assigned}) but center {bi} is \
                                 closer (d={b})"
                            ));
                        }
                    } else {
                        assign[i] = bi as u32;
                        ub[i] = b;
                        lb[i] = s;
                    }
                }
            }
            Ok(())
        },
    );
}

/// The carried (source group x center group) lower bounds, widened per
/// round by max member drift per center group, keep lower-bounding
/// every (member point, member center) distance — the incremental
/// group-filter's soundness (center-group membership fixed, as in the
/// engine).
#[test]
fn prop_incremental_pair_lbs_stay_sound() {
    prop::check(
        &Config { cases: 12, max_size: 100, seed: 0xB0027, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(4);
            let k = 4 + rng.below(16);
            let zs = 2 + rng.below(5);
            let zt = 2 + rng.below(4);
            let rounds = 2 + rng.below(3);
            let step = 0.02 + rng.f32() * 0.2;
            (rand_points(rng, n, d), rand_points(rng, k, d), zs, zt, rounds, step)
        },
        |(points, centers, zs, zt, rounds, step)| {
            let k = centers.rows();
            let mut centers = centers.clone();
            let gs = Grouping::build(points, *zs, 2, 4096, 8).map_err(|e| e.to_string())?;
            let gc = Grouping::build(&centers, (*zt).min(k), 2, 4096, 9)
                .map_err(|e| e.to_string())?;
            let mut pair_lb: Vec<Vec<f32>> = bounds::group_pair_bounds(&gs, &gc)
                .iter()
                .map(|row| row.iter().map(|b| b.lb).collect())
                .collect();
            let mut rng = Rng::new(0xD01FA);
            for round in 0..*rounds {
                let mut drift = vec![0.0f32; k];
                for c in 0..k {
                    let mut d2 = 0.0f32;
                    for v in centers.row_mut(c) {
                        let delta = rng.range_f32(-*step, *step);
                        *v += delta;
                        d2 += delta * delta;
                    }
                    drift[c] = d2.sqrt();
                }
                let cg_drift =
                    bounds::center_group_drift(&gc.assign, gc.num_groups(), &drift);
                bounds::widen_pair_lbs(&mut pair_lb, &cg_drift);
                for i in 0..points.rows() {
                    let g = gs.assign[i] as usize;
                    for c in 0..k {
                        let b = gc.assign[c] as usize;
                        let d_true = points.dist2(i, &centers, c).max(0.0).sqrt();
                        if pair_lb[g][b] > d_true + 1e-3 {
                            return Err(format!(
                                "round {round}: pair lb[{g}][{b}]={} above \
                                 d({i},{c})={d_true}",
                                pair_lb[g][b]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Trace-based widening stays sound: bounds computed from *stale*
/// center distances, widened by the per-group drifts that recentering
/// reports, still contain every true pair distance of the *moved*
/// points (the N-body filter's reuse invariant).
#[test]
fn prop_drift_widened_bounds_stay_sound() {
    prop::check(
        &Config { cases: 14, max_size: 120, seed: 0xB0024, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let z = 2 + rng.below(6);
            let step = 0.02 + rng.f32() * 0.15;
            (rand_points(rng, n, 3), z, step)
        },
        |(points, z, step)| {
            let mut grouping =
                Grouping::build(points, *z, 2, 4096, 7).map_err(|e| e.to_string())?;
            // Stale center distances, captured before any motion.
            let stale = bounds::center_distances(&grouping.centers, &grouping.centers);
            let zg = grouping.num_groups();

            // Move the points, then recenter (drift per group, fresh radii).
            let mut moved = points.clone();
            let mut rng = Rng::new(0xD01F7);
            for i in 0..moved.rows() {
                for v in moved.row_mut(i) {
                    *v += rng.range_f32(-*step, *step);
                }
            }
            let drifts = grouping.recenter(&moved);

            for i in 0..moved.rows() {
                for j in 0..moved.rows() {
                    let (a, b) =
                        (grouping.assign[i] as usize, grouping.assign[j] as usize);
                    let bound = bounds::GroupPairBound::from_center_dist(
                        stale[a * zg + b],
                        grouping.radii[a],
                        grouping.radii[b],
                    )
                    .widened(drifts[a], drifts[b]);
                    let d_true = moved.dist2(i, &moved, j).sqrt();
                    if d_true < bound.lb - 1e-3 {
                        return Err(format!(
                            "pair ({i},{j}): d={d_true} below widened lb {} \
                             (groups {a},{b}, drifts {}/{})",
                            bound.lb, drifts[a], drifts[b]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
