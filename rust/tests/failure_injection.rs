//! Failure-injection tests: the runtime must fail loudly and
//! informatively on corrupted deployments, never start on a broken
//! artifact directory, and never panic on malformed inputs — and the
//! serving runtime above it must requeue, retry and fail over instead
//! of losing accepted queries.

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::runtime::Runtime;
use accd::serve::{QueryBatcher, Server, ServeRequest, VirtualClock, DRAIN_RETRY_LIMIT};
use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("accd_fail_{name}"));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn write(p: &std::path::Path, name: &str, content: &str) {
    let mut f = std::fs::File::create(p.join(name)).unwrap();
    f.write_all(content.as_bytes()).unwrap();
}

#[test]
fn missing_artifact_dir_is_a_clear_error() {
    let err = Runtime::load("/nonexistent/accd_artifacts").err().expect("expected an error");
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_json_is_rejected() {
    let dir = tmpdir("corrupt_json");
    write(&dir, "manifest.json", "{ not json !!");
    assert!(Runtime::load(&dir).is_err());
}

#[test]
fn wrong_manifest_version_is_rejected() {
    let dir = tmpdir("bad_version");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 99, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]}, "artifacts": []}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn manifest_referencing_missing_file_is_rejected() {
    let dir = tmpdir("missing_file");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "ghost", "file": "ghost.hlo.txt",
            "kind": "distance", "inputs": [[64, 4], [64, 4]],
            "meta": {"metric": "l2sq", "bm": 64, "bn": 64, "d": 4}}]}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("ghost.hlo.txt"), "{err}");
}

#[test]
fn malformed_hlo_text_fails_at_compile_not_load() {
    let dir = tmpdir("bad_hlo");
    write(&dir, "garbage.hlo.txt", "this is not an HLO module");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "distance_l2sq_m64_n64_d4", "file": "garbage.hlo.txt",
            "kind": "distance", "inputs": [[64, 4], [64, 4]],
            "meta": {"metric": "l2sq", "bm": 64, "bn": 64, "d": 4}}]}"#,
    );
    // Load succeeds (lazy compilation)...
    let rt = Runtime::load(&dir).unwrap();
    // ...but the first execution surfaces the parse failure as an Err.
    let a = vec![0.0f32; 64 * 4];
    let b = vec![0.0f32; 64 * 4];
    assert!(rt.distance_tile("l2sq", 4, &a, &b).is_err());
}

#[test]
fn unknown_artifact_kind_is_rejected() {
    let dir = tmpdir("bad_kind");
    write(&dir, "x.hlo.txt", "HloModule x");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "x", "file": "x.hlo.txt",
            "kind": "quantum", "inputs": [[64, 4]], "meta": {}}]}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("quantum"), "{err}");
}

#[test]
fn requesting_nonexistent_tile_shape_errors_cleanly() {
    let Ok(rt) = Runtime::load("artifacts") else {
        eprintln!("skipping (no artifacts)");
        return;
    };
    // d=7 is not a padded dim; no artifact exists.
    let a = vec![0.0f32; 64 * 7];
    let b = vec![0.0f32; 64 * 7];
    let err = rt.distance_tile("l2sq", 7, &a, &b).err().expect("expected an error");
    assert!(err.to_string().contains("no artifact"), "{err}");
    // Unknown metric name likewise.
    let a = vec![0.0f32; 64 * 4];
    let b = vec![0.0f32; 64 * 4];
    assert!(rt.distance_tile("linf", 4, &a, &b).is_err());
}

// --- mid-flush failure under the serving runtime ---------------------------
//
// A manifest whose single artifact (`distance_l2sq_m64_n64_d4`, the
// one tile a small d=4 KNN join needs) is malformed HLO: loading
// succeeds (lazy compilation), the first flush fails mid-execution.
// Failed compiles are never cached and the HLO file is re-read per
// attempt, so repairing the file in place makes the retry succeed.

const TILE_HLO: &str = "tile.hlo.txt";

fn broken_knn_deployment(name: &str) -> std::path::PathBuf {
    let dir = tmpdir(name);
    write(&dir, TILE_HLO, "this is not an HLO module");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "distance_l2sq_m64_n64_d4", "file": "tile.hlo.txt",
            "kind": "distance", "inputs": [[64, 4], [64, 4]],
            "meta": {"metric": "l2sq", "bm": 64, "bn": 64, "d": 4}}]}"#,
    );
    dir
}

fn repair_deployment(dir: &std::path::Path) {
    write(dir, TILE_HLO, "HloModule distance_l2sq_m64_n64_d4");
}

fn engine_over(dir: &std::path::Path, cfg: &AccdConfig) -> Engine {
    let rt = Arc::new(Runtime::load(dir).expect("lazy load succeeds"));
    Engine::with_runtime(cfg.clone(), rt).expect("engine")
}

/// Two small KNN queries sharing one target cohort (d=4, every
/// dataset under one 64-point tile, so exactly the broken artifact is
/// requested).
fn knn_pair(seed: u64) -> [ServeRequest; 2] {
    let trg = Arc::new(synthetic::clustered(60, 4, 3, 0.05, seed));
    let src_a = Arc::new(synthetic::clustered(40, 4, 3, 0.05, seed + 1));
    let src_b = Arc::new(synthetic::clustered(30, 4, 3, 0.05, seed + 2));
    [ServeRequest::knn(src_a, trg.clone(), 3), ServeRequest::knn(src_b, trg, 3)]
}

fn assert_knn_parity(
    resp: &accd::serve::ServeResponse,
    req: &ServeRequest,
    solo: &mut Engine,
    what: &str,
) {
    let ServeRequest::Knn { src, trg, k, metric } = req else {
        unreachable!("scenario is KNN-only")
    };
    let want = solo.knn_join_metric(src, trg, *k, *metric).expect("solo knn");
    let got = resp.as_knn().unwrap_or_else(|| panic!("{what}: wrong kind"));
    assert_eq!(got.neighbors, want.neighbors, "{what}: retry must not perturb results");
}

/// Caller-driven requeue contract, deterministically: a mid-flush
/// compile failure re-queues the drained batch at the front — in
/// submission order, deadlines intact — and the retry after repairing
/// the artifact serves it bit-for-bit like the solo engine.
#[test]
fn batcher_requeues_in_order_with_deadlines_after_midflush_failure() {
    let dir = broken_knn_deployment("batcher_requeue");
    let cfg = AccdConfig::new();
    let clock = VirtualClock::new();
    let mut b = QueryBatcher::with_clock(
        engine_over(&dir, &cfg),
        cfg.serve.clone(),
        Arc::new(clock.clone()),
    );
    let reqs = knn_pair(0xF1A5);
    let id0 = b.submit_with_deadline(reqs[0].clone(), Duration::from_millis(5));
    let id1 = b.submit_with_deadline(reqs[1].clone(), Duration::from_millis(8));

    clock.advance(Duration::from_millis(8));
    b.poll().expect_err("malformed HLO must fail the flush");
    assert_eq!(b.pending_len(), 2, "failed batch requeued, not lost");
    assert_eq!(b.next_deadline(), Some(5_000_000), "requeued queries keep their deadlines");
    assert_eq!(b.stats().flushes, 0, "a failed flush commits no stats");
    assert!(b.stats().latency_ns.is_empty());

    repair_deployment(&dir);
    let out = b.poll().expect("retry succeeds once the artifact is repaired");
    let ids: Vec<u64> = out.iter().map(|(id, _)| *id).collect();
    assert_eq!(ids, vec![id0, id1], "submission order survives the requeue");
    let stats = b.stats();
    assert_eq!(stats.flushes, 1);
    // Served at the 8 ms retry: query 0's 5 ms deadline had expired
    // (the failure cost it its deadline — counted, not hidden); query
    // 1's 8 ms deadline was met exactly.
    assert_eq!((stats.deadline_met, stats.deadline_misses), (1, 1), "{stats:?}");
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for (id, resp) in &out {
        let qi = *id as usize;
        assert_knn_parity(resp, &reqs[qi], &mut solo, &format!("requeued query {qi}"));
    }
}

/// The same failure under the always-on `Server`: the scheduler's
/// failed attempt is counted in `flush_failures`, the batch is
/// requeued, and the next wake event after the repair serves every
/// accepted query — nothing lost, solo-parity intact.
#[test]
fn server_recovers_from_midflush_failure_without_losing_queries() {
    let dir = broken_knn_deployment("server_retry");
    let cfg = AccdConfig::new();
    let clock = VirtualClock::new();
    let server = Server::with_clock(
        engine_over(&dir, &cfg),
        cfg.serve.clone(),
        Arc::new(clock.clone()),
    );
    let reqs = knn_pair(0xF1A6);
    let h0 = server.submit_with_deadline(reqs[0].clone(), Duration::from_millis(5)).unwrap();
    let h1 = server.submit_with_deadline(reqs[1].clone(), Duration::from_millis(8)).unwrap();

    // Trip the failure and wait (by yielding, not sleeping) until the
    // scheduler has observably hit it and requeued the batch.
    clock.advance(Duration::from_millis(5));
    while server.stats().flush_failures == 0 {
        std::thread::yield_now();
    }
    repair_deployment(&dir);
    clock.advance(Duration::from_millis(3));

    let r0 = h0.wait().expect("requeued query served after the repair");
    let r1 = h1.wait().expect("second query served after the repair");
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    assert_knn_parity(&r0, &reqs[0], &mut solo, "retried query 0");
    assert_knn_parity(&r1, &reqs[1], &mut solo, "retried query 1");
    let stats = server.shutdown();
    assert_eq!(stats.latency_ns.len(), 2, "both queries answered: {stats:?}");
    assert!(stats.flush_failures >= 1, "the failure is visible to operators: {stats:?}");
    assert_eq!(stats.shed, 0, "an engine failure is not overload");
}

/// When the engine never recovers, shutdown must not hang on its
/// drain: after `DRAIN_RETRY_LIMIT` consecutive failures the
/// remaining handles are failed over with the underlying error —
/// resolved, not leaked.
#[test]
fn shutdown_drain_fails_over_handles_when_engine_never_recovers() {
    let dir = broken_knn_deployment("drain_failover");
    let cfg = AccdConfig::new();
    let clock = VirtualClock::new();
    let server = Server::with_clock(
        engine_over(&dir, &cfg),
        cfg.serve.clone(),
        Arc::new(clock.clone()),
    );
    let [req, _] = knn_pair(0xF1A7);
    // A far-future deadline keeps the scheduler idle pre-shutdown, so
    // the drain's retry budget is observed exactly.
    let handle = server.submit_with_deadline(req, Duration::from_secs(3_600)).unwrap();
    let stats = server.shutdown();
    let err = handle.wait().expect_err("failed over, not leaked");
    assert!(matches!(err, accd::Error::Serve(_)), "{err}");
    assert!(err.to_string().contains("drain failed"), "{err}");
    assert_eq!(stats.flush_failures, DRAIN_RETRY_LIMIT as u64, "{stats:?}");
    assert!(stats.latency_ns.is_empty(), "nothing was served: {stats:?}");
}

#[test]
fn config_loader_rejects_broken_files() {
    let dir = tmpdir("config");
    write(&dir, "bad.json", "{");
    assert!(AccdConfig::load(dir.join("bad.json").to_str().unwrap()).is_err());
    assert!(AccdConfig::load("/nonexistent/accd.json").is_err());
    write(&dir, "invalid.json", r#"{"hw": {"block": 3}}"#); // not a power of two
    assert!(AccdConfig::load(dir.join("invalid.json").to_str().unwrap()).is_err());
}
