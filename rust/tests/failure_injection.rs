//! Failure-injection tests: the runtime must fail loudly and
//! informatively on corrupted deployments, never start on a broken
//! artifact directory, and never panic on malformed inputs.

use accd::runtime::Runtime;
use std::io::Write;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("accd_fail_{name}"));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn write(p: &std::path::Path, name: &str, content: &str) {
    let mut f = std::fs::File::create(p.join(name)).unwrap();
    f.write_all(content.as_bytes()).unwrap();
}

#[test]
fn missing_artifact_dir_is_a_clear_error() {
    let err = Runtime::load("/nonexistent/accd_artifacts").err().expect("expected an error");
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_json_is_rejected() {
    let dir = tmpdir("corrupt_json");
    write(&dir, "manifest.json", "{ not json !!");
    assert!(Runtime::load(&dir).is_err());
}

#[test]
fn wrong_manifest_version_is_rejected() {
    let dir = tmpdir("bad_version");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 99, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]}, "artifacts": []}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn manifest_referencing_missing_file_is_rejected() {
    let dir = tmpdir("missing_file");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "ghost", "file": "ghost.hlo.txt",
            "kind": "distance", "inputs": [[64, 4], [64, 4]],
            "meta": {"metric": "l2sq", "bm": 64, "bn": 64, "d": 4}}]}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("ghost.hlo.txt"), "{err}");
}

#[test]
fn malformed_hlo_text_fails_at_compile_not_load() {
    let dir = tmpdir("bad_hlo");
    write(&dir, "garbage.hlo.txt", "this is not an HLO module");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "distance_l2sq_m64_n64_d4", "file": "garbage.hlo.txt",
            "kind": "distance", "inputs": [[64, 4], [64, 4]],
            "meta": {"metric": "l2sq", "bm": 64, "bn": 64, "d": 4}}]}"#,
    );
    // Load succeeds (lazy compilation)...
    let rt = Runtime::load(&dir).unwrap();
    // ...but the first execution surfaces the parse failure as an Err.
    let a = vec![0.0f32; 64 * 4];
    let b = vec![0.0f32; 64 * 4];
    assert!(rt.distance_tile("l2sq", 4, &a, &b).is_err());
}

#[test]
fn unknown_artifact_kind_is_rejected() {
    let dir = tmpdir("bad_kind");
    write(&dir, "x.hlo.txt", "HloModule x");
    write(
        &dir,
        "manifest.json",
        r#"{"version": 1, "tile": {"m": 64, "n": 64, "d_pad": [4], "knn_k": 32,
            "kmeans_k_pad": [64], "nbody": 64, "variants": [64]},
            "artifacts": [{"name": "x", "file": "x.hlo.txt",
            "kind": "quantum", "inputs": [[64, 4]], "meta": {}}]}"#,
    );
    let err = Runtime::load(&dir).err().expect("expected an error");
    assert!(err.to_string().contains("quantum"), "{err}");
}

#[test]
fn requesting_nonexistent_tile_shape_errors_cleanly() {
    let Ok(rt) = Runtime::load("artifacts") else {
        eprintln!("skipping (no artifacts)");
        return;
    };
    // d=7 is not a padded dim; no artifact exists.
    let a = vec![0.0f32; 64 * 7];
    let b = vec![0.0f32; 64 * 7];
    let err = rt.distance_tile("l2sq", 7, &a, &b).err().expect("expected an error");
    assert!(err.to_string().contains("no artifact"), "{err}");
    // Unknown metric name likewise.
    let a = vec![0.0f32; 64 * 4];
    let b = vec![0.0f32; 64 * 4];
    assert!(rt.distance_tile("linf", 4, &a, &b).is_err());
}

#[test]
fn config_loader_rejects_broken_files() {
    use accd::config::AccdConfig;
    let dir = tmpdir("config");
    write(&dir, "bad.json", "{");
    assert!(AccdConfig::load(dir.join("bad.json").to_str().unwrap()).is_err());
    assert!(AccdConfig::load("/nonexistent/accd.json").is_err());
    write(&dir, "invalid.json", r#"{"hw": {"block": 3}}"#); // not a power of two
    assert!(AccdConfig::load(dir.join("invalid.json").to_str().unwrap()).is_err());
}
