//! The always-on `serve::Server`, end to end, on a virtual clock.
//!
//! Producers submit through the bounded intake; a background scheduler
//! owns the `QueryBatcher` and flushes when `next_wakeup()` says work
//! is due.  Everything runs on a `VirtualClock` the tests advance by
//! hand — the scheduler registers a clock waker, so there is not a
//! single wall-clock sleep anywhere:
//!
//! (a) an open-loop Poisson arrival trace drains clean: every accepted
//!     query is answered, bit-for-bit equal to the solo engine, across
//!     shard counts 1 / 2 / 4,
//! (b) deadline-free queries are served without any clock advance (the
//!     `next_deadline()`-sleeping loop of old stalled forever here),
//! (c) deadline queries coalesce into ONE flush at expiry,
//! (d) `queue_cap` + `overload = "reject"` sheds deterministically and
//!     counts it; `"block"` parks the producer until space frees,
//! (e) shutdown drains every accepted query before returning,
//! (f) an invalid query fails its OWN handle; the server keeps serving.

use std::sync::Arc;
use std::time::Duration;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::serve::{ResponseHandle, Server, ServeRequest, ServeResponse, VirtualClock};
use accd::util::rng::Rng;

fn clocked_server(clock: &VirtualClock, tweak: impl FnOnce(&mut AccdConfig)) -> Server {
    let mut cfg = AccdConfig::new();
    tweak(&mut cfg);
    let engine = Engine::new(cfg.clone()).unwrap();
    Server::with_clock(engine, cfg.serve.clone(), Arc::new(clock.clone()))
}

/// Exact parity of one response against the solo engine — the server
/// must never perturb a result, whatever the arrival interleaving.
fn assert_solo_parity(resp: &ServeResponse, req: &ServeRequest, solo: &mut Engine, what: &str) {
    match req {
        ServeRequest::Knn { src, trg, k, metric } => {
            let want = solo.knn_join_metric(src, trg, *k, *metric).expect("solo knn");
            let got = resp.as_knn().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.k, want.k, "{what}: k");
            assert_eq!(got.neighbors, want.neighbors, "{what}: knn diverged");
        }
        ServeRequest::Kmeans { ds, k, max_iters } => {
            let want = solo.kmeans(ds, *k, *max_iters).expect("solo kmeans");
            let got = resp.as_kmeans().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.assign, want.assign, "{what}: kmeans diverged");
            assert_eq!(got.sse, want.sse, "{what}: kmeans sse diverged");
            assert_eq!(got.iterations, want.iterations, "{what}: iterations diverged");
            assert_eq!(
                got.centers.as_slice(),
                want.centers.as_slice(),
                "{what}: kmeans centers diverged"
            );
        }
        ServeRequest::RangeJoin { src, trg, threshold, metric } => {
            let want =
                solo.range_join_metric(src, trg, *threshold, *metric).expect("solo rangejoin");
            let got = resp.as_rangejoin().unwrap_or_else(|| panic!("{what}: wrong kind"));
            assert_eq!(got.neighbors, want.neighbors, "{what}: rangejoin diverged");
        }
        ServeRequest::Nbody { .. } => unreachable!("workload has no N-body queries"),
    }
}

/// The mixed KNN / K-means request pool the open-loop tests draw from:
/// two KNN cohorts (shared targets), K-means on two datasets with
/// varying k, plus exact duplicates to keep dedup in the picture.
fn request_pool(seed: u64) -> Vec<ServeRequest> {
    let trg_a = Arc::new(synthetic::clustered(240, 4, 5, 0.03, seed));
    let trg_b = Arc::new(synthetic::clustered(180, 4, 4, 0.03, seed + 1));
    let km_a = Arc::new(synthetic::clustered(150, 4, 5, 0.04, seed + 2));
    let km_b = Arc::new(synthetic::clustered(120, 4, 4, 0.04, seed + 3));
    let src = |s: u64, n: usize| Arc::new(synthetic::clustered(n, 4, 3, 0.05, seed + 10 + s));
    let dup_src = src(0, 60);
    vec![
        ServeRequest::knn(dup_src.clone(), trg_a.clone(), 5),
        ServeRequest::kmeans(km_a.clone(), 6, 3),
        ServeRequest::knn(src(1, 70), trg_a.clone(), 5),
        ServeRequest::kmeans(km_b.clone(), 4, 2),
        ServeRequest::knn(src(2, 50), trg_b.clone(), 4),
        ServeRequest::kmeans(km_a.clone(), 9, 2),
        ServeRequest::knn(dup_src, trg_a.clone(), 5), // exact duplicate of [0]
        ServeRequest::kmeans(km_b, 4, 2),             // exact duplicate of [3]
        ServeRequest::knn(src(3, 80), trg_b, 4),
        ServeRequest::kmeans(km_a, 3, 4),
        ServeRequest::knn(src(4, 40), trg_a, 5),
    ]
}

/// (a) The tentpole contract: a seeded open-loop Poisson arrival trace
/// (the producer never waits for responses) drains with zero lost and
/// zero shed queries, and every response equals the solo run —
/// across shard counts 1 / 2 / 4.
#[test]
fn open_loop_poisson_trace_drains_clean_with_solo_parity() {
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for shards in [1usize, 2, 4] {
        let reqs = request_pool(0xACC0);
        // Seeded Poisson arrivals: exponential inter-arrival times with
        // a 2 ms mean, precomputed so every run sees the same trace.
        let mut rng = Rng::new(0x9015_5017 + shards as u64);
        let mut at = 0u64;
        let arrivals: Vec<u64> = reqs
            .iter()
            .map(|_| {
                let u = 1.0 - rng.f64(); // (0, 1]: ln is finite
                at += (-u.ln() * 2_000_000.0) as u64 + 1;
                at
            })
            .collect();

        let clock = VirtualClock::new();
        let server = clocked_server(&clock, |c| c.serve.shards = shards);
        let mut handles: Vec<ResponseHandle> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            clock.set(arrivals[i]);
            // Open loop: submit at the arrival tick and move on; a mix
            // of deadline-free and 4 ms-deadline queries exercises both
            // the straggler and the coalescing path under load.
            let handle = if i % 3 == 0 {
                server.submit(req.clone())
            } else {
                server.submit_with_deadline(req.clone(), Duration::from_millis(4))
            };
            handles.push(handle.expect("accepted"));
        }
        // Let the last deadlines expire, then drain via shutdown.
        clock.advance(Duration::from_millis(4));
        let stats = server.shutdown();

        assert_eq!(stats.latency_ns.len(), reqs.len(), "{shards} shards: all answered");
        assert_eq!(stats.shed, 0, "{shards} shards: nothing shed");
        assert!(stats.flushes >= 1, "{shards} shards: {stats:?}");
        assert!(stats.queue_depth_watermark >= 1, "{shards} shards: {stats:?}");
        for (i, handle) in handles.into_iter().enumerate() {
            let resp = handle.wait().expect("no accepted query may be lost");
            assert_solo_parity(&resp, &reqs[i], &mut solo, &format!("{shards} shards, query {i}"));
        }
    }
}

/// (b) The wake-up regression: deadline-free queries must be served
/// without ANY clock advance.  A scheduler sleeping on the
/// deadline-only `next_deadline()` (always `None` here) would stall
/// forever and hang this test; `next_wakeup()` reports such stragglers
/// as due immediately.
#[test]
fn deadline_free_queries_are_served_without_any_clock_advance() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| c.serve.shards = 2);
    let km = Arc::new(synthetic::clustered(140, 4, 4, 0.04, 77));
    let reqs = [
        ServeRequest::kmeans(km.clone(), 4, 3),
        ServeRequest::kmeans(km.clone(), 6, 2),
        ServeRequest::kmeans(km, 3, 2),
    ];
    let handles: Vec<_> =
        reqs.iter().map(|r| server.submit(r.clone()).expect("accepted")).collect();
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for (i, handle) in handles.into_iter().enumerate() {
        let resp = handle.wait().expect("straggler served, not stalled");
        assert_solo_parity(&resp, &reqs[i], &mut solo, &format!("straggler {i}"));
    }
    let stats = server.shutdown();
    assert_eq!(stats.latency_ns.len(), reqs.len());
    assert_eq!((stats.deadline_met, stats.deadline_misses), (0, 0), "no deadlines here");
}

/// (c) Deadline queries coalesce: with the clock frozen short of the
/// shared deadline nothing is served, and the expiry tick serves all
/// of them in ONE flush (met, not missed).
#[test]
fn deadline_queries_coalesce_into_one_flush_at_expiry() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| c.serve.shards = 2);
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.03, 31));
    let km = Arc::new(synthetic::clustered(130, 4, 4, 0.04, 32));
    let src = |s: u64| Arc::new(synthetic::clustered(60, 4, 3, 0.05, 40 + s));
    let reqs = [
        ServeRequest::knn(src(0), trg.clone(), 5),
        ServeRequest::knn(src(1), trg, 5),
        ServeRequest::kmeans(km.clone(), 5, 2),
        ServeRequest::kmeans(km, 8, 2),
    ];
    let deadline = Duration::from_millis(5);
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| server.submit_with_deadline(r.clone(), deadline).expect("accepted"))
        .collect();

    // Wait (yielding, not sleeping) until the scheduler has moved all
    // four out of the intake: only then is "one coalesced flush" a
    // deterministic claim — a clock advance racing a half-transferred
    // burst could legally serve it in two.
    while server.pending_len() < reqs.len() {
        std::thread::yield_now();
    }

    // Frozen clock: nothing is due, nothing may be served.
    assert_eq!(server.in_flight(), reqs.len());
    assert!(handles[0].try_take().is_none(), "not resolved before its deadline");
    let before = server.stats();
    assert_eq!((before.flushes, before.latency_ns.len()), (0, 0), "{before:?}");

    clock.advance(deadline);
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for (i, handle) in handles.into_iter().enumerate() {
        let resp = handle.wait().expect("served at expiry");
        assert_solo_parity(&resp, &reqs[i], &mut solo, &format!("wave query {i}"));
    }
    assert_eq!(server.in_flight(), 0, "capacity released before handles resolve");
    let stats = server.shutdown();
    assert_eq!(stats.flushes, 1, "one coalesced flush, not four: {stats:?}");
    assert_eq!((stats.deadline_met, stats.deadline_misses), (4, 0), "{stats:?}");
}

/// (d) `overload = "reject"`: at `queue_cap` accepted-but-unanswered
/// queries the next submit is shed — deterministically, because the
/// frozen clock keeps the first two unresolved — and counted.  Space
/// freed by resolution is visible to the producer as soon as `wait()`
/// returns.
#[test]
fn reject_policy_sheds_at_the_bound_and_counts_it() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| {
        c.serve.shards = 1;
        c.serve.queue_cap = 2;
        c.serve.overload = "reject".to_string();
    });
    let km = Arc::new(synthetic::clustered(120, 4, 4, 0.04, 55));
    let rush = Duration::from_millis(50);
    let a = server.submit_with_deadline(ServeRequest::kmeans(km.clone(), 4, 2), rush).unwrap();
    let b = server.submit_with_deadline(ServeRequest::kmeans(km.clone(), 6, 2), rush).unwrap();
    let shed_err = server
        .submit_with_deadline(ServeRequest::kmeans(km.clone(), 8, 2), rush)
        .expect_err("third query must be shed at cap 2");
    assert!(matches!(shed_err, accd::Error::Serve(_)), "{shed_err}");
    assert!(shed_err.to_string().contains("shed"), "{shed_err}");
    let stats = server.stats();
    assert_eq!((stats.shed, stats.queue_depth_watermark), (1, 2), "{stats:?}");

    clock.advance(rush);
    a.wait().expect("served");
    b.wait().expect("served");
    // Both resolved => both slots are free again.
    let c = server
        .submit_with_deadline(ServeRequest::kmeans(km, 5, 2), rush)
        .expect("capacity came back after resolution");
    clock.advance(rush);
    c.wait().expect("served");
    let stats = server.shutdown();
    assert_eq!(stats.shed, 1, "the one rejection, nothing more: {stats:?}");
    assert_eq!(stats.queue_depth_watermark, 2, "{stats:?}");
    assert_eq!(stats.latency_ns.len(), 3, "shed queries leave no latency sample");
}

/// (d) `overload = "block"`: a producer hitting the bound parks until
/// resolution frees a slot, then its query goes through unharmed.
#[test]
fn block_policy_parks_the_producer_until_space_frees() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| {
        c.serve.shards = 1;
        c.serve.queue_cap = 1;
        c.serve.overload = "block".to_string();
    });
    let km = Arc::new(synthetic::clustered(110, 4, 4, 0.04, 66));
    let first = ServeRequest::kmeans(km.clone(), 4, 2);
    let second = ServeRequest::kmeans(km, 7, 2);
    let wait_ms = Duration::from_millis(10);
    let h1 = server.submit_with_deadline(first.clone(), wait_ms).unwrap();
    let (r1, r2) = std::thread::scope(|s| {
        let producer = s.spawn(|| {
            // Cap 1 and the first query unresolved: this submit blocks
            // until the scheduler serves it at the 10 ms tick.
            server.submit_with_deadline(second.clone(), wait_ms).expect("accepted after room")
        });
        clock.advance(wait_ms);
        let r1 = h1.wait().expect("first served");
        let h2 = producer.join().expect("producer thread");
        clock.advance(wait_ms);
        (r1, h2.wait().expect("second served"))
    });
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    assert_solo_parity(&r1, &first, &mut solo, "blocked producer, first");
    assert_solo_parity(&r2, &second, &mut solo, "blocked producer, second");
    let stats = server.shutdown();
    assert_eq!((stats.shed, stats.queue_depth_watermark), (0, 1), "{stats:?}");
    assert_eq!(stats.latency_ns.len(), 2);
}

/// (e) Shutdown drains: far-future deadlines keep the scheduler idle,
/// yet `shutdown()` answers every accepted query before returning.
#[test]
fn shutdown_drains_every_accepted_query() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| c.serve.shards = 2);
    let km = Arc::new(synthetic::clustered(130, 4, 4, 0.04, 88));
    let patient = Duration::from_secs(3_600);
    let reqs: Vec<_> = (0..5).map(|i| ServeRequest::kmeans(km.clone(), 3 + i, 2)).collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| server.submit_with_deadline(r.clone(), patient).expect("accepted"))
        .collect();
    let stats = server.shutdown();
    assert_eq!(stats.latency_ns.len(), reqs.len(), "drained, not dropped: {stats:?}");
    assert_eq!(stats.deadline_met, reqs.len() as u64, "served well before the hour");
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    for (i, handle) in handles.into_iter().enumerate() {
        let resp = handle.wait().expect("resolved by the drain");
        assert_solo_parity(&resp, &reqs[i], &mut solo, &format!("drained query {i}"));
    }
}

/// (f) A query that fails admission validation fails its OWN handle
/// with the real error; the server keeps serving everyone else.  (The
/// caller-driven batcher would instead refuse the whole flush and
/// leave the bad query queued — poison, under an autonomous loop.)
#[test]
fn invalid_query_fails_its_own_handle_not_the_server() {
    let clock = VirtualClock::new();
    let server = clocked_server(&clock, |c| c.serve.shards = 2);
    let trg = Arc::new(synthetic::clustered(150, 4, 4, 0.03, 99));
    let src = Arc::new(synthetic::clustered(50, 4, 3, 0.05, 100));
    let km = Arc::new(synthetic::clustered(120, 4, 4, 0.04, 101));
    let bad = server.submit(ServeRequest::knn(src, trg, 0)).expect("accepted; fails later");
    let good_req = ServeRequest::kmeans(km, 4, 2);
    let good = server.submit(good_req.clone()).expect("accepted");
    let err = bad.wait().expect_err("k = 0 must fail validation");
    assert!(matches!(err, accd::Error::Data(_)), "{err}");
    assert!(err.to_string().contains("k=0"), "{err}");
    let resp = good.wait().expect("the server outlives its poison query");
    let mut solo = Engine::new(AccdConfig::new()).expect("engine");
    assert_solo_parity(&resp, &good_req, &mut solo, "query after the poison one");
    let stats = server.shutdown();
    assert_eq!(stats.latency_ns.len(), 1, "only the served query samples latency");
    assert_eq!(stats.shed, 0, "a validation failure is not a shed");
}
