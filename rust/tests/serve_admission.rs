//! Admission semantics of the serving runtime: deadline-driven
//! `poll()`, coalescing, dedup deadline inheritance, and the
//! fingerprint-based identity fast path — plus the batcher-facade
//! behaviors that used to live in `serve/mod.rs` unit tests (order,
//! dedup, max_batch overflow, failure recovery, cache warmth).
//!
//! Deadline-triggered behavior is driven through an injected
//! `VirtualClock` — no test here (or anywhere in the serve suite)
//! sleeps to make a deadline expire.

use std::sync::Arc;
use std::time::Duration;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset};
use accd::serve::{QueryBatcher, ServeRequest, VirtualClock};

fn batcher() -> QueryBatcher {
    let cfg = AccdConfig::new();
    let engine = Engine::new(cfg.clone()).unwrap();
    QueryBatcher::new(engine, cfg.serve.clone())
}

fn batcher_with(tweak: impl FnOnce(&mut AccdConfig)) -> QueryBatcher {
    let mut cfg = AccdConfig::new();
    tweak(&mut cfg);
    let engine = Engine::new(cfg.clone()).unwrap();
    QueryBatcher::new(engine, cfg.serve.clone())
}

/// A batcher on a test-controlled clock: deadlines expire when the
/// test advances `clock`, never by sleeping.
fn batcher_with_clock(
    tweak: impl FnOnce(&mut AccdConfig),
    clock: &VirtualClock,
) -> QueryBatcher {
    let mut cfg = AccdConfig::new();
    tweak(&mut cfg);
    let engine = Engine::new(cfg.clone()).unwrap();
    QueryBatcher::with_clock(engine, cfg.serve.clone(), Arc::new(clock.clone()))
}

/// A bitwise copy behind a fresh `Arc` — what deserializing the same
/// dataset twice produces: identical content, unrelated pointers.
fn deserialized_copy(ds: &Arc<Dataset>) -> Arc<Dataset> {
    Arc::new((**ds).clone())
}

const FAR: Duration = Duration::from_secs(3600);

// --- construction-time config validation --------------------------------

#[test]
fn try_new_rejects_invalid_serve_configs() {
    let cfg = AccdConfig::new();
    let tweaks: [fn(&mut accd::config::ServeConfig); 3] = [
        |s| s.shards = 0,
        |s| s.pipeline_depth = 0,
        |s| s.grouping_cache_cap = 0,
    ];
    for tweak in tweaks {
        let mut serve = cfg.serve.clone();
        tweak(&mut serve);
        let engine = Engine::new(cfg.clone()).unwrap();
        assert!(
            QueryBatcher::try_new(engine, serve).is_err(),
            "invalid serve config must be rejected on construction"
        );
    }
    // slab_cache_bytes == 0 is legal: it means DISABLED, not invalid.
    let mut serve = cfg.serve.clone();
    serve.slab_cache_bytes = 0;
    let engine = Engine::new(cfg.clone()).unwrap();
    assert!(QueryBatcher::try_new(engine, serve).is_ok());
}

#[test]
fn disabled_slab_cache_still_answers_identically() {
    let mut on = batcher();
    let mut off = batcher_with(|cfg| cfg.serve.slab_cache_bytes = 0);
    let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 91));
    let src = Arc::new(synthetic::clustered(60, 4, 4, 0.03, 92));
    let mut run = |b: &mut QueryBatcher| {
        b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
        let first = b.flush().unwrap();
        b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
        let second = b.flush().unwrap();
        (
            first[0].1.as_knn().unwrap().neighbors.clone(),
            second[0].1.as_knn().unwrap().neighbors.clone(),
        )
    };
    let (on1, on2) = run(&mut on);
    let (off1, off2) = run(&mut off);
    // Identical answers either way (cached slabs are bit-identical to
    // fresh builds)...
    assert_eq!(on1, off1);
    assert_eq!(on2, off2);
    // ...but the disabled cache retains nothing across flushes.
    assert!(on.stats().slab_cache_hits > 0, "{:?}", on.stats());
    assert_eq!(off.stats().slab_cache_hits, 0, "{:?}", off.stats());
    assert_eq!(off.stats().slab_cache_bytes, 0, "nothing resident when disabled");
}

// --- deadline-driven admission (poll) ----------------------------------

#[test]
fn poll_on_empty_or_not_yet_due_queue_is_a_noop() {
    let mut b = batcher();
    assert!(b.poll().unwrap().is_empty());
    assert_eq!(b.stats().flushes, 0);

    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    let src = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 2));
    b.submit_with_deadline(ServeRequest::knn(src, trg, 3), FAR);
    assert!(b.poll().unwrap().is_empty(), "not-yet-due query must keep waiting");
    assert_eq!(b.pending_len(), 1);
    assert_eq!(b.stats().flushes, 0);
    // next_deadline is on the batcher's own clock: a serving loop can
    // compute how long to wait before the next poll.
    let wait = b.next_deadline().expect("deadline pending").saturating_sub(b.now());
    assert!(wait > 0 && wait <= FAR.as_nanos() as u64, "wait {wait} ticks");
}

#[test]
fn deadline_expired_queries_flush_alone() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    let hot = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 2));
    let cold = Arc::new(synthetic::clustered(50, 4, 3, 0.05, 3));
    let id_hot = b.submit_with_deadline(ServeRequest::knn(hot, trg.clone(), 3), Duration::ZERO);
    b.submit_with_deadline(ServeRequest::knn(cold, trg.clone(), 3), FAR);
    b.submit(ServeRequest::knn(
        Arc::new(synthetic::clustered(60, 4, 3, 0.05, 4)),
        trg,
        3,
    )); // no deadline: waits for an explicit flush
    let out = b.poll().unwrap();
    assert_eq!(out.len(), 1, "only the expired query is due");
    assert_eq!(out[0].0, id_hot);
    assert_eq!(b.pending_len(), 2);
    assert_eq!(b.stats().flushes, 1);
    assert_eq!(b.stats().deadline_flushes, 1);
}

#[test]
fn under_deadline_queries_coalesce_in_one_explicit_flush() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    for s in 0..3u64 {
        let src = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 10 + s));
        b.submit_with_deadline(ServeRequest::knn(src, trg.clone(), 3), FAR);
    }
    assert!(b.poll().unwrap().is_empty());
    let out = b.flush().unwrap();
    assert_eq!(out.len(), 3, "explicit flush coalesces everything pending");
    assert_eq!(b.stats().flushes, 1);
    assert_eq!(b.stats().deadline_flushes, 0);
}

#[test]
fn deduped_queries_inherit_the_earliest_deadline() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    let src = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 2));
    // Same query twice: one patient, one already due.  The patient
    // copy inherits the earliest deadline and rides along.
    let id_a = b.submit_with_deadline(ServeRequest::knn(src.clone(), trg.clone(), 3), FAR);
    let id_b = b.submit_with_deadline(ServeRequest::knn(src, trg, 3), Duration::ZERO);
    let out = b.poll().unwrap();
    assert_eq!(out.len(), 2, "duplicate must flush with its expired twin");
    assert_eq!((out[0].0, out[1].0), (id_a, id_b));
    assert_eq!(b.pending_len(), 0);
    assert_eq!(b.stats().dedup_hits, 1);
    assert_eq!(
        out[0].1.as_knn().unwrap().neighbors,
        out[1].1.as_knn().unwrap().neighbors
    );
}

#[test]
fn poll_size_trigger_takes_a_full_batch() {
    let mut b = batcher_with(|c| c.serve.max_batch = 2);
    let trg = Arc::new(synthetic::clustered(200, 3, 4, 0.05, 1));
    for s in 0..3u64 {
        let src = Arc::new(synthetic::clustered(40, 3, 3, 0.05, 10 + s));
        b.submit_with_deadline(ServeRequest::knn(src, trg.clone(), 3), FAR);
    }
    // No deadline expired, but max_batch queries are pending.
    let out = b.poll().unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(b.pending_len(), 1);
    assert_eq!(b.stats().flushes, 1);
    assert_eq!(b.stats().deadline_flushes, 0, "size trigger is not a deadline flush");
}

#[test]
fn default_deadline_from_config_applies_to_submit() {
    let clock = VirtualClock::new();
    let mut b = batcher_with_clock(|c| c.serve.deadline_ms = 5, &clock);
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    let src = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 2));
    b.submit(ServeRequest::knn(src, trg, 3));
    assert!(b.next_deadline().is_some());
    // One tick short of the default deadline: still waiting.
    clock.advance(Duration::from_millis(5) - Duration::from_nanos(1));
    assert!(b.poll().unwrap().is_empty(), "deadline not reached yet");
    // At exactly the deadline the query is due — and met, not missed.
    clock.advance(Duration::from_nanos(1));
    let out = b.poll().unwrap();
    assert_eq!(out.len(), 1, "default deadline expired; poll must flush");
    assert_eq!(b.stats().deadline_flushes, 1);
    assert_eq!(b.stats().deadline_met, 1);
    assert_eq!(b.stats().deadline_misses, 0);
}

#[test]
fn deadline_inheritance_is_deterministic_on_a_virtual_clock() {
    // The dedup-inheritance semantics of `poll`, with the expiry
    // driven by the test instead of a zero deadline: a patient copy of
    // an urgent query rides along the moment the urgent twin expires.
    let clock = VirtualClock::new();
    let mut b = batcher_with_clock(|_| {}, &clock);
    let trg = Arc::new(synthetic::clustered(200, 4, 4, 0.05, 1));
    let src = Arc::new(synthetic::clustered(40, 4, 3, 0.05, 2));
    let urgent_req = ServeRequest::knn(src.clone(), trg.clone(), 3);
    let id_urgent = b.submit_with_deadline(urgent_req, Duration::from_millis(10));
    let id_patient = b.submit_with_deadline(ServeRequest::knn(src, trg, 3), FAR);
    clock.advance(Duration::from_millis(9));
    assert!(b.poll().unwrap().is_empty(), "nothing due at 9ms");
    clock.advance(Duration::from_millis(1));
    let out = b.poll().unwrap();
    assert_eq!(out.len(), 2, "patient duplicate must inherit the expired deadline");
    assert_eq!((out[0].0, out[1].0), (id_urgent, id_patient));
    assert_eq!(b.stats().dedup_hits, 1);
    // Served at exactly its deadline: the urgent query is met; the
    // patient twin (far-future deadline) is met trivially.
    assert_eq!(b.stats().deadline_met, 2);
    assert_eq!(b.stats().deadline_misses, 0);
    // Both latency samples are the full 10 virtual milliseconds.
    assert_eq!(b.stats().latency_ns, vec![10_000_000, 10_000_000]);
}

// --- fingerprint-based identity (no full point scans) ------------------

#[test]
fn deserialized_identical_queries_dedup_without_full_scans() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
    let src = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 2));
    // Arc-distinct but bit-identical request pair, as arrives from two
    // network clients deserializing the same catalogue.
    b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
    b.submit(ServeRequest::knn(deserialized_copy(&src), deserialized_copy(&trg), 5));
    let out = b.flush().unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(b.stats().dedup_hits, 1, "fingerprint identity must dedup across Arcs");
    assert_eq!(
        b.stats().content_full_scans,
        0,
        "dataset identity must resolve by pointer or fingerprint, never a point scan"
    );
    assert_eq!(
        out[0].1.as_knn().unwrap().neighbors,
        out[1].1.as_knn().unwrap().neighbors
    );
    // Both queries answered from ONE execution: all tiles shared.
    assert!(b.stats().tiles_total > 0);
    assert_eq!(b.stats().tiles_shared, b.stats().tiles_total);
}

// --- persistent caches across flushes ----------------------------------

#[test]
fn slab_and_grouping_caches_persist_across_flushes() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
    let src = Arc::new(synthetic::clustered(60, 4, 4, 0.03, 2));
    b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
    b.flush().unwrap();
    let misses_after_first = b.stats().grouping_cache_misses;
    let slab_misses_after_first = b.stats().slab_cache_misses;
    assert!(b.stats().slab_cache_bytes > 0, "slabs must stay resident");
    b.submit(ServeRequest::knn(src, trg, 5));
    b.flush().unwrap();
    // Second flush reuses both groupings and every packed slab.
    assert_eq!(b.stats().grouping_cache_misses, misses_after_first);
    assert!(b.stats().grouping_cache_hits >= 2);
    assert_eq!(b.stats().slab_cache_misses, slab_misses_after_first);
    assert!(b.stats().slab_cache_hits >= 1, "{:?}", b.stats());
    assert!(b.stats().slabs_shared >= 1);
}

// --- facade behaviors (migrated from serve/mod.rs unit tests) -----------

#[test]
fn flush_on_empty_queue_is_a_noop() {
    let mut b = batcher();
    assert!(b.flush().unwrap().is_empty());
    assert_eq!(b.stats().flushes, 0);
}

#[test]
fn responses_come_back_in_submission_order() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(400, 4, 8, 0.03, 1));
    let src_a = Arc::new(synthetic::clustered(60, 4, 4, 0.03, 2));
    let src_b = Arc::new(synthetic::clustered(80, 4, 4, 0.03, 3));
    let ds = Arc::new(synthetic::clustered(200, 5, 6, 0.03, 4));
    let id0 = b.submit(ServeRequest::knn(src_a, trg.clone(), 5));
    let id1 = b.submit(ServeRequest::kmeans(ds, 8, 4));
    let id2 = b.submit(ServeRequest::knn(src_b, trg, 7));
    let out = b.flush().unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].0, id0);
    assert_eq!(out[1].0, id1);
    assert_eq!(out[2].0, id2);
    assert!(out[0].1.as_knn().is_some());
    assert!(out[1].1.as_kmeans().is_some());
    assert_eq!(out[2].1.as_knn().unwrap().k, 7);
    assert_eq!(b.stats().queries, 3);
    assert_eq!(b.stats().knn_queries, 2);
    assert_eq!(b.stats().kmeans_queries, 1);
    // Per-shard stats sum to the merged view.
    let shard_total: u64 = b.shard_stats().iter().map(|s| s.queries).sum();
    assert_eq!(shard_total, 3);
}

#[test]
fn identical_queries_are_deduplicated() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
    let src = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 2));
    for _ in 0..4 {
        b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
    }
    let out = b.flush().unwrap();
    assert_eq!(out.len(), 4);
    assert_eq!(b.stats().dedup_hits, 3);
    let first = out[0].1.as_knn().unwrap();
    for (_, r) in &out[1..] {
        assert_eq!(r.as_knn().unwrap().neighbors, first.neighbors);
    }
    // Dedup makes every dispatched tile serve all four queries.
    assert!(b.stats().tiles_total > 0);
    assert_eq!(b.stats().tiles_shared, b.stats().tiles_total);
}

#[test]
fn max_batch_leaves_overflow_pending() {
    let mut b = batcher_with(|c| c.serve.max_batch = 2);
    let trg = Arc::new(synthetic::clustered(200, 3, 4, 0.05, 1));
    for s in 0..3u64 {
        let src = Arc::new(synthetic::clustered(40, 3, 3, 0.05, 10 + s));
        b.submit(ServeRequest::knn(src, trg.clone(), 3));
    }
    let out = b.flush().unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(b.pending_len(), 1);
    let out2 = b.flush().unwrap();
    assert_eq!(out2.len(), 1);
    assert_eq!(b.pending_len(), 0);
}

#[test]
fn invalid_query_fails_the_flush_without_consuming_the_queue() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 1));
    let src = Arc::new(synthetic::clustered(20, 4, 4, 0.03, 2));
    b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5)); // valid
    b.submit(ServeRequest::knn(src, trg, 51)); // k > target size
    assert!(b.flush().is_err());
    // Nothing was drained or executed: both queries still queued,
    // no flush/query counted.
    assert_eq!(b.pending_len(), 2);
    assert_eq!(b.stats().flushes, 0);
    assert_eq!(b.stats().queries, 0);
    assert_eq!(b.stats().tiles_total, 0);
}

#[test]
fn dedup_requires_matching_dataset_names() {
    let mut b = batcher();
    let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
    let src_a = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 2));
    // Same points, different name: must NOT dedup (report.dataset
    // would otherwise carry the wrong name).
    let mut renamed = (*src_a).clone();
    renamed.name = "renamed-copy".to_string();
    let src_b = Arc::new(renamed);
    b.submit(ServeRequest::knn(src_a, trg.clone(), 5));
    b.submit(ServeRequest::knn(src_b, trg, 5));
    let out = b.flush().unwrap();
    assert_eq!(b.stats().dedup_hits, 0);
    assert_ne!(out[0].1.as_knn().unwrap().report.dataset, "renamed-copy");
    assert_eq!(out[1].1.as_knn().unwrap().report.dataset, "renamed-copy");
    // Results still identical (same points), just attributed right.
    assert_eq!(
        out[0].1.as_knn().unwrap().neighbors,
        out[1].1.as_knn().unwrap().neighbors
    );
}
