//! Property-based tests on coordinator invariants (routing, batching,
//! state), using the in-tree `util::prop` runner.
//!
//! These are the safety arguments of the system as executable checks:
//! GTI candidate sets must always contain every group the exact answer
//! needs, layout schedules must be permutations, padding must be
//! value-neutral, and the pipeline must conserve jobs in FIFO order.

use accd::data::{synthetic, Matrix};
use accd::gti::{bounds, Grouping, KnnFilter, NbodyFilter};
use accd::layout::{self, PackedSet};
use accd::util::prop::{self, Config};
use accd::util::rng::Rng;
use accd::util::topk::topk_smallest;

fn rand_points(rng: &mut Rng, n: usize, d: usize) -> Matrix {
    Matrix::from_vec(prop::gen_points(rng, n, d, 3.0), n, d).unwrap()
}

/// KNN filter safety: for every source point, its true K nearest target
/// points all live inside the candidate target groups of its source
/// group — pruning never removes a true neighbor.
#[test]
fn prop_knn_filter_never_prunes_true_neighbors() {
    prop::check(
        &Config { cases: 16, max_size: 220, seed: 0x4B, ..Default::default() },
        |rng, size| {
            let n_src = 20 + size / 2;
            let n_trg = 40 + size;
            let d = 1 + rng.below(6);
            let k = 1 + rng.below(12);
            let zs = 2 + rng.below(8);
            let zt = 2 + rng.below(10);
            (rand_points(rng, n_src, d), rand_points(rng, n_trg, d), k, zs, zt)
        },
        |(src, trg, k, zs, zt)| {
            let gs = Grouping::build(src, *zs, 2, 4096, 1).map_err(|e| e.to_string())?;
            let gt = Grouping::build(trg, *zt, 2, 4096, 2).map_err(|e| e.to_string())?;
            let mut filter = KnnFilter::new();
            let (cands, _) = filter.candidates(&gs, &gt, *k);
            for i in 0..src.rows() {
                let sg = gs.assign[i] as usize;
                let cand_set: std::collections::HashSet<u32> =
                    cands[sg].iter().copied().collect();
                // True top-k by exhaustive scan.
                let dists: Vec<f32> =
                    (0..trg.rows()).map(|j| src.dist2(i, trg, j)).collect();
                for (dist, j) in topk_smallest(&dists, *k) {
                    let tg = gt.assign[j as usize];
                    if !cand_set.contains(&tg) {
                        return Err(format!(
                            "point {i}: true neighbor {j} (d2={dist}) in pruned group {tg}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// N-body filter safety: every pair of points within radius r lives in
/// a surviving (group, group) pair — even after drift widening.
#[test]
fn prop_nbody_filter_covers_all_interactions() {
    prop::check(
        &Config { cases: 12, max_size: 150, seed: 0xB0D1, ..Default::default() },
        |rng, size| {
            let n = 30 + size;
            let z = 2 + rng.below(12);
            let r = 0.3 + rng.f32() * 0.8;
            (rand_points(rng, n, 3), z, r)
        },
        |(pts, z, r)| {
            let mut grouping = Grouping::build(pts, *z, 2, 4096, 3).map_err(|e| e.to_string())?;
            let mut filter = NbodyFilter::new(&grouping, 0.25);
            // Perturb positions (simulating a step) and re-derive drift.
            let mut moved = pts.clone();
            let mut rng = Rng::new(99);
            for i in 0..moved.rows() {
                for v in moved.row_mut(i) {
                    *v += rng.range_f32(-0.05, 0.05);
                }
            }
            let drifts = grouping.recenter(&moved);
            filter.step(&grouping, &drifts, *r);
            let cands = filter.candidates(&grouping, *r);
            for i in 0..moved.rows() {
                for j in 0..moved.rows() {
                    if moved.dist2(i, &moved, j).sqrt() <= *r {
                        let (ga, gb) =
                            (grouping.assign[i] as usize, grouping.assign[j] as u32);
                        if !cands[ga].contains(&gb) {
                            return Err(format!(
                                "interacting pair ({i},{j}) lost: groups ({ga},{gb})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Eq. 2 + widening soundness on arbitrary geometry: group-pair bounds
/// always contain the true min/max member distances.
#[test]
fn prop_group_bounds_contain_extremes() {
    prop::check(
        &Config { cases: 24, max_size: 120, seed: 0xE92, ..Default::default() },
        |rng, size| {
            let n = 20 + size;
            let d = 1 + rng.below(5);
            let z = 2 + rng.below(6);
            (rand_points(rng, n, d), z)
        },
        |(pts, z)| {
            let g = Grouping::build(pts, *z, 2, 4096, 5).map_err(|e| e.to_string())?;
            let bnds = bounds::group_pair_bounds(&g, &g);
            for (a, ma) in g.members.iter().enumerate() {
                for (b, mb) in g.members.iter().enumerate() {
                    for &i in ma.iter().take(4) {
                        for &j in mb.iter().take(4) {
                            let d = pts.dist2(i as usize, pts, j as usize).sqrt();
                            let bd = bnds[a][b];
                            if d < bd.lb - 1e-3 || d > bd.ub + 1e-3 {
                                return Err(format!(
                                    "pair ({i},{j}) d={d} outside [{}, {}]",
                                    bd.lb, bd.ub
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Packing state: PackedSet is a value-preserving permutation with
/// contiguous group ranges, for any grouping.
#[test]
fn prop_packing_is_value_preserving_permutation() {
    prop::check(
        &Config { cases: 24, max_size: 200, seed: 0xFACC, ..Default::default() },
        |rng, size| {
            let n = 10 + size;
            let d = 1 + rng.below(8);
            let z = 1 + rng.below(16);
            (rand_points(rng, n, d), z)
        },
        |(pts, z)| {
            let g = Grouping::build(pts, *z, 2, 4096, 7).map_err(|e| e.to_string())?;
            let packed = PackedSet::pack(pts, &g, 4);
            let n = pts.rows();
            // Permutation.
            let mut seen = vec![false; n];
            for &old in &packed.new2old {
                if seen[old as usize] {
                    return Err(format!("point {old} packed twice"));
                }
                seen[old as usize] = true;
            }
            // Value preservation + inverse consistency.
            for old in 0..n {
                let new = packed.old2new[old] as usize;
                if packed.points.row(new) != pts.row(old) {
                    return Err(format!("row {old} corrupted by packing"));
                }
            }
            // Contiguous coverage.
            let covered: u32 = packed.group_range.iter().map(|&(_, l)| l).sum();
            if covered as usize != n {
                return Err("group ranges do not cover all points".into());
            }
            Ok(())
        },
    );
}

/// Batching state: feature-axis zero padding never changes distances
/// (checked against scalar math for random shapes).
#[test]
fn prop_zero_padding_distance_neutral() {
    prop::check(
        &Config { cases: 24, max_size: 60, seed: 0x9AD, ..Default::default() },
        |rng, size| {
            let n = 2 + size / 4;
            let d = 1 + rng.below(9);
            let d_pad = d + rng.below(8);
            (rand_points(rng, n, d), d_pad.max(d))
        },
        |(pts, d_pad)| {
            let n = pts.rows();
            let padded = pts.padded(n, *d_pad).map_err(|e| e.to_string())?;
            let pm = Matrix::from_vec(padded, n, *d_pad).map_err(|e| e.to_string())?;
            for i in 0..n.min(8) {
                for j in 0..n.min(8) {
                    let d0 = pts.dist2(i, pts, j);
                    let d1 = pm.dist2(i, &pm, j);
                    if (d0 - d1).abs() > 1e-4 * (1.0 + d0) {
                        return Err(format!("padding changed d2({i},{j}): {d0} -> {d1}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Routing: layout schedule is always a permutation, and grouping
/// identical candidate sets never decreases measured reuse.
#[test]
fn prop_layout_schedule_routing() {
    prop::check(
        &Config { cases: 32, max_size: 60, seed: 0x105, ..Default::default() },
        |rng, size| {
            let zs = 2 + size;
            let zt = 12usize;
            // Draw from a few "templates" so duplicates actually occur.
            let templates: Vec<Vec<u32>> = (0..4)
                .map(|_| {
                    let mut t: Vec<u32> =
                        (0..zt as u32).filter(|_| rng.f32() < 0.4).collect();
                    t.sort_unstable();
                    t
                })
                .collect();
            (0..zs)
                .map(|_| templates[rng.below(templates.len())].clone())
                .collect::<Vec<_>>()
        },
        |cands| {
            let order = layout::schedule_source_groups(cands);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            if sorted != (0..cands.len() as u32).collect::<Vec<_>>() {
                return Err("schedule is not a permutation".into());
            }
            let natural: Vec<u32> = (0..cands.len() as u32).collect();
            let nat = layout::measure_reuse(&natural, cands);
            let sch = layout::measure_reuse(&order, cands);
            if sch.reused < nat.reused {
                return Err(format!(
                    "template-duplicated sets: scheduled reuse {} < natural {}",
                    sch.reused, nat.reused
                ));
            }
            Ok(())
        },
    );
}

/// Grouping state invariants under random recentering cycles (the
/// N-body steady state): membership fixed, radii stay covering.
#[test]
fn prop_grouping_survives_recentering_cycles() {
    prop::check(
        &Config { cases: 10, max_size: 120, seed: 0x6E6, ..Default::default() },
        |rng, size| {
            let n = 30 + size;
            let z = 2 + rng.below(8);
            (rand_points(rng, n, 3), z)
        },
        |(pts, z)| {
            let mut moved = pts.clone();
            let mut g = Grouping::build(pts, *z, 2, 4096, 11).map_err(|e| e.to_string())?;
            let mut rng = Rng::new(13);
            for _cycle in 0..4 {
                for i in 0..moved.rows() {
                    for v in moved.row_mut(i) {
                        *v += rng.range_f32(-0.1, 0.1);
                    }
                }
                let drifts = g.recenter(&moved);
                if drifts.iter().any(|d| !d.is_finite()) {
                    return Err("non-finite drift".into());
                }
                g.check_invariants(&moved)?;
            }
            Ok(())
        },
    );
}

/// Dataset generators produce what the Table V specs promise.
#[test]
fn prop_tablev_specs_generate_exact_shapes() {
    for spec in accd::data::kmeans_datasets().iter().take(2) {
        let small = spec.scaled(0.01);
        let ds = small.generate();
        assert_eq!(ds.n(), small.size);
        assert_eq!(ds.d(), small.dim);
    }
    let _ = synthetic::uniform(10, 2, 1); // module reachable
}
