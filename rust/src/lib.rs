//! # AccD — Accelerating Distance-related algorithms by compiler-based co-Design
//!
//! A reproduction of *"AccD: A Compiler-based Framework for Accelerating
//! Distance-related Algorithms on CPU-FPGA Platforms"* (Wang et al., 2019)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the CPU-side coordinator: the DDSL
//!   compiler, GTI (Generalized Triangle Inequality) filtering engine,
//!   data-layout optimizer, design-space explorer, and the heterogeneous
//!   pipeline that streams surviving distance tiles to the accelerator.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   distance tiles, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the paper's Eq. 4 matrix-decomposed distance computation.
//!
//! The paper's Intel Stratix-10 FPGA is not available in this environment;
//! it is substituted by [`fpga::FpgaDevice`], which couples *functional*
//! execution of the real AOT kernels through PJRT with an *analytical*
//! cycle/power model of the DE10-Pro (paper Eqs. 5-10).  See
//! `DESIGN.md` §Substitutions.
//!
//! ## Quickstart
//!
//! ```no_run
//! use accd::prelude::*;
//!
//! let dataset = accd::data::synthetic::clustered(10_000, 16, 64, 0.05, 42);
//! let cfg = accd::config::AccdConfig::default();
//! let mut engine = accd::coordinator::Engine::new(cfg).unwrap();
//! let result = engine.kmeans(&dataset, 64, 20).unwrap();
//! println!("converged in {} iters", result.iterations);
//! ```

pub mod baselines;
pub mod config;
pub mod figures;
pub mod coordinator;
pub mod data;
pub mod ddsl;
pub mod dse;
pub mod fpga;
pub mod gti;
pub mod layout;
pub mod metrics;
pub mod runtime;
pub mod util;

/// Commonly used types, re-exported for `use accd::prelude::*`.
pub mod prelude {
    pub use crate::config::AccdConfig;
    pub use crate::coordinator::Engine;
    pub use crate::data::{Dataset, Matrix};
    pub use crate::ddsl::compile_program;
    pub use crate::fpga::FpgaDevice;
    pub use crate::gti::Grouping;
    pub use crate::runtime::Runtime;
}

/// Crate-wide error type.
#[derive(thiserror::Error, Debug)]
pub enum Error {
    #[error("xla/pjrt error: {0}")]
    Xla(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("ddsl error: {0}")]
    Ddsl(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("dse error: {0}")]
    Dse(String),
    #[error("data error: {0}")]
    Data(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
