//! # AccD — Accelerating Distance-related algorithms by compiler-based co-Design
//!
//! A reproduction of *"AccD: A Compiler-based Framework for Accelerating
//! Distance-related Algorithms on CPU-FPGA Platforms"* (Wang et al., 2019)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the CPU-side coordinator: the DDSL
//!   compiler, GTI (Generalized Triangle Inequality) filtering engine,
//!   data-layout optimizer, design-space explorer, the heterogeneous
//!   pipeline that streams surviving distance tiles to the accelerator,
//!   and the [`serve`] batched multi-query serving runtime layered on
//!   top of it all.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   distance tiles, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the paper's Eq. 4 matrix-decomposed distance computation.
//!
//! The paper's Intel Stratix-10 FPGA is not available in this environment;
//! it is substituted by [`fpga::FpgaDevice`], which couples *functional*
//! execution of the tile kernels with an *analytical* cycle/power model
//! of the DE10-Pro (paper Eqs. 5-10).  Functional execution uses the
//! in-tree reference backend ([`runtime`]): the offline registry carries
//! no PJRT/XLA native libraries, so the runtime validates tile requests
//! against the artifact manifest (or a built-in manifest mirroring the
//! shipped kernel catalogue) and computes them with bit-deterministic
//! scalar kernels whose semantics are pinned by
//! `rust/tests/runtime_roundtrip.rs`.  See `DESIGN.md` §Substitutions.
//!
//! ## Quickstart
//!
//! ```no_run
//! use accd::prelude::*;
//!
//! let dataset = accd::data::synthetic::clustered(10_000, 16, 64, 0.05, 42);
//! let cfg = accd::config::AccdConfig::default();
//! let mut engine = accd::coordinator::Engine::new(cfg).unwrap();
//! let result = engine.kmeans(&dataset, 64, 20).unwrap();
//! println!("converged in {} iters", result.iterations);
//! ```
//!
//! ## Sharded batched serving (`accd::serve`)
//!
//! One [`coordinator::Engine`] call amortizes GTI grouping *within* a
//! query; [`serve::QueryBatcher`] amortizes it *across* queries and
//! engine shards.  The runtime is layered — `serve::admission` (queue,
//! dedup, deadline/size-triggered flush decisions via a
//! [`serve::FlushPolicy`]), `serve::placement` (a
//! [`serve::ShardPlanner`] balancing cohorts across an
//! [`serve::EnginePool`] by earliest-deadline tier + cost estimate,
//! `serve.placement = "edf-lpt" | "lpt"`) and `serve::exec`
//! (per-shard execution on scoped threads, with per-shard grouping and
//! packed-slab caches that persist across flushes):
//!
//! * compatible KNN queries (same target content + metric) are
//!   coalesced into one cohort sharing a target grouping and packed
//!   target slabs, and their surviving tiles stream through a single
//!   tagged [`coordinator::pipeline`] run with per-query demux;
//! * groupings are memoized in a per-shard [`serve::GroupingCache`]
//!   and target slabs in a per-shard byte-budgeted
//!   [`coordinator::SlabCache`], both keyed by 128-bit dataset
//!   fingerprints and both surviving across flushes;
//! * identical in-flight queries are deduplicated (and inherit the
//!   earliest deadline of their identity class) without ever
//!   re-scanning points;
//! * `submit_with_deadline` + `poll` flush only what is due, so
//!   latency-sensitive queries stop waiting for stragglers — and
//!   every deadline decision reads an injected [`serve::Clock`]
//!   ([`serve::VirtualClock`] in tests: deadline semantics without
//!   sleeps);
//! * a [`metrics::ServeStats`] report exposes queries/sec, the
//!   tiles-shared ratio, cache hit rates, per-query latency
//!   percentiles and deadline met/miss counts, merged and per shard.
//!
//! The contract is strict: batched results are **identical** to running
//! each query alone through [`coordinator::Engine`], for any shard
//! count and flush order (enforced by `rust/tests/serve_parity.rs`).
//!
//! ```no_run
//! use accd::prelude::*;
//! use std::sync::Arc;
//!
//! let cfg = accd::config::AccdConfig::default();
//! let engine = Engine::new(cfg.clone()).unwrap();
//! let mut batcher = accd::serve::QueryBatcher::new(engine, cfg.serve.clone());
//! let trg = Arc::new(accd::data::synthetic::clustered(50_000, 8, 64, 0.03, 1));
//! for user in 0..100u64 {
//!     let src = Arc::new(accd::data::synthetic::clustered(500, 8, 8, 0.03, user));
//!     batcher.submit(accd::serve::ServeRequest::knn(src, trg.clone(), 10));
//! }
//! // One flush serves at most `serve.max_batch` queries; drain the queue.
//! let mut responses = Vec::new();
//! while batcher.pending_len() > 0 {
//!     responses.extend(batcher.flush().unwrap());
//! }
//! println!("{}", batcher.stats().summary());
//! # let _ = responses;
//! ```

pub mod baselines;
pub mod config;
pub mod figures;
pub mod coordinator;
pub mod data;
pub mod ddsl;
pub mod dse;
pub mod fpga;
pub mod gti;
pub mod layout;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod util;

/// Commonly used types, re-exported for `use accd::prelude::*`.
pub mod prelude {
    pub use crate::config::AccdConfig;
    pub use crate::coordinator::Engine;
    pub use crate::data::{Dataset, Matrix};
    pub use crate::ddsl::compile_program;
    pub use crate::fpga::FpgaDevice;
    pub use crate::gti::Grouping;
    pub use crate::runtime::Runtime;
    pub use crate::serve::{QueryBatcher, ServeRequest, ServeResponse};
}

/// Crate-wide error type.
///
/// Hand-implemented `Display`/`Error` (the offline vendored registry
/// carries neither `thiserror` nor its proc-macro closure).
#[derive(Debug)]
pub enum Error {
    Xla(String),
    Artifact(String),
    Ddsl(String),
    Config(String),
    Shape(String),
    Io(std::io::Error),
    Json(String),
    Dse(String),
    Data(String),
    /// Serving-runtime front-end failures: overload shedding, submits
    /// after shutdown, a query failed over from a draining server.
    Serve(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Ddsl(m) => write!(f, "ddsl error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Dse(m) => write!(f, "dse error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Serve(m) => write!(f, "serve error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::Error;

    #[test]
    fn error_messages_keep_their_prefixes() {
        assert_eq!(
            Error::Artifact("missing manifest".into()).to_string(),
            "artifact error: missing manifest"
        );
        assert_eq!(Error::Ddsl("bad token".into()).to_string(), "ddsl error: bad token");
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
        assert!(std::error::Error::source(&io).is_some());
    }
}
