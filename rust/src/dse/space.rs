//! The design space: tunable parameters + their legal ranges (§VI-A).

use crate::config::HwConfig;
use crate::util::rng::Rng;

/// One design point: algorithm-level group counts + hardware-level
/// kernel shape (the paper's parameter list in §VI-A).
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub n_src_grp: usize,
    pub n_trg_grp: usize,
    pub block: usize,
    pub simd: usize,
    pub unroll: usize,
}

impl Config {
    pub fn to_hw(&self, freq_mhz: f64) -> HwConfig {
        HwConfig { block: self.block, simd: self.simd, unroll: self.unroll, freq_mhz }
    }
}

/// Legal ranges for each axis; values are sampled from the given lists
/// (all powers of two for the hardware axes, matching what an OpenCL
/// kernel generator would instantiate).
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub src_grp_choices: Vec<usize>,
    pub trg_grp_choices: Vec<usize>,
    pub block_choices: Vec<usize>,
    pub simd_choices: Vec<usize>,
    pub unroll_choices: Vec<usize>,
}

impl DesignSpace {
    /// Space for a workload of `src_size` x `trg_size` points.
    pub fn for_workload(src_size: usize, trg_size: usize) -> Self {
        let grp = |n: usize| -> Vec<usize> {
            let root = (n as f64).sqrt() as usize;
            [root / 4, root / 2, root, root * 2, root * 4]
                .into_iter()
                .map(|g| g.clamp(1, n.max(1)))
                .collect()
        };
        Self {
            src_grp_choices: grp(src_size),
            trg_grp_choices: grp(trg_size),
            block_choices: vec![16, 32, 64, 128],
            simd_choices: vec![1, 2, 4, 8, 16, 32],
            unroll_choices: vec![1, 2, 4, 8, 16],
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        let pick = |rng: &mut Rng, xs: &[usize]| xs[rng.below(xs.len())];
        Config {
            n_src_grp: pick(rng, &self.src_grp_choices),
            n_trg_grp: pick(rng, &self.trg_grp_choices),
            block: pick(rng, &self.block_choices),
            simd: pick(rng, &self.simd_choices),
            unroll: pick(rng, &self.unroll_choices),
        }
    }

    /// Uniform crossover of two parents.
    pub fn crossover(&self, rng: &mut Rng, a: &Config, b: &Config) -> Config {
        let pick = |rng: &mut Rng, x, y| if rng.f32() < 0.5 { x } else { y };
        Config {
            n_src_grp: pick(rng, a.n_src_grp, b.n_src_grp),
            n_trg_grp: pick(rng, a.n_trg_grp, b.n_trg_grp),
            block: pick(rng, a.block, b.block),
            simd: pick(rng, a.simd, b.simd),
            unroll: pick(rng, a.unroll, b.unroll),
        }
    }

    /// Mutate one axis to a neighboring choice.
    pub fn mutate(&self, rng: &mut Rng, c: &Config) -> Config {
        let mut out = c.clone();
        let step = |rng: &mut Rng, xs: &[usize], cur: usize| -> usize {
            let i = xs.iter().position(|&x| x == cur).unwrap_or(0);
            let j = if rng.f32() < 0.5 { i.saturating_sub(1) } else { (i + 1).min(xs.len() - 1) };
            xs[j]
        };
        match rng.below(5) {
            0 => out.n_src_grp = step(rng, &self.src_grp_choices, c.n_src_grp),
            1 => out.n_trg_grp = step(rng, &self.trg_grp_choices, c.n_trg_grp),
            2 => out.block = step(rng, &self.block_choices, c.block),
            3 => out.simd = step(rng, &self.simd_choices, c.simd),
            _ => out.unroll = step(rng, &self.unroll_choices, c.unroll),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_space() {
        let space = DesignSpace::for_workload(100_000, 1_000);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let c = space.sample(&mut rng);
            assert!(space.src_grp_choices.contains(&c.n_src_grp));
            assert!(space.block_choices.contains(&c.block));
        }
    }

    #[test]
    fn crossover_inherits_from_parents() {
        let space = DesignSpace::for_workload(10_000, 500);
        let mut rng = Rng::new(2);
        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        let c = space.crossover(&mut rng, &a, &b);
        assert!(c.simd == a.simd || c.simd == b.simd);
        assert!(c.block == a.block || c.block == b.block);
    }

    #[test]
    fn mutation_changes_at_most_one_axis() {
        let space = DesignSpace::for_workload(10_000, 500);
        let mut rng = Rng::new(3);
        let c = space.sample(&mut rng);
        let m = space.mutate(&mut rng, &c);
        let diffs = [
            c.n_src_grp != m.n_src_grp,
            c.n_trg_grp != m.n_trg_grp,
            c.block != m.block,
            c.simd != m.simd,
            c.unroll != m.unroll,
        ]
        .iter()
        .filter(|&&x| x)
        .count();
        assert!(diffs <= 1);
    }

    #[test]
    fn tiny_workload_groups_clamped() {
        let space = DesignSpace::for_workload(4, 4);
        assert!(space.src_grp_choices.iter().all(|&g| (1..=4).contains(&g)));
    }
}
