//! The genetic-algorithm explorer loop (paper Fig. 7).

use crate::fpga::cost::{CostModel, DmaModel, WorkloadModel};
use crate::fpga::resource::{ResourceModel, StratixBudget};
use crate::util::rng::Rng;
use crate::{Error, Result};

use super::space::{Config, DesignSpace};

/// Result of one exploration run.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    pub best: Config,
    /// Modeled end-to-end latency of the best point (seconds).
    pub best_latency: f64,
    pub generations: usize,
    /// Configurations evaluated / discarded by Eq. 10.
    pub evaluated: usize,
    pub infeasible: usize,
    /// Best latency per generation (for convergence plots).
    pub history: Vec<f64>,
}

/// Explorer parameters.
#[derive(Debug, Clone)]
pub struct Explorer {
    pub population: usize,
    pub survivors: usize,
    pub mutation_rate: f32,
    pub max_generations: usize,
    /// Relative improvement threshold that terminates the search
    /// (the paper's "modeling results difference ... lower than a
    /// predefined threshold").
    pub threshold: f64,
    pub budget: StratixBudget,
    pub resource_model: ResourceModel,
    /// Physical block instances the board can host concurrently.
    pub max_parallel_blocks: usize,
    pub freq_mhz: f64,
    pub seed: u64,
}

impl Default for Explorer {
    fn default() -> Self {
        Self {
            population: 32,
            survivors: 8,
            mutation_rate: 0.3,
            max_generations: 40,
            threshold: 1e-3,
            budget: StratixBudget::default(),
            resource_model: ResourceModel::default(),
            max_parallel_blocks: 8,
            freq_mhz: 250.0,
            seed: 0xD5E,
        }
    }
}

/// A workload description for the explorer (what the paper feeds the
/// analytical model with).
#[derive(Debug, Clone)]
pub struct Workload {
    pub src_size: usize,
    pub trg_size: usize,
    pub d: usize,
    pub n_iteration: usize,
    /// Point-density alpha for the Eq. 7 saving estimate.
    pub alpha: f64,
}

/// One point of the serving-oriented devices × DMA-bandwidth
/// frontier: the modeled multi-device Eq. 5 latency (and its
/// reciprocal throughput) of one design replicated over `devices`
/// emulated devices on a `dma_gbps` link.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    pub devices: usize,
    pub dma_gbps: f64,
    /// Modeled end-to-end latency, seconds ([`CostModel::latency_multi_device`]).
    pub latency_secs: f64,
    /// Modeled workloads per second (`1 / latency_secs`).
    pub throughput: f64,
}

impl Explorer {
    /// The Eq. 5–7 workload model of `w` under configuration `c` (the
    /// surviving ratio already folded in via Eq. 7).
    fn workload_model(&self, w: &Workload, c: &Config) -> WorkloadModel {
        let mut wm = WorkloadModel {
            src_size: w.src_size,
            trg_size: w.trg_size,
            d: w.d,
            n_src_grp: c.n_src_grp,
            n_trg_grp: c.n_trg_grp,
            n_iteration: w.n_iteration,
            ratio_surviving: 1.0,
            dtype_bytes: 4,
        };
        wm.ratio_surviving = wm.eq7_surviving_ratio(w.alpha);
        wm
    }

    /// Sweep device count × DMA link speed through
    /// [`CostModel::latency_multi_device`] for one design point — the
    /// serving-dimension counterpart of the tile-shape search, ranking
    /// `serve.devices` / `serve.dma_gbps` settings the same analytical
    /// way the GA ranks tile shapes.  Rows come out in sweep order
    /// (devices-major), deterministically.
    pub fn device_frontier(
        &self,
        w: &Workload,
        c: &Config,
        devices: &[usize],
        dma_gbps: &[f64],
    ) -> Vec<FrontierPoint> {
        let cost = CostModel::new(c.to_hw(self.freq_mhz));
        let wm = self.workload_model(w, c);
        let mut out = Vec::with_capacity(devices.len() * dma_gbps.len());
        for &n in devices {
            for &gbps in dma_gbps {
                let dma = DmaModel::new(gbps);
                let latency_secs = cost.latency_multi_device(&wm, &dma, n).total();
                let throughput =
                    if latency_secs > 0.0 { 1.0 / latency_secs } else { f64::INFINITY };
                out.push(FrontierPoint { devices: n, dma_gbps: gbps, latency_secs, throughput });
            }
        }
        out
    }

    /// Modeled fitness (latency; lower = better) of one configuration,
    /// or None if it violates Eq. 10.
    pub fn evaluate(&self, w: &Workload, c: &Config) -> Option<f64> {
        let hw = c.to_hw(self.freq_mhz);
        let cost = CostModel::new(hw.clone());
        let wm = self.workload_model(w, c);
        let lat = cost.latency(&wm);
        let total = lat.total();
        let bw = cost.bandwidth(&wm, total);
        let est = self.resource_model.estimate(
            &hw,
            w.d,
            w.src_size,
            w.trg_size,
            self.max_parallel_blocks,
            bw,
        );
        if est.fits(&self.budget) {
            Some(total)
        } else {
            None
        }
    }

    /// Run the Fig. 7 loop.
    pub fn explore(&self, w: &Workload) -> Result<ExploreOutcome> {
        let space = DesignSpace::for_workload(w.src_size, w.trg_size);
        let mut rng = Rng::new(self.seed);
        // Phase 1 (first round): random seed population.
        let mut population: Vec<Config> =
            (0..self.population).map(|_| space.sample(&mut rng)).collect();
        let mut evaluated = 0usize;
        let mut infeasible = 0usize;
        let mut history: Vec<f64> = Vec::new();
        let mut best: Option<(Config, f64)> = None;

        for gen in 0..self.max_generations {
            // Phase 2 + 3: model + validate.
            let mut scored: Vec<(Config, f64)> = Vec::new();
            for c in &population {
                evaluated += 1;
                match self.evaluate(w, c) {
                    Some(lat) => scored.push((c.clone(), lat)),
                    None => infeasible += 1,
                }
            }
            if scored.is_empty() {
                // Whole generation infeasible: reseed.
                population = (0..self.population).map(|_| space.sample(&mut rng)).collect();
                continue;
            }
            scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let gen_best = scored[0].clone();
            let improved = match &best {
                None => true,
                Some((_, b)) => gen_best.1 < *b * (1.0 - self.threshold),
            };
            if best.is_none() || gen_best.1 < best.as_ref().unwrap().1 {
                best = Some(gen_best.clone());
            }
            history.push(best.as_ref().unwrap().1);
            if gen > 0 && !improved {
                // Converged: consecutive generations within threshold.
                return Ok(ExploreOutcome {
                    best: best.as_ref().unwrap().0.clone(),
                    best_latency: best.as_ref().unwrap().1,
                    generations: gen + 1,
                    evaluated,
                    infeasible,
                    history,
                });
            }
            // Phase 1 (later rounds): crossover + mutate the premium set.
            let elite: Vec<Config> =
                scored.iter().take(self.survivors).map(|(c, _)| c.clone()).collect();
            let mut next = elite.clone();
            while next.len() < self.population {
                let a = &elite[rng.below(elite.len())];
                let b = &elite[rng.below(elite.len())];
                let mut child = space.crossover(&mut rng, a, b);
                if rng.f32() < self.mutation_rate {
                    child = space.mutate(&mut rng, &child);
                }
                next.push(child);
            }
            population = next;
        }
        let (cfg, lat) = best.ok_or_else(|| {
            Error::Dse("no feasible configuration found in the design space".into())
        })?;
        Ok(ExploreOutcome {
            best: cfg,
            best_latency: lat,
            generations: self.max_generations,
            evaluated,
            infeasible,
            history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> Workload {
        Workload { src_size: 70_000, trg_size: 265, d: 60, n_iteration: 3, alpha: 10.0 }
    }

    #[test]
    fn explorer_finds_feasible_design() {
        let out = Explorer::default().explore(&workload()).unwrap();
        assert!(out.best_latency.is_finite() && out.best_latency > 0.0);
        assert!(out.evaluated > 0);
        // The winner must itself validate.
        assert!(Explorer::default().evaluate(&workload(), &out.best).is_some());
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let out = Explorer::default().explore(&workload()).unwrap();
        for w in out.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = Explorer::default().explore(&workload()).unwrap();
        let b = Explorer::default().explore(&workload()).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.generations, b.generations);
    }

    #[test]
    fn infeasible_configs_are_rejected() {
        let ex = Explorer::default();
        let monster = Config { n_src_grp: 10, n_trg_grp: 10, block: 128, simd: 32, unroll: 16 };
        // 512 lanes => 512 DSPs per block x 8 instances >> 648 budget.
        assert!(ex.evaluate(&workload(), &monster).is_none());
    }

    #[test]
    fn tight_budget_still_converges_or_errors_cleanly() {
        let mut ex = Explorer::default();
        ex.budget.dsps = 4.0; // almost nothing fits
        match ex.explore(&workload()) {
            Ok(out) => {
                // Whatever survived must fit the tiny budget.
                assert!(ex.evaluate(&workload(), &out.best).is_some());
            }
            Err(e) => assert!(e.to_string().contains("no feasible")),
        }
    }

    #[test]
    fn device_frontier_ranks_devices_and_links_sanely() {
        let ex = Explorer::default();
        let c = Config { n_src_grp: 130, n_trg_grp: 8, block: 64, simd: 4, unroll: 4 };
        let pts = ex.device_frontier(&workload(), &c, &[1, 2, 4], &[4.0, 16.0]);
        assert_eq!(pts.len(), 6, "devices-major sweep order, all points present");
        // More devices at the same link never models slower; strictly
        // faster here (comp and xfer both shrink).
        let at = |n: usize, g: f64| {
            pts.iter().find(|p| p.devices == n && p.dma_gbps == g).unwrap().latency_secs
        };
        assert!(at(2, 16.0) < at(1, 16.0));
        assert!(at(4, 16.0) < at(2, 16.0));
        // A faster link at the same device count never models slower.
        assert!(at(2, 16.0) <= at(2, 4.0));
        // Throughput is the reciprocal and the rows are deterministic.
        for p in &pts {
            assert!((p.throughput - 1.0 / p.latency_secs).abs() < 1e-9);
        }
        let again = ex.device_frontier(&workload(), &c, &[1, 2, 4], &[4.0, 16.0]);
        for (a, b) in pts.iter().zip(&again) {
            assert_eq!(a.latency_secs.to_bits(), b.latency_secs.to_bits());
        }
    }

    #[test]
    fn better_hardware_beats_worse_hardware_in_model() {
        let ex = Explorer::default();
        // Both fit the DSP budget (lanes x 8 instances <= 648 DSPs).
        let small = Config { n_src_grp: 130, n_trg_grp: 8, block: 64, simd: 2, unroll: 2 };
        let large = Config { n_src_grp: 130, n_trg_grp: 8, block: 64, simd: 8, unroll: 8 };
        let (ls, ll) = (
            ex.evaluate(&workload(), &small).unwrap(),
            ex.evaluate(&workload(), &large).unwrap(),
        );
        assert!(ll < ls, "more lanes should model faster: {ll} vs {ls}");
    }
}
