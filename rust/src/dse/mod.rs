//! AccD Explorer: design-space exploration — paper §VI-B, Fig. 7.
//!
//! Three phases per iteration, exactly the paper's loop:
//!
//! 1. **Configuration generation & selection** — first round seeds
//!    random configurations; later rounds apply genetic crossover +
//!    mutation over the surviving "premium" configurations.
//! 2. **Performance & resource modeling** — Eqs. 5-8 latency model and
//!    Eq. 9 resource scaling ([`crate::fpga::cost`] /
//!    [`crate::fpga::resource`]).
//! 3. **Constraints validation** — Eq. 10 budget check; infeasible
//!    configurations are discarded, survivors are ranked by modeled
//!    latency.
//!
//! Termination: best-fitness improvement below `threshold` between
//! consecutive generations, or `max_generations`.

pub mod explorer;
pub mod space;

pub use explorer::{ExploreOutcome, Explorer, FrontierPoint, Workload};
pub use space::{Config as DesignConfig, DesignSpace};
