//! Configuration system: every tunable of the engine in one place.
//!
//! `AccdConfig` is the root; it nests the algorithmic (GTI), hardware
//! (FPGA model) and explorer configs.  Configs load from JSON files
//! (`--config path.json` on the CLI), with field-level overrides from
//! CLI options, and serialize back to JSON for provenance in result
//! files.

use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Algorithm-level (GTI) parameters — paper §IV & §VI-A.
#[derive(Debug, Clone, PartialEq)]
pub struct GtiConfig {
    /// Number of source-point groups (0 = auto: ~sqrt(n)).
    pub src_groups: usize,
    /// Number of target-point groups (0 = auto).
    pub trg_groups: usize,
    /// Grouping refinement iterations (paper's n_iteration).
    pub grouping_iters: usize,
    /// Sample size for grouping (grouping runs on a sample, then
    /// assigns all points — keeps filter cost sublinear).
    pub grouping_sample: usize,
}

impl Default for GtiConfig {
    fn default() -> Self {
        Self { src_groups: 0, trg_groups: 0, grouping_iters: 3, grouping_sample: 4096 }
    }
}

/// Hardware-level kernel parameters — paper §VI-A.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    /// Computation block edge (paper `blk`).
    pub block: usize,
    /// SIMD workers per block (paper `simd`).
    pub simd: usize,
    /// Per-distance unroll factor (paper `unroll`).
    pub unroll: usize,
    /// Design clock in MHz (paper `frequency`).
    pub freq_mhz: f64,
}

impl Default for HwConfig {
    fn default() -> Self {
        Self { block: 64, simd: 16, unroll: 8, freq_mhz: 250.0 }
    }
}

/// Placement policy of the serving runtime's `ShardPlanner`
/// (`serve.placement`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// Pure longest-processing-time-first cost balancing: minimizes
    /// makespan, ignores deadlines.
    Lpt,
    /// Earliest-deadline-first tiers, LPT within each tier: urgent
    /// units are assigned (and so claimed) first, landing on the
    /// lightest shards; deadline-free units sort last.  Degenerates to
    /// pure LPT when no unit carries a deadline.
    EdfLpt,
    /// Calibrated tail-bounding placement: units are converted to
    /// predicted nanoseconds through the `serve::calibrate` layer and
    /// greedily assigned to the shard that keeps every predicted
    /// finish time inside its deadline — minimizing the predicted
    /// per-shard tail rather than abstract-cost makespan.  Falls back
    /// to EDF-LPT behaviour while the calibrator is still cold (seed
    /// rates only).
    PredictedP99,
}

impl PlacementMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lpt" => Ok(Self::Lpt),
            "edf-lpt" => Ok(Self::EdfLpt),
            "predicted-p99" => Ok(Self::PredictedP99),
            other => Err(Error::Config(format!(
                "serve.placement must be \"lpt\", \"edf-lpt\" or \"predicted-p99\", \
                 got \"{other}\""
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Lpt => "lpt",
            Self::EdfLpt => "edf-lpt",
            Self::PredictedP99 => "predicted-p99",
        }
    }
}

/// Overload policy of the always-on server's bounded intake queue
/// (`serve.overload`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Backpressure: `Server::submit` blocks the producer until the
    /// queue has room (or the server shuts down).
    Block,
    /// Shedding: `Server::submit` fails fast with an overload error;
    /// the rejection is counted in `ServeStats::shed`.
    Reject,
}

impl OverloadPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "block" => Ok(Self::Block),
            "reject" => Ok(Self::Reject),
            other => Err(Error::Config(format!(
                "serve.overload must be \"block\" or \"reject\", got \"{other}\""
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Block => "block",
            Self::Reject => "reject",
        }
    }
}

/// K-means program parameters (`coordinator::kmeans`).
#[derive(Debug, Clone, PartialEq)]
pub struct KmeansConfig {
    /// Incremental cross-iteration triangle-inequality pruning
    /// (Elkan/Hamerly-style): carry per-point upper/lower bounds and
    /// group-pair lower bounds across `step()` calls, widen them O(1)
    /// per step by per-center drift, and skip device work for points
    /// (and whole tiles) whose assignment is provably stable.
    /// `false` restores the per-iteration bound recomputation of the
    /// pre-incremental engine (the A/B lever for the bench).
    pub incremental_ti: bool,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        Self { incremental_ti: true }
    }
}

/// Serving-runtime parameters (`accd::serve`) — the batched multi-query
/// layer on top of the engine.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Maximum queries coalesced per flush (0 = unbounded).  A flush
    /// processes at most this many pending queries; the rest stay
    /// queued for the next flush.
    pub max_batch: usize,
    /// LRU capacity (entries) of each shard's grouping cache.
    pub grouping_cache_cap: usize,
    /// Bounded-queue depth of the merged device pipeline.
    pub pipeline_depth: usize,
    /// Deduplicate identical in-flight queries within a flush.
    pub dedup: bool,
    /// Engine shards in the execution pool.  Cohorts are partitioned
    /// across shards by cost estimate and run concurrently; results
    /// are bit-identical for any shard count (serve parity contract).
    pub shards: usize,
    /// Default admission deadline in milliseconds applied by
    /// `QueryBatcher::submit` (0 = none: such queries flush only via
    /// an explicit `flush()` or the max_batch size trigger).
    /// `submit_with_deadline` overrides this per query.
    pub deadline_ms: u64,
    /// Byte budget of each shard's cross-flush packed-slab cache.
    /// **0 = disabled**: every slab is built fresh and nothing is
    /// retained (results are unchanged; only the reuse disappears).
    /// Hot cohorts' packed slabs otherwise stay resident across
    /// flushes until LRU-evicted over this budget.
    pub slab_cache_bytes: usize,
    /// Lockstep step scheduling: each shard advances all its resident
    /// iterative programs one iteration per round (sharing cached
    /// groupings and packed slabs across same-dataset programs)
    /// instead of running each work unit to completion serially.
    /// Results are bit-identical either way (serve parity contract).
    pub lockstep: bool,
    /// Work stealing: minimum cost estimate a not-yet-started work
    /// unit must have for an idle shard to steal it from a busy one
    /// when the LPT placement's estimates misfire.  **0 disables
    /// stealing**; 1 (the default) steals anything available.
    pub steal_threshold: u64,
    /// Shard-placement policy: `"lpt"` (pure cost balancing) or
    /// `"edf-lpt"` (the default: earliest-deadline-first tiers, LPT
    /// within each tier — urgent cohorts land on lightly-loaded shards
    /// and are claimed first).  Results are bit-identical either way
    /// (serve parity contract); only latency changes.
    pub placement: String,
    /// Bound on the always-on server's accepted-but-unanswered queries
    /// (intake backlog + admitted pending).  **0 = unbounded** (no
    /// backpressure, nothing shed).  Caller-driven `QueryBatcher` use
    /// ignores it.
    pub queue_cap: usize,
    /// What `Server::submit` does when `queue_cap` is reached:
    /// `"block"` (the default: backpressure the producer) or
    /// `"reject"` (fail fast; counted in `ServeStats::shed`).
    pub overload: String,
    /// Emulated devices in the pool.  Shards are pinned round-robin
    /// (`shard % devices`); each device carries its own memory budget
    /// and modeled DMA link.  Must be ≥ 1.  Compute is still executed
    /// by the shared reference runtime — the devices model *where data
    /// lives and what moving it costs*, so results are bit-identical
    /// for any device count (serve parity contract).
    pub devices: usize,
    /// Modeled memory per emulated device in bytes.  **0 = unlimited**:
    /// per-shard slab budgets fall back to `slab_cache_bytes` alone.
    /// Otherwise each shard's slab budget is clamped to its share of
    /// its device's memory (device memory / shards pinned to it).
    pub device_mem_bytes: usize,
    /// Modeled DMA link rate per device, decimal GB/s.  Feeds the
    /// movement term of placement/stealing and the transfer half of
    /// the double-buffered overlap accounting.  Must be > 0.
    pub dma_gbps: f64,
    /// Double-buffered transfer/compute overlap in the shard exec
    /// loop: with it on (default), a shard's modeled slab uploads
    /// proceed on a second DMA channel while resident programs
    /// compute (ping-pong buffers); off, transfer and compute
    /// serialize on one timeline.  Pure accounting — results are
    /// bit-identical; only `transfer_ns`/`compute_ns`/`overlap_ns`
    /// change.
    pub overlap: bool,
    /// Data-movement-aware placement and stealing: charge each
    /// (unit, shard) pair the modeled DMA cost of the unit's cold
    /// slab bytes, so units land where their slabs are already warm
    /// and an idle thief prefers a warm unit over a slightly bigger
    /// cold one.  `false` restores movement-blind cost balancing (the
    /// A/B lever for the bench).  Results are bit-identical either
    /// way (serve parity contract); only placement changes.
    pub movement_aware: bool,
    /// Predictive early deadline shedding: at flush selection, a query
    /// whose calibrated predicted completion already overshoots its
    /// (already-expired) deadline is shed instead of executed, counted
    /// in `ServeStats::predicted_sheds` (distinct from the server's
    /// overload `shed`).  Shedding is strictly order-only: only
    /// queries the reactive path would have *missed* anyway are ever
    /// shed, so every served result stays bit-identical to solo runs.
    /// Defaults off.
    pub predictive_shed: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            grouping_cache_cap: 32,
            pipeline_depth: 8,
            dedup: true,
            shards: 2,
            deadline_ms: 0,
            slab_cache_bytes: 64 << 20,
            lockstep: true,
            steal_threshold: 1,
            placement: "edf-lpt".to_string(),
            queue_cap: 1024,
            overload: "block".to_string(),
            devices: 1,
            device_mem_bytes: 0,
            dma_gbps: 16.0,
            overlap: true,
            movement_aware: true,
            predictive_shed: false,
        }
    }
}

impl ServeConfig {
    /// Validate the serving knobs.  Called by `AccdConfig::validate`
    /// and by `QueryBatcher` construction, so an invalid config can
    /// never reach the serving runtime.  Note the explicit zero
    /// semantics: `max_batch == 0` means unbounded batches,
    /// `slab_cache_bytes == 0` means the slab cache is *disabled* (not
    /// unbounded), `steal_threshold == 0` disables work stealing —
    /// `queue_cap == 0` means the server intake is unbounded; `shards`,
    /// `pipeline_depth` and `grouping_cache_cap` must be positive, and
    /// `placement` / `overload` must name known policies.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::Config("serve.shards must be positive".into()));
        }
        if self.pipeline_depth == 0 {
            return Err(Error::Config("serve.pipeline_depth must be positive".into()));
        }
        if self.grouping_cache_cap == 0 {
            return Err(Error::Config("serve.grouping_cache_cap must be positive".into()));
        }
        if self.devices == 0 {
            return Err(Error::Config("serve.devices must be positive".into()));
        }
        if !self.dma_gbps.is_finite() || self.dma_gbps <= 0.0 {
            return Err(Error::Config("serve.dma_gbps must be positive".into()));
        }
        self.placement_mode()?;
        self.overload_policy()?;
        Ok(())
    }

    /// The parsed `placement` policy.  Errs on an unknown name —
    /// `validate()` (run at `QueryBatcher` construction) guarantees
    /// the serving runtime itself never sees the error path.
    pub fn placement_mode(&self) -> Result<PlacementMode> {
        PlacementMode::parse(&self.placement)
    }

    /// The parsed `overload` policy.  Errs on an unknown name —
    /// `validate()` (run at `Server` construction) guarantees the
    /// server loop itself never sees the error path.
    pub fn overload_policy(&self) -> Result<OverloadPolicy> {
        OverloadPolicy::parse(&self.overload)
    }
}

/// Root configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AccdConfig {
    pub gti: GtiConfig,
    pub hw: HwConfig,
    /// K-means program knobs (`coordinator::kmeans`).
    pub kmeans: KmeansConfig,
    /// Serving-runtime knobs (`accd::serve`).
    pub serve: ServeConfig,
    /// Artifact directory (default "artifacts").
    pub artifact_dir: String,
    /// Use the accelerator (false = CPU-only AccD, Fig. 10's third bar).
    pub use_fpga: bool,
    /// Global seed for all stochastic components.
    pub seed: u64,
}

impl AccdConfig {
    pub fn new() -> Self {
        Self {
            gti: GtiConfig::default(),
            hw: HwConfig::default(),
            kmeans: KmeansConfig::default(),
            serve: ServeConfig::default(),
            artifact_dir: "artifacts".to_string(),
            use_fpga: true,
            seed: 42,
        }
    }

    /// Parse from a JSON document; missing fields keep defaults.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut cfg = Self::new();
        let g = v.get("gti");
        if !matches!(g, Value::Null) {
            cfg.gti.src_groups = g.get("src_groups").as_usize().unwrap_or(cfg.gti.src_groups);
            cfg.gti.trg_groups = g.get("trg_groups").as_usize().unwrap_or(cfg.gti.trg_groups);
            cfg.gti.grouping_iters =
                g.get("grouping_iters").as_usize().unwrap_or(cfg.gti.grouping_iters);
            cfg.gti.grouping_sample =
                g.get("grouping_sample").as_usize().unwrap_or(cfg.gti.grouping_sample);
        }
        let h = v.get("hw");
        if !matches!(h, Value::Null) {
            cfg.hw.block = h.get("block").as_usize().unwrap_or(cfg.hw.block);
            cfg.hw.simd = h.get("simd").as_usize().unwrap_or(cfg.hw.simd);
            cfg.hw.unroll = h.get("unroll").as_usize().unwrap_or(cfg.hw.unroll);
            cfg.hw.freq_mhz = h.get("freq_mhz").as_f64().unwrap_or(cfg.hw.freq_mhz);
        }
        let k = v.get("kmeans");
        if !matches!(k, Value::Null) {
            if let Some(b) = k.get("incremental_ti").as_bool() {
                cfg.kmeans.incremental_ti = b;
            }
        }
        let s = v.get("serve");
        if !matches!(s, Value::Null) {
            cfg.serve.max_batch = s.get("max_batch").as_usize().unwrap_or(cfg.serve.max_batch);
            cfg.serve.grouping_cache_cap = s
                .get("grouping_cache_cap")
                .as_usize()
                .unwrap_or(cfg.serve.grouping_cache_cap);
            cfg.serve.pipeline_depth =
                s.get("pipeline_depth").as_usize().unwrap_or(cfg.serve.pipeline_depth);
            if let Some(b) = s.get("dedup").as_bool() {
                cfg.serve.dedup = b;
            }
            cfg.serve.shards = s.get("shards").as_usize().unwrap_or(cfg.serve.shards);
            cfg.serve.deadline_ms =
                s.get("deadline_ms").as_usize().map(|v| v as u64).unwrap_or(cfg.serve.deadline_ms);
            cfg.serve.slab_cache_bytes =
                s.get("slab_cache_bytes").as_usize().unwrap_or(cfg.serve.slab_cache_bytes);
            if let Some(b) = s.get("lockstep").as_bool() {
                cfg.serve.lockstep = b;
            }
            cfg.serve.steal_threshold = s
                .get("steal_threshold")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(cfg.serve.steal_threshold);
            if let Some(p) = s.get("placement").as_str() {
                cfg.serve.placement = p.to_string();
            }
            cfg.serve.queue_cap = s.get("queue_cap").as_usize().unwrap_or(cfg.serve.queue_cap);
            if let Some(p) = s.get("overload").as_str() {
                cfg.serve.overload = p.to_string();
            }
            cfg.serve.devices = s.get("devices").as_usize().unwrap_or(cfg.serve.devices);
            cfg.serve.device_mem_bytes =
                s.get("device_mem_bytes").as_usize().unwrap_or(cfg.serve.device_mem_bytes);
            cfg.serve.dma_gbps = s.get("dma_gbps").as_f64().unwrap_or(cfg.serve.dma_gbps);
            if let Some(b) = s.get("overlap").as_bool() {
                cfg.serve.overlap = b;
            }
            if let Some(b) = s.get("movement_aware").as_bool() {
                cfg.serve.movement_aware = b;
            }
            if let Some(b) = s.get("predictive_shed").as_bool() {
                cfg.serve.predictive_shed = b;
            }
        }
        if let Some(s) = v.get("artifact_dir").as_str() {
            cfg.artifact_dir = s.to_string();
        }
        if let Some(b) = v.get("use_fpga").as_bool() {
            cfg.use_fpga = b;
        }
        if let Some(s) = v.get("seed").as_usize() {
            cfg.seed = s as u64;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn validate(&self) -> Result<()> {
        if self.hw.block == 0 || !self.hw.block.is_power_of_two() {
            return Err(Error::Config(format!(
                "hw.block must be a power of two, got {}",
                self.hw.block
            )));
        }
        if self.hw.simd == 0 || self.hw.unroll == 0 {
            return Err(Error::Config("hw.simd and hw.unroll must be positive".into()));
        }
        if self.hw.freq_mhz <= 0.0 {
            return Err(Error::Config("hw.freq_mhz must be positive".into()));
        }
        self.serve.validate()?;
        Ok(())
    }

    /// Serialize for provenance in result files.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            (
                "gti",
                json::obj(vec![
                    ("src_groups", json::num(self.gti.src_groups as f64)),
                    ("trg_groups", json::num(self.gti.trg_groups as f64)),
                    ("grouping_iters", json::num(self.gti.grouping_iters as f64)),
                    ("grouping_sample", json::num(self.gti.grouping_sample as f64)),
                ]),
            ),
            (
                "hw",
                json::obj(vec![
                    ("block", json::num(self.hw.block as f64)),
                    ("simd", json::num(self.hw.simd as f64)),
                    ("unroll", json::num(self.hw.unroll as f64)),
                    ("freq_mhz", json::num(self.hw.freq_mhz)),
                ]),
            ),
            (
                "kmeans",
                json::obj(vec![("incremental_ti", Value::Bool(self.kmeans.incremental_ti))]),
            ),
            (
                "serve",
                json::obj(vec![
                    ("max_batch", json::num(self.serve.max_batch as f64)),
                    ("grouping_cache_cap", json::num(self.serve.grouping_cache_cap as f64)),
                    ("pipeline_depth", json::num(self.serve.pipeline_depth as f64)),
                    ("dedup", Value::Bool(self.serve.dedup)),
                    ("shards", json::num(self.serve.shards as f64)),
                    ("deadline_ms", json::num(self.serve.deadline_ms as f64)),
                    ("slab_cache_bytes", json::num(self.serve.slab_cache_bytes as f64)),
                    ("lockstep", Value::Bool(self.serve.lockstep)),
                    ("steal_threshold", json::num(self.serve.steal_threshold as f64)),
                    ("placement", json::s(self.serve.placement.clone())),
                    ("queue_cap", json::num(self.serve.queue_cap as f64)),
                    ("overload", json::s(self.serve.overload.clone())),
                    ("devices", json::num(self.serve.devices as f64)),
                    ("device_mem_bytes", json::num(self.serve.device_mem_bytes as f64)),
                    ("dma_gbps", json::num(self.serve.dma_gbps)),
                    ("overlap", Value::Bool(self.serve.overlap)),
                    ("movement_aware", Value::Bool(self.serve.movement_aware)),
                    ("predictive_shed", Value::Bool(self.serve.predictive_shed)),
                ]),
            ),
            ("artifact_dir", json::s(self.artifact_dir.clone())),
            ("use_fpga", Value::Bool(self.use_fpga)),
            ("seed", json::num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        AccdConfig::new().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = AccdConfig::new();
        cfg.hw.block = 32;
        cfg.gti.src_groups = 99;
        cfg.use_fpga = false;
        cfg.serve.max_batch = 7;
        cfg.serve.grouping_cache_cap = 3;
        cfg.serve.pipeline_depth = 2;
        cfg.serve.dedup = false;
        cfg.serve.shards = 4;
        cfg.serve.deadline_ms = 15;
        cfg.serve.slab_cache_bytes = 1 << 20;
        cfg.serve.lockstep = false;
        cfg.serve.steal_threshold = 9000;
        cfg.serve.placement = "lpt".to_string();
        cfg.serve.queue_cap = 37;
        cfg.serve.overload = "reject".to_string();
        cfg.serve.devices = 4;
        cfg.serve.device_mem_bytes = 8 << 20;
        cfg.serve.dma_gbps = 3.5;
        cfg.serve.overlap = false;
        cfg.serve.movement_aware = false;
        cfg.serve.predictive_shed = true;
        cfg.kmeans.incremental_ti = false;
        let re = AccdConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, re);
    }

    #[test]
    fn device_knobs_default_validated_and_parse() {
        let d = ServeConfig::default();
        assert_eq!(d.devices, 1, "one emulated device by default");
        assert_eq!(d.device_mem_bytes, 0, "0 = unlimited device memory");
        assert_eq!(d.dma_gbps, 16.0);
        assert!(d.overlap, "transfer/compute overlap defaults on");
        assert!(d.movement_aware, "movement-aware placement defaults on");
        let bad = ServeConfig { devices: 0, ..ServeConfig::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("devices"));
        let bad = ServeConfig { dma_gbps: 0.0, ..ServeConfig::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("dma_gbps"));
        let bad = ServeConfig { dma_gbps: -1.0, ..ServeConfig::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("dma_gbps"));
        let v = json::parse(
            r#"{"serve": {"devices": 2, "device_mem_bytes": 1048576,
                "dma_gbps": 8.0, "overlap": false, "movement_aware": false}}"#,
        )
        .unwrap();
        let cfg = AccdConfig::from_json(&v).unwrap();
        assert_eq!(cfg.serve.devices, 2);
        assert_eq!(cfg.serve.device_mem_bytes, 1 << 20);
        assert_eq!(cfg.serve.dma_gbps, 8.0);
        assert!(!cfg.serve.overlap);
        assert!(!cfg.serve.movement_aware);
        let v = json::parse(r#"{"serve": {"devices": 0}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
    }

    #[test]
    fn kmeans_incremental_ti_defaults_on_and_parses() {
        assert!(AccdConfig::new().kmeans.incremental_ti, "incremental TI defaults on");
        let v = json::parse(r#"{"kmeans": {"incremental_ti": false}}"#).unwrap();
        assert!(!AccdConfig::from_json(&v).unwrap().kmeans.incremental_ti);
        // A kmeans section without the knob keeps the default.
        let v = json::parse(r#"{"kmeans": {}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).unwrap().kmeans.incremental_ti);
    }

    #[test]
    fn serve_knobs_validated() {
        let v = json::parse(r#"{"serve": {"pipeline_depth": 0}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"serve": {"grouping_cache_cap": 0}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"serve": {"shards": 0}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"serve": {"max_batch": 5, "dedup": false, "shards": 3}}"#).unwrap();
        let cfg = AccdConfig::from_json(&v).unwrap();
        assert_eq!(cfg.serve.max_batch, 5);
        assert!(!cfg.serve.dedup);
        assert_eq!(cfg.serve.shards, 3);
        assert_eq!(cfg.serve.pipeline_depth, ServeConfig::default().pipeline_depth);
        assert_eq!(cfg.serve.deadline_ms, ServeConfig::default().deadline_ms);
        assert_eq!(cfg.serve.slab_cache_bytes, ServeConfig::default().slab_cache_bytes);
        assert!(cfg.serve.lockstep, "lockstep defaults on");
        assert_eq!(cfg.serve.steal_threshold, 1, "stealing defaults on at threshold 1");
        assert_eq!(cfg.serve.placement, "edf-lpt", "deadline-aware placement defaults on");
        assert_eq!(cfg.serve.queue_cap, 1024, "server intake bounded by default");
        assert_eq!(cfg.serve.overload, "block", "backpressure is the default overload policy");
        assert!(!cfg.serve.predictive_shed, "predictive shedding defaults off");
        let v = json::parse(r#"{"serve": {"predictive_shed": true}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).unwrap().serve.predictive_shed);
    }

    #[test]
    fn overload_policy_parses_and_rejects_unknown_names() {
        assert_eq!(OverloadPolicy::parse("block").unwrap(), OverloadPolicy::Block);
        assert_eq!(OverloadPolicy::parse("reject").unwrap(), OverloadPolicy::Reject);
        assert_eq!(OverloadPolicy::Block.as_str(), "block");
        assert_eq!(OverloadPolicy::Reject.as_str(), "reject");
        let msg = OverloadPolicy::parse("drop-newest").unwrap_err().to_string();
        assert!(msg.contains("overload"), "{msg}");
        // validate() gates it, so Server construction rejects it.
        let bad = ServeConfig { overload: "panic".into(), ..ServeConfig::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("overload"), "{msg}");
        let v = json::parse(r#"{"serve": {"overload": "nope"}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"serve": {"overload": "reject", "queue_cap": 0}}"#).unwrap();
        let cfg = AccdConfig::from_json(&v).unwrap();
        assert_eq!(cfg.serve.overload, "reject");
        assert_eq!(cfg.serve.queue_cap, 0, "0 = unbounded intake is legal");
    }

    #[test]
    fn placement_mode_parses_and_rejects_unknown_names() {
        assert_eq!(PlacementMode::parse("lpt").unwrap(), PlacementMode::Lpt);
        assert_eq!(PlacementMode::parse("edf-lpt").unwrap(), PlacementMode::EdfLpt);
        assert_eq!(PlacementMode::parse("predicted-p99").unwrap(), PlacementMode::PredictedP99);
        assert_eq!(PlacementMode::Lpt.as_str(), "lpt");
        assert_eq!(PlacementMode::EdfLpt.as_str(), "edf-lpt");
        assert_eq!(PlacementMode::PredictedP99.as_str(), "predicted-p99");
        let msg = PlacementMode::parse("sjf").unwrap_err().to_string();
        assert!(msg.contains("placement"), "{msg}");
        // ...and validate() gates it, so QueryBatcher::try_new rejects it.
        let bad = ServeConfig { placement: "random".into(), ..ServeConfig::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("placement"), "{msg}");
        let v = json::parse(r#"{"serve": {"placement": "nope"}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"serve": {"placement": "lpt"}}"#).unwrap();
        assert_eq!(AccdConfig::from_json(&v).unwrap().serve.placement, "lpt");
    }

    #[test]
    fn serve_validate_error_paths_and_zero_semantics() {
        // Each rejected knob names itself in the error.
        let bad = ServeConfig { shards: 0, ..ServeConfig::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("shards"), "{msg}");
        let bad = ServeConfig { pipeline_depth: 0, ..ServeConfig::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("pipeline_depth"), "{msg}");
        let bad = ServeConfig { grouping_cache_cap: 0, ..ServeConfig::default() };
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("grouping_cache_cap"), "{msg}");
        // Legal zeros: unbounded batches, DISABLED slab cache,
        // DISABLED stealing — explicitly not errors.
        let ok = ServeConfig {
            max_batch: 0,
            slab_cache_bytes: 0,
            steal_threshold: 0,
            lockstep: false,
            ..ServeConfig::default()
        };
        ok.validate().unwrap();
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let v = json::parse(r#"{"hw": {"block": 128}}"#).unwrap();
        let cfg = AccdConfig::from_json(&v).unwrap();
        assert_eq!(cfg.hw.block, 128);
        assert_eq!(cfg.hw.simd, HwConfig::default().simd);
    }

    #[test]
    fn invalid_block_rejected() {
        let v = json::parse(r#"{"hw": {"block": 48}}"#).unwrap();
        assert!(AccdConfig::from_json(&v).is_err());
    }
}
