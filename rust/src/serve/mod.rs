//! Batched multi-query serving runtime — `accd::serve`.
//!
//! AccD's whole premise is amortization: the GTI filter prunes work on
//! the CPU so the accelerator only sees surviving tiles.  A solo
//! [`Engine`] call amortizes *within* one query; this module amortizes
//! *across* queries, which is what a serving deployment (many users
//! querying a handful of hot datasets) actually needs:
//!
//! * [`QueryBatcher`] accepts concurrent KNN / K-means / N-body
//!   requests ([`ServeRequest`]) against reference-counted datasets,
//!   coalesces compatible KNN queries (same target set + metric) into
//!   **cohorts** that share one target grouping and packed target
//!   slabs, and streams every cohort's surviving tiles through ONE
//!   tagged [`pipeline`] run with per-query demultiplexing.
//! * [`GroupingCache`] memoizes grouping builds (the `Latency_filt`
//!   term) across queries *and* flushes, keyed by dataset fingerprint +
//!   build parameters, LRU-bounded.
//! * Identical in-flight queries are deduplicated: one execution, every
//!   requester answered.
//! * [`ServeStats`] (in [`crate::metrics`]) reports queries/sec, the
//!   tiles-shared ratio and the grouping-cache hit rate.
//!
//! **Correctness contract:** batched results are identical to running
//! each query alone through [`Engine`] with the same config.  Every
//! shared artifact is bit-identical to what the solo path would build
//! (deterministic grouping builds, byte-equal target slabs, per-tag
//! FIFO tile order), so no sharing can perturb a result.  The contract
//! is enforced end-to-end by `rust/tests/serve_parity.rs`.

mod cache;

pub use cache::{GroupingCache, GroupingKey};

use std::sync::Arc;

use crate::config::ServeConfig;
use crate::coordinator::{kmeans, knn, nbody, pipeline};
use crate::coordinator::{Engine, KmeansResult, KnnResult, NbodyResult};
use crate::data::Dataset;
use crate::fpga::TileResult;
use crate::gti::{self, Metric};
use crate::layout::PackedGrouping;
use crate::metrics::{RunReport, ServeStats};
use crate::{Error, Result};

/// Ticket handed back by [`QueryBatcher::submit`].
pub type QueryId = u64;

/// One client request against a registered (reference-counted) dataset.
#[derive(Debug, Clone)]
pub enum ServeRequest {
    /// K nearest targets for every source point.
    Knn { src: Arc<Dataset>, trg: Arc<Dataset>, k: usize, metric: Metric },
    /// Lloyd clustering of `ds` into `k` clusters.
    Kmeans { ds: Arc<Dataset>, k: usize, max_iters: usize },
    /// Radius-limited gravitational integration.
    Nbody {
        ds: Arc<Dataset>,
        masses: Arc<Vec<f32>>,
        steps: usize,
        dt: f32,
        radius: f32,
    },
}

impl ServeRequest {
    /// Euclidean KNN-join request.
    pub fn knn(src: Arc<Dataset>, trg: Arc<Dataset>, k: usize) -> Self {
        Self::knn_metric(src, trg, k, Metric::L2)
    }

    pub fn knn_metric(src: Arc<Dataset>, trg: Arc<Dataset>, k: usize, metric: Metric) -> Self {
        Self::Knn { src, trg, k, metric }
    }

    pub fn kmeans(ds: Arc<Dataset>, k: usize, max_iters: usize) -> Self {
        Self::Kmeans { ds, k, max_iters }
    }

    pub fn nbody(
        ds: Arc<Dataset>,
        masses: Arc<Vec<f32>>,
        steps: usize,
        dt: f32,
        radius: f32,
    ) -> Self {
        Self::Nbody { ds, masses, steps, dt, radius }
    }
}

/// The answer to one [`ServeRequest`], in the exact shape the solo
/// engine entry points return.
#[derive(Debug, Clone)]
pub enum ServeResponse {
    Knn(KnnResult),
    Kmeans(KmeansResult),
    Nbody(NbodyResult),
}

impl ServeResponse {
    pub fn as_knn(&self) -> Option<&KnnResult> {
        match self {
            Self::Knn(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_kmeans(&self) -> Option<&KmeansResult> {
        match self {
            Self::Kmeans(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_nbody(&self) -> Option<&NbodyResult> {
        match self {
            Self::Nbody(r) => Some(r),
            _ => None,
        }
    }
}

/// Content identity of two datasets: cheap pointer equality first (the
/// common case under serving traffic is a shared `Arc`), exact
/// bit-for-bit point comparison otherwise.  Shape mismatch makes the
/// content compare trivially cheap, so this never false-positives and
/// rarely pays the full scan.
fn same_points(a: &Arc<Dataset>, b: &Arc<Dataset>) -> bool {
    Arc::ptr_eq(a, b) || a.points == b.points
}

// --- internal partition records --------------------------------------------

struct KnnQ {
    pos: usize,
    src: Arc<Dataset>,
    k: usize,
}

struct KnnCohort {
    trg: Arc<Dataset>,
    metric: Metric,
    queries: Vec<KnnQ>,
}

struct KmeansJob {
    pos: usize,
    ds: Arc<Dataset>,
    k: usize,
    max_iters: usize,
    dups: Vec<usize>,
}

struct NbodyJob {
    pos: usize,
    ds: Arc<Dataset>,
    masses: Arc<Vec<f32>>,
    steps: usize,
    dt: f32,
    radius: f32,
    dups: Vec<usize>,
}

/// The batched query-serving front end: submit many, flush once.
pub struct QueryBatcher {
    engine: Engine,
    cfg: ServeConfig,
    cache: GroupingCache,
    pending: Vec<(QueryId, ServeRequest)>,
    next_id: QueryId,
    stats: ServeStats,
}

impl QueryBatcher {
    pub fn new(engine: Engine, cfg: ServeConfig) -> Self {
        let cache = GroupingCache::new(cfg.grouping_cache_cap);
        Self { engine, cfg, cache, pending: Vec::new(), next_id: 0, stats: ServeStats::default() }
    }

    /// Enqueue a request; it executes at the next [`QueryBatcher::flush`].
    pub fn submit(&mut self, req: ServeRequest) -> QueryId {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push((id, req));
        id
    }

    /// Number of queries waiting for the next flush.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Lifetime serving statistics (across flushes).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Borrow the underlying engine (e.g. for config inspection).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Execute up to `serve.max_batch` pending queries as one batch and
    /// return `(id, response)` pairs in submission order.
    ///
    /// Failure never loses queued work: every query of the prospective
    /// batch is validated (arguments + tile-catalogue limits) *before*
    /// anything is drained, and if execution itself fails mid-flush
    /// (e.g. a corrupted artifact deployment) the drained queries are
    /// re-queued in order and the stats rolled back before the error
    /// is returned.  A query that fails validation must be removed or
    /// fixed by the caller before retrying.
    pub fn flush(&mut self) -> Result<Vec<(QueryId, ServeResponse)>> {
        let t0 = std::time::Instant::now();
        let take = if self.cfg.max_batch == 0 {
            self.pending.len()
        } else {
            self.cfg.max_batch.min(self.pending.len())
        };
        for i in 0..take {
            let (_, req) = &self.pending[i];
            self.validate_request(req)?;
        }
        let batch: Vec<(QueryId, ServeRequest)> = self.pending.drain(..take).collect();
        if batch.is_empty() {
            return Ok(Vec::new());
        }

        // --- Partition: coalesce cohorts, dedup identical queries ---------
        let mut cohorts: Vec<KnnCohort> = Vec::new();
        let mut kmeans_jobs: Vec<KmeansJob> = Vec::new();
        let mut nbody_jobs: Vec<NbodyJob> = Vec::new();
        for (pos, (_, req)) in batch.iter().enumerate() {
            match req {
                ServeRequest::Knn { src, trg, k, metric } => {
                    let found = cohorts
                        .iter()
                        .position(|c| c.metric == *metric && same_points(&c.trg, trg));
                    let q = KnnQ { pos, src: src.clone(), k: *k };
                    match found {
                        Some(ci) => cohorts[ci].queries.push(q),
                        None => cohorts.push(KnnCohort {
                            trg: trg.clone(),
                            metric: *metric,
                            queries: vec![q],
                        }),
                    }
                }
                ServeRequest::Kmeans { ds, k, max_iters } => {
                    // Dedup requires the dataset *name* to match too:
                    // results carry it in report.dataset, and batched
                    // responses must be indistinguishable from solo runs.
                    let dup = if self.cfg.dedup {
                        kmeans_jobs.iter().position(|j| {
                            j.k == *k
                                && j.max_iters == *max_iters
                                && j.ds.name == ds.name
                                && same_points(&j.ds, ds)
                        })
                    } else {
                        None
                    };
                    match dup {
                        Some(ji) => kmeans_jobs[ji].dups.push(pos),
                        None => kmeans_jobs.push(KmeansJob {
                            pos,
                            ds: ds.clone(),
                            k: *k,
                            max_iters: *max_iters,
                            dups: Vec::new(),
                        }),
                    }
                }
                ServeRequest::Nbody { ds, masses, steps, dt, radius } => {
                    let dup = if self.cfg.dedup {
                        nbody_jobs.iter().position(|j| {
                            j.steps == *steps
                                && j.dt.to_bits() == dt.to_bits()
                                && j.radius.to_bits() == radius.to_bits()
                                && j.ds.name == ds.name
                                && (Arc::ptr_eq(&j.masses, masses) || *j.masses == **masses)
                                && same_points(&j.ds, ds)
                        })
                    } else {
                        None
                    };
                    match dup {
                        Some(ji) => nbody_jobs[ji].dups.push(pos),
                        None => nbody_jobs.push(NbodyJob {
                            pos,
                            ds: ds.clone(),
                            masses: masses.clone(),
                            steps: *steps,
                            dt: *dt,
                            radius: *radius,
                            dups: Vec::new(),
                        }),
                    }
                }
            }
        }

        // --- Execute -------------------------------------------------------
        // A mid-flush execution error (e.g. a corrupted artifact file
        // failing lazy kernel resolution) must not cost clients their
        // queued work: on failure, roll the stats back and re-queue the
        // whole drained batch at the front, then surface the error.
        let mut responses: Vec<Option<ServeResponse>> = batch.iter().map(|_| None).collect();
        let stats_snapshot = self.stats.clone();
        let executed = self.execute_batch(cohorts, kmeans_jobs, nbody_jobs, &mut responses);
        if let Err(e) = executed {
            self.stats = stats_snapshot;
            self.pending.splice(0..0, batch);
            return Err(e);
        }

        // Headline counters land only after the whole batch succeeded
        // (per-kind counters mutated during execution are covered by
        // the rollback above), keeping ServeStats self-consistent.
        self.stats.flushes += 1;
        self.stats.queries += batch.len() as u64;
        self.stats.grouping_cache_hits = self.cache.hits;
        self.stats.grouping_cache_misses = self.cache.misses;
        self.stats.wall_secs += t0.elapsed().as_secs_f64();

        Ok(batch
            .into_iter()
            .zip(responses)
            .map(|((id, _), r)| (id, r.expect("every query answered")))
            .collect())
    }

    /// Execute a partitioned batch (all-or-nothing from the caller's
    /// perspective; `flush` rolls back on error).
    fn execute_batch(
        &mut self,
        cohorts: Vec<KnnCohort>,
        kmeans_jobs: Vec<KmeansJob>,
        nbody_jobs: Vec<NbodyJob>,
        responses: &mut [Option<ServeResponse>],
    ) -> Result<()> {
        for cohort in cohorts {
            self.run_knn_cohort(cohort, responses)?;
        }
        for job in kmeans_jobs {
            self.run_kmeans_job(job, responses)?;
        }
        for job in nbody_jobs {
            self.run_nbody_job(job, responses)?;
        }
        Ok(())
    }

    /// Admission-time validation: the same argument checks the solo
    /// engine entry points perform (shared helpers, so the two paths
    /// cannot diverge) plus the tile-catalogue limits the planner would
    /// otherwise only hit mid-flush — all applied before a flush
    /// consumes anything.
    fn validate_request(&self, req: &ServeRequest) -> Result<()> {
        let tile = &self.engine.runtime.manifest().tile;
        match req {
            ServeRequest::Knn { src, trg, k, .. } => {
                knn::validate(src, trg, *k)?;
                tile.pad_d(src.d())?;
                Ok(())
            }
            ServeRequest::Kmeans { ds, k, .. } => {
                kmeans::validate(ds, *k)?;
                tile.pad_d(ds.d())?;
                tile.pad_kmeans_k(*k)?;
                Ok(())
            }
            ServeRequest::Nbody { ds, masses, .. } => nbody::validate(ds, masses),
        }
    }

    /// Grouping-cache lookup with the engine's config baked into the
    /// key.  One `fingerprint_pair` pass covers both the key hash and
    /// the collision probe.
    fn cached_grouping(
        &mut self,
        ds: &Arc<Dataset>,
        groups: usize,
        seed: u64,
        metric: Metric,
    ) -> Result<Arc<PackedGrouping>> {
        let cfg = &self.engine.config.gti;
        let (iters, sample) = (cfg.grouping_iters, cfg.grouping_sample);
        let (fingerprint, probe) = gti::fingerprint_pair(&ds.points);
        let key = GroupingKey { fingerprint, groups, iters, sample, seed, metric };
        let points = &ds.points;
        self.cache.get_or_build(key, probe, || {
            PackedGrouping::build(points, groups, iters, sample, seed, metric, 8)
        })
    }

    /// Execute one KNN cohort: shared target grouping + slabs, one
    /// tagged pipeline over every unique query's dispatch batches,
    /// per-query demux and merge.
    fn run_knn_cohort(
        &mut self,
        cohort: KnnCohort,
        responses: &mut [Option<ServeResponse>],
    ) -> Result<()> {
        let cohort_t0 = std::time::Instant::now();
        let KnnCohort { trg, metric, queries } = cohort;
        let seed = self.engine.config.seed;
        let tile = self.engine.runtime.manifest().tile.clone();

        let trg_groups = self.engine.trg_groups(trg.n());
        let trg_pg = self.cached_grouping(&trg, trg_groups, seed ^ 0x7267, metric)?;

        // Plan every unique query, sharing packed target slabs.
        struct Unique {
            pos: usize,
            src: Arc<Dataset>,
            k: usize,
            src_pg: Arc<PackedGrouping>,
            plan: knn::KnnPlan,
            dups: Vec<usize>,
        }
        let mut uniques: Vec<Unique> = Vec::new();
        let mut slab_cache = knn::TrgSlabCache::new();
        for q in queries {
            if self.cfg.dedup {
                // Name must match too: report.dataset carries it, and a
                // deduplicated answer must equal the solo answer exactly.
                let dup = uniques.iter().position(|u| {
                    u.k == q.k && u.src.name == q.src.name && same_points(&u.src, &q.src)
                });
                if let Some(ui) = dup {
                    uniques[ui].dups.push(q.pos);
                    continue;
                }
            }
            let src_groups = self.engine.src_groups(q.src.n());
            let src_pg = self.cached_grouping(&q.src, src_groups, seed, metric)?;
            let plan =
                knn::plan_metric(&tile, &q.src, q.k, metric, &src_pg, &trg_pg, &mut slab_cache)?;
            self.stats.slabs_shared +=
                plan.batches.iter().filter(|b| b.shared).count() as u64;
            uniques.push(Unique {
                pos: q.pos,
                src: q.src,
                k: q.k,
                src_pg,
                plan,
                dups: Vec::new(),
            });
        }

        // Stream every unique query's batches through one tagged
        // bounded pipeline (query-major order: per-tag FIFO makes each
        // query's merge identical to its solo run).
        self.engine.device.reset_stats();
        let device = &self.engine.device;
        let depth = self.cfg.pipeline_depth;
        let flat: Vec<(usize, usize)> = uniques
            .iter()
            .enumerate()
            .flat_map(|(qi, u)| (0..u.plan.batches.len()).map(move |bi| (qi, bi)))
            .collect();
        let mut results: Vec<Vec<(usize, TileResult)>> =
            uniques.iter().map(|_| Vec::new()).collect();
        let mut tiles_by_query = vec![0u64; uniques.len()];
        let mut shared_tiles_by_query = vec![0u64; uniques.len()];
        let mut job_err: Option<Error> = None;
        {
            let uniques_ref = &uniques;
            pipeline::run_tagged(
                depth,
                |i| {
                    let &(qi, bi) = flat.get(i as usize)?;
                    let u = &uniques_ref[qi];
                    Some((
                        qi as u64,
                        (bi, knn::build_job(&u.plan.batches[bi], &u.src_pg, &u.plan, &tile)),
                    ))
                },
                |tag, (bi, job)| {
                    if job_err.is_some() {
                        return;
                    }
                    if job.src_rows == 0 || job.trg_rows == 0 {
                        return;
                    }
                    let qi = tag as usize;
                    let before = device.stats().tiles;
                    match device.distance_block(&job) {
                        Ok(res) => {
                            let delta = device.stats().tiles - before;
                            tiles_by_query[qi] += delta;
                            if uniques_ref[qi].plan.batches[bi].shared {
                                shared_tiles_by_query[qi] += delta;
                            }
                            results[qi].push((bi, res));
                        }
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        let cohort_device = self.engine.device.stats();
        let cohort_secs = cohort_t0.elapsed().as_secs_f64();

        // Per-query merge + response fan-out.
        for (qi, u) in uniques.into_iter().enumerate() {
            let batch_results = std::mem::take(&mut results[qi]);
            let neighbors = knn::merge_results(&u.plan, batch_results.into_iter());
            let mut report = RunReport::new("knn_join", &u.src.name, "accd-serve");
            report.filter.merge(&u.plan.filter_stats);
            report.layout = u.plan.layout_stats.clone();
            // Device/wall accounting is cohort-scoped: tile execution is
            // deliberately shared, so per-query attribution would lie.
            report.device = cohort_device.clone();
            report.device_wall_secs = cohort_device.wall_secs;
            report.device_modeled_secs = cohort_device.modeled_secs;
            report.wall_secs = cohort_secs;
            report.iterations = 1;
            report.quality = knn::quality_of(&neighbors);
            let result = KnnResult { neighbors, k: u.k, report };

            let has_dups = !u.dups.is_empty();
            self.stats.tiles_total += tiles_by_query[qi];
            self.stats.tiles_shared += if has_dups {
                tiles_by_query[qi]
            } else {
                shared_tiles_by_query[qi]
            };
            self.stats.knn_queries += 1 + u.dups.len() as u64;
            self.stats.dedup_hits += u.dups.len() as u64;
            for &pos in &u.dups {
                responses[pos] = Some(ServeResponse::Knn(result.clone()));
            }
            responses[u.pos] = Some(ServeResponse::Knn(result));
        }
        Ok(())
    }

    fn run_kmeans_job(
        &mut self,
        job: KmeansJob,
        responses: &mut [Option<ServeResponse>],
    ) -> Result<()> {
        let seed = self.engine.config.seed;
        let groups = self.engine.src_groups(job.ds.n());
        let pg = self.cached_grouping(&job.ds, groups, seed, Metric::L2)?;
        let result = kmeans::run_shared(&mut self.engine, &job.ds, job.k, job.max_iters, Some(&pg))?;
        // `run_shared` resets device stats on entry, so this is the
        // query's own tile count.
        let tiles = self.engine.device.stats().tiles;
        let has_dups = !job.dups.is_empty();
        self.stats.tiles_total += tiles;
        if has_dups {
            self.stats.tiles_shared += tiles;
        }
        self.stats.kmeans_queries += 1 + job.dups.len() as u64;
        self.stats.dedup_hits += job.dups.len() as u64;
        for &pos in &job.dups {
            responses[pos] = Some(ServeResponse::Kmeans(result.clone()));
        }
        responses[job.pos] = Some(ServeResponse::Kmeans(result));
        Ok(())
    }

    fn run_nbody_job(
        &mut self,
        job: NbodyJob,
        responses: &mut [Option<ServeResponse>],
    ) -> Result<()> {
        let seed = self.engine.config.seed;
        let groups = self.engine.src_groups(job.ds.n());
        let pg = self.cached_grouping(&job.ds, groups, seed, Metric::L2)?;
        let result = nbody::run_shared(
            &mut self.engine,
            &job.ds,
            &job.masses,
            job.steps,
            job.dt,
            job.radius,
            Some(&pg),
        )?;
        let tiles = self.engine.device.stats().tiles;
        let has_dups = !job.dups.is_empty();
        self.stats.tiles_total += tiles;
        if has_dups {
            self.stats.tiles_shared += tiles;
        }
        self.stats.nbody_queries += 1 + job.dups.len() as u64;
        self.stats.dedup_hits += job.dups.len() as u64;
        for &pos in &job.dups {
            responses[pos] = Some(ServeResponse::Nbody(result.clone()));
        }
        responses[job.pos] = Some(ServeResponse::Nbody(result));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccdConfig;
    use crate::data::synthetic;

    fn batcher() -> QueryBatcher {
        let cfg = AccdConfig::new();
        let engine = Engine::new(cfg.clone()).unwrap();
        QueryBatcher::new(engine, cfg.serve.clone())
    }

    #[test]
    fn flush_on_empty_queue_is_a_noop() {
        let mut b = batcher();
        assert!(b.flush().unwrap().is_empty());
        assert_eq!(b.stats().flushes, 0);
    }

    #[test]
    fn responses_come_back_in_submission_order() {
        let mut b = batcher();
        let trg = Arc::new(synthetic::clustered(400, 4, 8, 0.03, 1));
        let src_a = Arc::new(synthetic::clustered(60, 4, 4, 0.03, 2));
        let src_b = Arc::new(synthetic::clustered(80, 4, 4, 0.03, 3));
        let ds = Arc::new(synthetic::clustered(200, 5, 6, 0.03, 4));
        let id0 = b.submit(ServeRequest::knn(src_a, trg.clone(), 5));
        let id1 = b.submit(ServeRequest::kmeans(ds, 8, 4));
        let id2 = b.submit(ServeRequest::knn(src_b, trg, 7));
        let out = b.flush().unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].0, id0);
        assert_eq!(out[1].0, id1);
        assert_eq!(out[2].0, id2);
        assert!(out[0].1.as_knn().is_some());
        assert!(out[1].1.as_kmeans().is_some());
        assert_eq!(out[2].1.as_knn().unwrap().k, 7);
        assert_eq!(b.stats().queries, 3);
        assert_eq!(b.stats().knn_queries, 2);
        assert_eq!(b.stats().kmeans_queries, 1);
    }

    #[test]
    fn identical_queries_are_deduplicated() {
        let mut b = batcher();
        let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
        let src = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 2));
        for _ in 0..4 {
            b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
        }
        let out = b.flush().unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(b.stats().dedup_hits, 3);
        // All four answers identical.
        let first = out[0].1.as_knn().unwrap();
        for (_, r) in &out[1..] {
            assert_eq!(r.as_knn().unwrap().neighbors, first.neighbors);
        }
        // Dedup makes every dispatched tile serve all four queries.
        assert!(b.stats().tiles_total > 0);
        assert_eq!(b.stats().tiles_shared, b.stats().tiles_total);
    }

    #[test]
    fn max_batch_leaves_overflow_pending() {
        let mut b = batcher();
        b.cfg.max_batch = 2;
        let trg = Arc::new(synthetic::clustered(200, 3, 4, 0.05, 1));
        for s in 0..3u64 {
            let src = Arc::new(synthetic::clustered(40, 3, 3, 0.05, 10 + s));
            b.submit(ServeRequest::knn(src, trg.clone(), 3));
        }
        let out = b.flush().unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(b.pending_len(), 1);
        let out2 = b.flush().unwrap();
        assert_eq!(out2.len(), 1);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn grouping_cache_hits_across_flushes() {
        let mut b = batcher();
        let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
        let src = Arc::new(synthetic::clustered(60, 4, 4, 0.03, 2));
        b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5));
        b.flush().unwrap();
        let misses_after_first = b.stats().grouping_cache_misses;
        b.submit(ServeRequest::knn(src, trg, 5));
        b.flush().unwrap();
        // Second flush reuses both groupings: no new misses, two hits.
        assert_eq!(b.stats().grouping_cache_misses, misses_after_first);
        assert!(b.stats().grouping_cache_hits >= 2);
    }

    #[test]
    fn invalid_query_fails_the_flush_without_consuming_the_queue() {
        let mut b = batcher();
        let trg = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 1));
        let src = Arc::new(synthetic::clustered(20, 4, 4, 0.03, 2));
        b.submit(ServeRequest::knn(src.clone(), trg.clone(), 5)); // valid
        b.submit(ServeRequest::knn(src, trg, 51)); // k > target size
        assert!(b.flush().is_err());
        // Nothing was drained or executed: both queries still queued,
        // no flush/query counted.
        assert_eq!(b.pending_len(), 2);
        assert_eq!(b.stats().flushes, 0);
        assert_eq!(b.stats().queries, 0);
        assert_eq!(b.stats().tiles_total, 0);
    }

    #[test]
    fn dedup_requires_matching_dataset_names() {
        let mut b = batcher();
        let trg = Arc::new(synthetic::clustered(300, 4, 6, 0.03, 1));
        let src_a = Arc::new(synthetic::clustered(50, 4, 4, 0.03, 2));
        // Same points, different name: must NOT dedup (report.dataset
        // would otherwise carry the wrong name).
        let mut renamed = (*src_a).clone();
        renamed.name = "renamed-copy".to_string();
        let src_b = Arc::new(renamed);
        b.submit(ServeRequest::knn(src_a, trg.clone(), 5));
        b.submit(ServeRequest::knn(src_b, trg, 5));
        let out = b.flush().unwrap();
        assert_eq!(b.stats().dedup_hits, 0);
        assert_ne!(out[0].1.as_knn().unwrap().report.dataset, "renamed-copy");
        assert_eq!(out[1].1.as_knn().unwrap().report.dataset, "renamed-copy");
        // Results still identical (same points), just attributed right.
        assert_eq!(
            out[0].1.as_knn().unwrap().neighbors,
            out[1].1.as_knn().unwrap().neighbors
        );
    }
}
