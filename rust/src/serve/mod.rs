//! Batched multi-query serving runtime — `accd::serve`.
//!
//! AccD's whole premise is amortization: the GTI filter prunes work on
//! the CPU so the accelerator only sees surviving tiles.  A solo
//! [`Engine`] call amortizes *within* one query; this module amortizes
//! *across* queries — and, since the sharded core, across *engines*.
//! It is layered, one module per concern, talking only through their
//! public types:
//!
//! ```text
//!      submit / submit_with_deadline          poll / flush
//!           |                                     |
//!           v                                     v
//!   +-- admission -------------------------------------------+
//!   | AdmissionQueue + FlushPolicy: deadline- and size-      |
//!   | triggered selection (dup queries inherit the earliest  |
//!   | deadline); partition -> WorkUnits: KNN cohort          |
//!   | coalescing + dedup via 128-bit fingerprint identity    |
//!   +-----------------------+--------------------------------+
//!                           v
//!   +-- placement -----------------------------------------+
//!   | ShardPlanner: LPT partition by cohort cost estimate  |
//!   | EnginePool: N engine shards over one shared Runtime  |
//!   | WorkPool: shared queue of not-yet-started units;     |
//!   |   idle shards STEAL from busy ones when LPT misfires |
//!   +------+------------------------+----------------------+
//!          v                        v
//!   +-- exec: shard 0 ----+  +-- exec: shard N-1 --+  scoped
//!   | lockstep rounds over|  |        ...          |  threads,
//!   |   resident stepwise |  |                     |  one per
//!   |   CohortPrograms    |  |                     |  busy shard
//!   | GroupingCache (LRU) |  |                     |
//!   | SlabCache (byte-    |  |                     |
//!   |   budget LRU, lives |  |                     |
//!   |   across flushes)   |  |                     |
//!   | tagged pipeline,    |  |                     |
//!   |   per-query demux   |  |                     |
//!   +------+--------------+  +---------+-----------+
//!          v                           v
//!     responses in submission order + per-shard ServeStats
//! ```
//!
//! * [`QueryBatcher`] is the facade over the three layers: `submit`
//!   many, then `flush` (everything due now) or `poll` (only what the
//!   [`FlushPolicy`] says is due — expired deadlines flush alone, so
//!   latency-sensitive queries stop waiting for stragglers, while
//!   under-deadline queries keep coalescing).
//! * Compatible KNN queries (same target content + metric) form
//!   **cohorts** sharing one target grouping and packed target slabs;
//!   each cohort streams through ONE tagged [`coordinator::pipeline`]
//!   run with per-query demux.  Cohorts are the unit of placement —
//!   and, on a shard, every unit is planned into a stepwise
//!   `CohortProgram` the **lockstep scheduler** advances one iteration
//!   per round (`serve.lockstep`), so co-resident K-means / N-body /
//!   KNN programs on one dataset share packed tiles per round instead
//!   of per job, and the tail of a shard's queue stays stealable
//!   (`serve.steal_threshold`) for idle shards.
//! * [`GroupingCache`] (groupings, per shard) and the coordinator's
//!   [`crate::coordinator::SlabCache`] (packed target slabs, per
//!   shard, byte-budgeted) persist across flushes, keyed by 128-bit
//!   content fingerprints; identical in-flight queries are
//!   deduplicated without ever re-scanning points.
//! * [`crate::metrics::ServeStats`] reports the merged view
//!   ([`QueryBatcher::stats`]) and per-shard views
//!   ([`QueryBatcher::shard_stats`]).
//!
//! **Correctness contract:** batched results are identical to running
//! each query alone through [`Engine`] with the same config — for any
//! shard count, any flush order, lockstep on or off, stealing on or
//! off.  Every shared artifact is bit-identical to what the solo path
//! would build (deterministic grouping builds, byte-equal target and
//! assignment slabs, per-tag FIFO tile order), every work unit is
//! self-contained, and every program owns its iteration state, so
//! neither sharing, placement, step interleaving nor migration can
//! perturb a result.  Enforced end-to-end by
//! `rust/tests/serve_parity.rs` and `rust/tests/prop_serve_lockstep.rs`.
//!
//! [`coordinator::pipeline`]: crate::coordinator::pipeline

mod admission;
mod cache;
mod exec;
mod placement;

pub use admission::{FlushPolicy, QueryId, ServeRequest, ServeResponse};
pub use cache::{GroupingCache, GroupingKey};
pub use placement::{EnginePool, ShardPlanner};

use std::time::{Duration, Instant};

use admission::{AdmissionQueue, FingerprintMemo};
use exec::ShardState;

use crate::config::ServeConfig;
use crate::coordinator::Engine;
use crate::metrics::ServeStats;
use crate::Result;

/// The batched query-serving front end: submit many, flush what's due.
pub struct QueryBatcher {
    pool: EnginePool,
    cfg: ServeConfig,
    policy: FlushPolicy,
    queue: AdmissionQueue,
    /// Dataset fingerprints, memoized across polls/flushes and pruned
    /// to the still-pending datasets after every flush attempt.
    memo: FingerprintMemo,
    shards: Vec<ShardState>,
    stats: ServeStats,
}

impl QueryBatcher {
    /// Build a batcher over `cfg.shards` engine shards: the given
    /// engine plus clones of its configuration sharing its runtime.
    ///
    /// Panics on an invalid `cfg` (see [`ServeConfig::validate`]);
    /// use [`QueryBatcher::try_new`] to handle the error instead.
    pub fn new(engine: Engine, cfg: ServeConfig) -> Self {
        match Self::try_new(engine, cfg) {
            Ok(batcher) => batcher,
            Err(e) => panic!("invalid serve config: {e}"),
        }
    }

    /// Fallible construction: the config is validated here, so an
    /// invalid `ServeConfig` (zero shards, zero pipeline depth, zero
    /// grouping-cache capacity) can never reach the serving runtime.
    /// `slab_cache_bytes == 0` is legal and means the per-shard slab
    /// cache is *disabled*.
    pub fn try_new(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let pool = EnginePool::new(engine, cfg.shards)?;
        let shards = (0..pool.shard_count()).map(|_| ShardState::new(&cfg)).collect();
        let policy = FlushPolicy::from_config(&cfg);
        Ok(Self {
            pool,
            cfg,
            policy,
            queue: AdmissionQueue::new(),
            memo: FingerprintMemo::new(),
            shards,
            stats: ServeStats::default(),
        })
    }

    /// Enqueue a request under the config's default deadline (none
    /// when `serve.deadline_ms == 0`).  It executes at the next
    /// [`QueryBatcher::flush`], or at a [`QueryBatcher::poll`] once
    /// due.
    pub fn submit(&mut self, req: ServeRequest) -> QueryId {
        let deadline = self.policy.admission_deadline(Instant::now());
        self.queue.push(req, deadline)
    }

    /// Enqueue a request that becomes due `deadline` from now.
    pub fn submit_with_deadline(&mut self, req: ServeRequest, deadline: Duration) -> QueryId {
        self.queue.push(req, Some(Instant::now() + deadline))
    }

    /// Number of queries waiting for a flush.
    pub fn pending_len(&self) -> usize {
        self.queue.len()
    }

    /// Earliest pending deadline — when the next `poll` could have
    /// work (absent a size trigger).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.next_deadline()
    }

    /// Merged lifetime serving statistics (all shards, all flushes).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Per-shard lifetime serving statistics, in shard order.
    pub fn shard_stats(&self) -> Vec<&ServeStats> {
        self.shards.iter().map(|s| &s.stats).collect()
    }

    pub fn shard_count(&self) -> usize {
        self.pool.shard_count()
    }

    /// Borrow the primary shard's engine (e.g. for config inspection).
    pub fn engine(&self) -> &Engine {
        self.pool.primary()
    }

    /// Execute up to `serve.max_batch` pending queries as one batch and
    /// return `(id, response)` pairs in submission order.
    ///
    /// Failure never loses queued work: every query of the prospective
    /// batch is validated (arguments + tile-catalogue limits) *before*
    /// anything is drained, and if execution itself fails mid-flush
    /// (e.g. a corrupted artifact deployment) the drained queries are
    /// re-queued at the front in order, with no stats applied, before
    /// the error is returned.  A query that fails validation must be
    /// removed or fixed by the caller before retrying.
    pub fn flush(&mut self) -> Result<Vec<(QueryId, ServeResponse)>> {
        let sel = self.policy.select_flush(&self.queue);
        self.run_selected(sel, false)
    }

    /// Execute only what the [`FlushPolicy`] says is due now: queries
    /// whose deadline expired (plus their dedup-identical duplicates,
    /// which inherit the earliest deadline of the class), or a full
    /// batch when `max_batch` queries are already pending.  A no-op
    /// returning an empty vec when nothing is due.  Same failure
    /// contract as [`QueryBatcher::flush`].
    pub fn poll(&mut self) -> Result<Vec<(QueryId, ServeResponse)>> {
        let (sel, deadline_driven) =
            self.policy.select_due(&self.queue, Instant::now(), self.cfg.dedup, &mut self.memo);
        self.run_selected(sel, deadline_driven)
    }

    /// Shared flush core: validate, drain, partition, place, execute,
    /// commit stats (only on full success), prune the memo.
    fn run_selected(
        &mut self,
        sel: Vec<usize>,
        deadline_driven: bool,
    ) -> Result<Vec<(QueryId, ServeResponse)>> {
        if sel.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let tile = self.pool.primary().runtime.manifest().tile.clone();
        for &i in &sel {
            admission::validate_request(&self.queue.get(i).req, &tile)?;
        }
        let batch = self.queue.remove_selected(&sel);
        let units = admission::partition(&batch, self.cfg.dedup, &mut self.memo);
        let costs: Vec<u64> = units.iter().map(|u| u.cost_estimate(self.cfg.dedup)).collect();
        let assignments = ShardPlanner::partition(&costs, self.pool.shard_count());
        let executed = exec::execute_plan(
            &mut self.pool,
            &mut self.shards,
            units,
            costs,
            &assignments,
            batch.len(),
            &self.cfg,
        );
        let out = match executed {
            Ok((responses, deltas)) => {
                self.stats.flushes += 1;
                if deadline_driven {
                    self.stats.deadline_flushes += 1;
                }
                // Absolute, like the cache gauges: cannot drift.
                self.stats.content_full_scans = self.memo.full_scans;
                self.stats.wall_secs += t0.elapsed().as_secs_f64();
                exec::commit_deltas(&mut self.shards, &deltas, &mut self.stats);
                Ok(batch
                    .into_iter()
                    .zip(responses)
                    .map(|(p, r)| (p.id, r.expect("every query answered")))
                    .collect())
            }
            Err(e) => {
                self.queue.requeue_front(batch);
                Err(e)
            }
        };
        self.memo.prune(&self.queue);
        out
    }
}
