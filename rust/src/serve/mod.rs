//! Batched multi-query serving runtime — `accd::serve`.
//!
//! AccD's whole premise is amortization: the GTI filter prunes work on
//! the CPU so the accelerator only sees surviving tiles.  A solo
//! [`Engine`] call amortizes *within* one query; this module amortizes
//! *across* queries — and, since the sharded core, across *engines*.
//! It is layered, one module per concern, talking only through their
//! public types:
//!
//! ```text
//!      submit / submit_with_deadline          poll / flush
//!           |                                     |
//!           v                                     v
//!   +-- admission -------------------------------------------+
//!   | AdmissionQueue + FlushPolicy: deadline- and size-      |
//!   | triggered selection (dup queries inherit the earliest  |
//!   | deadline); partition -> WorkUnits: KNN cohort          |
//!   | coalescing + dedup via 128-bit fingerprint identity    |
//!   +-----------------------+--------------------------------+
//!                           v
//!   +-- placement -----------------------------------------+
//!   | ShardPlanner: EDF-tiered LPT partition by inherited  |
//!   |   unit deadline + cohort cost (serve.placement:      |
//!   |   "edf-lpt" default | "lpt" | "predicted-p99" via    |
//!   |   the CostCalibrator's service-time predictions)     |
//!   | EnginePool: N engine shards over one shared Runtime  |
//!   | WorkPool: shared queue of not-yet-started units;     |
//!   |   urgent-first claims; idle shards STEAL from busy   |
//!   |   ones (most urgent at-risk unit preferred)          |
//!   +------+------------------------+----------------------+
//!          v                        v
//!   +-- exec: shard 0 ----+  +-- exec: shard N-1 --+  scoped
//!   | lockstep rounds over|  |        ...          |  threads,
//!   |   resident stepwise |  |                     |  one per
//!   |   CohortPrograms    |  |                     |  busy shard
//!   | GroupingCache (LRU) |  |                     |
//!   | SlabCache (byte-    |  |                     |
//!   |   budget LRU, lives |  |                     |
//!   |   across flushes)   |  |                     |
//!   | tagged pipeline,    |  |                     |
//!   |   per-query demux   |  |                     |
//!   +------+--------------+  +---------+-----------+
//!          v                           v
//!     responses in submission order + per-shard ServeStats
//! ```
//!
//! * [`QueryBatcher`] is the facade over the three layers: `submit`
//!   many, then `flush` (everything due now) or `poll` (only what the
//!   [`FlushPolicy`] says is due — expired deadlines flush alone, so
//!   latency-sensitive queries stop waiting for stragglers, while
//!   under-deadline queries keep coalescing).
//! * [`Server`] is the always-on front end over the batcher: a
//!   background scheduler thread owns the batcher, sleeps until
//!   [`QueryBatcher::next_wakeup`] (deadline, size trigger or
//!   straggler — never the deadline-only target that stalled on
//!   deadline-free workloads), and producers `submit` concurrently
//!   through a bounded intake (`serve.queue_cap`; `serve.overload`
//!   picks backpressure or shedding), each getting a
//!   [`ResponseHandle`] that resolves to its response.  Shutdown
//!   drains: every accepted query is answered before the thread
//!   exits.
//! * Compatible KNN queries (same target content + metric) form
//!   **cohorts** sharing one target grouping and packed target slabs;
//!   each cohort streams through ONE tagged [`coordinator::pipeline`]
//!   run with per-query demux.  Cohorts are the unit of placement —
//!   and, on a shard, every unit is planned into a stepwise
//!   `CohortProgram` the **lockstep scheduler** advances one iteration
//!   per round (`serve.lockstep`), so co-resident K-means / N-body /
//!   KNN programs on one dataset share packed tiles per round instead
//!   of per job, and the tail of a shard's queue stays stealable
//!   (`serve.steal_threshold`) for idle shards.
//! * [`GroupingCache`] (groupings, per shard) and the coordinator's
//!   [`crate::coordinator::SlabCache`] (packed target slabs, per
//!   shard, byte-budgeted) persist across flushes, keyed by 128-bit
//!   content fingerprints; identical in-flight queries are
//!   deduplicated without ever re-scanning points.
//! * Every deadline decision — admission stamping, `poll`
//!   due-selection, the planner's EDF tiers, urgent-first claims and
//!   at-risk steals, latency / miss accounting — reads one injected
//!   [`Clock`] ([`MonotonicClock`] in production; tests inject a
//!   [`VirtualClock`] and advance it by hand, so deadline semantics
//!   are testable without sleeping).
//! * [`crate::metrics::ServeStats`] reports the merged view
//!   ([`QueryBatcher::stats`]) and per-shard views
//!   ([`QueryBatcher::shard_stats`]) — including per-query latency
//!   percentiles and `deadline_met` / `deadline_misses` counters (a
//!   late query is answered late and counted, never dropped).
//!
//! **Correctness contract:** batched results are identical to running
//! each query alone through [`Engine`] with the same config — for any
//! shard count, any flush order, any placement mode, any deadline
//! pattern, lockstep on or off, stealing on or off.  Every shared
//! artifact is bit-identical to what the solo path
//! would build (deterministic grouping builds, byte-equal target and
//! assignment slabs, per-tag FIFO tile order), every work unit is
//! self-contained, and every program owns its iteration state, so
//! neither sharing, placement, step interleaving nor migration can
//! perturb a result.  Enforced end-to-end by
//! `rust/tests/serve_parity.rs` and `rust/tests/prop_serve_lockstep.rs`.
//!
//! [`coordinator::pipeline`]: crate::coordinator::pipeline

mod admission;
mod cache;
mod calibrate;
mod clock;
mod exec;
mod placement;
mod server;

pub use admission::{FlushPolicy, QueryId, ServeRequest, ServeResponse};
pub use cache::{GroupingCache, GroupingKey};
pub use calibrate::{AlgoKind, CostCalibrator};
pub use clock::{ticks, Clock, ClockWaker, MonotonicClock, Tick, VirtualClock};
pub use placement::{EnginePool, ShardPlanner};
pub use server::{ResponseHandle, Server, DRAIN_RETRY_LIMIT};

use std::sync::Arc;
use std::time::{Duration, Instant};

use admission::{AdmissionQueue, FingerprintMemo};
use exec::ShardState;

use admission::WorkUnit;

use crate::config::{PlacementMode, ServeConfig};
use crate::coordinator::Engine;
use crate::metrics::ServeStats;
use crate::runtime::DeviceTopology;
use crate::Result;

/// The batched query-serving front end: submit many, flush what's due.
pub struct QueryBatcher {
    pool: EnginePool,
    cfg: ServeConfig,
    /// Parsed once at construction (`cfg.placement` is validated
    /// there), so the flush path never re-parses.
    placement: PlacementMode,
    policy: FlushPolicy,
    queue: AdmissionQueue,
    /// Dataset fingerprints, memoized across polls/flushes and pruned
    /// to the still-pending datasets after every flush attempt.
    memo: FingerprintMemo,
    shards: Vec<ShardState>,
    stats: ServeStats,
    /// Online cost-units → nanoseconds model (per shard × algorithm
    /// kind), seeded analytically and corrected from every retired
    /// unit's modeled compute — see [`CostCalibrator`].  Drives
    /// `predicted-p99` placement, predicted-slack steals, the
    /// predictive shed check and the predicted-vs-actual telemetry.
    calibrator: CostCalibrator,
    /// Per shard: measured (modeled) DMA transfer ns of the previous
    /// flush, fed back into the movement penalties as a congestion
    /// surcharge — a shard that just re-uploaded everything is briefly
    /// dearer to place cold work on; a warm shard's surcharge decays
    /// to zero after one quiet flush.
    prev_transfer_ns: Vec<u64>,
    /// Queries predictively shed by flushes since the last
    /// [`QueryBatcher::take_predicted_sheds`] drain.
    pending_sheds: Vec<QueryId>,
    /// The injected time source every deadline decision reads
    /// ([`MonotonicClock`] by default; tests inject a
    /// [`VirtualClock`]).
    clock: Arc<dyn Clock>,
}

impl QueryBatcher {
    /// Build a batcher over `cfg.shards` engine shards: the given
    /// engine plus clones of its configuration sharing its runtime.
    ///
    /// Panics on an invalid `cfg` (see [`ServeConfig::validate`]);
    /// use [`QueryBatcher::try_new`] to handle the error instead.
    pub fn new(engine: Engine, cfg: ServeConfig) -> Self {
        match Self::try_new(engine, cfg) {
            Ok(batcher) => batcher,
            Err(e) => panic!("invalid serve config: {e}"),
        }
    }

    /// Fallible construction: the config is validated here, so an
    /// invalid `ServeConfig` (zero shards, zero pipeline depth, zero
    /// grouping-cache capacity, unknown placement policy) can never
    /// reach the serving runtime.  `slab_cache_bytes == 0` is legal
    /// and means the per-shard slab cache is *disabled*.  Deadlines
    /// run on a fresh [`MonotonicClock`]; use
    /// [`QueryBatcher::try_new_with_clock`] to inject a
    /// [`VirtualClock`] for deterministic deadline tests.
    pub fn try_new(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        Self::try_new_with_clock(engine, cfg, Arc::new(MonotonicClock::new()))
    }

    /// Like [`QueryBatcher::new`], with an injected clock; panics on
    /// an invalid config.
    pub fn with_clock(engine: Engine, cfg: ServeConfig, clock: Arc<dyn Clock>) -> Self {
        match Self::try_new_with_clock(engine, cfg, clock) {
            Ok(batcher) => batcher,
            Err(e) => panic!("invalid serve config: {e}"),
        }
    }

    /// Like [`QueryBatcher::try_new`], but every deadline decision —
    /// admission stamping, `poll` due-selection, EDF placement,
    /// urgency-preferring steals, latency / miss accounting — reads
    /// the given clock.
    pub fn try_new_with_clock(
        engine: Engine,
        cfg: ServeConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Self> {
        cfg.validate()?;
        let placement = cfg.placement_mode().expect("validated above");
        let topology = DeviceTopology::from_serve(&cfg);
        let pool = EnginePool::with_topology(engine, cfg.shards, topology)?;
        // Each shard's slab budget is clamped to its share of its
        // device's memory — residency is bounded by modeled capacity,
        // not just the per-shard knob.
        let shards = (0..pool.shard_count())
            .map(|s| {
                let budget =
                    pool.topology().shard_slab_budget(s, cfg.shards, cfg.slab_cache_bytes);
                ShardState::with_budget(&cfg, budget)
            })
            .collect();
        let policy = FlushPolicy::from_config(&cfg);
        let calibrator =
            CostCalibrator::new(pool.primary().device.cost_model().clone(), pool.shard_count());
        let prev_transfer_ns = vec![0; pool.shard_count()];
        Ok(Self {
            pool,
            cfg,
            placement,
            policy,
            queue: AdmissionQueue::new(),
            memo: FingerprintMemo::new(),
            shards,
            stats: ServeStats::default(),
            calibrator,
            prev_transfer_ns,
            pending_sheds: Vec::new(),
            clock,
        })
    }

    /// Enqueue a request under the config's default deadline (none
    /// when `serve.deadline_ms == 0`).  It executes at the next
    /// [`QueryBatcher::flush`], or at a [`QueryBatcher::poll`] once
    /// due.
    pub fn submit(&mut self, req: ServeRequest) -> QueryId {
        let now = self.clock.now();
        let deadline = self.policy.admission_deadline(now);
        self.queue.push(req, deadline, now)
    }

    /// Enqueue a request that becomes due `deadline` from now (on the
    /// batcher's clock).
    pub fn submit_with_deadline(&mut self, req: ServeRequest, deadline: Duration) -> QueryId {
        let now = self.clock.now();
        self.queue.push(req, Some(now.saturating_add(ticks(deadline))), now)
    }

    /// Number of queries waiting for a flush.
    pub fn pending_len(&self) -> usize {
        self.queue.len()
    }

    /// Server-internal admission: enqueue with an absolute deadline
    /// and the producer-observed submission tick.  Latency samples
    /// must start when the producer handed the query over, not when
    /// the scheduler got around to transferring it out of the intake
    /// queue — intake wait is real service latency.
    pub(crate) fn submit_at(
        &mut self,
        req: ServeRequest,
        deadline: Option<Tick>,
        submitted_at: Tick,
    ) -> QueryId {
        self.queue.push(req, deadline, submitted_at)
    }

    /// Absolute deadline the configured policy would stamp on a
    /// deadline-free `submit` at tick `now` (the server stamps at
    /// producer accept time, not transfer time).
    pub(crate) fn admission_deadline(&self, now: Tick) -> Option<Tick> {
        self.policy.admission_deadline(now)
    }

    /// Admission-time validation of one request against this
    /// batcher's tile catalogue — exactly the checks a flush performs
    /// before draining anything.  The server pre-validates at
    /// transfer so an invalid query fails its own handle instead of
    /// wedging every subsequent flush attempt.
    pub(crate) fn validate_request(&self, req: &ServeRequest) -> Result<()> {
        admission::validate_request(req, &self.pool.primary().runtime.manifest().tile)
    }

    /// The injected time source (shared with the [`Server`] loop).
    pub(crate) fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// The batcher's current clock reading.
    /// [`QueryBatcher::next_wakeup`] is on the same timeline, so a
    /// serving loop sleeps for
    /// `next_wakeup().map(|t| t.saturating_sub(batcher.now()))`
    /// nanoseconds before its next poll.
    pub fn now(&self) -> Tick {
        self.clock.now()
    }

    /// Earliest pending deadline, in ticks of the batcher's clock
    /// (compare with [`QueryBatcher::now`]).  NOT a safe sleep
    /// target: deadline-free pending queries leave it `None`, and a
    /// loop sleeping on it stalls forever on size-trigger-only
    /// workloads — sleep on [`QueryBatcher::next_wakeup`] instead.
    pub fn next_deadline(&self) -> Option<Tick> {
        self.queue.next_deadline()
    }

    /// The next tick at which pending work could become due — the
    /// sleep target of a serving loop, accounting for every trigger:
    /// the earliest pending deadline, the `max_batch` size trigger
    /// (already met ⇒ due now) and deadline-free stragglers (due now;
    /// no future trigger would ever fire for them on its own).
    /// `None` only when nothing is pending — a new `submit` is then
    /// the only possible wake source, and it wakes the [`Server`]
    /// loop by itself.
    pub fn next_wakeup(&self) -> Option<Tick> {
        self.policy.next_wakeup(&self.queue, self.clock.now())
    }

    /// Merged lifetime serving statistics (all shards, all flushes).
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Per-shard lifetime serving statistics, in shard order.
    pub fn shard_stats(&self) -> Vec<&ServeStats> {
        self.shards.iter().map(|s| &s.stats).collect()
    }

    /// The batcher's online cost calibrator (read-only: coverage and
    /// prediction introspection).
    pub fn calibrator(&self) -> &CostCalibrator {
        &self.calibrator
    }

    /// Drain the IDs of queries predictively shed by flushes since the
    /// last call.  Shed queries are never executed and produce no
    /// response pair; a front end (the [`Server`]) resolves their
    /// handles with an error from this list.
    pub fn take_predicted_sheds(&mut self) -> Vec<QueryId> {
        std::mem::take(&mut self.pending_sheds)
    }

    pub fn shard_count(&self) -> usize {
        self.pool.shard_count()
    }

    /// Number of emulated devices the shards are pinned onto
    /// (`serve.devices`).
    pub fn device_count(&self) -> usize {
        self.pool.topology().device_count()
    }

    /// The emulated device shard `shard` is pinned to (round-robin,
    /// deterministic — see [`crate::runtime::DeviceTopology`]).
    pub fn device_of(&self, shard: usize) -> usize {
        self.pool.device_of(shard)
    }

    /// Borrow the primary shard's engine (e.g. for config inspection).
    pub fn engine(&self) -> &Engine {
        self.pool.primary()
    }

    /// Execute up to `serve.max_batch` pending queries as one batch and
    /// return `(id, response)` pairs in submission order.
    ///
    /// Failure never loses queued work: every query of the prospective
    /// batch is validated (arguments + tile-catalogue limits) *before*
    /// anything is drained, and if execution itself fails mid-flush
    /// (e.g. a corrupted artifact deployment) the drained queries are
    /// re-queued at the front in order, with no stats applied, before
    /// the error is returned.  A query that fails validation must be
    /// removed or fixed by the caller before retrying.
    pub fn flush(&mut self) -> Result<Vec<(QueryId, ServeResponse)>> {
        let now = self.clock.now();
        let sel = self.policy.select_flush(&self.queue);
        self.run_selected(sel, false, now)
    }

    /// Execute only what the [`FlushPolicy`] says is due now: queries
    /// whose deadline expired (plus their dedup-identical duplicates,
    /// which inherit the earliest deadline of the class), or a full
    /// batch when `max_batch` queries are already pending.  A no-op
    /// returning an empty vec when nothing is due.  Same failure
    /// contract as [`QueryBatcher::flush`].
    pub fn poll(&mut self) -> Result<Vec<(QueryId, ServeResponse)>> {
        let now = self.clock.now();
        let (sel, deadline_driven) =
            self.policy.select_due(&self.queue, now, self.cfg.dedup, &mut self.memo);
        self.run_selected(sel, deadline_driven, now)
    }

    /// The per-unit x per-shard movement table: what placing each unit
    /// on each shard would cost in *data movement*, in the same cost
    /// units as [`WorkUnit::cost_estimate`].  A shard whose slab cache
    /// already holds the unit's packed slabs (matched by content
    /// fingerprint) is cheap; a cold shard pays the modeled DMA upload
    /// of the unit's footprint, converted to equivalent compute via
    /// the device cost model.  On top of the analytical upload cost,
    /// each shard pays a **measured congestion surcharge**: half of
    /// the previous flush's observed transfer time on that shard
    /// (converted back to cost units), so the overlap timeline the
    /// exec layer already measures feeds placement — a shard that just
    /// re-uploaded everything is briefly dearer, and a warm shard's
    /// penalty drops after one flush (warm bytes cancel the upload
    /// term, and a quiet flush decays the surcharge to zero).  Empty
    /// when movement-awareness is off or trivially irrelevant (one
    /// shard) — the planner and the stealer then behave exactly as
    /// before.
    fn movement_table(&self, units: &[WorkUnit]) -> Vec<Vec<u64>> {
        if !self.cfg.movement_aware || self.pool.shard_count() <= 1 {
            return Vec::new();
        }
        let topo = self.pool.topology();
        let cost = self.pool.primary().device.cost_model();
        units
            .iter()
            .map(|u| {
                let (fp, bytes) = u.movement_footprint();
                let d = u.dim();
                self.shards
                    .iter()
                    .enumerate()
                    .map(|(s, state)| {
                        let warm = state.slab_cache.warm_bytes_for(fp).min(bytes);
                        let upload =
                            cost.move_penalty_units(topo.dma_for_shard(s), bytes - warm, d);
                        let congestion = xfer_feedback_units(
                            self.prev_transfer_ns.get(s).copied().unwrap_or(0),
                            cost.pairs_per_sec(d),
                        );
                        upload.saturating_add(congestion)
                    })
                    .collect()
            })
            .collect()
    }

    /// Shared flush core: validate, drain, partition, place (deadline
    /// aware under `edf-lpt`), execute, commit stats + latency / miss
    /// accounting (only on full success), prune the memo.
    ///
    /// `flush_now` is the SELECTION-time clock reading of the calling
    /// `poll`/`flush` — passed in rather than re-read, so a
    /// deadline-triggered query selected exactly at expiry
    /// (`deadline <= now` in `select_due`) is judged against that same
    /// instant and counts met, not an ε-miss from a second,
    /// strictly-later monotonic read.
    fn run_selected(
        &mut self,
        sel: Vec<usize>,
        deadline_driven: bool,
        flush_now: Tick,
    ) -> Result<Vec<(QueryId, ServeResponse)>> {
        if sel.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let tile = self.pool.primary().runtime.manifest().tile.clone();
        for &i in &sel {
            admission::validate_request(&self.queue.get(i).req, &tile)?;
        }
        let mut batch = self.queue.remove_selected(&sel);
        if self.cfg.predictive_shed {
            // Early deadline shedding: drop a selected query only when
            // its OWN deadline already expired at selection time — a
            // certain reactive miss (met/missed is judged at service
            // START, so the reactive path would count it missed too) —
            // AND the calibrated completion estimate overshoots it.
            // The second condition is implied by the first (predicted
            // service time is never negative), which is exactly what
            // makes the shed safe: no query the reactive path would
            // have served within deadline is ever shed.
            let shard0_kind_pred = |p: &admission::Pending| {
                self.calibrator.predict_ns(0, p.req.kind(), p.req.solo_cost_units(), p.req.dim())
            };
            let mut kept = Vec::with_capacity(batch.len());
            for p in batch {
                let doomed = p.deadline.is_some_and(|d| {
                    d < flush_now && flush_now.saturating_add(shard0_kind_pred(&p)) > d
                });
                if doomed {
                    self.stats.predicted_sheds += 1;
                    self.pending_sheds.push(p.id);
                } else {
                    kept.push(p);
                }
            }
            batch = kept;
            if batch.is_empty() {
                self.memo.prune(&self.queue);
                return Ok(Vec::new());
            }
        }
        let units = admission::partition(&batch, self.cfg.dedup, &mut self.memo);
        let costs: Vec<u64> = units.iter().map(|u| u.cost_estimate(self.cfg.dedup)).collect();
        let deadlines: Vec<Option<Tick>> = units.iter().map(|u| u.deadline()).collect();
        let move_units = self.movement_table(&units);
        let n_shards = self.pool.shard_count();
        // Calibrated per-unit × per-shard predicted service ns:
        // compute (calibrated rate × planner cost) plus the unit's
        // movement penalty on that shard, both in the same cost
        // currency the rate was learned on.  Always computed — the
        // predicted-vs-actual telemetry is on for every flush.
        let pred_table: Vec<Vec<u64>> = units
            .iter()
            .enumerate()
            .map(|(i, u)| {
                let (kind, d) = (u.kind(), u.dim());
                (0..n_shards)
                    .map(|s| {
                        let move_cost =
                            move_units.get(i).and_then(|row| row.get(s)).copied().unwrap_or(0);
                        self.calibrator.predict_ns(s, kind, costs[i].saturating_add(move_cost), d)
                    })
                    .collect()
            })
            .collect();
        let assignments = match self.placement {
            PlacementMode::PredictedP99 => {
                ShardPlanner::plan_predicted_p99(&pred_table, &deadlines, n_shards, flush_now)
            }
            _ => ShardPlanner::plan_with_movement(
                &costs,
                &deadlines,
                &move_units,
                n_shards,
                self.placement,
            ),
        };
        // Each unit's prediction on the shard it was actually placed
        // on: the predicted-slack steal horizon and the error baseline.
        let mut home = vec![0usize; units.len()];
        for (s, list) in assignments.iter().enumerate() {
            for &i in list {
                home[i] = s;
            }
        }
        let pred_ns: Vec<u64> = (0..units.len()).map(|i| pred_table[i][home[i]]).collect();
        let executed = exec::execute_plan(
            &mut self.pool,
            &mut self.shards,
            units,
            costs,
            deadlines,
            move_units,
            pred_ns,
            &assignments,
            batch.len(),
            &self.cfg,
            flush_now,
        );
        let out = match executed {
            Ok((responses, shard_of, deltas)) => {
                self.stats.flushes += 1;
                if deadline_driven {
                    self.stats.deadline_flushes += 1;
                }
                // Absolute, like the cache gauges: cannot drift.
                self.stats.content_full_scans = self.memo.full_scans;
                self.stats.wall_secs += t0.elapsed().as_secs_f64();
                exec::commit_deltas(&mut self.shards, &deltas, &mut self.stats);
                // Calibrator feedback (shard order, retirement order
                // within a shard — deterministic) and the measured
                // transfer feedback for the next flush's movement
                // penalties.  Only committed flushes teach the model:
                // a failed flush's deltas are dropped wholesale.
                for (s, delta) in deltas.iter().enumerate() {
                    for o in &delta.observations {
                        self.calibrator.observe(s, o.kind, o.cost_units, o.actual_ns);
                    }
                    self.prev_transfer_ns[s] = delta.stats.transfer_ns;
                }
                // Latency / deadline accounting: one sample per
                // answered query, on the merged view and on the
                // executing shard's.  Latency runs submit -> response
                // (`done`, read after execution: a real clock yields
                // true completion latency).  Met/missed is judged at
                // service START (`flush_now`): a deadline-triggered
                // poll fires exactly when `deadline <= now`, so
                // judging by completion would brand every such query
                // an epsilon-miss by construction; "missed" instead
                // means the scheduler had not even started serving
                // the query by its deadline (a backlog, not the
                // unavoidable execution tail — that tail stays
                // visible in the latency percentiles).
                let done = self.clock.now();
                for (slot, p) in batch.iter().enumerate() {
                    let latency = done.saturating_sub(p.submitted_at);
                    let missed = p.deadline.map(|d| flush_now > d);
                    self.stats.record_latency(latency, missed);
                    let shard = shard_of[slot].expect("every query answered");
                    self.shards[shard].stats.record_latency(latency, missed);
                }
                Ok(batch
                    .into_iter()
                    .zip(responses)
                    .map(|(p, r)| (p.id, r.expect("every query answered")))
                    .collect())
            }
            Err(e) => {
                self.queue.requeue_front(batch);
                Err(e)
            }
        };
        self.memo.prune(&self.queue);
        out
    }
}

/// Measured-transfer congestion surcharge, in planner cost units: half
/// of the shard's previous-flush transfer time converted through the
/// same pair-throughput the analytical movement penalty uses.  Half,
/// not all: the feedback is a hint layered on a model that already
/// charges the upload itself — full weight would double-count a cold
/// upload, half keeps the surcharge strictly below the analytical
/// penalty it echoes, so one quiet flush always drops a warm shard's
/// total penalty.
fn xfer_feedback_units(prev_transfer_ns: u64, pairs_per_sec: f64) -> u64 {
    ((prev_transfer_ns as f64 * 1e-9 * pairs_per_sec) as u64) / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_feedback_is_half_the_equivalent_compute_and_decays_to_zero() {
        // 1 ms of measured transfer at 2e9 pairs/sec == 2_000_000
        // equivalent units; the surcharge is half that.
        assert_eq!(xfer_feedback_units(1_000_000, 2.0e9), 1_000_000);
        // A quiet previous flush charges nothing.
        assert_eq!(xfer_feedback_units(0, 2.0e9), 0);
        // Strictly below the full equivalent, so warm-shard penalties
        // can only drop once the upload term is cancelled by warmth.
        assert!(xfer_feedback_units(123_456, 3.7e9) * 2 <= (123_456f64 * 1e-9 * 3.7e9) as u64);
    }
}
