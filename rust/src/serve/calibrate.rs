//! Cost calibration layer: abstract planner cost → predicted time.
//!
//! The `ShardPlanner` balances *abstract* cost units (dominant
//! distance-pair counts, [`super::admission::WorkUnit::cost_estimate`]);
//! deadlines live in clock nanoseconds.  The [`CostCalibrator`] bridges
//! the two: an online EWMA of observed nanoseconds-per-cost-unit, kept
//! per (shard × algorithm kind), seeded from the analytical
//! `CostModel::pairs_per_sec` rate (AccD Eq. 5's throughput term) and
//! corrected from the per-program modeled compute deltas the execution
//! layer already snapshot-diffs for its `XferClock` accounting.
//!
//! Predictions drive three order-only mechanisms (none may change
//! result bits — the serve parity contract):
//!
//! * **admission** — `serve.predictive_shed` sheds a selected query
//!   whose calibrated completion estimate already overshoots an
//!   expired deadline instead of spending device time on a guaranteed
//!   miss (`ServeStats::predicted_sheds`);
//! * **placement** — the `predicted-p99` mode bounds per-shard
//!   predicted finish-time tails, and `WorkPool::steal` treats a unit
//!   as at-risk on *predicted* slack deficit before its deadline
//!   expires;
//! * **exec** — every retired program records predicted-vs-actual
//!   error permille into `ServeStats`, so the calibrator's quality is
//!   observable and the EWMA self-corrects.
//!
//! Determinism: the calibrator is a pure fold over its observation
//! sequence (no wall clock, no randomness).  Identical observation
//! sequences yield bit-identical rates and hence identical
//! predictions — which is what keeps predictive scheduling
//! reproducible on a `VirtualClock`.

use crate::fpga::cost::CostModel;

/// Algorithm kind axis of the calibrator: each kind has its own
/// ns-per-unit behaviour (KNN pairs stream through the filter, K-means
/// iterations prune, N-body tiles are dense), so their rates are
/// learned independently.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    Knn,
    Kmeans,
    Nbody,
    /// Fixed-threshold radius query.  Shares KNN's cost-unit shape
    /// (`trg + src*trg` pairs) but not its rate: the threshold filter
    /// prunes and CPU-emits differently, so it learns its own cell.
    RangeJoin,
}

impl AlgoKind {
    pub const COUNT: usize = 4;

    fn index(self) -> usize {
        match self {
            AlgoKind::Knn => 0,
            AlgoKind::Kmeans => 1,
            AlgoKind::Nbody => 2,
            AlgoKind::RangeJoin => 3,
        }
    }
}

/// One retired-program measurement fed back into the calibrator: the
/// shard that ran the unit, its kind/dimensionality, the abstract cost
/// the planner balanced, and the modeled nanoseconds the device
/// accounting actually charged (plan + steps + finish).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Observation {
    pub kind: AlgoKind,
    pub cost_units: u64,
    pub actual_ns: u64,
}

/// EWMA weight of a new observation.  Low enough to ride out one
/// outlier (a cold-cache flush), high enough that a handful of
/// flushes converge; the *first* observation replaces the analytical
/// seed outright, so a steady workload is calibrated after one flush.
const EWMA_ALPHA: f64 = 0.25;

/// Online cost-units → nanoseconds calibrator, per (shard × kind).
///
/// Until a (shard, kind) cell has seen an observation, predictions
/// fall back to the analytical seed rate `1e9 / pairs_per_sec(d)` —
/// the same Eq. 5 throughput the DSE explorer ranks designs by — so a
/// cold calibrator is exactly the cost model, and a warm one is the
/// cost model corrected by what this shard actually measured.
pub struct CostCalibrator {
    cost: CostModel,
    /// `rates[shard][kind]`: learned ns per cost unit; `None` = cold
    /// (use the analytical seed).
    rates: Vec<[Option<f64>; AlgoKind::COUNT]>,
    /// Observations folded in, total (calibration-coverage gauge).
    observations: u64,
}

impl CostCalibrator {
    pub fn new(cost: CostModel, shards: usize) -> Self {
        Self { cost, rates: vec![[None; AlgoKind::COUNT]; shards.max(1)], observations: 0 }
    }

    /// Analytical ns-per-unit seed for dimensionality `d`: the inverse
    /// of the cost model's pair throughput.
    fn seed_rate(&self, d: usize) -> f64 {
        1e9 / self.cost.pairs_per_sec(d).max(1.0)
    }

    /// The rate used for a prediction: learned if warm, seed if cold.
    fn rate(&self, shard: usize, kind: AlgoKind, d: usize) -> f64 {
        self.rates
            .get(shard)
            .and_then(|r| r[kind.index()])
            .unwrap_or_else(|| self.seed_rate(d))
    }

    /// Whether the (shard, kind) cell has folded in at least one
    /// observation (predictions no longer ride the analytical seed).
    pub fn is_warm(&self, shard: usize, kind: AlgoKind) -> bool {
        self.rates.get(shard).is_some_and(|r| r[kind.index()].is_some())
    }

    /// Total observations folded in across all cells.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Predicted service nanoseconds of `cost_units` abstract units of
    /// kind `kind` on `shard`, at dimensionality `d`.
    pub fn predict_ns(&self, shard: usize, kind: AlgoKind, cost_units: u64, d: usize) -> u64 {
        (self.rate(shard, kind, d) * cost_units as f64).round().max(0.0) as u64
    }

    /// Fold one retired-program measurement into the (shard, kind)
    /// cell.  The first observation replaces the analytical seed
    /// outright; later ones blend by [`EWMA_ALPHA`].  Zero-cost units
    /// and zero-ns measurements are skipped (neither defines a usable
    /// rate, and a zero rate would predict instant service forever).
    pub fn observe(&mut self, shard: usize, kind: AlgoKind, cost_units: u64, actual_ns: u64) {
        if cost_units == 0 || actual_ns == 0 {
            return;
        }
        let Some(row) = self.rates.get_mut(shard) else { return };
        let observed = actual_ns as f64 / cost_units as f64;
        let cell = &mut row[kind.index()];
        *cell = Some(match *cell {
            None => observed,
            Some(prev) => prev + EWMA_ALPHA * (observed - prev),
        });
        self.observations += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    fn calibrator(shards: usize) -> CostCalibrator {
        CostCalibrator::new(CostModel::new(HwConfig::default()), shards)
    }

    #[test]
    fn cold_prediction_is_the_analytical_seed() {
        let c = calibrator(2);
        let cost = CostModel::new(HwConfig::default());
        let want = (1e9 / cost.pairs_per_sec(8) * 1_000.0).round() as u64;
        assert_eq!(c.predict_ns(0, AlgoKind::Knn, 1_000, 8), want);
        assert!(!c.is_warm(0, AlgoKind::Knn));
        // Every shard and kind shares the same cold seed at equal d.
        assert_eq!(
            c.predict_ns(0, AlgoKind::Knn, 1_000, 8),
            c.predict_ns(1, AlgoKind::Nbody, 1_000, 8)
        );
    }

    #[test]
    fn first_observation_replaces_the_seed_exactly() {
        let mut c = calibrator(1);
        // 500 units took 2_000 ns -> 4 ns/unit, adopted outright.
        c.observe(0, AlgoKind::Kmeans, 500, 2_000);
        assert!(c.is_warm(0, AlgoKind::Kmeans));
        assert_eq!(c.predict_ns(0, AlgoKind::Kmeans, 700, 8), 2_800);
        // A steady workload is perfectly predicted after round one.
        assert_eq!(c.predict_ns(0, AlgoKind::Kmeans, 500, 8), 2_000);
    }

    #[test]
    fn ewma_tracks_drift_without_jumping() {
        let mut c = calibrator(1);
        c.observe(0, AlgoKind::Knn, 100, 1_000); // 10 ns/unit
        c.observe(0, AlgoKind::Knn, 100, 2_000); // observed 20 -> 12.5
        assert_eq!(c.predict_ns(0, AlgoKind::Knn, 100, 8), 1_250);
        // Kinds and shards are independent cells.
        assert!(!c.is_warm(0, AlgoKind::Kmeans));
    }

    #[test]
    fn identical_observation_sequences_yield_identical_predictions() {
        let obs = [
            (0usize, AlgoKind::Knn, 120u64, 1_440u64),
            (1, AlgoKind::Kmeans, 77, 900),
            (0, AlgoKind::Knn, 130, 1_100),
            (1, AlgoKind::Nbody, 999, 12_345),
            (0, AlgoKind::Kmeans, 10, 55),
            (0, AlgoKind::RangeJoin, 64, 800),
        ];
        let mut a = calibrator(2);
        let mut b = calibrator(2);
        for &(s, k, u, ns) in &obs {
            a.observe(s, k, u, ns);
            b.observe(s, k, u, ns);
        }
        for s in 0..2 {
            for k in [AlgoKind::Knn, AlgoKind::Kmeans, AlgoKind::Nbody, AlgoKind::RangeJoin] {
                for units in [1u64, 50, 1_000, 123_456] {
                    assert_eq!(a.predict_ns(s, k, units, 8), b.predict_ns(s, k, units, 8));
                }
            }
        }
        assert_eq!(a.observations(), 6);
    }

    #[test]
    fn zero_cost_and_out_of_range_observations_are_ignored() {
        let mut c = calibrator(1);
        c.observe(0, AlgoKind::Knn, 0, 999);
        assert!(!c.is_warm(0, AlgoKind::Knn), "zero-cost unit defines no rate");
        c.observe(0, AlgoKind::Knn, 10, 0);
        assert!(!c.is_warm(0, AlgoKind::Knn), "zero-ns measurement defines no rate");
        c.observe(5, AlgoKind::Knn, 10, 100); // shard out of range
        assert_eq!(c.observations(), 0);
        // Out-of-range predictions fall back to the seed, not panic.
        let _ = c.predict_ns(9, AlgoKind::Knn, 10, 8);
    }
}
