//! Placement layer of the serving runtime: which shard runs what.
//!
//! Owns the [`EnginePool`] — N independent [`Engine`] shards over one
//! shared [`crate::runtime::Runtime`] (so the kernel cache is paid for
//! once) — and the [`ShardPlanner`], which partitions a flush's work
//! units across the shards by cost estimate.  Placement never looks
//! inside a unit beyond its cost: admission decides *what* runs,
//! execution decides *how*; this layer only decides *where*.
//!
//! Placement cannot affect results: every work unit is self-contained
//! (the parity contract holds for any shard count), so the planner is
//! free to optimize purely for balance.  It uses the classic LPT
//! (longest-processing-time-first) greedy — sort units by descending
//! cost, assign each to the least-loaded shard — which is within 4/3
//! of the optimal makespan and, with deterministic tie-breaking, makes
//! placement reproducible run to run.
//!
//! LPT balances *a-priori estimates*; when they misfire (skewed filter
//! survival, a cohort converging early), the [`WorkPool`] corrects at
//! runtime: shard queues hold not-yet-started units, shards claim
//! their own units incrementally (one per lockstep round), and an idle
//! shard **steals** whole not-yet-started units from a busy victim.
//! Stealing relocates only work, never state — units are
//! self-contained, so results stay bit-identical; only which shard's
//! caches warm up changes.

use std::collections::VecDeque;

use crate::coordinator::Engine;
use crate::Result;

/// A pool of independent engine shards sharing one runtime.
pub struct EnginePool {
    engines: Vec<Engine>,
}

impl EnginePool {
    /// Build a pool of `shards` engines (>= 1): the given engine plus
    /// `shards - 1` clones of its configuration over the same shared
    /// runtime.
    pub fn new(primary: Engine, shards: usize) -> Result<Self> {
        let shards = shards.max(1);
        let mut engines = Vec::with_capacity(shards);
        let cfg = primary.config.clone();
        let runtime = primary.runtime.clone();
        engines.push(primary);
        for _ in 1..shards {
            engines.push(Engine::with_runtime(cfg.clone(), runtime.clone())?);
        }
        Ok(Self { engines })
    }

    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    /// The first shard — the engine existing single-engine callers see
    /// through `QueryBatcher::engine()`.
    pub fn primary(&self) -> &Engine {
        &self.engines[0]
    }

    pub(crate) fn engines_mut(&mut self) -> &mut [Engine] {
        &mut self.engines
    }
}

/// Cost-balancing partitioner of work units onto shards.
pub struct ShardPlanner;

impl ShardPlanner {
    /// Assign unit indices to shards, balancing total cost (LPT
    /// greedy).  Returns one ascending index list per shard; every
    /// index in `0..costs.len()` appears exactly once.  Deterministic:
    /// cost ties break by unit index, load ties by shard index.
    pub fn partition(costs: &[u64], shards: usize) -> Vec<Vec<usize>> {
        let shards = shards.max(1);
        let mut order: Vec<usize> = (0..costs.len()).collect();
        order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; shards];
        let mut out = vec![Vec::new(); shards];
        for i in order {
            let s = (0..shards)
                .min_by_key(|&s| (load[s], s))
                .expect("at least one shard");
            // Even zero-cost units occupy a slot, so they still
            // spread instead of all landing on shard 0.
            load[s] += costs[i].max(1);
            out[s].push(i);
        }
        for units in &mut out {
            units.sort_unstable();
        }
        out
    }
}

/// Flush-scoped shared queue of not-yet-started work units, one
/// pending FIFO per shard (behind one mutex at the execution layer).
///
/// Shards pull their *own* pending units via [`WorkPool::claim_own`];
/// an idle shard (nothing resident, own queue empty) may
/// [`WorkPool::steal`] from a victim.  Steal rules, all deterministic:
///
/// * only not-yet-started units move — a running program stays where
///   its caches are warm;
/// * the victim must have claimed at least one unit already (a shard
///   that has not even started is about to run its queue itself;
///   robbing it would merely relocate work and its cache warm-up);
/// * the most expensive eligible unit wins (ties: lowest unit index),
///   and it must cost at least `min_cost` — tiny units are not worth
///   migrating.
///
/// Generic over the unit type so the policy is testable without
/// constructing real cohorts.
pub(crate) struct WorkPool<T> {
    slots: Vec<Option<T>>,
    costs: Vec<u64>,
    pending: Vec<VecDeque<usize>>,
    claimed: Vec<usize>,
}

impl<T> WorkPool<T> {
    /// `assignments[s]` lists the unit indices the planner gave shard
    /// `s` (each index in `0..units.len()` at most once).
    pub fn new(units: Vec<T>, costs: Vec<u64>, assignments: &[Vec<usize>]) -> Self {
        debug_assert_eq!(units.len(), costs.len());
        Self {
            slots: units.into_iter().map(Some).collect(),
            costs,
            pending: assignments.iter().map(|idxs| idxs.iter().copied().collect()).collect(),
            claimed: vec![0; assignments.len()],
        }
    }

    /// Next not-yet-started unit assigned to `shard`, in placement
    /// order.
    pub fn claim_own(&mut self, shard: usize) -> Option<T> {
        let i = self.pending[shard].pop_front()?;
        self.claimed[shard] += 1;
        Some(self.slots[i].take().expect("unit claimed twice"))
    }

    /// Whether some OTHER shard still holds a pending unit that meets
    /// the cost bar — i.e. a unit that either is stealable now or will
    /// become stealable the moment its owner starts.  An idle thief
    /// whose `steal` came up empty uses this to decide between
    /// retrying (the victim merely has not started yet) and exiting
    /// (nothing will ever qualify).
    pub fn stealable_prospect(&self, thief: usize, min_cost: u64) -> bool {
        (0..self.pending.len()).any(|victim| {
            victim != thief
                && self.pending[victim].iter().any(|&i| self.costs[i].max(1) >= min_cost)
        })
    }

    /// Whether any queue's *tail* — everything behind the first unit,
    /// which its owner always claims before anything becomes stealable
    /// — holds a unit meeting the cost bar: i.e. whether stealing
    /// could ever fire at all.  The execution layer uses this to
    /// decide whether idle shards spawn as thieves for a flush.
    pub fn any_tail_prospect(&self, min_cost: u64) -> bool {
        self.pending.iter().any(|queue| {
            queue.len() >= 2
                && queue.iter().skip(1).any(|&i| self.costs[i].max(1) >= min_cost)
        })
    }

    /// Steal the best eligible unit for `thief` (see type docs for the
    /// rules), or `None` when nothing qualifies.
    pub fn steal(&mut self, thief: usize, min_cost: u64) -> Option<T> {
        let mut best: Option<(u64, usize, usize)> = None; // (cost, unit, victim)
        for victim in 0..self.pending.len() {
            if victim == thief || self.claimed[victim] == 0 {
                continue;
            }
            for &i in &self.pending[victim] {
                // Zero-cost units still occupy a slot (mirrors the
                // planner's load accounting), so they stay stealable
                // at the default threshold of 1.
                let cost = self.costs[i].max(1);
                if cost < min_cost {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bc, bi, _)) => cost > bc || (cost == bc && i < bi),
                };
                if better {
                    best = Some((cost, i, victim));
                }
            }
        }
        let (_, i, victim) = best?;
        self.pending[victim].retain(|&x| x != i);
        self.claimed[thief] += 1;
        Some(self.slots[i].take().expect("unit stolen twice"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten(mut parts: Vec<Vec<usize>>) -> Vec<usize> {
        let mut all: Vec<usize> = parts.drain(..).flatten().collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn partition_covers_every_unit_exactly_once() {
        let costs = [5, 1, 9, 3, 3, 7];
        for shards in [1, 2, 3, 4, 8] {
            let parts = ShardPlanner::partition(&costs, shards);
            assert_eq!(parts.len(), shards);
            assert_eq!(flatten(parts), (0..costs.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn partition_balances_load() {
        // LPT on [9,7,5,3,3,1] over 2 shards: {9,3,3} vs {7,5,1} —
        // loads 15 vs 13, optimal within the LPT bound.
        let costs = [5, 1, 9, 3, 3, 7];
        let parts = ShardPlanner::partition(&costs, 2);
        let load =
            |p: &Vec<usize>| -> u64 { p.iter().map(|&i| costs[i]).sum() };
        let (a, b) = (load(&parts[0]), load(&parts[1]));
        assert_eq!(a + b, 28);
        assert!(a.abs_diff(b) <= 2, "unbalanced: {a} vs {b}");
    }

    #[test]
    fn partition_is_deterministic_and_single_shard_trivial() {
        let costs = [2, 2, 2, 2];
        assert_eq!(
            ShardPlanner::partition(&costs, 2),
            ShardPlanner::partition(&costs, 2)
        );
        assert_eq!(ShardPlanner::partition(&costs, 1), vec![vec![0, 1, 2, 3]]);
        // More shards than units: extras stay empty.
        let parts = ShardPlanner::partition(&[4, 2], 4);
        assert_eq!(parts.iter().filter(|p| !p.is_empty()).count(), 2);
    }

    #[test]
    fn zero_cost_units_still_spread() {
        let parts = ShardPlanner::partition(&[0, 0, 0, 0], 2);
        assert_eq!(parts[0].len(), 2);
        assert_eq!(parts[1].len(), 2);
    }

    /// Units "a".."e" with costs, shard 0 owns 0..=2, shard 1 owns 3..=4.
    fn pool() -> WorkPool<&'static str> {
        WorkPool::new(
            vec!["a", "b", "c", "d", "e"],
            vec![5, 9, 2, 4, 4],
            &[vec![0, 1, 2], vec![3, 4]],
        )
    }

    #[test]
    fn claim_own_is_fifo_in_placement_order() {
        let mut p = pool();
        assert_eq!(p.claim_own(0), Some("a"));
        assert_eq!(p.claim_own(0), Some("b"));
        assert_eq!(p.claim_own(1), Some("d"));
        assert_eq!(p.claim_own(0), Some("c"));
        assert_eq!(p.claim_own(0), None);
    }

    #[test]
    fn steal_requires_a_started_victim() {
        let mut p = pool();
        // Shard 0 has not claimed anything yet: nothing is stealable —
        // but its queue IS a prospect, so an idle thief waits instead
        // of exiting.
        assert!(p.steal(1, 1).is_none());
        assert!(p.stealable_prospect(1, 1));
        assert!(!p.stealable_prospect(1, 100), "no unit meets a cost bar of 100");
        // Tail prospect (the thief-spawn gate): shard 0's tail [b, c]
        // qualifies at 1 and at 9 (unit b), but not at 10.
        assert!(p.any_tail_prospect(1));
        assert!(p.any_tail_prospect(9));
        assert!(!p.any_tail_prospect(10));
        // Once shard 0 started, its backlog is fair game — the most
        // expensive pending unit goes first.
        assert_eq!(p.claim_own(0), Some("a"));
        assert_eq!(p.steal(1, 1), Some("b"));
        assert_eq!(p.steal(1, 1), Some("c"));
        assert!(p.steal(1, 1).is_none(), "victim's queue drained");
        assert!(!p.stealable_prospect(1, 1), "no prospect left either");
        // The victim keeps claiming what is left of its own queue.
        assert_eq!(p.claim_own(0), None);
    }

    #[test]
    fn steal_respects_the_cost_threshold() {
        let mut p = pool();
        p.claim_own(0);
        // Threshold above every pending cost: no steal.
        assert!(p.steal(1, 100).is_none());
        // "b" (cost 9) qualifies at threshold 9; "c" (cost 2) does not.
        assert_eq!(p.steal(1, 9), Some("b"));
        assert!(p.steal(1, 9).is_none());
    }

    #[test]
    fn steal_never_takes_from_the_thief_and_ties_break_low() {
        let mut p: WorkPool<u32> =
            WorkPool::new(vec![10, 11, 12], vec![4, 4, 4], &[vec![0, 1], vec![2]]);
        p.claim_own(0);
        p.claim_own(1);
        // Thief 1: only shard 0's pending unit 1 is eligible (its own
        // queue is never a victim).
        assert_eq!(p.steal(1, 1), Some(11));
        // Equal costs tie-break by unit index.
        let mut p: WorkPool<u32> =
            WorkPool::new(vec![20, 21, 22], vec![4, 4, 4], &[vec![0, 1, 2], vec![]]);
        p.claim_own(0);
        assert_eq!(p.steal(1, 1), Some(21));
    }
}
