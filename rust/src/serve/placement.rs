//! Placement layer of the serving runtime: which shard runs what.
//!
//! Owns the [`EnginePool`] — N independent [`Engine`] shards over one
//! shared [`crate::runtime::Runtime`] (so the kernel cache is paid for
//! once) — and the [`ShardPlanner`], which partitions a flush's work
//! units across the shards by cost estimate.  Placement never looks
//! inside a unit beyond its cost: admission decides *what* runs,
//! execution decides *how*; this layer only decides *where*.
//!
//! Placement cannot affect results: every work unit is self-contained
//! (the parity contract holds for any shard count), so the planner is
//! free to optimize purely for balance.  It uses the classic LPT
//! (longest-processing-time-first) greedy — sort units by descending
//! cost, assign each to the least-loaded shard — which is within 4/3
//! of the optimal makespan and, with deterministic tie-breaking, makes
//! placement reproducible run to run.

use crate::coordinator::Engine;
use crate::Result;

/// A pool of independent engine shards sharing one runtime.
pub struct EnginePool {
    engines: Vec<Engine>,
}

impl EnginePool {
    /// Build a pool of `shards` engines (>= 1): the given engine plus
    /// `shards - 1` clones of its configuration over the same shared
    /// runtime.
    pub fn new(primary: Engine, shards: usize) -> Result<Self> {
        let shards = shards.max(1);
        let mut engines = Vec::with_capacity(shards);
        let cfg = primary.config.clone();
        let runtime = primary.runtime.clone();
        engines.push(primary);
        for _ in 1..shards {
            engines.push(Engine::with_runtime(cfg.clone(), runtime.clone())?);
        }
        Ok(Self { engines })
    }

    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    /// The first shard — the engine existing single-engine callers see
    /// through `QueryBatcher::engine()`.
    pub fn primary(&self) -> &Engine {
        &self.engines[0]
    }

    pub(crate) fn engines_mut(&mut self) -> &mut [Engine] {
        &mut self.engines
    }
}

/// Cost-balancing partitioner of work units onto shards.
pub struct ShardPlanner;

impl ShardPlanner {
    /// Assign unit indices to shards, balancing total cost (LPT
    /// greedy).  Returns one ascending index list per shard; every
    /// index in `0..costs.len()` appears exactly once.  Deterministic:
    /// cost ties break by unit index, load ties by shard index.
    pub fn partition(costs: &[u64], shards: usize) -> Vec<Vec<usize>> {
        let shards = shards.max(1);
        let mut order: Vec<usize> = (0..costs.len()).collect();
        order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; shards];
        let mut out = vec![Vec::new(); shards];
        for i in order {
            let s = (0..shards)
                .min_by_key(|&s| (load[s], s))
                .expect("at least one shard");
            // Even zero-cost units occupy a slot, so they still
            // spread instead of all landing on shard 0.
            load[s] += costs[i].max(1);
            out[s].push(i);
        }
        for units in &mut out {
            units.sort_unstable();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten(mut parts: Vec<Vec<usize>>) -> Vec<usize> {
        let mut all: Vec<usize> = parts.drain(..).flatten().collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn partition_covers_every_unit_exactly_once() {
        let costs = [5, 1, 9, 3, 3, 7];
        for shards in [1, 2, 3, 4, 8] {
            let parts = ShardPlanner::partition(&costs, shards);
            assert_eq!(parts.len(), shards);
            assert_eq!(flatten(parts), (0..costs.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn partition_balances_load() {
        // LPT on [9,7,5,3,3,1] over 2 shards: {9,3,3} vs {7,5,1} —
        // loads 15 vs 13, optimal within the LPT bound.
        let costs = [5, 1, 9, 3, 3, 7];
        let parts = ShardPlanner::partition(&costs, 2);
        let load =
            |p: &Vec<usize>| -> u64 { p.iter().map(|&i| costs[i]).sum() };
        let (a, b) = (load(&parts[0]), load(&parts[1]));
        assert_eq!(a + b, 28);
        assert!(a.abs_diff(b) <= 2, "unbalanced: {a} vs {b}");
    }

    #[test]
    fn partition_is_deterministic_and_single_shard_trivial() {
        let costs = [2, 2, 2, 2];
        assert_eq!(
            ShardPlanner::partition(&costs, 2),
            ShardPlanner::partition(&costs, 2)
        );
        assert_eq!(ShardPlanner::partition(&costs, 1), vec![vec![0, 1, 2, 3]]);
        // More shards than units: extras stay empty.
        let parts = ShardPlanner::partition(&[4, 2], 4);
        assert_eq!(parts.iter().filter(|p| !p.is_empty()).count(), 2);
    }

    #[test]
    fn zero_cost_units_still_spread() {
        let parts = ShardPlanner::partition(&[0, 0, 0, 0], 2);
        assert_eq!(parts[0].len(), 2);
        assert_eq!(parts[1].len(), 2);
    }
}
