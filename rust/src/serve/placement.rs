//! Placement layer of the serving runtime: which shard runs what.
//!
//! Owns the [`EnginePool`] — N independent [`Engine`] shards over one
//! shared [`crate::runtime::Runtime`] (so the kernel cache is paid for
//! once) — and the [`ShardPlanner`], which partitions a flush's work
//! units across the shards by cost estimate.  Placement never looks
//! inside a unit beyond its cost: admission decides *what* runs,
//! execution decides *how*; this layer only decides *where*.
//!
//! Placement cannot affect results: every work unit is self-contained
//! (the parity contract holds for any shard count), so the planner is
//! free to optimize for balance and urgency.  Two policies exist
//! ([`crate::config::PlacementMode`], `serve.placement`):
//!
//! * **`lpt`** — the classic LPT (longest-processing-time-first)
//!   greedy: sort units by descending cost, assign each to the
//!   least-loaded shard — within 4/3 of the optimal makespan and,
//!   with deterministic tie-breaking, reproducible run to run.
//! * **`edf-lpt`** (default) — the slack-weighted planner: units are
//!   ordered into earliest-deadline-first *tiers* (units sharing a
//!   deadline form one tier; deadline-free units form the last tier),
//!   LPT order within each tier, then the same least-loaded greedy.
//!   Urgent units are therefore assigned while shards are still
//!   lightly loaded — and, combined with the [`WorkPool`]'s
//!   deadline-ordered claims, are claimed first on their shard.  With
//!   no deadlines (or one shared deadline) the tier structure
//!   collapses and `edf-lpt` IS pure LPT.
//! * **`predicted-p99`** — the calibrated tail-bounder
//!   ([`ShardPlanner::plan_predicted_p99`]): units are priced in
//!   predicted nanoseconds through `serve::calibrate` and each goes to
//!   the shard whose predicted finish time keeps it inside its
//!   deadline, bounding per-shard predicted tails instead of abstract
//!   makespan.
//!
//! The planner balances *a-priori estimates*; when they misfire
//! (skewed filter survival, a cohort converging early), the
//! [`WorkPool`] corrects at runtime: shard queues hold not-yet-started
//! units, shards claim their own units incrementally (one per lockstep
//! round, most urgent first), and an idle shard **steals** whole
//! not-yet-started units from a busy victim — preferring the most
//! urgent at-risk unit when a deadline has expired, the max-cost unit
//! otherwise.  Stealing relocates only work, never state — units are
//! self-contained, so results stay bit-identical; only which shard's
//! caches warm up changes.

use std::collections::VecDeque;

use crate::config::PlacementMode;
use crate::coordinator::Engine;
use crate::runtime::DeviceTopology;
use crate::Result;

use super::clock::Tick;

/// A pool of independent engine shards sharing one runtime, each
/// pinned to one emulated device of a [`DeviceTopology`] (round-robin:
/// `shard % devices`).  The pinning decides whose memory budget clamps
/// the shard's slab cache and whose DMA link prices its data movement;
/// compute still runs through the one shared runtime, so the device
/// count cannot change results (serve parity contract).
pub struct EnginePool {
    engines: Vec<Engine>,
    topology: DeviceTopology,
}

impl EnginePool {
    /// Build a pool of `shards` engines (>= 1): the given engine plus
    /// `shards - 1` clones of its configuration over the same shared
    /// runtime, all pinned to a single-device topology.
    pub fn new(primary: Engine, shards: usize) -> Result<Self> {
        Self::with_topology(primary, shards, DeviceTopology::new(1, 0, 16.0))
    }

    /// Build a pool of `shards` engines pinned round-robin onto
    /// `topology`'s devices.
    pub fn with_topology(
        primary: Engine,
        shards: usize,
        topology: DeviceTopology,
    ) -> Result<Self> {
        let shards = shards.max(1);
        let mut engines = Vec::with_capacity(shards);
        let cfg = primary.config.clone();
        let runtime = primary.runtime.clone();
        engines.push(primary);
        for _ in 1..shards {
            engines.push(Engine::with_runtime(cfg.clone(), runtime.clone())?);
        }
        Ok(Self { engines, topology })
    }

    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    /// The first shard — the engine existing single-engine callers see
    /// through `QueryBatcher::engine()`.
    pub fn primary(&self) -> &Engine {
        &self.engines[0]
    }

    pub(crate) fn engines_mut(&mut self) -> &mut [Engine] {
        &mut self.engines
    }

    /// The emulated device topology the shards are pinned onto.
    pub fn topology(&self) -> &DeviceTopology {
        &self.topology
    }

    /// The emulated device shard `shard` is pinned to.
    pub fn device_of(&self, shard: usize) -> usize {
        self.topology.device_for_shard(shard)
    }
}

/// Cost- and deadline-balancing partitioner of work units onto shards.
pub struct ShardPlanner;

impl ShardPlanner {
    /// Pure-LPT assignment (no deadline information): equivalent to
    /// [`ShardPlanner::plan`] with [`PlacementMode::Lpt`].
    pub fn partition(costs: &[u64], shards: usize) -> Vec<Vec<usize>> {
        Self::plan(costs, &vec![None; costs.len()], shards, PlacementMode::Lpt)
    }

    /// Assign unit indices to shards.  Returns one ascending index
    /// list per shard; every index in `0..costs.len()` appears exactly
    /// once.  Deterministic throughout: deadline ties fall back to the
    /// LPT order, cost ties break by unit index, load ties by shard
    /// index.
    ///
    /// Assignment order is the policy (see module docs):
    /// * [`PlacementMode::Lpt`] — descending cost.
    /// * [`PlacementMode::EdfLpt`] — earliest-deadline-first tiers
    ///   (deadline-free units last), descending cost within a tier.
    ///
    /// Each ordered unit goes to the least-loaded shard, so under
    /// `EdfLpt` the most urgent units land on still-empty shards.
    /// All-same-deadline (or all-`None`) degenerates to pure LPT.
    pub fn plan(
        costs: &[u64],
        deadlines: &[Option<Tick>],
        shards: usize,
        mode: PlacementMode,
    ) -> Vec<Vec<usize>> {
        Self::plan_with_movement(costs, deadlines, &[], shards, mode)
    }

    /// [`ShardPlanner::plan`] with a data-movement term: `move_units[i][s]`
    /// is the modeled cost (in the same units as `costs`) of the cold
    /// slab bytes unit `i` would have to upload to run on shard `s` —
    /// zero where the unit's slabs are already warm (see
    /// `CostModel::move_penalty_units`).  Each ordered unit goes to the
    /// shard minimizing `load + movement`, so a unit warm on shard A is
    /// cheaper there exactly by what the re-transfer would have cost.
    ///
    /// Movement rows are normalized by their row minimum before use:
    /// only *differences* between shards can steer placement, so a
    /// uniformly cold (or uniformly warm) unit places identically to
    /// the movement-blind planner — which also makes an empty
    /// `move_units` (or an all-equal table) behave exactly like
    /// [`ShardPlanner::plan`], preserving every existing balance and
    /// determinism property.  The accepted movement is charged to the
    /// shard's load (data transfer occupies the shard), keeping the
    /// greedy consistent with what it just decided.
    pub fn plan_with_movement(
        costs: &[u64],
        deadlines: &[Option<Tick>],
        move_units: &[Vec<u64>],
        shards: usize,
        mode: PlacementMode,
    ) -> Vec<Vec<usize>> {
        debug_assert_eq!(costs.len(), deadlines.len());
        debug_assert!(move_units.is_empty() || move_units.len() == costs.len());
        let shards = shards.max(1);
        let mut order: Vec<usize> = (0..costs.len()).collect();
        let tier = |i: usize| match mode {
            // One flat tier: deadlines are invisible to pure LPT.
            PlacementMode::Lpt => 0u64,
            PlacementMode::EdfLpt => deadlines[i].unwrap_or(Tick::MAX),
        };
        order.sort_by(|&a, &b| {
            tier(a).cmp(&tier(b)).then(costs[b].cmp(&costs[a])).then(a.cmp(&b))
        });
        let movement = |i: usize, s: usize| -> u64 {
            let Some(row) = move_units.get(i) else { return 0 };
            let min = row.iter().copied().min().unwrap_or(0);
            row.get(s).map_or(0, |&m| m - min)
        };
        let mut load = vec![0u64; shards];
        let mut out = vec![Vec::new(); shards];
        for i in order {
            let s = (0..shards)
                .min_by_key(|&s| (load[s].saturating_add(movement(i, s)), s))
                .expect("at least one shard");
            // Even zero-cost units occupy a slot, so they still
            // spread instead of all landing on shard 0.
            load[s] += costs[i].max(1) + movement(i, s);
            out[s].push(i);
        }
        for units in &mut out {
            units.sort_unstable();
        }
        out
    }

    /// Calibrated tail-bounding assignment ([`PlacementMode::PredictedP99`]):
    /// `pred_ns[i][s]` is the calibrated predicted service time of unit
    /// `i` on shard `s` in clock nanoseconds (compute plus the shard's
    /// modeled cold-transfer time), on the same timeline as
    /// `deadlines`.  Units are ordered EDF-first (predicted size
    /// descending within a tier), and each goes to the shard whose
    /// predicted finish time keeps the unit inside its deadline —
    /// preferring (1) shards where the unit would NOT miss, then
    /// (2) the earliest predicted finish, then (3) the lowest shard
    /// index.  Minimizing each unit's predicted finish bounds the
    /// per-shard tail directly instead of balancing abstract makespan:
    /// a shard predicted to be slow for a kind (learned rate) absorbs
    /// less of that kind even when raw cost balancing would load it.
    ///
    /// `now` anchors the timeline: every shard's first unit starts at
    /// `now`, so `deadlines` (absolute ticks) compare directly.
    /// Deterministic for fixed inputs; order-only by construction
    /// (every unit still runs — placement never drops work).
    pub fn plan_predicted_p99(
        pred_ns: &[Vec<u64>],
        deadlines: &[Option<Tick>],
        shards: usize,
        now: Tick,
    ) -> Vec<Vec<usize>> {
        debug_assert_eq!(pred_ns.len(), deadlines.len());
        let shards = shards.max(1);
        let n = pred_ns.len();
        // Tier-first order mirrors EDF-LPT; within a tier, the unit's
        // best-case (min over shards) prediction stands in for cost.
        let size = |i: usize| pred_ns[i].iter().copied().min().unwrap_or(0);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            let tier = |i: usize| deadlines[i].unwrap_or(Tick::MAX);
            tier(a).cmp(&tier(b)).then(size(b).cmp(&size(a))).then(a.cmp(&b))
        });
        let mut finish = vec![now; shards];
        let mut out = vec![Vec::new(); shards];
        for i in order {
            let deadline = deadlines[i].unwrap_or(Tick::MAX);
            let s = (0..shards)
                .min_by_key(|&s| {
                    let done = finish[s].saturating_add(pred_ns[i].get(s).copied().unwrap_or(0));
                    (done > deadline, done, s)
                })
                .expect("at least one shard");
            finish[s] = finish[s].saturating_add(pred_ns[i].get(s).copied().unwrap_or(0).max(1));
            out[s].push(i);
        }
        for units in &mut out {
            units.sort_unstable();
        }
        out
    }
}

/// Flush-scoped shared queue of not-yet-started work units, one
/// pending queue per shard (behind one mutex at the execution layer).
///
/// Shards pull their *own* pending units via [`WorkPool::claim_own`] —
/// most urgent deadline first, placement order among equals — so an
/// urgent unit is never parked behind a patient one on its own shard.
/// An idle shard (nothing resident, own queue empty) may
/// [`WorkPool::steal`] from a victim.  Steal rules, all deterministic:
///
/// * only not-yet-started units move — a running program stays where
///   its caches are warm;
/// * the victim must have claimed at least one unit already (a shard
///   that has not even started is about to run its queue itself;
///   robbing it would merely relocate work and its cache warm-up);
/// * every candidate must cost at least `min_cost` — tiny units are
///   not worth migrating;
/// * when any candidate's deadline is **at risk** — its deadline lands
///   inside the unit's calibrated predicted service window starting
///   `now` ([`WorkPool::set_predictions`]), or, without predictions,
///   has already expired — the most urgent such unit wins (ties:
///   higher cost, then lowest unit index) — an idle thief rescues the
///   deadline *before* it expires instead of after; otherwise the most
///   expensive candidate wins (ties: lowest unit index), the classic
///   makespan correction.
///
/// Generic over the unit type so the policy is testable without
/// constructing real cohorts.
pub(crate) struct WorkPool<T> {
    slots: Vec<Option<T>>,
    costs: Vec<u64>,
    deadlines: Vec<Option<Tick>>,
    /// `move_units[i][s]`: modeled cost of the cold bytes unit `i`
    /// would re-transfer to run on shard `s` (empty = movement-blind).
    /// Stealing discounts a candidate's value by the *thief's* entry —
    /// absolute, not row-normalized: the thief pays exactly its own
    /// cold bytes, wherever the unit was planned.
    move_units: Vec<Vec<u64>>,
    /// `pred_ns[i]`: calibrated predicted service nanoseconds of unit
    /// `i` (empty = no calibration).  Stealing judges a unit at-risk
    /// on *predicted* slack deficit — its deadline lands inside
    /// `now + pred_ns[i]` — instead of waiting for the deadline to
    /// expire outright, so an idle thief rescues the unit while the
    /// rescue can still succeed.
    pred_ns: Vec<u64>,
    pending: Vec<VecDeque<usize>>,
    claimed: Vec<usize>,
}

impl<T> WorkPool<T> {
    /// `assignments[s]` lists the unit indices the planner gave shard
    /// `s` (each index in `0..units.len()` at most once).
    /// Movement-blind: every steal values candidates at raw cost.
    pub fn new(
        units: Vec<T>,
        costs: Vec<u64>,
        deadlines: Vec<Option<Tick>>,
        assignments: &[Vec<usize>],
    ) -> Self {
        Self::with_movement(units, costs, deadlines, Vec::new(), assignments)
    }

    /// [`WorkPool::new`] plus the movement table the planner used (see
    /// [`ShardPlanner::plan_with_movement`]), enabling warmth-aware
    /// stealing.
    pub fn with_movement(
        units: Vec<T>,
        costs: Vec<u64>,
        deadlines: Vec<Option<Tick>>,
        move_units: Vec<Vec<u64>>,
        assignments: &[Vec<usize>],
    ) -> Self {
        debug_assert_eq!(units.len(), costs.len());
        debug_assert_eq!(units.len(), deadlines.len());
        debug_assert!(move_units.is_empty() || move_units.len() == units.len());
        Self {
            slots: units.into_iter().map(Some).collect(),
            costs,
            deadlines,
            move_units,
            pred_ns: Vec::new(),
            pending: assignments.iter().map(|idxs| idxs.iter().copied().collect()).collect(),
            claimed: vec![0; assignments.len()],
        }
    }

    /// Attach calibrated per-unit service-time predictions (see the
    /// `pred_ns` field docs).  Empty (the default) keeps the legacy
    /// expired-only at-risk rule.
    pub fn set_predictions(&mut self, pred_ns: Vec<u64>) {
        debug_assert!(pred_ns.is_empty() || pred_ns.len() == self.slots.len());
        self.pred_ns = pred_ns;
    }

    /// What stealing unit `i` is worth to `thief`: the unit's cost
    /// (the compute the steal offloads) minus the modeled cost of the
    /// cold bytes the thief's device would have to upload first.  A
    /// warm unit keeps its full value; a unit whose re-transfer
    /// outweighs its compute discounts to zero — below any positive
    /// `steal_threshold`, so it is never worth migrating.  With no
    /// movement table this IS the raw cost.
    fn steal_value(&self, i: usize, thief: usize) -> u64 {
        let penalty =
            self.move_units.get(i).and_then(|row| row.get(thief)).copied().unwrap_or(0);
        self.costs[i].max(1).saturating_sub(penalty)
    }

    /// Queue position `claim_own` would take next for `shard`: the
    /// pending unit with the earliest deadline (deadline-free units
    /// last), placement order among equals.
    fn claim_pos(&self, shard: usize) -> Option<usize> {
        let queue = &self.pending[shard];
        (0..queue.len())
            .min_by_key(|&pos| (self.deadlines[queue[pos]].unwrap_or(Tick::MAX), pos))
    }

    /// Next not-yet-started unit assigned to `shard`, most urgent
    /// deadline first (placement order among equals and for
    /// deadline-free units).
    pub fn claim_own(&mut self, shard: usize) -> Option<T> {
        self.claim_own_indexed(shard).map(|(_, unit)| unit)
    }

    /// [`WorkPool::claim_own`] plus the claimed unit's flush-scoped
    /// index (the key into the per-unit cost/prediction tables).
    pub fn claim_own_indexed(&mut self, shard: usize) -> Option<(usize, T)> {
        let pos = self.claim_pos(shard)?;
        let i = self.pending[shard].remove(pos).expect("claim position in range");
        self.claimed[shard] += 1;
        Some((i, self.slots[i].take().expect("unit claimed twice")))
    }

    /// Whether some OTHER shard still holds a pending unit that meets
    /// the cost bar — i.e. a unit that either is stealable now or will
    /// become stealable the moment its owner starts.  An idle thief
    /// whose `steal` came up empty uses this to decide between
    /// retrying (the victim merely has not started yet) and exiting
    /// (nothing will ever qualify).
    ///
    /// Judged on the SAME movement-discounted value as [`WorkPool::steal`]:
    /// a unit whose re-transfer cost eats its compute value is no
    /// prospect for this thief — otherwise the thief would spin
    /// forever waiting for a steal that can never fire.
    pub fn stealable_prospect(&self, thief: usize, min_cost: u64) -> bool {
        (0..self.pending.len()).any(|victim| {
            victim != thief
                && self.pending[victim].iter().any(|&i| self.steal_value(i, thief) >= min_cost)
        })
    }

    /// Whether any queue's *tail* — everything behind the unit its
    /// owner will claim first, which happens before anything becomes
    /// stealable — holds a unit meeting the cost bar: i.e. whether
    /// stealing could ever fire at all.  The execution layer uses this
    /// to decide whether idle shards spawn as thieves for a flush.
    pub fn any_tail_prospect(&self, min_cost: u64) -> bool {
        (0..self.pending.len()).any(|shard| {
            let queue = &self.pending[shard];
            queue.len() >= 2 && {
                let first = self.claim_pos(shard).expect("non-empty queue");
                (0..queue.len())
                    .any(|pos| pos != first && self.costs[queue[pos]].max(1) >= min_cost)
            }
        })
    }

    /// Steal the best eligible unit for `thief` at time `now` (see
    /// type docs for the rules), or `None` when nothing qualifies.
    /// Candidates are valued (and the `min_cost` bar applied) through
    /// the movement discount of [`WorkPool::steal_value`]: a slightly
    /// smaller unit whose slabs are warm on the thief beats a bigger
    /// one that would force a full slab re-transfer.
    pub fn steal(&mut self, thief: usize, min_cost: u64, now: Tick) -> Option<T> {
        self.steal_indexed(thief, min_cost, now).map(|(_, unit)| unit)
    }

    /// [`WorkPool::steal`] plus the stolen unit's flush-scoped index
    /// (the key into the per-unit cost/prediction tables).
    pub fn steal_indexed(&mut self, thief: usize, min_cost: u64, now: Tick) -> Option<(usize, T)> {
        // (at-risk deadline or MAX, value, unit, victim); at-risk
        // units dominate, then urgency, then the max-value rule.
        let mut best: Option<(Tick, u64, usize, usize)> = None;
        for victim in 0..self.pending.len() {
            if victim == thief || self.claimed[victim] == 0 {
                continue;
            }
            for &i in &self.pending[victim] {
                // Zero-cost units still occupy a slot (mirrors the
                // planner's load accounting), so they stay stealable
                // at the default threshold of 1 — unless the movement
                // discount says the migration costs more than it
                // saves.
                let cost = self.steal_value(i, thief);
                if cost < min_cost {
                    continue;
                }
                // At-risk: the deadline falls inside the unit's
                // predicted service window starting now — i.e. even an
                // immediate start is predicted to (or did) run past it.
                // Without predictions this degrades to "expired".
                let horizon =
                    now.saturating_add(self.pred_ns.get(i).copied().unwrap_or(0));
                let risk = match self.deadlines[i] {
                    Some(d) if d <= horizon => d,
                    _ => Tick::MAX,
                };
                let better = match best {
                    None => true,
                    Some((br, bc, bi, _)) => {
                        risk < br
                            || (risk == br && cost > bc)
                            || (risk == br && cost == bc && i < bi)
                    }
                };
                if better {
                    best = Some((risk, cost, i, victim));
                }
            }
        }
        let (_, _, i, victim) = best?;
        self.pending[victim].retain(|&x| x != i);
        self.claimed[thief] += 1;
        Some((i, self.slots[i].take().expect("unit stolen twice")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten(mut parts: Vec<Vec<usize>>) -> Vec<usize> {
        let mut all: Vec<usize> = parts.drain(..).flatten().collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn partition_covers_every_unit_exactly_once() {
        let costs = [5, 1, 9, 3, 3, 7];
        for shards in [1, 2, 3, 4, 8] {
            let parts = ShardPlanner::partition(&costs, shards);
            assert_eq!(parts.len(), shards);
            assert_eq!(flatten(parts), (0..costs.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn partition_balances_load() {
        // LPT on [9,7,5,3,3,1] over 2 shards: {9,3,3} vs {7,5,1} —
        // loads 15 vs 13, optimal within the LPT bound.
        let costs = [5, 1, 9, 3, 3, 7];
        let parts = ShardPlanner::partition(&costs, 2);
        let load =
            |p: &Vec<usize>| -> u64 { p.iter().map(|&i| costs[i]).sum() };
        let (a, b) = (load(&parts[0]), load(&parts[1]));
        assert_eq!(a + b, 28);
        assert!(a.abs_diff(b) <= 2, "unbalanced: {a} vs {b}");
    }

    #[test]
    fn partition_is_deterministic_and_single_shard_trivial() {
        let costs = [2, 2, 2, 2];
        assert_eq!(
            ShardPlanner::partition(&costs, 2),
            ShardPlanner::partition(&costs, 2)
        );
        assert_eq!(ShardPlanner::partition(&costs, 1), vec![vec![0, 1, 2, 3]]);
        // More shards than units: extras stay empty.
        let parts = ShardPlanner::partition(&[4, 2], 4);
        assert_eq!(parts.iter().filter(|p| !p.is_empty()).count(), 2);
    }

    #[test]
    fn zero_cost_units_still_spread() {
        let parts = ShardPlanner::partition(&[0, 0, 0, 0], 2);
        assert_eq!(parts[0].len(), 2);
        assert_eq!(parts[1].len(), 2);
    }

    // --- the EDF-LPT planner ------------------------------------------

    /// Reconstruct the planner's assignment order for one shard: which
    /// unit is claimed first under deadline-ordered claims.
    fn first_claim(parts: &[Vec<usize>], shard: usize, deadlines: &[Option<Tick>]) -> usize {
        *parts[shard]
            .iter()
            .min_by_key(|&&i| deadlines[i].unwrap_or(Tick::MAX))
            .expect("shard has units")
    }

    #[test]
    fn edf_orders_deadline_tiers_before_cost() {
        // Unit 2 is tiny but urgent; units 0/1 are heavy and patient.
        let costs = [100, 80, 10];
        let deadlines = [None, None, Some(5u64)];
        let parts = ShardPlanner::plan(&costs, &deadlines, 2, PlacementMode::EdfLpt);
        // EDF tier first: the urgent unit is assigned while both
        // shards are empty -> shard 0, and its shard's remaining load
        // (80) is the lighter one.
        assert!(parts[0].contains(&2), "urgent unit must go to the first empty shard");
        assert_eq!(parts[0], vec![1, 2]);
        assert_eq!(parts[1], vec![0]);
        assert_eq!(first_claim(&parts, 0, &deadlines), 2, "urgent unit claimed first");
        // Pure LPT ignores the deadline: 100 -> s0, 80 -> s1, urgent
        // 10 queues BEHIND the 80 on s1.
        let lpt = ShardPlanner::plan(&costs, &deadlines, 2, PlacementMode::Lpt);
        assert_eq!(lpt[0], vec![0]);
        assert_eq!(lpt[1], vec![1, 2]);
    }

    #[test]
    fn edf_ties_fall_back_to_lpt_and_degenerate_cases_are_pure_lpt() {
        let costs = [5, 1, 9, 3, 3, 7];
        // All-same-deadline: one tier -> identical to pure LPT.
        let same = vec![Some(40u64); costs.len()];
        assert_eq!(
            ShardPlanner::plan(&costs, &same, 2, PlacementMode::EdfLpt),
            ShardPlanner::partition(&costs, 2)
        );
        // All-None: also one (last) tier -> pure LPT.
        let none = vec![None; costs.len()];
        assert_eq!(
            ShardPlanner::plan(&costs, &none, 2, PlacementMode::EdfLpt),
            ShardPlanner::partition(&costs, 2)
        );
        // Deterministic: same inputs, same plan.
        let mixed = [Some(9u64), None, Some(3), Some(9), None, Some(3)];
        assert_eq!(
            ShardPlanner::plan(&costs, &mixed, 3, PlacementMode::EdfLpt),
            ShardPlanner::plan(&costs, &mixed, 3, PlacementMode::EdfLpt)
        );
        // Every unit appears exactly once under every mode.
        for mode in [PlacementMode::Lpt, PlacementMode::EdfLpt] {
            let parts = ShardPlanner::plan(&costs, &mixed, 3, mode);
            assert_eq!(flatten(parts), (0..costs.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn edf_sorts_no_deadline_units_last() {
        // One shard: the assignment order IS the claim order modulo
        // the ascending-index normalization, so probe via claim_pos
        // through a WorkPool instead.
        let costs = [4, 4, 4];
        let deadlines = [None, Some(50u64), Some(20u64)];
        let parts = ShardPlanner::plan(&costs, &deadlines, 1, PlacementMode::EdfLpt);
        assert_eq!(parts, vec![vec![0, 1, 2]]);
        let mut p: WorkPool<u32> =
            WorkPool::new(vec![10, 11, 12], costs.to_vec(), deadlines.to_vec(), &parts);
        assert_eq!(p.claim_own(0), Some(12), "earliest deadline first");
        assert_eq!(p.claim_own(0), Some(11));
        assert_eq!(p.claim_own(0), Some(10), "deadline-free unit last");
    }

    // --- the work pool -------------------------------------------------

    /// Units "a".."e" with costs, shard 0 owns 0..=2, shard 1 owns
    /// 3..=4.  No deadlines: claims stay placement-ordered FIFO.
    fn pool() -> WorkPool<&'static str> {
        WorkPool::new(
            vec!["a", "b", "c", "d", "e"],
            vec![5, 9, 2, 4, 4],
            vec![None; 5],
            &[vec![0, 1, 2], vec![3, 4]],
        )
    }

    #[test]
    fn claim_own_is_fifo_in_placement_order() {
        let mut p = pool();
        assert_eq!(p.claim_own(0), Some("a"));
        assert_eq!(p.claim_own(0), Some("b"));
        assert_eq!(p.claim_own(1), Some("d"));
        assert_eq!(p.claim_own(0), Some("c"));
        assert_eq!(p.claim_own(0), None);
    }

    #[test]
    fn claim_own_prefers_the_most_urgent_unit() {
        let mut p: WorkPool<&'static str> = WorkPool::new(
            vec!["patient", "urgent", "no-deadline"],
            vec![9, 1, 9],
            vec![Some(100), Some(10), None],
            &[vec![0, 1, 2]],
        );
        assert_eq!(p.claim_own(0), Some("urgent"));
        assert_eq!(p.claim_own(0), Some("patient"));
        assert_eq!(p.claim_own(0), Some("no-deadline"));
    }

    #[test]
    fn steal_requires_a_started_victim() {
        let mut p = pool();
        // Shard 0 has not claimed anything yet: nothing is stealable —
        // but its queue IS a prospect, so an idle thief waits instead
        // of exiting.
        assert!(p.steal(1, 1, 0).is_none());
        assert!(p.stealable_prospect(1, 1));
        assert!(!p.stealable_prospect(1, 100), "no unit meets a cost bar of 100");
        // Tail prospect (the thief-spawn gate): shard 0's tail [b, c]
        // qualifies at 1 and at 9 (unit b), but not at 10.
        assert!(p.any_tail_prospect(1));
        assert!(p.any_tail_prospect(9));
        assert!(!p.any_tail_prospect(10));
        // Once shard 0 started, its backlog is fair game — the most
        // expensive pending unit goes first.
        assert_eq!(p.claim_own(0), Some("a"));
        assert_eq!(p.steal(1, 1, 0), Some("b"));
        assert_eq!(p.steal(1, 1, 0), Some("c"));
        assert!(p.steal(1, 1, 0).is_none(), "victim's queue drained");
        assert!(!p.stealable_prospect(1, 1), "no prospect left either");
        // The victim keeps claiming what is left of its own queue.
        assert_eq!(p.claim_own(0), None);
    }

    #[test]
    fn steal_respects_the_cost_threshold() {
        let mut p = pool();
        p.claim_own(0);
        // Threshold above every pending cost: no steal.
        assert!(p.steal(1, 100, 0).is_none());
        // "b" (cost 9) qualifies at threshold 9; "c" (cost 2) does not.
        assert_eq!(p.steal(1, 9, 0), Some("b"));
        assert!(p.steal(1, 9, 0).is_none());
    }

    #[test]
    fn steal_never_takes_from_the_thief_and_ties_break_low() {
        let mut p: WorkPool<u32> = WorkPool::new(
            vec![10, 11, 12],
            vec![4, 4, 4],
            vec![None; 3],
            &[vec![0, 1], vec![2]],
        );
        p.claim_own(0);
        p.claim_own(1);
        // Thief 1: only shard 0's pending unit 1 is eligible (its own
        // queue is never a victim).
        assert_eq!(p.steal(1, 1, 0), Some(11));
        // Equal costs tie-break by unit index.
        let mut p: WorkPool<u32> = WorkPool::new(
            vec![20, 21, 22],
            vec![4, 4, 4],
            vec![None; 3],
            &[vec![0, 1, 2], vec![]],
        );
        p.claim_own(0);
        assert_eq!(p.steal(1, 1, 0), Some(21));
    }

    #[test]
    fn steal_prefers_the_most_urgent_at_risk_unit() {
        // Victim backlog: a heavy patient unit, a light unit whose
        // deadline expired at tick 10, and a lighter one expired at 5.
        let mut p: WorkPool<&'static str> = WorkPool::new(
            vec!["tiny", "heavy", "late10", "late5"],
            vec![1, 50, 8, 3],
            vec![None, None, Some(10), Some(5)],
            &[vec![0, 1, 2, 3], vec![]],
        );
        assert_eq!(p.claim_own(0), Some("late5"), "owner claims most urgent first");
        // At tick 20 both remaining deadlines are at risk... only
        // late10 is left with one; urgency beats the heavy unit.
        assert_eq!(p.steal(1, 1, 20), Some("late10"));
        // No at-risk unit left: fall back to max-cost.
        assert_eq!(p.steal(1, 1, 20), Some("heavy"));
        // Before any deadline expires, the plain max-cost rule holds.
        let mut p: WorkPool<&'static str> = WorkPool::new(
            vec!["tiny", "heavy", "urgent-later"],
            vec![1, 50, 3],
            vec![None, None, Some(1_000)],
            &[vec![0, 2, 1], vec![]],
        );
        assert_eq!(p.claim_own(0), Some("urgent-later"));
        assert_eq!(p.steal(1, 1, 0), Some("heavy"), "nothing at risk at tick 0");
    }

    // --- movement-aware placement & stealing ---------------------------

    #[test]
    fn movement_term_steers_equal_costs_to_the_warm_shard() {
        // Two equal-cost units; unit 0 is warm on shard 1, unit 1 on
        // shard 0.  Movement-blind LPT places by index (0 -> s0,
        // 1 -> s1); the movement term flips both to their warm shard.
        let costs = [100u64, 100];
        let none = [None, None];
        let moves = vec![vec![40u64, 0], vec![0, 40]];
        let parts = ShardPlanner::plan_with_movement(
            &costs,
            &none,
            &moves,
            2,
            PlacementMode::Lpt,
        );
        assert_eq!(parts, vec![vec![1], vec![0]], "each unit lands where it is warm");
        // Blind placement differs — the term did the steering.
        let blind = ShardPlanner::plan(&costs, &none, 2, PlacementMode::Lpt);
        assert_eq!(blind, vec![vec![0], vec![1]]);
    }

    #[test]
    fn uniform_or_empty_movement_is_exactly_the_blind_plan() {
        let costs = [5u64, 1, 9, 3, 3, 7];
        let deadlines = [Some(9u64), None, Some(3), Some(9), None, Some(3)];
        for mode in [PlacementMode::Lpt, PlacementMode::EdfLpt] {
            let blind = ShardPlanner::plan(&costs, &deadlines, 3, mode);
            // All-cold: every shard costs the same re-transfer.
            let cold = vec![vec![77u64; 3]; costs.len()];
            assert_eq!(
                ShardPlanner::plan_with_movement(&costs, &deadlines, &cold, 3, mode),
                blind,
                "uniform movement rows must not steer anything"
            );
            // Rows of different uniform heights: still no steering.
            let mixed: Vec<Vec<u64>> =
                (0..costs.len()).map(|i| vec![i as u64 * 13; 3]).collect();
            assert_eq!(
                ShardPlanner::plan_with_movement(&costs, &deadlines, &mixed, 3, mode),
                blind
            );
        }
    }

    #[test]
    fn movement_beats_load_only_when_it_outweighs_the_imbalance() {
        // Unit 1 is warm on shard 0, but shard 0 already carries unit
        // 0's 100-cost load.  A small warmth edge (10) loses to the
        // imbalance; a big one (200) wins.
        let costs = [100u64, 50];
        let none = [None, None];
        let small = vec![vec![0u64, 0], vec![0, 10]];
        let parts =
            ShardPlanner::plan_with_movement(&costs, &none, &small, 2, PlacementMode::Lpt);
        assert_eq!(parts, vec![vec![0], vec![1]], "10 cold units < 100 load imbalance");
        let big = vec![vec![0u64, 0], vec![0, 200]];
        let parts =
            ShardPlanner::plan_with_movement(&costs, &none, &big, 2, PlacementMode::Lpt);
        assert_eq!(parts, vec![vec![0, 1], vec![]], "200 cold units > 100 load imbalance");
    }

    #[test]
    fn steal_discounts_candidates_by_the_thiefs_cold_bytes() {
        // Victim backlog after its first claim: "cold-big" (cost 50,
        // 45 cold units for thief 1) vs "warm-small" (cost 40, warm).
        // Raw max-cost would take cold-big; the discount (50-45=5 vs
        // 40) takes the warm unit — the ISSUE's acceptance example.
        let mut p: WorkPool<&'static str> = WorkPool::with_movement(
            vec!["first", "cold-big", "warm-small"],
            vec![60, 50, 40],
            vec![None; 3],
            vec![vec![0, 0], vec![0, 45], vec![0, 0]],
            &[vec![0, 1, 2], vec![]],
        );
        assert_eq!(p.claim_own(0), Some("first"));
        assert_eq!(p.steal(1, 1, 0), Some("warm-small"), "warmth beats raw size");
        // The cold unit is still worth 5 > threshold 1: stolen next.
        assert_eq!(p.steal(1, 1, 0), Some("cold-big"));
    }

    #[test]
    fn prospect_uses_the_same_discounted_bar_as_steal() {
        // Regression: one pending unit, raw cost 50 but fully cold for
        // thief 1 (penalty 49 -> value 1 < threshold 5).  The old
        // raw-cost prospect said "wait for it" while steal() rejected
        // it forever — an idle thief spun.  Both must now agree.
        let mut p: WorkPool<&'static str> = WorkPool::with_movement(
            vec!["own", "cold"],
            vec![10, 50],
            vec![None; 2],
            vec![vec![0, 0], vec![0, 49]],
            &[vec![0, 1], vec![]],
        );
        assert_eq!(p.claim_own(0), Some("own"));
        assert!(p.steal(1, 5, 0).is_none(), "discounted value 1 misses the bar of 5");
        assert!(
            !p.stealable_prospect(1, 5),
            "prospect must agree with steal, or the thief spins"
        );
        // At a bar the discounted value does meet, both agree again.
        assert!(p.stealable_prospect(1, 1));
        assert_eq!(p.steal(1, 1, 0), Some("cold"));
        // And the discount is per-thief: the same unit would have been
        // a full-value prospect for a warm shard 2 (if one existed).
        let p2: WorkPool<&'static str> = WorkPool::with_movement(
            vec!["cold"],
            vec![50],
            vec![None],
            vec![vec![0, 49, 0]],
            &[vec![0], vec![], vec![]],
        );
        assert!(!p2.stealable_prospect(1, 5));
        assert!(p2.stealable_prospect(2, 5), "shard 2 is warm: full value 50");
    }

    // --- predicted-p99 placement & predicted-slack stealing ------------

    #[test]
    fn predicted_p99_avoids_the_shard_predicted_to_miss() {
        // Unit 0 (deadline 100) is predicted at 50 ns on shard 0 but
        // 150 ns on shard 1 (say shard 1's learned rate is slow for
        // its kind).  Makespan balancing is indifferent when loads tie
        // — the tail-bounder must pick the shard that meets the
        // deadline.
        let pred = vec![vec![50u64, 150]];
        let parts =
            ShardPlanner::plan_predicted_p99(&pred, &[Some(100u64)], 2, 0);
        assert_eq!(parts, vec![vec![0], vec![]]);
        // Anchored at now=80 even shard 0 is predicted to miss (done
        // 130 > 100): it still wins on earliest predicted finish.
        let parts =
            ShardPlanner::plan_predicted_p99(&pred, &[Some(100u64)], 2, 80);
        assert_eq!(parts, vec![vec![0], vec![]]);
    }

    #[test]
    fn predicted_p99_bounds_tails_rather_than_makespan() {
        // Three urgent units (deadline 100) of 60 ns each, one patient
        // 200 ns unit.  Tail-bounding packs at most one urgent unit
        // per shard before any shard's finish exceeds 100, and the
        // patient unit lands wherever it finishes earliest.
        let pred: Vec<Vec<u64>> = vec![
            vec![60, 60],
            vec![60, 60],
            vec![60, 60],
            vec![200, 200],
        ];
        let deadlines = [Some(100u64), Some(100), Some(100), None];
        let parts = ShardPlanner::plan_predicted_p99(&pred, &deadlines, 2, 0);
        // Units 0,1 land on distinct shards (both meet the deadline);
        // unit 2 must miss somewhere — earliest finish breaks the tie.
        let all: Vec<usize> = flatten(parts.clone());
        assert_eq!(all, vec![0, 1, 2, 3], "every unit assigned exactly once");
        assert!(
            !parts[0].contains(&0) || !parts[0].contains(&1),
            "two urgent units never stack while the other shard is free: {parts:?}"
        );
        // Deterministic.
        assert_eq!(parts, ShardPlanner::plan_predicted_p99(&pred, &deadlines, 2, 0));
    }

    #[test]
    fn predicted_p99_single_shard_and_empty_are_trivial() {
        assert_eq!(
            ShardPlanner::plan_predicted_p99(&[vec![10], vec![20]], &[None, None], 1, 0),
            vec![vec![0, 1]]
        );
        let empty: Vec<Vec<u64>> = Vec::new();
        assert_eq!(
            ShardPlanner::plan_predicted_p99(&empty, &[], 3, 0),
            vec![Vec::<usize>::new(); 3]
        );
    }

    #[test]
    fn steal_fires_on_predicted_slack_deficit_before_expiry() {
        // Victim backlog: "doomed" has deadline 1_000 and a predicted
        // service time of 900 ns.  At now=200 the old rule sees
        // nothing at risk (1_000 > 200); the predicted rule sees
        // 1_000 <= 200 + 900 and rescues it ahead of the heavy unit.
        let build = || -> WorkPool<&'static str> {
            WorkPool::new(
                vec!["first", "heavy", "doomed"],
                vec![60, 50, 10],
                vec![None, None, Some(1_000)],
                &[vec![0, 1, 2], vec![]],
            )
        };
        let mut blind = build();
        assert_eq!(blind.claim_own(0), Some("doomed"), "owner claims most urgent first");
        assert_eq!(blind.steal(1, 1, 200), Some("heavy"), "expired-only rule grabs bulk");
        // "doomed" goes first to its owner above — probe the thief's
        // choice with it still pending behind another urgent unit.
        let mut p: WorkPool<&'static str> = WorkPool::new(
            vec!["urgent-now", "heavy", "doomed"],
            vec![10, 50, 10],
            vec![Some(150), None, Some(1_000)],
            &[vec![0, 1, 2], vec![]],
        );
        p.set_predictions(vec![0, 0, 900]);
        assert_eq!(p.claim_own(0), Some("urgent-now"));
        assert_eq!(
            p.steal(1, 1, 200),
            Some("doomed"),
            "predicted slack deficit beats the max-cost rule before expiry"
        );
        // Without predictions the same state steals the heavy unit.
        let mut q: WorkPool<&'static str> = WorkPool::new(
            vec!["urgent-now", "heavy", "doomed"],
            vec![10, 50, 10],
            vec![Some(150), None, Some(1_000)],
            &[vec![0, 1, 2], vec![]],
        );
        assert_eq!(q.claim_own(0), Some("urgent-now"));
        assert_eq!(q.steal(1, 1, 200), Some("heavy"));
    }

    #[test]
    fn engine_pool_pins_shards_round_robin() {
        use crate::config::AccdConfig;
        let engine = Engine::new(AccdConfig::new()).expect("engine");
        let pool =
            EnginePool::with_topology(engine, 4, DeviceTopology::new(2, 0, 16.0)).unwrap();
        assert_eq!(pool.shard_count(), 4);
        assert_eq!(pool.topology().device_count(), 2);
        assert_eq!(
            (0..4).map(|s| pool.device_of(s)).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
    }
}
