//! Admission layer of the serving runtime: the client-facing queue.
//!
//! Owns everything that happens *before* work is placed on an engine
//! shard: request/response types, the pending queue, deadline
//! bookkeeping, the [`FlushPolicy`] that decides *when* queries become
//! due, and the partition step that coalesces a drained batch into
//! [`WorkUnit`]s (KNN cohorts sharing a target grouping; deduplicated
//! K-means / N-body jobs).
//!
//! Identity is fingerprint-based: dataset equality resolves through a
//! per-flush [`FingerprintMemo`] — `Arc` pointer equality first, then
//! the 128-bit [`crate::gti::fingerprint_pair`] (computed once per
//! distinct `Arc`, and reused downstream for grouping-cache keys and
//! slab-cache scopes) — so deserialized-identical datasets never cost
//! a full O(n·d) point comparison.
//!
//! All deadline bookkeeping runs on injected [`super::clock::Clock`]
//! [`Tick`]s, never on `Instant`: the batcher stamps `submitted_at`
//! and absolute deadlines at admission, [`FlushPolicy::select_due`]
//! compares them against the caller-provided `now`, and
//! [`partition`] threads each [`WorkUnit`]'s *inherited* deadline (the
//! earliest across its member queries) through to placement and
//! execution — so tests drive every deadline semantic through a
//! `VirtualClock` instead of sleeping.

use std::collections::HashMap;
use std::sync::{Arc, Weak};
use std::time::Duration;

use crate::config::ServeConfig;
use crate::coordinator::{kmeans, knn, nbody, rangejoin};
use crate::coordinator::{KmeansResult, KnnResult, NbodyResult, RangeJoinResult};
use crate::data::Dataset;
use crate::gti::{self, Metric};
use crate::runtime::TileInfo;
use crate::Result;

use super::calibrate::AlgoKind;
use super::clock::{ticks, Tick};

/// Ticket handed back by `QueryBatcher::submit`.
pub type QueryId = u64;

/// One client request against a registered (reference-counted) dataset.
#[derive(Debug, Clone)]
pub enum ServeRequest {
    /// K nearest targets for every source point.
    Knn { src: Arc<Dataset>, trg: Arc<Dataset>, k: usize, metric: Metric },
    /// Every target within `threshold` of every source point (radius
    /// query / range join).  `threshold` is in metric units.
    RangeJoin { src: Arc<Dataset>, trg: Arc<Dataset>, threshold: f32, metric: Metric },
    /// Lloyd clustering of `ds` into `k` clusters.
    Kmeans { ds: Arc<Dataset>, k: usize, max_iters: usize },
    /// Radius-limited gravitational integration.
    Nbody {
        ds: Arc<Dataset>,
        masses: Arc<Vec<f32>>,
        steps: usize,
        dt: f32,
        radius: f32,
    },
}

impl ServeRequest {
    /// Euclidean KNN-join request.
    pub fn knn(src: Arc<Dataset>, trg: Arc<Dataset>, k: usize) -> Self {
        Self::knn_metric(src, trg, k, Metric::L2)
    }

    pub fn knn_metric(src: Arc<Dataset>, trg: Arc<Dataset>, k: usize, metric: Metric) -> Self {
        Self::Knn { src, trg, k, metric }
    }

    /// Euclidean range-join request.
    pub fn rangejoin(src: Arc<Dataset>, trg: Arc<Dataset>, threshold: f32) -> Self {
        Self::rangejoin_metric(src, trg, threshold, Metric::L2)
    }

    pub fn rangejoin_metric(
        src: Arc<Dataset>,
        trg: Arc<Dataset>,
        threshold: f32,
        metric: Metric,
    ) -> Self {
        Self::RangeJoin { src, trg, threshold, metric }
    }

    pub fn kmeans(ds: Arc<Dataset>, k: usize, max_iters: usize) -> Self {
        Self::Kmeans { ds, k, max_iters }
    }

    pub fn nbody(
        ds: Arc<Dataset>,
        masses: Arc<Vec<f32>>,
        steps: usize,
        dt: f32,
        radius: f32,
    ) -> Self {
        Self::Nbody { ds, masses, steps, dt, radius }
    }

    /// Calibrator kind axis of this request.
    pub(crate) fn kind(&self) -> AlgoKind {
        match self {
            Self::Knn { .. } => AlgoKind::Knn,
            Self::RangeJoin { .. } => AlgoKind::RangeJoin,
            Self::Kmeans { .. } => AlgoKind::Kmeans,
            Self::Nbody { .. } => AlgoKind::Nbody,
        }
    }

    /// Dimensionality of the request's distance pairs (the calibrator
    /// seed rate's `d`).
    pub(crate) fn dim(&self) -> usize {
        match self {
            Self::Knn { trg, .. } | Self::RangeJoin { trg, .. } => trg.d(),
            Self::Kmeans { ds, .. } | Self::Nbody { ds, .. } => ds.d(),
        }
    }

    /// Abstract cost of serving this request alone — the single-query
    /// analogue of [`WorkUnit::cost_estimate`], used by predictive
    /// shedding to price a query before it is partitioned into units.
    pub(crate) fn solo_cost_units(&self) -> u64 {
        match self {
            Self::Knn { src, trg, .. } | Self::RangeJoin { src, trg, .. } => {
                let t = trg.n() as u64;
                t + src.n() as u64 * t
            }
            Self::Kmeans { ds, k, max_iters } => {
                ds.n() as u64 * *k as u64 * (*max_iters as u64 + 1)
            }
            Self::Nbody { ds, steps, .. } => {
                let n = ds.n() as u64;
                n * n * *steps as u64
            }
        }
    }
}

/// The answer to one [`ServeRequest`], in the exact shape the solo
/// engine entry points return.
#[derive(Debug, Clone)]
pub enum ServeResponse {
    Knn(KnnResult),
    RangeJoin(RangeJoinResult),
    Kmeans(KmeansResult),
    Nbody(NbodyResult),
}

impl ServeResponse {
    pub fn as_knn(&self) -> Option<&KnnResult> {
        match self {
            Self::Knn(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_rangejoin(&self) -> Option<&RangeJoinResult> {
        match self {
            Self::RangeJoin(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_kmeans(&self) -> Option<&KmeansResult> {
        match self {
            Self::Kmeans(r) => Some(r),
            _ => None,
        }
    }

    pub fn as_nbody(&self) -> Option<&NbodyResult> {
        match self {
            Self::Nbody(r) => Some(r),
            _ => None,
        }
    }
}

// --- content identity ------------------------------------------------------

/// Memo of dataset fingerprints, keyed by `Arc` address and guarded
/// by a [`Weak`] reference to the allocation the address was taken
/// from.  An address alone is NOT identity: a dataset `Arc` dropped
/// between flushes can have its allocation reused by a *different*
/// dataset at the same address (ABA), so a hit only counts while the
/// original allocation is still alive — a successful upgrade at the
/// same address is the same allocation.  Holding `Weak` (not strong)
/// references also means the memo never pins point data: a dataset
/// dropped by its last client is freed immediately, not at the next
/// prune.  Content identity of two datasets then costs pointer
/// equality in the common case, one `fingerprint_pair` pass per
/// *distinct live* `Arc` otherwise — never a repeated full point
/// scan, even for deserialized-identical duplicates.  Equal 128-bit
/// pairs imply equal content under the same ~2^-128 collision
/// assumption the grouping cache already relies on.
///
/// The batcher keeps one memo for its lifetime and [`prunes`] it to
/// the still-pending datasets after every flush attempt: repeated
/// `poll`s over a deep patient queue never re-hash an unchanged
/// dataset.
///
/// [`prunes`]: FingerprintMemo::prune
#[derive(Default)]
pub struct FingerprintMemo {
    map: HashMap<usize, (Weak<Dataset>, (u64, u64))>,
    /// Full element-wise comparisons performed where no fingerprint
    /// fast path exists (today: only N-body mass vectors), over the
    /// memo's lifetime.
    pub full_scans: u64,
}

impl FingerprintMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// The 128-bit content fingerprint of `ds`, computed at most once
    /// per distinct live `Arc`.
    pub fn fingerprint(&mut self, ds: &Arc<Dataset>) -> (u64, u64) {
        let key = Arc::as_ptr(ds) as usize;
        if let Some((live, fp)) = self.map.get(&key) {
            // The upgrade proves the memoized allocation is the one
            // `ds` points at; a dead entry is a reused address and
            // must be re-fingerprinted, never trusted.
            if live.upgrade().is_some() {
                return *fp;
            }
        }
        let fp = gti::fingerprint_pair(&ds.points);
        self.map.insert(key, (Arc::downgrade(ds), fp));
        fp
    }

    /// Content equality of two datasets (names NOT compared).
    pub fn same_dataset(&mut self, a: &Arc<Dataset>, b: &Arc<Dataset>) -> bool {
        if Arc::ptr_eq(a, b) {
            return true;
        }
        if a.points.rows() != b.points.rows() || a.points.cols() != b.points.cols() {
            return false;
        }
        self.fingerprint(a) == self.fingerprint(b)
    }

    /// Drop memoized fingerprints whose dataset no longer appears in
    /// any pending request (or whose allocation has died — a reused
    /// address must never inherit a stale fingerprint), keeping the
    /// memo's footprint bounded by the queue.  Fingerprints of
    /// still-pending datasets survive — repeated polls never re-hash
    /// them.
    pub(crate) fn prune(&mut self, queue: &AdmissionQueue) {
        if self.map.is_empty() {
            return;
        }
        let mut pending = std::collections::HashSet::new();
        for p in &queue.pending {
            match &p.req {
                ServeRequest::Knn { src, trg, .. }
                | ServeRequest::RangeJoin { src, trg, .. } => {
                    pending.insert(Arc::as_ptr(src) as usize);
                    pending.insert(Arc::as_ptr(trg) as usize);
                }
                ServeRequest::Kmeans { ds, .. } | ServeRequest::Nbody { ds, .. } => {
                    pending.insert(Arc::as_ptr(ds) as usize);
                }
            }
        }
        self.map.retain(|ptr, (live, _)| pending.contains(ptr) && live.upgrade().is_some());
    }

    /// Content equality of two mass vectors.  No fingerprint is kept
    /// for these (they are O(n), not O(n·d)); the fallback full scan
    /// is counted so it stays observable in `ServeStats`.
    pub fn same_masses(&mut self, a: &Arc<Vec<f32>>, b: &Arc<Vec<f32>>) -> bool {
        if Arc::ptr_eq(a, b) {
            return true;
        }
        if a.len() != b.len() {
            return false;
        }
        self.full_scans += 1;
        a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Dedup identity of two requests: same kind, same parameters,
    /// same dataset *name* (responses carry it in `report.dataset`, so
    /// a deduplicated answer must equal the solo answer exactly) and
    /// same dataset content.  This is exactly the identity the
    /// execution layer deduplicates under, which is what lets the
    /// admission layer give duplicates a shared (earliest) deadline.
    pub fn same_request(&mut self, a: &ServeRequest, b: &ServeRequest) -> bool {
        match (a, b) {
            (
                ServeRequest::Knn { src: sa, trg: ta, k: ka, metric: ma },
                ServeRequest::Knn { src: sb, trg: tb, k: kb, metric: mb },
            ) => {
                ka == kb
                    && ma == mb
                    && sa.name == sb.name
                    && self.same_dataset(sa, sb)
                    && self.same_dataset(ta, tb)
            }
            (
                ServeRequest::RangeJoin { src: sa, trg: ta, threshold: ha, metric: ma },
                ServeRequest::RangeJoin { src: sb, trg: tb, threshold: hb, metric: mb },
            ) => {
                ha.to_bits() == hb.to_bits()
                    && ma == mb
                    && sa.name == sb.name
                    && self.same_dataset(sa, sb)
                    && self.same_dataset(ta, tb)
            }
            (
                ServeRequest::Kmeans { ds: da, k: ka, max_iters: ia },
                ServeRequest::Kmeans { ds: db, k: kb, max_iters: ib },
            ) => ka == kb && ia == ib && da.name == db.name && self.same_dataset(da, db),
            (
                ServeRequest::Nbody { ds: da, masses: xa, steps: pa, dt: ta, radius: ra },
                ServeRequest::Nbody { ds: db, masses: xb, steps: pb, dt: tb, radius: rb },
            ) => {
                pa == pb
                    && ta.to_bits() == tb.to_bits()
                    && ra.to_bits() == rb.to_bits()
                    && da.name == db.name
                    && self.same_masses(xa, xb)
                    && self.same_dataset(da, db)
            }
            _ => false,
        }
    }
}

// --- pending queue ---------------------------------------------------------

/// One admitted, not-yet-executed query.
#[derive(Debug, Clone)]
pub(crate) struct Pending {
    pub id: QueryId,
    pub req: ServeRequest,
    /// Absolute due time (clock ticks); `None` waits for an explicit
    /// flush or the size trigger.
    pub deadline: Option<Tick>,
    /// Clock reading at admission — the latency accounting's zero.
    pub submitted_at: Tick,
}

/// FIFO queue of admitted queries.  Storage only — *when* entries
/// leave is the [`FlushPolicy`]'s decision.
#[derive(Default)]
pub(crate) struct AdmissionQueue {
    pending: Vec<Pending>,
    next_id: QueryId,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: ServeRequest, deadline: Option<Tick>, now: Tick) -> QueryId {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push(Pending { id, req, deadline, submitted_at: now });
        id
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn get(&self, i: usize) -> &Pending {
        &self.pending[i]
    }

    /// Earliest pending deadline, if any.  NOT a safe sleep target on
    /// its own: deadline-free queries make it `None` while work is
    /// still pending — [`FlushPolicy::next_wakeup`] is the
    /// trigger-aware sleep target a serving loop must use.
    pub fn next_deadline(&self) -> Option<Tick> {
        self.pending.iter().filter_map(|p| p.deadline).min()
    }

    /// Remove the entries at `sel` (ascending indices), preserving the
    /// relative order of both the removed and the remaining entries.
    pub fn remove_selected(&mut self, sel: &[usize]) -> Vec<Pending> {
        let mut take = vec![false; self.pending.len()];
        for &i in sel {
            take[i] = true;
        }
        let mut out = Vec::with_capacity(sel.len());
        let mut kept = Vec::with_capacity(self.pending.len().saturating_sub(sel.len()));
        for (i, p) in self.pending.drain(..).enumerate() {
            if take[i] {
                out.push(p);
            } else {
                kept.push(p);
            }
        }
        self.pending = kept;
        out
    }

    /// Put a drained batch back at the front (failed flush recovery),
    /// preserving its relative order.
    pub fn requeue_front(&mut self, batch: Vec<Pending>) {
        self.pending.splice(0..0, batch);
    }
}

// --- flush policy ----------------------------------------------------------

/// Decides when pending queries become due.
///
/// * `flush()` — explicit: the first `max_batch` pending queries
///   (all of them when `max_batch == 0`).
/// * `poll()` — deadline/size-triggered: if `max_batch` queries are
///   already pending, a full batch is due (size trigger); otherwise
///   exactly the queries whose deadline has expired — plus their
///   dedup-identical duplicates, which inherit the class's earliest
///   deadline — are due, so latency-sensitive queries stop waiting
///   for stragglers while under-deadline queries keep coalescing.
#[derive(Debug, Clone)]
pub struct FlushPolicy {
    /// Maximum queries per flush (0 = unbounded) and the size trigger.
    pub max_batch: usize,
    /// Deadline applied by `submit` when the caller gives none.
    pub default_deadline: Option<Duration>,
}

impl FlushPolicy {
    pub fn from_config(cfg: &ServeConfig) -> Self {
        let default_deadline =
            (cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms));
        Self { max_batch: cfg.max_batch, default_deadline }
    }

    /// Absolute deadline `submit` stamps on a new query.
    pub fn admission_deadline(&self, now: Tick) -> Option<Tick> {
        self.default_deadline.map(|d| now.saturating_add(ticks(d)))
    }

    /// The next tick at which a trigger could make pending work due —
    /// the serving loop's sleep target.  The deadline-only
    /// [`AdmissionQueue::next_deadline`] is `None` whenever every
    /// pending query is deadline-free, so a loop sleeping on it
    /// stalls forever on size-trigger-only workloads with admitted
    /// queries queued; this accounts for every trigger:
    ///
    /// * empty queue — `None`: nothing becomes due until a submit,
    ///   which wakes the loop by itself.
    /// * size trigger already met (`max_batch > 0` and a full batch
    ///   pending) — due `now`.
    /// * else the earliest pending deadline (`default_deadline` was
    ///   already stamped as a per-query deadline at admission, so it
    ///   is covered here).
    /// * deadline-free stragglers below the size trigger — due `now`:
    ///   no future trigger would ever fire for them on its own, so
    ///   the loop must flush them rather than sleep forever.
    pub(crate) fn next_wakeup(&self, queue: &AdmissionQueue, now: Tick) -> Option<Tick> {
        if queue.is_empty() {
            return None;
        }
        if self.max_batch > 0 && queue.len() >= self.max_batch {
            return Some(now);
        }
        Some(queue.next_deadline().unwrap_or(now))
    }

    /// Selection for an explicit flush: the queue's front.
    pub(crate) fn select_flush(&self, queue: &AdmissionQueue) -> Vec<usize> {
        let take =
            if self.max_batch == 0 { queue.len() } else { self.max_batch.min(queue.len()) };
        (0..take).collect()
    }

    /// Selection for `poll` at time `now`: indices (ascending) of the
    /// due queries (empty when nothing is due), plus whether the
    /// selection was triggered by an expired deadline — `false` for
    /// a pure size-triggered batch, so `ServeStats::deadline_flushes`
    /// counts only genuinely deadline-driven flushes.
    ///
    /// Due queries are selected first, regardless of queue position:
    /// an urgent query never waits behind a full batch of patient
    /// ones.  When `max_batch` queries are pending, the selection is
    /// then topped up from the queue's front to a full batch.
    pub(crate) fn select_due(
        &self,
        queue: &AdmissionQueue,
        now: Tick,
        dedup: bool,
        memo: &mut FingerprintMemo,
    ) -> (Vec<usize>, bool) {
        let n = queue.len();
        let mut due: Vec<bool> =
            (0..n).map(|i| queue.get(i).deadline.is_some_and(|d| d <= now)).collect();
        if dedup {
            // Duplicates inherit the earliest deadline of their
            // identity class: one pass suffices because identity is
            // transitive (anything identical to a newly-marked entry
            // is identical to the expired entry that marked it).
            for i in 0..n {
                if !due[i] {
                    continue;
                }
                for j in 0..n {
                    if !due[j] && memo.same_request(&queue.get(i).req, &queue.get(j).req) {
                        due[j] = true;
                    }
                }
            }
        }
        let mut sel: Vec<usize> = (0..n).filter(|&i| due[i]).collect();
        let deadline_triggered = !sel.is_empty();
        if self.max_batch > 0 {
            if sel.len() > self.max_batch {
                // Even the due set overflows a batch: serve the most
                // overdue first (inherited duplicates without their
                // own deadline rank as just-due).
                sel.sort_by_key(|&i| (queue.get(i).deadline.unwrap_or(now), i));
                sel.truncate(self.max_batch);
                sel.sort_unstable();
            } else if n >= self.max_batch {
                // Size trigger: top up with the queue's front.
                for i in 0..n {
                    if sel.len() >= self.max_batch {
                        break;
                    }
                    if !due[i] {
                        due[i] = true;
                        sel.push(i);
                    }
                }
                sel.sort_unstable();
            }
        }
        (sel, deadline_triggered)
    }
}

// --- admission-time validation ---------------------------------------------

/// The same argument checks the solo engine entry points perform
/// (shared helpers, so the two paths cannot diverge) plus the
/// tile-catalogue limits the planner would otherwise only hit
/// mid-flush — applied to every selected query *before* a flush
/// consumes anything.
pub(crate) fn validate_request(req: &ServeRequest, tile: &TileInfo) -> Result<()> {
    match req {
        ServeRequest::Knn { src, trg, k, .. } => {
            knn::validate(src, trg, *k)?;
            tile.pad_d(src.d())?;
            Ok(())
        }
        ServeRequest::RangeJoin { src, trg, threshold, .. } => {
            rangejoin::validate(src, trg, *threshold)?;
            tile.pad_d(src.d())?;
            Ok(())
        }
        ServeRequest::Kmeans { ds, k, .. } => {
            kmeans::validate(ds, *k)?;
            tile.pad_d(ds.d())?;
            tile.pad_kmeans_k(*k)?;
            Ok(())
        }
        ServeRequest::Nbody { ds, masses, .. } => nbody::validate(ds, masses),
    }
}

// --- partition: batch -> work units ----------------------------------------

/// One KNN query inside a cohort.
pub(crate) struct KnnQ {
    /// Index into the drained batch (response slot).
    pub pos: usize,
    pub src: Arc<Dataset>,
    pub src_fp: (u64, u64),
    pub k: usize,
}

impl KnnQ {
    /// Dedup identity of two queries *within one cohort* (the cohort
    /// already fixes target content and metric): parameters + source
    /// name + source content, by pointer or admission-computed
    /// fingerprint — the within-cohort half of
    /// [`FingerprintMemo::same_request`]'s KNN identity, shared by the
    /// execution layer's dedup and the planner's cost estimate so the
    /// two can never drift.
    pub fn same_query(&self, other: &KnnQ) -> bool {
        self.k == other.k
            && self.src.name == other.src.name
            && (Arc::ptr_eq(&self.src, &other.src) || self.src_fp == other.src_fp)
    }
}

/// Coalesced KNN queries sharing one target set + metric (and so one
/// target grouping and one packed-slab scope).
pub(crate) struct KnnCohort {
    pub trg: Arc<Dataset>,
    pub trg_fp: (u64, u64),
    pub metric: Metric,
    pub queries: Vec<KnnQ>,
    /// Inherited deadline: the earliest across the cohort's member
    /// queries (`None` when no member carries one).
    pub deadline: Option<Tick>,
}

/// One range-join query inside a cohort.
pub(crate) struct RangeJoinQ {
    /// Index into the drained batch (response slot).
    pub pos: usize,
    pub src: Arc<Dataset>,
    pub src_fp: (u64, u64),
    /// Metric-space radius (the cohort fixes the metric itself).
    pub threshold: f32,
}

impl RangeJoinQ {
    /// Dedup identity within one cohort (which already fixes target
    /// content and metric): threshold bits + source name + source
    /// content — the range-join analogue of [`KnnQ::same_query`].
    pub fn same_query(&self, other: &RangeJoinQ) -> bool {
        self.threshold.to_bits() == other.threshold.to_bits()
            && self.src.name == other.src.name
            && (Arc::ptr_eq(&self.src, &other.src) || self.src_fp == other.src_fp)
    }
}

/// Coalesced range-join queries sharing one target set + metric — the
/// same coalescing axis as [`KnnCohort`], so a shard serving both
/// workloads over one target set shares its grouping *and* its packed
/// slabs between them.
pub(crate) struct RangeJoinCohort {
    pub trg: Arc<Dataset>,
    pub trg_fp: (u64, u64),
    pub metric: Metric,
    pub queries: Vec<RangeJoinQ>,
    /// Inherited deadline: the earliest across the cohort's member
    /// queries (`None` when no member carries one).
    pub deadline: Option<Tick>,
}

pub(crate) struct KmeansJob {
    pub pos: usize,
    pub ds: Arc<Dataset>,
    pub ds_fp: (u64, u64),
    pub k: usize,
    pub max_iters: usize,
    /// Response slots of deduplicated identical queries.
    pub dups: Vec<usize>,
    /// Inherited deadline: the earliest across the job + its dups.
    pub deadline: Option<Tick>,
}

pub(crate) struct NbodyJob {
    pub pos: usize,
    pub ds: Arc<Dataset>,
    pub ds_fp: (u64, u64),
    pub masses: Arc<Vec<f32>>,
    pub steps: usize,
    pub dt: f32,
    pub radius: f32,
    pub dups: Vec<usize>,
    /// Inherited deadline: the earliest across the job + its dups.
    pub deadline: Option<Tick>,
}

/// Earliest of two optional deadlines (`None` = no deadline).
pub(crate) fn earliest(a: Option<Tick>, b: Option<Tick>) -> Option<Tick> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (x, None) => x,
        (None, y) => y,
    }
}

/// The unit of placement: one independent piece of work an engine
/// shard executes in isolation.  The cohort is the natural unit —
/// everything inside it shares artifacts; nothing across units does
/// (persistent caches excepted, and those are per shard).
pub(crate) enum WorkUnit {
    Knn(KnnCohort),
    RangeJoin(RangeJoinCohort),
    Kmeans(KmeansJob),
    Nbody(NbodyJob),
}

impl WorkUnit {
    /// The unit's inherited deadline: the earliest deadline of any
    /// member query (`None` = fully patient).  Placement sorts EDF
    /// tiers by it, the lockstep scheduler orders claims and step
    /// priority by it, and urgency-preferring steals read it.
    pub fn deadline(&self) -> Option<Tick> {
        match self {
            WorkUnit::Knn(c) => c.deadline,
            WorkUnit::RangeJoin(c) => c.deadline,
            WorkUnit::Kmeans(j) => j.deadline,
            WorkUnit::Nbody(j) => j.deadline,
        }
    }

    /// Relative cost estimate for load balancing: the dominant
    /// distance-pair count of the unit.  Only ratios matter.  With
    /// `dedup` on, KNN queries the execution layer will collapse into
    /// one run (same k, name, content) are counted once — a dup-heavy
    /// cohort must not look expensive to the planner (K-means / N-body
    /// jobs already collapsed their duplicates at partition time).
    pub fn cost_estimate(&self, dedup: bool) -> u64 {
        match self {
            WorkUnit::Knn(c) => {
                let trg = c.trg.n() as u64;
                let mut seen: Vec<&KnnQ> = Vec::new();
                let src_total: u64 = c
                    .queries
                    .iter()
                    .filter(|q| {
                        if !dedup {
                            return true;
                        }
                        if seen.iter().any(|s| s.same_query(q)) {
                            false
                        } else {
                            seen.push(q);
                            true
                        }
                    })
                    .map(|q| q.src.n() as u64)
                    .sum();
                trg + src_total * trg
            }
            WorkUnit::RangeJoin(c) => {
                let trg = c.trg.n() as u64;
                let mut seen: Vec<&RangeJoinQ> = Vec::new();
                let src_total: u64 = c
                    .queries
                    .iter()
                    .filter(|q| {
                        if !dedup {
                            return true;
                        }
                        if seen.iter().any(|s| s.same_query(q)) {
                            false
                        } else {
                            seen.push(q);
                            true
                        }
                    })
                    .map(|q| q.src.n() as u64)
                    .sum();
                trg + src_total * trg
            }
            WorkUnit::Kmeans(j) => j.ds.n() as u64 * j.k as u64 * (j.max_iters as u64 + 1),
            WorkUnit::Nbody(j) => {
                let n = j.ds.n() as u64;
                n * n * j.steps as u64
            }
        }
    }

    /// The unit's dominant slab footprint for the movement term: the
    /// content fingerprint its packed slabs are scoped under (the
    /// `SlabScope::fingerprint` every shard cache keys warmth by) and
    /// the raw bytes of the dataset behind them — what a shard without
    /// resident slabs would have to upload.  KNN cohorts move their
    /// target slab, K-means its packed points slab, N-body its packed
    /// positions; padding is ignored (a consistent under-estimate).
    pub fn movement_footprint(&self) -> (u64, u64) {
        match self {
            WorkUnit::Knn(c) => (c.trg_fp.0, (c.trg.n() * c.trg.d() * 4) as u64),
            WorkUnit::RangeJoin(c) => (c.trg_fp.0, (c.trg.n() * c.trg.d() * 4) as u64),
            WorkUnit::Kmeans(j) => (j.ds_fp.0, (j.ds.n() * j.ds.d() * 4) as u64),
            WorkUnit::Nbody(j) => (j.ds_fp.0, (j.ds.n() * j.ds.d() * 4) as u64),
        }
    }

    /// Dimensionality of the unit's distance pairs — converts the
    /// movement footprint's transfer time into the same pairs-per-`d`
    /// units as [`WorkUnit::cost_estimate`] (see
    /// `CostModel::move_penalty_units`).
    pub fn dim(&self) -> usize {
        match self {
            WorkUnit::Knn(c) => c.trg.d(),
            WorkUnit::RangeJoin(c) => c.trg.d(),
            WorkUnit::Kmeans(j) => j.ds.d(),
            WorkUnit::Nbody(j) => j.ds.d(),
        }
    }

    /// Calibrator kind axis of this unit (`CostCalibrator` learns one
    /// ns-per-unit rate per shard × kind).
    pub fn kind(&self) -> AlgoKind {
        match self {
            WorkUnit::Knn(_) => AlgoKind::Knn,
            WorkUnit::RangeJoin(_) => AlgoKind::RangeJoin,
            WorkUnit::Kmeans(_) => AlgoKind::Kmeans,
            WorkUnit::Nbody(_) => AlgoKind::Nbody,
        }
    }
}

/// Partition a drained batch into work units: coalesce KNN and
/// range-join queries into cohorts by (target content, metric);
/// deduplicate identical K-means / N-body queries (KNN / range-join
/// dedup happens inside cohort execution, where the per-query plans
/// are built).  Every unit inherits the earliest deadline of its
/// member queries.  Deterministic in the batch order.
pub(crate) fn partition(
    batch: &[Pending],
    dedup: bool,
    memo: &mut FingerprintMemo,
) -> Vec<WorkUnit> {
    let mut cohorts: Vec<KnnCohort> = Vec::new();
    let mut rj_cohorts: Vec<RangeJoinCohort> = Vec::new();
    let mut kmeans_jobs: Vec<KmeansJob> = Vec::new();
    let mut nbody_jobs: Vec<NbodyJob> = Vec::new();
    for (pos, p) in batch.iter().enumerate() {
        match &p.req {
            ServeRequest::Knn { src, trg, k, metric } => {
                let found = cohorts
                    .iter()
                    .position(|c| c.metric == *metric && memo.same_dataset(&c.trg, trg));
                let q = KnnQ { pos, src: src.clone(), src_fp: memo.fingerprint(src), k: *k };
                match found {
                    Some(ci) => {
                        cohorts[ci].queries.push(q);
                        cohorts[ci].deadline = earliest(cohorts[ci].deadline, p.deadline);
                    }
                    None => cohorts.push(KnnCohort {
                        trg: trg.clone(),
                        trg_fp: memo.fingerprint(trg),
                        metric: *metric,
                        queries: vec![q],
                        deadline: p.deadline,
                    }),
                }
            }
            ServeRequest::RangeJoin { src, trg, threshold, metric } => {
                let found = rj_cohorts
                    .iter()
                    .position(|c| c.metric == *metric && memo.same_dataset(&c.trg, trg));
                let q = RangeJoinQ {
                    pos,
                    src: src.clone(),
                    src_fp: memo.fingerprint(src),
                    threshold: *threshold,
                };
                match found {
                    Some(ci) => {
                        rj_cohorts[ci].queries.push(q);
                        rj_cohorts[ci].deadline = earliest(rj_cohorts[ci].deadline, p.deadline);
                    }
                    None => rj_cohorts.push(RangeJoinCohort {
                        trg: trg.clone(),
                        trg_fp: memo.fingerprint(trg),
                        metric: *metric,
                        queries: vec![q],
                        deadline: p.deadline,
                    }),
                }
            }
            ServeRequest::Kmeans { ds, k, max_iters } => {
                // Dedup under the ONE request identity (same_request),
                // so admission's deadline inheritance and this
                // partition can never disagree.
                let dup = if dedup {
                    kmeans_jobs
                        .iter()
                        .position(|j| memo.same_request(&batch[j.pos].req, &p.req))
                } else {
                    None
                };
                match dup {
                    Some(ji) => {
                        kmeans_jobs[ji].dups.push(pos);
                        kmeans_jobs[ji].deadline = earliest(kmeans_jobs[ji].deadline, p.deadline);
                    }
                    None => kmeans_jobs.push(KmeansJob {
                        pos,
                        ds: ds.clone(),
                        ds_fp: memo.fingerprint(ds),
                        k: *k,
                        max_iters: *max_iters,
                        dups: Vec::new(),
                        deadline: p.deadline,
                    }),
                }
            }
            ServeRequest::Nbody { ds, masses, steps, dt, radius } => {
                let dup = if dedup {
                    nbody_jobs
                        .iter()
                        .position(|j| memo.same_request(&batch[j.pos].req, &p.req))
                } else {
                    None
                };
                match dup {
                    Some(ji) => {
                        nbody_jobs[ji].dups.push(pos);
                        nbody_jobs[ji].deadline = earliest(nbody_jobs[ji].deadline, p.deadline);
                    }
                    None => nbody_jobs.push(NbodyJob {
                        pos,
                        ds: ds.clone(),
                        ds_fp: memo.fingerprint(ds),
                        masses: masses.clone(),
                        steps: *steps,
                        dt: *dt,
                        radius: *radius,
                        dups: Vec::new(),
                        deadline: p.deadline,
                    }),
                }
            }
        }
    }
    cohorts
        .into_iter()
        .map(WorkUnit::Knn)
        .chain(rj_cohorts.into_iter().map(WorkUnit::RangeJoin))
        .chain(kmeans_jobs.into_iter().map(WorkUnit::Kmeans))
        .chain(nbody_jobs.into_iter().map(WorkUnit::Nbody))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(synthetic::clustered(60, 4, 4, 0.05, seed))
    }

    /// A bitwise copy behind a fresh `Arc` with a fresh name
    /// allocation — what deserializing the same dataset twice yields.
    fn deserialized_copy(d: &Arc<Dataset>) -> Arc<Dataset> {
        Arc::new((**d).clone())
    }

    #[test]
    fn memo_identity_never_full_scans_datasets() {
        let mut memo = FingerprintMemo::new();
        let a = ds(1);
        let b = deserialized_copy(&a);
        let c = ds(2);
        assert!(memo.same_dataset(&a, &a), "pointer fast path");
        assert!(memo.same_dataset(&a, &b), "fingerprint path");
        assert!(!memo.same_dataset(&a, &c));
        assert_eq!(memo.full_scans, 0);
        // Fingerprints were computed once per distinct Arc, then
        // memoized: repeating the comparison stays cheap.
        assert!(memo.same_dataset(&a, &b));
        assert_eq!(memo.full_scans, 0);
    }

    #[test]
    fn memo_counts_mass_full_scans() {
        let mut memo = FingerprintMemo::new();
        let m1 = Arc::new(vec![1.0f32; 16]);
        let m2 = Arc::new(vec![1.0f32; 16]);
        assert!(memo.same_masses(&m1, &m1));
        assert_eq!(memo.full_scans, 0);
        assert!(memo.same_masses(&m1, &m2));
        assert_eq!(memo.full_scans, 1);
    }

    #[test]
    fn queue_remove_selected_preserves_order() {
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        for s in 0..5u64 {
            q.push(ServeRequest::knn(ds(s), trg.clone(), 3), None, 0);
        }
        let taken = q.remove_selected(&[1, 3]);
        assert_eq!(taken.iter().map(|p| p.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(q.len(), 3);
        assert_eq!((q.get(0).id, q.get(1).id, q.get(2).id), (0, 2, 4));
        q.requeue_front(taken);
        assert_eq!((q.get(0).id, q.get(1).id), (1, 3));
    }

    #[test]
    fn policy_selects_expired_and_their_duplicates_only() {
        let policy = FlushPolicy { max_batch: 64, default_deadline: None };
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        let src = ds(1);
        let (now, later) = (1_000u64, 900_000u64);
        // 0: expired; 1: far future, NOT identical; 2: far future,
        // identical to 0 (deserialized copy) -> inherits 0's deadline;
        // 3: no deadline.
        q.push(ServeRequest::knn(src.clone(), trg.clone(), 3), Some(now), 0);
        q.push(ServeRequest::knn(ds(2), trg.clone(), 3), Some(later), 0);
        q.push(
            ServeRequest::knn(deserialized_copy(&src), deserialized_copy(&trg), 3),
            Some(later),
            0,
        );
        q.push(ServeRequest::knn(ds(3), trg.clone(), 3), None, 0);
        let mut memo = FingerprintMemo::new();
        let (sel, by_deadline) = policy.select_due(&q, now, true, &mut memo);
        assert_eq!(sel, vec![0, 2]);
        assert!(by_deadline);
        assert_eq!(memo.full_scans, 0, "identity resolved without point scans");
        // Without dedup, only the expired entry itself is due.
        let mut memo = FingerprintMemo::new();
        let (sel, _) = policy.select_due(&q, now, false, &mut memo);
        assert_eq!(sel, vec![0]);
    }

    #[test]
    fn policy_size_trigger_takes_a_full_batch() {
        let policy = FlushPolicy { max_batch: 2, default_deadline: None };
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        for s in 0..3u64 {
            q.push(ServeRequest::knn(ds(s), trg.clone(), 3), None, 0);
        }
        let mut memo = FingerprintMemo::new();
        let (sel, by_deadline) = policy.select_due(&q, 0, true, &mut memo);
        assert_eq!(sel, vec![0, 1]);
        assert!(!by_deadline, "size trigger is not a deadline flush");
        assert_eq!(policy.select_flush(&q), vec![0, 1]);
    }

    #[test]
    fn policy_due_queries_preempt_the_size_trigger_prefix() {
        // An urgent query behind a full batch of patient ones must be
        // selected ahead of the FIFO prefix, not wait a whole flush.
        let policy = FlushPolicy { max_batch: 2, default_deadline: None };
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        q.push(ServeRequest::knn(ds(1), trg.clone(), 3), None, 0);
        q.push(ServeRequest::knn(ds(2), trg.clone(), 3), None, 0);
        q.push(ServeRequest::knn(ds(3), trg.clone(), 3), Some(5), 0);
        let mut memo = FingerprintMemo::new();
        let (sel, by_deadline) = policy.select_due(&q, 5, true, &mut memo);
        assert_eq!(sel, vec![0, 2], "due query included, batch topped up from the front");
        assert!(by_deadline);
    }

    #[test]
    fn policy_truncation_serves_most_overdue_first() {
        let policy = FlushPolicy { max_batch: 1, default_deadline: None };
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        let (early, later) = (100u64, 200u64);
        q.push(ServeRequest::knn(ds(1), trg.clone(), 3), Some(later), 0);
        q.push(ServeRequest::knn(ds(2), trg, 3), Some(early), 0);
        let mut memo = FingerprintMemo::new();
        let (sel, by_deadline) = policy.select_due(&q, 300, true, &mut memo); // both expired
        assert_eq!(sel, vec![1], "the longer-overdue query wins the only slot");
        assert!(by_deadline);
    }

    #[test]
    fn memo_does_not_pin_dropped_datasets() {
        // The memo must hold Weak references: a memoized dataset whose
        // last client drops it must be freed immediately, not pinned
        // until the next prune (an always-on server would otherwise
        // accumulate every dataset it ever fingerprinted).
        let mut memo = FingerprintMemo::new();
        let a = ds(1);
        memo.fingerprint(&a);
        let w = Arc::downgrade(&a);
        drop(a);
        assert!(w.upgrade().is_none(), "memo kept a dropped dataset alive");
    }

    #[test]
    fn memo_never_trusts_a_reused_address() {
        // ABA: drop a fingerprinted dataset and allocate fresh ones of
        // the same shape until the allocator reuses its address.  The
        // stale entry must be re-fingerprinted, never returned as-is.
        let mut memo = FingerprintMemo::new();
        let first = ds(100);
        let stale_fp = memo.fingerprint(&first);
        let stale_ptr = Arc::as_ptr(&first) as usize;
        drop(first);
        let mut reused = false;
        for seed in 101..164u64 {
            let fresh = ds(seed);
            let got = memo.fingerprint(&fresh);
            let want = gti::fingerprint_pair(&fresh.points);
            assert_eq!(got, want, "stale memo entry aliased a different dataset");
            if Arc::as_ptr(&fresh) as usize == stale_ptr {
                reused = true;
                assert_ne!(got, stale_fp, "distinct content, same address");
            }
        }
        // Same-size allocations on the test allocator overwhelmingly
        // reuse the freed block; if this ever stops holding the assert
        // above still ran against every fresh allocation.
        let _ = reused;
    }

    #[test]
    fn memo_identity_survives_drop_and_reallocate() {
        // same_dataset must stay correct across address reuse too: a
        // fresh dataset at a recycled address must not compare equal
        // to anything through the stale fingerprint.
        let mut memo = FingerprintMemo::new();
        let reference = ds(7);
        memo.fingerprint(&reference);
        for seed in 8..40u64 {
            let probe = ds(seed);
            assert!(!memo.same_dataset(&reference, &probe), "seed {seed} falsely deduped");
            drop(probe);
        }
        let copy = deserialized_copy(&reference);
        assert!(memo.same_dataset(&reference, &copy), "true duplicate still dedupes");
    }

    #[test]
    fn policy_next_wakeup_covers_every_trigger() {
        // Pre-fix, the serving loop slept on next_deadline() alone:
        // None for deadline-free queues, so size-trigger-only
        // workloads stalled forever with admitted queries pending.
        let policy = FlushPolicy { max_batch: 3, default_deadline: None };
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        let now = 1_000u64;
        assert_eq!(policy.next_wakeup(&q, now), None, "empty queue: nothing to wake for");
        // Deadline-free straggler below the size trigger: due now, not
        // never (next_deadline would say None here — the bug).
        q.push(ServeRequest::knn(ds(1), trg.clone(), 3), None, now);
        assert_eq!(q.next_deadline(), None);
        assert_eq!(policy.next_wakeup(&q, now), Some(now));
        // A pending deadline becomes the sleep target.
        q.push(ServeRequest::knn(ds(2), trg.clone(), 3), Some(5_000), now);
        assert_eq!(policy.next_wakeup(&q, now), Some(5_000));
        // Size trigger met: due immediately, deadline notwithstanding.
        q.push(ServeRequest::knn(ds(3), trg.clone(), 3), None, now);
        assert_eq!(policy.next_wakeup(&q, now), Some(now));
        // max_batch == 0 disables the size trigger entirely.
        let unbounded = FlushPolicy { max_batch: 0, default_deadline: None };
        assert_eq!(unbounded.next_wakeup(&q, now), Some(5_000));
    }

    #[test]
    fn memo_prune_keeps_only_pending_datasets() {
        let mut memo = FingerprintMemo::new();
        let mut q = AdmissionQueue::new();
        let trg = ds(10);
        let kept = ds(1);
        let dropped = ds(2);
        memo.fingerprint(&kept);
        memo.fingerprint(&dropped);
        memo.fingerprint(&trg);
        q.push(ServeRequest::knn(kept.clone(), trg.clone(), 3), None, 0);
        memo.prune(&q);
        assert_eq!(memo.map.len(), 2, "kept src + trg survive, flushed dataset dropped");
        assert!(memo.map.contains_key(&(Arc::as_ptr(&kept) as usize)));
        assert!(memo.map.contains_key(&(Arc::as_ptr(&trg) as usize)));
        assert!(!memo.map.contains_key(&(Arc::as_ptr(&dropped) as usize)));
    }

    fn pending(id: QueryId, req: ServeRequest, deadline: Option<Tick>) -> Pending {
        Pending { id, req, deadline, submitted_at: 0 }
    }

    #[test]
    fn partition_coalesces_arc_distinct_identical_targets() {
        let trg = ds(10);
        let trg_copy = deserialized_copy(&trg);
        let batch = vec![
            pending(0, ServeRequest::knn(ds(1), trg.clone(), 3), None),
            pending(1, ServeRequest::knn(ds(2), trg_copy, 3), None),
            pending(2, ServeRequest::kmeans(ds(3), 4, 2), None),
        ];
        let mut memo = FingerprintMemo::new();
        let units = partition(&batch, true, &mut memo);
        assert_eq!(units.len(), 2, "one cohort + one kmeans job");
        match &units[0] {
            WorkUnit::Knn(c) => assert_eq!(c.queries.len(), 2),
            _ => panic!("first unit must be the cohort"),
        }
        assert_eq!(memo.full_scans, 0);
        assert!(units[0].cost_estimate(true) > 0);
    }

    #[test]
    fn partition_inherits_the_earliest_member_deadline() {
        let trg = ds(10);
        let src = ds(1);
        let km = ds(3);
        let batch = vec![
            // Cohort members: patient, urgent (tick 40), deadline-free.
            pending(0, ServeRequest::knn(ds(2), trg.clone(), 3), Some(900)),
            pending(1, ServeRequest::knn(src.clone(), trg.clone(), 3), Some(40)),
            pending(2, ServeRequest::knn(ds(4), trg.clone(), 3), None),
            // Dedup pair: the duplicate carries the earlier deadline.
            pending(3, ServeRequest::kmeans(km.clone(), 4, 2), Some(500)),
            pending(4, ServeRequest::kmeans(km.clone(), 4, 2), Some(70)),
            // Deadline-free job stays deadline-free.
            pending(5, ServeRequest::kmeans(km.clone(), 8, 2), None),
        ];
        let mut memo = FingerprintMemo::new();
        let units = partition(&batch, true, &mut memo);
        assert_eq!(units.len(), 3, "one cohort + two kmeans jobs");
        assert_eq!(units[0].deadline(), Some(40), "cohort inherits its most urgent member");
        assert_eq!(units[1].deadline(), Some(70), "dedup inherits the earlier deadline");
        assert_eq!(units[2].deadline(), None, "no member deadline -> patient unit");
    }

    #[test]
    fn cost_estimate_counts_deduplicable_knn_queries_once() {
        let trg = ds(10);
        let src = ds(1);
        let other = ds(2);
        let batch = vec![
            pending(0, ServeRequest::knn(src.clone(), trg.clone(), 3), None),
            pending(1, ServeRequest::knn(src.clone(), trg.clone(), 3), None),
            pending(2, ServeRequest::knn(src, trg.clone(), 3), None),
        ];
        let mut memo = FingerprintMemo::new();
        let units = partition(&batch, true, &mut memo);
        assert_eq!(units.len(), 1);
        let single = {
            let batch = vec![pending(0, ServeRequest::knn(other, trg, 3), None)];
            let mut memo = FingerprintMemo::new();
            partition(&batch, true, &mut memo).remove(0)
        };
        // Three identical queries cost the same as one (they execute
        // once); without dedup they cost three times as much.
        assert_eq!(units[0].cost_estimate(true), single.cost_estimate(true));
        assert!(units[0].cost_estimate(false) > 2 * single.cost_estimate(true));
    }
}
