//! LRU cache of built groupings, keyed by dataset fingerprint +
//! grouping parameters.
//!
//! Building a grouping is the dominant CPU cost of a query's filter
//! stage (the paper's `Latency_filt`).  Under serving traffic the same
//! datasets are queried over and over, so the batcher memoizes the
//! [`PackedGrouping`] per (data, parameters) pair.  Correctness: the
//! grouping build is deterministic, so a cached instance is
//! byte-identical to what a fresh solo run would build — reuse can
//! never change results.  Fingerprint collisions are guarded by a
//! second, independent content probe stored per entry (two
//! simultaneous 64-bit collisions would be required to mis-serve);
//! entries hold only the grouping, never the dataset, so caching a
//! grouping does not pin gigabytes of points in memory.

use std::collections::HashMap;
use std::sync::Arc;

use crate::gti::Metric;
use crate::layout::PackedGrouping;
use crate::Result;

/// Cache key: everything [`PackedGrouping::build`] is deterministic in.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupingKey {
    /// Content fingerprint of the point set — the `.0` of
    /// [`crate::gti::fingerprint_pair`].
    pub fingerprint: u64,
    pub groups: usize,
    pub iters: usize,
    pub sample: usize,
    pub seed: u64,
    pub metric: Metric,
}

struct Entry {
    pg: Arc<PackedGrouping>,
    /// Secondary content probe — the `.1` of
    /// [`crate::gti::fingerprint_pair`] for the points the grouping was
    /// built from.  Key fingerprint and entry probe colliding
    /// *simultaneously* for different content is ~2^-128.
    probe: u64,
    last_used: u64,
}

/// LRU-bounded grouping cache.
pub struct GroupingCache {
    cap: usize,
    map: HashMap<GroupingKey, Entry>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// Probe collisions: the key's fingerprint matched a cached entry
    /// but the secondary content probe did not, so the grouping was
    /// rebuilt uncached.  Recorded (rather than silently folded into
    /// `misses`) so cache efficacy stays observable in `ServeStats`.
    pub probe_collisions: u64,
}

impl GroupingCache {
    /// `cap` is the maximum number of cached groupings (>= 1).
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            probe_collisions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch the grouping for `key` (whose fingerprint and `probe` come
    /// from one [`crate::gti::fingerprint_pair`] pass over the points),
    /// building it on a miss.
    pub fn get_or_build(
        &mut self,
        key: GroupingKey,
        probe: u64,
        build: impl FnOnce() -> Result<PackedGrouping>,
    ) -> Result<Arc<PackedGrouping>> {
        self.tick += 1;
        if let Some(entry) = self.map.get_mut(&key) {
            // Guard against fingerprint collisions: the cached entry
            // must have been built from identical content.
            if entry.probe == probe {
                entry.last_used = self.tick;
                self.hits += 1;
                return Ok(entry.pg.clone());
            }
            // Collision: do not serve, do not overwrite (the colliding
            // pair would thrash); build uncached and record the event.
            self.misses += 1;
            self.probe_collisions += 1;
            return Ok(Arc::new(build()?));
        }
        self.misses += 1;
        let pg = Arc::new(build()?);
        if self.map.len() >= self.cap {
            // Evict the least-recently-used entry.
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
            }
        }
        self.map.insert(key, Entry { pg: pg.clone(), probe, last_used: self.tick });
        Ok(pg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, Dataset};
    use crate::gti;

    fn key_for(ds: &Dataset, groups: usize, seed: u64) -> (GroupingKey, u64) {
        let (fingerprint, probe) = gti::fingerprint_pair(&ds.points);
        let key = GroupingKey {
            fingerprint,
            groups,
            iters: 2,
            sample: 256,
            seed,
            metric: Metric::L2,
        };
        (key, probe)
    }

    fn build_for(ds: &Dataset, groups: usize, seed: u64) -> Result<PackedGrouping> {
        PackedGrouping::build(&ds.points, groups, 2, 256, seed, Metric::L2, 8)
    }

    fn fetch(
        cache: &mut GroupingCache,
        ds: &Dataset,
        groups: usize,
        seed: u64,
    ) -> Arc<PackedGrouping> {
        let (key, probe) = key_for(ds, groups, seed);
        cache.get_or_build(key, probe, || build_for(ds, groups, seed)).unwrap()
    }

    #[test]
    fn hit_returns_the_same_grouping_instance() {
        let ds = synthetic::clustered(300, 4, 6, 0.05, 1);
        let mut cache = GroupingCache::new(4);
        let a = fetch(&mut cache, &ds, 8, 7);
        let b = fetch(&mut cache, &ds, 8, 7);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn different_params_are_different_entries() {
        let ds = synthetic::clustered(300, 4, 6, 0.05, 1);
        let mut cache = GroupingCache::new(4);
        let a = fetch(&mut cache, &ds, 8, 7);
        let b = fetch(&mut cache, &ds, 16, 7);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses, 2);
    }

    #[test]
    fn lru_evicts_oldest_entry() {
        let mut cache = GroupingCache::new(2);
        let mk = |seed: u64| synthetic::clustered(120, 3, 4, 0.05, seed);
        let (d1, d2, d3) = (mk(1), mk(2), mk(3));
        fetch(&mut cache, &d1, 4, 1);
        fetch(&mut cache, &d2, 4, 1);
        // Touch d1 so d2 becomes the LRU victim.
        fetch(&mut cache, &d1, 4, 1);
        fetch(&mut cache, &d3, 4, 1);
        assert_eq!(cache.len(), 2);
        // d1 must still be cached (hit), d2 must rebuild (miss).
        let hits_before = cache.hits;
        fetch(&mut cache, &d1, 4, 1);
        assert_eq!(cache.hits, hits_before + 1);
        let misses_before = cache.misses;
        fetch(&mut cache, &d2, 4, 1);
        assert_eq!(cache.misses, misses_before + 1);
    }

    #[test]
    fn colliding_key_with_different_content_is_not_served() {
        let d1 = synthetic::clustered(100, 3, 4, 0.05, 1);
        let d2 = synthetic::clustered(100, 3, 4, 0.05, 2);
        let mut cache = GroupingCache::new(4);
        // Force a "collision" by reusing d1's key with d2's probe.
        let (forged, _) = key_for(&d1, 4, 1);
        let (_, probe1) = key_for(&d1, 4, 1);
        let (_, probe2) = key_for(&d2, 4, 1);
        cache.get_or_build(forged.clone(), probe1, || build_for(&d1, 4, 1)).unwrap();
        let g2 = cache.get_or_build(forged, probe2, || build_for(&d2, 4, 1)).unwrap();
        // The cached (d1-built) grouping must NOT be returned for d2,
        // and the fallback must be recorded, not silent.
        assert_eq!(g2.grouping.num_points(), 100);
        assert_eq!(cache.probe_collisions, 1);
        assert_eq!(cache.misses, 2);
        let g1_again = fetch(&mut cache, &d1, 4, 1);
        assert_ne!(
            g1_again.grouping.centers.as_slice(),
            g2.grouping.centers.as_slice(),
            "collision guard failed: d2 was served d1's grouping"
        );
    }

    #[test]
    fn probe_is_independent_of_the_primary_fingerprint() {
        // Same shape, single value changed: both hashes must move.
        let a = synthetic::uniform(64, 4, 9);
        let mut b = a.clone();
        b.points.row_mut(10)[2] += 0.5;
        let (fa, pa) = gti::fingerprint_pair(&a.points);
        let (fb, pb) = gti::fingerprint_pair(&b.points);
        assert_ne!(fa, fb);
        assert_ne!(pa, pb);
        // And the probe differs from the fingerprint itself (different
        // algorithm, not an alias).
        assert_ne!(fa, pa);
    }
}
