//! The always-on serving front end: [`Server`].
//!
//! [`super::QueryBatcher`] is caller-driven: whoever owns it must keep
//! calling `poll`/`flush` at the right moments, which makes it a
//! building block, not a service.  `Server` wraps the batcher in a
//! background scheduler thread and turns the contract inside out:
//! producers on any thread `submit` and get a [`ResponseHandle`] back;
//! the scheduler owns *when* flushes happen.
//!
//! **Wake-up semantics.**  The scheduler sleeps until the earliest of:
//! a new submit, a shutdown request, or the batcher's
//! [`super::QueryBatcher::next_wakeup`] tick — the trigger-aware sleep
//! target (earliest deadline, size trigger, or deadline-free
//! stragglers due immediately).  The deadline-only `next_deadline()`
//! is NOT used: it returns `None` whenever every pending query is
//! deadline-free, and a loop sleeping on it stalls forever on
//! size-trigger-only workloads.  Under a [`super::VirtualClock`] the
//! scheduler registers a clock waker and waits purely on events, so
//! tests drive the whole loop with zero wall-clock sleeps; under the
//! production [`super::MonotonicClock`] it uses timed waits sized by
//! tick arithmetic.
//!
//! **Backpressure & shedding.**  `serve.queue_cap` bounds the number
//! of accepted-but-unanswered queries (0 = unbounded).  At the bound,
//! `serve.overload` decides: `"block"` parks the producer until space
//! frees (or shutdown), `"reject"` fails the submit fast and counts it
//! in [`ServeStats::shed`].  The high-water mark of the bounded queue
//! is reported as `ServeStats::queue_depth_watermark`.
//!
//! **Failure containment.**  Each query is validated at transfer (the
//! same checks a flush runs), so an invalid query fails its *own*
//! handle instead of wedging every later flush.  If execution itself
//! fails mid-flush, the batcher requeues the drained batch in order
//! and the scheduler retries at the next event (a submit or a clock
//! jump) — accepted queries are never dropped on an error.
//!
//! **Drain guarantee.**  Shutdown (explicit [`Server::shutdown`] or
//! `Drop`) stops intake, then flushes until the queue is empty: every
//! accepted query is answered before the scheduler exits.  Only if a
//! flush fails [`DRAIN_RETRY_LIMIT`] consecutive times during the
//! drain (e.g. a corrupted artifact deployment that never recovers)
//! are the remaining handles failed over with the underlying error —
//! resolved, not leaked, so no `wait()` can hang.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use super::clock::{ticks, Clock, Tick};
use super::{QueryBatcher, QueryId, ServeRequest, ServeResponse};
use crate::config::{OverloadPolicy, ServeConfig};
use crate::coordinator::Engine;
use crate::metrics::ServeStats;
use crate::{Error, Result};

/// Consecutive failed flushes the shutdown drain tolerates before
/// failing the remaining handles over with the error.
pub const DRAIN_RETRY_LIMIT: u32 = 3;

/// One query's response cell, shared between its [`ResponseHandle`]
/// and the scheduler.
#[derive(Default)]
struct Slot {
    cell: Mutex<Option<Result<ServeResponse>>>,
    ready: Condvar,
}

/// A producer's claim on one submitted query's response.
///
/// Resolution is one of: the query's [`ServeResponse`], its own
/// validation error, or a drain fail-over error ([`Error::Serve`])
/// when the server shut down with a persistently failing engine.  An
/// accepted query always resolves — dropping the handle merely
/// discards the answer.
pub struct ResponseHandle {
    slot: Arc<Slot>,
}

impl ResponseHandle {
    /// Block until the query resolves and take the result.
    pub fn wait(self) -> Result<ServeResponse> {
        let mut cell = self.slot.cell.lock().unwrap();
        loop {
            if let Some(resolution) = cell.take() {
                return resolution;
            }
            cell = self.slot.ready.wait(cell).unwrap();
        }
    }

    /// Take the result if the query has already resolved (`None`
    /// while still in flight).  A taken result is gone: a later
    /// `wait()` would block forever, so take-then-wait is a bug.
    pub fn try_take(&self) -> Option<Result<ServeResponse>> {
        self.slot.cell.lock().unwrap().take()
    }
}

/// One accepted query waiting in the intake for transfer.
struct Accepted {
    req: ServeRequest,
    /// Absolute deadline, stamped at accept time (producer-observed).
    deadline: Option<Tick>,
    /// Accept tick: latency runs from here, so time spent waiting in
    /// the intake is visible service latency, not hidden overhead.
    submitted_at: Tick,
    slot: Arc<Slot>,
}

/// Producer-facing state behind one mutex.
#[derive(Default)]
struct Intake {
    queue: VecDeque<Accepted>,
    /// Accepted and not yet resolved (intake + transferred pending).
    accepted: usize,
    watermark: usize,
    shed: u64,
    /// Failed service attempts (the batch was requeued; see
    /// `ServeStats::flush_failures`).
    flush_failures: u64,
    shutdown: bool,
    /// Bumped by the clock waker so a jump between a sleep decision
    /// and the wait itself is never lost.
    clock_events: u64,
}

struct Shared {
    intake: Mutex<Intake>,
    /// Scheduler's wake signal (submits, shutdown, clock jumps).
    wake: Condvar,
    /// Blocked producers' signal (space freed, shutdown).
    space: Condvar,
    cap: usize,
    overload: OverloadPolicy,
    default_deadline: Option<Duration>,
    clock: Arc<dyn Clock>,
}

/// The always-on serving front end (see the module docs).
pub struct Server {
    shared: Arc<Shared>,
    batcher: Arc<Mutex<QueryBatcher>>,
    thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Start a server over `cfg.shards` engine shards on a fresh
    /// [`super::MonotonicClock`].  Panics on an invalid config; use
    /// [`Server::try_new`] to handle the error instead.
    pub fn new(engine: Engine, cfg: ServeConfig) -> Self {
        match Self::try_new(engine, cfg) {
            Ok(server) => server,
            Err(e) => panic!("invalid serve config: {e}"),
        }
    }

    /// Fallible construction (invalid knobs, unknown `placement` or
    /// `overload` policy names).
    pub fn try_new(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        let batcher = QueryBatcher::try_new(engine, cfg.clone())?;
        Self::over(batcher, &cfg)
    }

    /// Like [`Server::new`] with an injected clock; panics on an
    /// invalid config.
    pub fn with_clock(engine: Engine, cfg: ServeConfig, clock: Arc<dyn Clock>) -> Self {
        match Self::try_new_with_clock(engine, cfg, clock) {
            Ok(server) => server,
            Err(e) => panic!("invalid serve config: {e}"),
        }
    }

    /// Like [`Server::try_new`], but the scheduler (and every deadline
    /// decision below it) runs on the given clock — a
    /// [`super::VirtualClock`] makes the whole loop event-driven and
    /// sleep-free for tests.
    pub fn try_new_with_clock(
        engine: Engine,
        cfg: ServeConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Self> {
        let batcher = QueryBatcher::try_new_with_clock(engine, cfg.clone(), clock)?;
        Self::over(batcher, &cfg)
    }

    fn over(batcher: QueryBatcher, cfg: &ServeConfig) -> Result<Self> {
        let overload = cfg.overload_policy()?;
        let clock = batcher.clock().clone();
        // The policy's default-deadline span, recovered as the absolute
        // deadline it would stamp at tick 0 — producers stamp deadlines
        // at accept time without taking the batcher lock.
        let default_deadline = batcher.admission_deadline(0).map(Duration::from_nanos);
        let shared = Arc::new(Shared {
            intake: Mutex::new(Intake::default()),
            wake: Condvar::new(),
            space: Condvar::new(),
            cap: cfg.queue_cap,
            overload,
            default_deadline,
            clock: clock.clone(),
        });
        // The clock waker holds only a Weak: a dropped server leaves a
        // no-op waker behind, never a Shared-clock reference cycle.
        let weak: Weak<Shared> = Arc::downgrade(&shared);
        clock.register_waker(Arc::new(move || {
            if let Some(shared) = weak.upgrade() {
                shared.intake.lock().unwrap().clock_events += 1;
                shared.wake.notify_all();
            }
        }));
        let batcher = Arc::new(Mutex::new(batcher));
        let thread = {
            let shared = shared.clone();
            let batcher = batcher.clone();
            std::thread::spawn(move || scheduler(&shared, &batcher))
        };
        Ok(Self { shared, batcher, thread: Some(thread) })
    }

    /// Submit under the config's default deadline (none when
    /// `serve.deadline_ms == 0`).  Errs on overload (`reject` policy)
    /// or after shutdown; blocks at the bound under `block`.
    pub fn submit(&self, req: ServeRequest) -> Result<ResponseHandle> {
        self.accept(req, None)
    }

    /// Submit a query that becomes due `deadline` from now (on the
    /// server's clock).
    pub fn submit_with_deadline(
        &self,
        req: ServeRequest,
        deadline: Duration,
    ) -> Result<ResponseHandle> {
        self.accept(req, Some(deadline))
    }

    fn accept(&self, req: ServeRequest, deadline: Option<Duration>) -> Result<ResponseHandle> {
        let mut intake = self.shared.intake.lock().unwrap();
        loop {
            if intake.shutdown {
                return Err(Error::Serve("server is shut down".into()));
            }
            if self.shared.cap == 0 || intake.accepted < self.shared.cap {
                break;
            }
            match self.shared.overload {
                OverloadPolicy::Reject => {
                    intake.shed += 1;
                    return Err(Error::Serve(format!(
                        "intake full ({} accepted queries unanswered, cap {}): query shed",
                        intake.accepted, self.shared.cap
                    )));
                }
                OverloadPolicy::Block => {
                    intake = self.shared.space.wait(intake).unwrap();
                }
            }
        }
        let now = self.shared.clock.now();
        let deadline = deadline
            .or(self.shared.default_deadline)
            .map(|d| now.saturating_add(ticks(d)));
        let slot = Arc::new(Slot::default());
        intake.queue.push_back(Accepted {
            req,
            deadline,
            submitted_at: now,
            slot: slot.clone(),
        });
        intake.accepted += 1;
        intake.watermark = intake.watermark.max(intake.accepted);
        self.shared.wake.notify_all();
        Ok(ResponseHandle { slot })
    }

    /// Accepted queries not yet answered (intake + pending).
    pub fn in_flight(&self) -> usize {
        self.shared.intake.lock().unwrap().accepted
    }

    /// Queries already transferred to the batcher and awaiting
    /// service — a subset of [`Server::in_flight`]; the difference is
    /// still sitting in the intake.  Tests use this to know when a
    /// burst has fully landed in one admission queue (and will
    /// therefore coalesce into one flush) before advancing a virtual
    /// clock.
    pub fn pending_len(&self) -> usize {
        self.batcher.lock().unwrap().pending_len()
    }

    /// Merged lifetime statistics: the batcher's view plus the
    /// server-level `shed` / `queue_depth_watermark` fields.
    pub fn stats(&self) -> ServeStats {
        let mut stats = self.batcher.lock().unwrap().stats().clone();
        let intake = self.shared.intake.lock().unwrap();
        stats.shed = intake.shed;
        stats.queue_depth_watermark = intake.watermark as u64;
        stats.flush_failures = intake.flush_failures;
        stats
    }

    /// Per-shard lifetime statistics, in shard order.
    pub fn shard_stats(&self) -> Vec<ServeStats> {
        self.batcher.lock().unwrap().shard_stats().into_iter().cloned().collect()
    }

    pub fn shard_count(&self) -> usize {
        self.batcher.lock().unwrap().shard_count()
    }

    /// Stop intake, drain every accepted query, join the scheduler
    /// and return the final merged statistics.
    pub fn shutdown(mut self) -> ServeStats {
        self.begin_shutdown();
        if let Some(thread) = self.thread.take() {
            let _ = thread.join();
        }
        self.stats()
    }

    fn begin_shutdown(&self) {
        let mut intake = self.shared.intake.lock().unwrap();
        intake.shutdown = true;
        self.shared.wake.notify_all();
        self.shared.space.notify_all();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.begin_shutdown();
        if let Some(thread) = self.thread.take() {
            let _ = thread.join();
        }
    }
}

/// Release `n` queue slots.  Always called BEFORE the matching
/// handles resolve, so a producer that saw `wait()` return can rely
/// on the freed capacity being visible to its next submit.
fn release_capacity(shared: &Shared, n: usize) {
    let mut intake = shared.intake.lock().unwrap();
    intake.accepted = intake.accepted.saturating_sub(n);
    shared.space.notify_all();
}

/// Resolve one handle and release its queue slot.
fn resolve_failure(shared: &Shared, slot: &Arc<Slot>, err: Error) {
    release_capacity(shared, 1);
    *slot.cell.lock().unwrap() = Some(Err(err));
    slot.ready.notify_all();
}

/// Resolve predictively shed queries' handles
/// ([`QueryBatcher::take_predicted_sheds`]): the query was never
/// executed — its deadline had already expired at selection time and
/// the calibrated completion estimate overshot it — so its handle
/// fails with a recognizable error instead of hanging.
fn resolve_sheds(shared: &Shared, slots: &mut HashMap<QueryId, Arc<Slot>>, sheds: Vec<QueryId>) {
    for id in sheds {
        if let Some(slot) = slots.remove(&id) {
            resolve_failure(
                shared,
                &slot,
                Error::Serve(
                    "query predictively shed: deadline expired before service began".into(),
                ),
            );
        }
    }
}

/// Resolve a successful flush's responses and release their slots.
fn resolve_responses(
    shared: &Shared,
    slots: &mut HashMap<QueryId, Arc<Slot>>,
    responses: Vec<(QueryId, ServeResponse)>,
) {
    release_capacity(shared, responses.len());
    for (id, resp) in responses {
        if let Some(slot) = slots.remove(&id) {
            *slot.cell.lock().unwrap() = Some(Ok(resp));
            slot.ready.notify_all();
        }
    }
}

/// One service attempt: `poll` what's due; if nothing was due by
/// deadline or size trigger but the wake target says "now"
/// (deadline-free stragglers), `flush` the front batch instead.
fn serve_once(b: &mut QueryBatcher) -> Result<Vec<(QueryId, ServeResponse)>> {
    let out = b.poll()?;
    if !out.is_empty() || b.pending_len() == 0 {
        return Ok(out);
    }
    if b.next_wakeup().is_some_and(|t| t <= b.now()) {
        return b.flush();
    }
    Ok(out)
}

/// Flush until empty; after [`DRAIN_RETRY_LIMIT`] consecutive
/// failures, fail the remaining handles over with the error so no
/// `wait()` can hang on a permanently broken engine.
fn drain(shared: &Shared, b: &mut QueryBatcher, slots: &mut HashMap<QueryId, Arc<Slot>>) {
    let mut consecutive_failures = 0u32;
    while b.pending_len() > 0 {
        match b.flush() {
            Ok(responses) => {
                consecutive_failures = 0;
                resolve_sheds(shared, slots, b.take_predicted_sheds());
                resolve_responses(shared, slots, responses);
            }
            Err(e) => {
                consecutive_failures += 1;
                shared.intake.lock().unwrap().flush_failures += 1;
                if consecutive_failures >= DRAIN_RETRY_LIMIT {
                    let msg =
                        format!("server drain failed {DRAIN_RETRY_LIMIT} consecutive times: {e}");
                    for (_, slot) in slots.drain() {
                        resolve_failure(shared, &slot, Error::Serve(msg.clone()));
                    }
                    return;
                }
            }
        }
    }
}

/// The scheduler loop: transfer intake, serve what's due, sleep until
/// the next wake source.  Runs until shutdown, then drains.
fn scheduler(shared: &Shared, batcher: &Mutex<QueryBatcher>) {
    // Transferred-but-unanswered queries' response slots, keyed by the
    // batcher's QueryId.  Scheduler-local: no lock needed.
    let mut slots: HashMap<QueryId, Arc<Slot>> = HashMap::new();
    // After a failed flush the batcher has requeued the batch; retry
    // only at the next event (submit / clock jump / shutdown) so a
    // deterministic failure cannot spin the loop hot.
    let mut backoff = false;
    loop {
        // Capture the clock-event counter BEFORE deciding anything,
        // so a jump racing the decision is seen at the sleep check.
        let seen = shared.intake.lock().unwrap().clock_events;
        // Phase 1: transfer the intake into the batcher, validating
        // each query so a bad one fails its own handle instead of
        // wedging every later flush.
        let (items, shutdown) = {
            let mut intake = shared.intake.lock().unwrap();
            (std::mem::take(&mut intake.queue), intake.shutdown)
        };
        let wake;
        {
            let mut b = batcher.lock().unwrap();
            for a in items {
                match b.validate_request(&a.req) {
                    Ok(()) => {
                        let id = b.submit_at(a.req, a.deadline, a.submitted_at);
                        slots.insert(id, a.slot);
                    }
                    Err(e) => resolve_failure(shared, &a.slot, e),
                }
            }
            if shutdown {
                drain(shared, &mut b, &mut slots);
                return;
            }
            // Phase 2: serve while due.
            let now = b.now();
            wake = b.next_wakeup();
            if !backoff && wake.is_some_and(|t| t <= now) {
                match serve_once(&mut b) {
                    Ok(responses) => {
                        // Predictive sheds resolve their own handles
                        // (no response pair exists for them) and count
                        // as progress: re-evaluate triggers.
                        let sheds = b.take_predicted_sheds();
                        let progressed = !responses.is_empty() || !sheds.is_empty();
                        resolve_sheds(shared, &mut slots, sheds);
                        resolve_responses(shared, &mut slots, responses);
                        if progressed {
                            continue; // re-evaluate triggers immediately
                        }
                        // An empty success while due cannot normally
                        // happen — wait for the next event rather than
                        // spin.
                        backoff = true;
                    }
                    // The failed flush requeued its batch in order;
                    // retry at the next wake event.
                    Err(_) => {
                        backoff = true;
                        shared.intake.lock().unwrap().flush_failures += 1;
                    }
                }
            }
        }
        // Phase 3: sleep until a submit, a shutdown, a clock jump, or
        // (on a real clock) the wake tick.
        let mut intake = shared.intake.lock().unwrap();
        let event_happened = |i: &Intake| {
            !i.queue.is_empty() || i.shutdown || i.clock_events != seen
        };
        if shared.clock.wakes_on_advance() || backoff || wake.is_none() {
            while !event_happened(&intake) {
                intake = shared.wake.wait(intake).unwrap();
            }
        } else if let Some(t) = wake {
            let now = shared.clock.now();
            if t > now && !event_happened(&intake) {
                let (guard, _) =
                    shared.wake.wait_timeout(intake, Duration::from_nanos(t - now)).unwrap();
                intake = guard;
            }
        }
        drop(intake);
        backoff = false;
    }
}
