//! Time source of the serving runtime.
//!
//! Every deadline decision in `accd::serve` — admission stamping,
//! `FlushPolicy` due-selection, deadline inheritance, the EDF tier of
//! the placement planner, urgency-preferring steals and the latency /
//! miss accounting — reads time from ONE injected [`Clock`] instead of
//! calling `Instant::now()` directly.  Production uses
//! [`MonotonicClock`] (a monotonic wall clock); tests inject a
//! [`VirtualClock`] they advance by hand, so every deadline semantic in
//! the test tree is exercised deterministically, without a single
//! `std::thread::sleep`.
//!
//! Time is a [`Tick`]: nanoseconds since the clock's epoch (~584 years
//! of range).  Ticks are plain `u64`s on purpose — deadline algebra is
//! `min`/`+`/`<=`, test fixtures write literals (`deadline: Some(10)`),
//! and the type never smuggles a wall-clock anchor into code that must
//! stay virtual-clock-clean.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A point in time: nanoseconds since the owning clock's epoch.
pub type Tick = u64;

/// Convert a span into clock ticks (saturating at ~584 years, so
/// "patient" far-future deadlines can never wrap into the past).
pub fn ticks(d: Duration) -> Tick {
    d.as_nanos().min(u64::MAX as u128) as Tick
}

/// A monotonic time source.  `now()` must never decrease.
pub trait Clock: Send + Sync {
    fn now(&self) -> Tick;
}

/// The production clock: monotonic wall time since construction.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Tick {
        ticks(self.epoch.elapsed())
    }
}

/// A test-controlled clock: time stands still until the test advances
/// it.  Clones share the same underlying time, so a test keeps one
/// handle while the batcher owns another.
#[derive(Clone, Default)]
pub struct VirtualClock {
    now: Arc<AtomicU64>,
}

impl VirtualClock {
    /// A virtual clock starting at tick 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// A virtual clock starting at `t`.
    pub fn at(t: Tick) -> Self {
        let clock = Self::default();
        clock.set(t);
        clock
    }

    /// Advance by a span.
    pub fn advance(&self, d: Duration) {
        self.advance_ticks(ticks(d));
    }

    /// Advance by raw ticks.
    pub fn advance_ticks(&self, t: Tick) {
        self.now.fetch_add(t, Ordering::SeqCst);
    }

    /// Jump to an absolute tick.  Must never move time backwards
    /// (monotonicity is the one promise of the `Clock` trait).
    pub fn set(&self, t: Tick) {
        let prev = self.now.swap(t, Ordering::SeqCst);
        assert!(prev <= t, "VirtualClock::set moved time backwards: {prev} -> {t}");
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Tick {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_test_controlled_and_shared() {
        let clock = VirtualClock::new();
        let handle = clock.clone();
        assert_eq!(clock.now(), 0);
        handle.advance(Duration::from_millis(3));
        assert_eq!(clock.now(), 3_000_000, "clones share one time line");
        clock.advance_ticks(5);
        assert_eq!(handle.now(), 3_000_005);
        clock.set(10_000_000);
        assert_eq!(handle.now(), 10_000_000);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn virtual_clock_refuses_to_rewind() {
        let clock = VirtualClock::at(100);
        clock.set(99);
    }

    #[test]
    fn monotonic_clock_never_decreases() {
        let clock = MonotonicClock::new();
        let a = clock.now();
        let b = clock.now();
        assert!(b >= a);
    }

    #[test]
    fn ticks_saturates_instead_of_wrapping() {
        assert_eq!(ticks(Duration::from_nanos(7)), 7);
        assert_eq!(ticks(Duration::MAX), u64::MAX);
    }
}
