//! Time source of the serving runtime.
//!
//! Every deadline decision in `accd::serve` — admission stamping,
//! `FlushPolicy` due-selection, deadline inheritance, the EDF tier of
//! the placement planner, urgency-preferring steals and the latency /
//! miss accounting — reads time from ONE injected [`Clock`] instead of
//! calling `Instant::now()` directly.  Production uses
//! [`MonotonicClock`] (a monotonic wall clock); tests inject a
//! [`VirtualClock`] they advance by hand, so every deadline semantic in
//! the test tree is exercised deterministically, without a single
//! `std::thread::sleep`.
//!
//! Time is a [`Tick`]: nanoseconds since the clock's epoch (~584 years
//! of range).  Ticks are plain `u64`s on purpose — deadline algebra is
//! `min`/`+`/`<=`, test fixtures write literals (`deadline: Some(10)`),
//! and the type never smuggles a wall-clock anchor into code that must
//! stay virtual-clock-clean.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A point in time: nanoseconds since the owning clock's epoch.
pub type Tick = u64;

/// Convert a span into clock ticks (saturating at ~584 years, so
/// "patient" far-future deadlines can never wrap into the past).
pub fn ticks(d: Duration) -> Tick {
    d.as_nanos().min(u64::MAX as u128) as Tick
}

/// Callback a clock invokes whenever its reading jumps (see
/// [`Clock::register_waker`]).
pub type ClockWaker = Arc<dyn Fn() + Send + Sync>;

/// A monotonic time source.  `now()` must never decrease.
pub trait Clock: Send + Sync {
    fn now(&self) -> Tick;

    /// Register a callback fired whenever the clock's reading jumps
    /// discontinuously — a `VirtualClock` being advanced by a test.
    /// The always-on `serve::Server` registers its scheduler's wake
    /// signal here so virtual time drives the loop with zero real
    /// sleeps.  A continuously-flowing clock has no jumps to report:
    /// the default implementation drops the waker, and such clocks
    /// return `false` from [`Clock::wakes_on_advance`] so the server
    /// falls back to timed waits.
    fn register_waker(&self, _waker: ClockWaker) {}

    /// Whether registered wakers will actually fire on time jumps —
    /// i.e. whether a waiter may sleep *indefinitely* and rely on the
    /// clock to wake it.  `false` (the default) means "use a timed
    /// wait sized by `now()` arithmetic instead".
    fn wakes_on_advance(&self) -> bool {
        false
    }
}

/// The production clock: monotonic wall time since construction.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Tick {
        ticks(self.epoch.elapsed())
    }
}

/// A test-controlled clock: time stands still until the test advances
/// it.  Clones share the same underlying time (and waker list), so a
/// test keeps one handle while the batcher owns another.
#[derive(Clone, Default)]
pub struct VirtualClock {
    now: Arc<AtomicU64>,
    wakers: Arc<Mutex<Vec<ClockWaker>>>,
}

impl VirtualClock {
    /// A virtual clock starting at tick 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// A virtual clock starting at `t`.
    pub fn at(t: Tick) -> Self {
        let clock = Self::default();
        clock.set(t);
        clock
    }

    /// Advance by a span.
    pub fn advance(&self, d: Duration) {
        self.advance_ticks(ticks(d));
    }

    /// Advance by raw ticks.
    pub fn advance_ticks(&self, t: Tick) {
        self.now.fetch_add(t, Ordering::SeqCst);
        self.wake_all();
    }

    /// Jump to an absolute tick.  Must never move time backwards
    /// (monotonicity is the one promise of the `Clock` trait).
    pub fn set(&self, t: Tick) {
        let prev = self.now.swap(t, Ordering::SeqCst);
        assert!(prev <= t, "VirtualClock::set moved time backwards: {prev} -> {t}");
        self.wake_all();
    }

    fn wake_all(&self) {
        let wakers = self.wakers.lock().unwrap();
        for w in wakers.iter() {
            w();
        }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Tick {
        self.now.load(Ordering::SeqCst)
    }

    fn register_waker(&self, waker: ClockWaker) {
        self.wakers.lock().unwrap().push(waker);
    }

    fn wakes_on_advance(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_test_controlled_and_shared() {
        let clock = VirtualClock::new();
        let handle = clock.clone();
        assert_eq!(clock.now(), 0);
        handle.advance(Duration::from_millis(3));
        assert_eq!(clock.now(), 3_000_000, "clones share one time line");
        clock.advance_ticks(5);
        assert_eq!(handle.now(), 3_000_005);
        clock.set(10_000_000);
        assert_eq!(handle.now(), 10_000_000);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn virtual_clock_refuses_to_rewind() {
        let clock = VirtualClock::at(100);
        clock.set(99);
    }

    #[test]
    fn monotonic_clock_never_decreases() {
        let clock = MonotonicClock::new();
        let a = clock.now();
        let b = clock.now();
        assert!(b >= a);
    }

    #[test]
    fn ticks_saturates_instead_of_wrapping() {
        assert_eq!(ticks(Duration::from_nanos(7)), 7);
        assert_eq!(ticks(Duration::MAX), u64::MAX);
    }

    #[test]
    fn virtual_clock_fires_wakers_on_every_jump() {
        use std::sync::atomic::AtomicUsize;
        let clock = VirtualClock::new();
        assert!(clock.wakes_on_advance());
        let fired = Arc::new(AtomicUsize::new(0));
        let probe = fired.clone();
        // Registration through a clone must reach the shared list.
        clock.clone().register_waker(Arc::new(move || {
            probe.fetch_add(1, Ordering::SeqCst);
        }));
        clock.advance(Duration::from_millis(1));
        clock.advance_ticks(5);
        clock.set(99_000_000);
        assert_eq!(fired.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn monotonic_clock_has_no_waker_support() {
        let clock = MonotonicClock::new();
        assert!(!clock.wakes_on_advance(), "real time flows; waiters must use timeouts");
        clock.register_waker(Arc::new(|| {})); // default no-op must not panic
    }
}
