//! Execution layer of the serving runtime: cohorts on engine shards.
//!
//! Each shard owns a [`ShardState`] — its grouping cache, its
//! persistent cross-flush [`SlabCache`] and its lifetime
//! [`ServeStats`] — and executes the work units the placement layer
//! assigned to it: KNN cohorts stream every member query's surviving
//! tiles through ONE tagged [`pipeline`] run with per-query demux;
//! K-means / N-body jobs run through the engine's shared-grouping
//! entry points.  [`execute_plan`] fans the shards out on scoped OS
//! threads (independent cohorts execute concurrently; everything a
//! thread touches is its own shard's state) and joins them in shard
//! order, so result assembly and stats accounting stay deterministic.
//!
//! Failure is all-or-nothing per flush: a shard error aborts the whole
//! flush; per-shard deltas are only applied by the facade on full
//! success, so no partial accounting can leak.

use std::sync::Arc;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::coordinator::{kmeans, knn, nbody, pipeline};
use crate::coordinator::{Engine, SlabCache, SlabScope};
use crate::data::Dataset;
use crate::fpga::TileResult;
use crate::gti::Metric;
use crate::layout::PackedGrouping;
use crate::metrics::{RunReport, ServeStats};
use crate::{Error, Result};

use super::admission::{KmeansJob, KnnCohort, KnnQ, NbodyJob, ServeResponse, WorkUnit};
use super::cache::{GroupingCache, GroupingKey};
use super::placement::EnginePool;

/// Per-shard serving state: caches survive across flushes (that is
/// the point), stats accumulate over the shard's lifetime.
pub(crate) struct ShardState {
    pub grouping_cache: GroupingCache,
    pub slab_cache: SlabCache,
    pub stats: ServeStats,
}

impl ShardState {
    pub fn new(cfg: &ServeConfig) -> Self {
        Self {
            grouping_cache: GroupingCache::new(cfg.grouping_cache_cap),
            slab_cache: SlabCache::with_budget(cfg.slab_cache_bytes),
            stats: ServeStats::default(),
        }
    }
}

/// What one shard produced for one flush: response fan-out slots and
/// the execution-counter delta (cache counters as before/after
/// differences, so a failed flush drops them with the delta).
#[derive(Default)]
pub(crate) struct ShardDelta {
    pub stats: ServeStats,
    pub responses: Vec<(usize, ServeResponse)>,
}

/// Execute one flush's placed units across the pool, concurrently when
/// more than one shard has work.  Returns the filled response slots
/// and one delta per shard (empty for idle shards); `Err` aborts the
/// whole flush (first erroring shard in shard order).
pub(crate) fn execute_plan(
    pool: &mut EnginePool,
    states: &mut [ShardState],
    units: Vec<WorkUnit>,
    assignments: &[Vec<usize>],
    n_slots: usize,
    cfg: &ServeConfig,
) -> Result<(Vec<Option<ServeResponse>>, Vec<ShardDelta>)> {
    debug_assert_eq!(pool.shard_count(), assignments.len());
    let mut slots: Vec<Option<WorkUnit>> = units.into_iter().map(Some).collect();
    let shard_units: Vec<Vec<WorkUnit>> = assignments
        .iter()
        .map(|idxs| {
            idxs.iter().map(|&i| slots[i].take().expect("unit assigned exactly once")).collect()
        })
        .collect();

    let active = shard_units.iter().filter(|u| !u.is_empty()).count();
    let engines = pool.engines_mut();
    let mut outcomes: Vec<Result<ShardDelta>> = Vec::with_capacity(engines.len());
    if active <= 1 {
        // Inline fast path: nothing to overlap, so skip thread spawn.
        for ((engine, state), units) in
            engines.iter_mut().zip(states.iter_mut()).zip(shard_units.into_iter())
        {
            outcomes.push(if units.is_empty() {
                Ok(ShardDelta::default())
            } else {
                run_shard(engine, state, units, cfg)
            });
        }
    } else {
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(engines.len());
            for ((engine, state), units) in
                engines.iter_mut().zip(states.iter_mut()).zip(shard_units.into_iter())
            {
                handles.push(if units.is_empty() {
                    None
                } else {
                    Some(scope.spawn(move || run_shard(engine, state, units, cfg)))
                });
            }
            for handle in handles {
                outcomes.push(match handle {
                    Some(h) => match h.join() {
                        Ok(outcome) => outcome,
                        Err(panic) => std::panic::resume_unwind(panic),
                    },
                    None => Ok(ShardDelta::default()),
                });
            }
        });
    }

    let mut deltas = Vec::with_capacity(outcomes.len());
    for outcome in outcomes {
        deltas.push(outcome?);
    }
    let mut responses: Vec<Option<ServeResponse>> = (0..n_slots).map(|_| None).collect();
    for delta in &mut deltas {
        for (pos, resp) in delta.responses.drain(..) {
            responses[pos] = Some(resp);
        }
    }
    Ok((responses, deltas))
}

/// Commit one successful flush's deltas: fold execution counters into
/// each shard's lifetime stats and the merged view, then re-publish
/// the cache gauges (hit/miss/collision/eviction counters and resident
/// bytes) as *absolute* values read from the caches themselves — so
/// the stats can never drift from cache reality, even across a failed
/// flush whose cache warm-up had no committable delta.
pub(crate) fn commit_deltas(
    states: &mut [ShardState],
    deltas: &[ShardDelta],
    merged: &mut ServeStats,
) {
    let mut gauges = ServeStats::default();
    for (state, delta) in states.iter_mut().zip(deltas) {
        merged.absorb_exec(&delta.stats);
        state.stats.absorb_exec(&delta.stats);
        if delta.stats.queries > 0 {
            state.stats.flushes += 1;
            state.stats.wall_secs += delta.stats.wall_secs;
        }
        let s = &mut state.stats;
        s.grouping_cache_hits = state.grouping_cache.hits;
        s.grouping_cache_misses = state.grouping_cache.misses;
        s.grouping_probe_collisions = state.grouping_cache.probe_collisions;
        s.slab_cache_hits = state.slab_cache.hits;
        s.slab_cache_misses = state.slab_cache.misses;
        s.slab_cache_evictions = state.slab_cache.evictions;
        s.slab_cache_bytes = state.slab_cache.resident_bytes() as u64;
        gauges.grouping_cache_hits += s.grouping_cache_hits;
        gauges.grouping_cache_misses += s.grouping_cache_misses;
        gauges.grouping_probe_collisions += s.grouping_probe_collisions;
        gauges.slab_cache_hits += s.slab_cache_hits;
        gauges.slab_cache_misses += s.slab_cache_misses;
        gauges.slab_cache_evictions += s.slab_cache_evictions;
        gauges.slab_cache_bytes += s.slab_cache_bytes;
    }
    merged.grouping_cache_hits = gauges.grouping_cache_hits;
    merged.grouping_cache_misses = gauges.grouping_cache_misses;
    merged.grouping_probe_collisions = gauges.grouping_probe_collisions;
    merged.slab_cache_hits = gauges.slab_cache_hits;
    merged.slab_cache_misses = gauges.slab_cache_misses;
    merged.slab_cache_evictions = gauges.slab_cache_evictions;
    merged.slab_cache_bytes = gauges.slab_cache_bytes;
}

/// Run one shard's units serially on its engine, collecting the delta.
fn run_shard(
    engine: &mut Engine,
    state: &mut ShardState,
    units: Vec<WorkUnit>,
    cfg: &ServeConfig,
) -> Result<ShardDelta> {
    let t0 = Instant::now();
    let mut delta = ShardDelta::default();
    for unit in units {
        match unit {
            WorkUnit::Knn(cohort) => run_knn_cohort(engine, state, cohort, cfg, &mut delta)?,
            WorkUnit::Kmeans(job) => run_kmeans_job(engine, state, job, &mut delta)?,
            WorkUnit::Nbody(job) => run_nbody_job(engine, state, job, &mut delta)?,
        }
    }
    delta.stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok(delta)
}

/// Grouping-cache lookup with the engine's config baked into the key.
/// The fingerprint pair was computed once at admission; no hashing
/// happens here.
fn cached_grouping(
    engine: &Engine,
    cache: &mut GroupingCache,
    ds: &Dataset,
    fp: (u64, u64),
    groups: usize,
    seed: u64,
    metric: Metric,
) -> Result<Arc<PackedGrouping>> {
    let iters = engine.config.gti.grouping_iters;
    let sample = engine.config.gti.grouping_sample;
    let key = GroupingKey { fingerprint: fp.0, groups, iters, sample, seed, metric };
    let points = &ds.points;
    cache.get_or_build(key, fp.1, || {
        PackedGrouping::build(points, groups, iters, sample, seed, metric, 8)
    })
}

/// Execute one KNN cohort: shared target grouping + slabs (served
/// through the shard's persistent cache), one tagged pipeline over
/// every unique query's dispatch batches, per-query demux and merge.
fn run_knn_cohort(
    engine: &mut Engine,
    state: &mut ShardState,
    cohort: KnnCohort,
    cfg: &ServeConfig,
    delta: &mut ShardDelta,
) -> Result<()> {
    let cohort_t0 = Instant::now();
    let KnnCohort { trg, trg_fp, metric, queries } = cohort;
    let seed = engine.config.seed;
    let (iters, sample) = (engine.config.gti.grouping_iters, engine.config.gti.grouping_sample);
    let tile = engine.runtime.manifest().tile.clone();

    let trg_groups = engine.trg_groups(trg.n());
    let trg_seed = seed ^ 0x7267;
    let trg_pg = cached_grouping(
        engine,
        &mut state.grouping_cache,
        &trg,
        trg_fp,
        trg_groups,
        trg_seed,
        metric,
    )?;
    // Slab scope: the target grouping's full identity + tile geometry,
    // so the persistent cache can never serve a slab across distinct
    // targets, parameters or paddings.
    let d_pad = tile.pad_d(trg.d())?;
    let slab_scope = SlabScope {
        fingerprint: trg_fp.0,
        probe: trg_fp.1,
        groups: trg_groups,
        iters,
        sample,
        seed: trg_seed,
        metric,
        d_pad,
        tile_n: tile.n,
    };

    // Plan every unique query, sharing packed target slabs.
    struct Unique {
        q: KnnQ,
        src_pg: Arc<PackedGrouping>,
        plan: knn::KnnPlan,
        dups: Vec<usize>,
    }
    let mut uniques: Vec<Unique> = Vec::new();
    for q in queries {
        if cfg.dedup {
            // The ONE within-cohort identity (KnnQ::same_query):
            // parameters + dataset name (report.dataset carries it) +
            // content via the admission-computed fingerprints — never
            // a point scan.
            if let Some(ui) = uniques.iter().position(|u| u.q.same_query(&q)) {
                uniques[ui].dups.push(q.pos);
                continue;
            }
        }
        let src_groups = engine.src_groups(q.src.n());
        let src_pg = cached_grouping(
            engine,
            &mut state.grouping_cache,
            &q.src,
            q.src_fp,
            src_groups,
            seed,
            metric,
        )?;
        let plan = knn::plan_metric(
            &tile,
            &q.src,
            q.k,
            metric,
            &src_pg,
            &trg_pg,
            &slab_scope,
            &mut state.slab_cache,
        )?;
        delta.stats.slabs_shared += plan.batches.iter().filter(|b| b.shared).count() as u64;
        uniques.push(Unique { q, src_pg, plan, dups: Vec::new() });
    }

    // Stream every unique query's batches through one tagged bounded
    // pipeline (query-major order: per-tag FIFO makes each query's
    // merge identical to its solo run).
    engine.device.reset_stats();
    let device = &engine.device;
    let depth = cfg.pipeline_depth;
    let flat: Vec<(usize, usize)> = uniques
        .iter()
        .enumerate()
        .flat_map(|(qi, u)| (0..u.plan.batches.len()).map(move |bi| (qi, bi)))
        .collect();
    let mut results: Vec<Vec<(usize, TileResult)>> =
        uniques.iter().map(|_| Vec::new()).collect();
    let mut tiles_by_query = vec![0u64; uniques.len()];
    let mut shared_tiles_by_query = vec![0u64; uniques.len()];
    let mut job_err: Option<Error> = None;
    {
        let uniques_ref = &uniques;
        pipeline::run_tagged(
            depth,
            |i| {
                let &(qi, bi) = flat.get(i as usize)?;
                let u = &uniques_ref[qi];
                Some((
                    qi as u64,
                    (bi, knn::build_job(&u.plan.batches[bi], &u.src_pg, &u.plan, &tile)),
                ))
            },
            |tag, (bi, job)| {
                if job_err.is_some() {
                    return;
                }
                if job.src_rows == 0 || job.trg_rows == 0 {
                    return;
                }
                let qi = tag as usize;
                let before = device.stats().tiles;
                match device.distance_block(&job) {
                    Ok(res) => {
                        let tiles = device.stats().tiles - before;
                        tiles_by_query[qi] += tiles;
                        if uniques_ref[qi].plan.batches[bi].shared {
                            shared_tiles_by_query[qi] += tiles;
                        }
                        results[qi].push((bi, res));
                    }
                    Err(e) => job_err = Some(e),
                }
            },
        );
    }
    if let Some(e) = job_err {
        return Err(e);
    }
    let cohort_device = engine.device.stats();
    let cohort_secs = cohort_t0.elapsed().as_secs_f64();

    // Per-query merge + response fan-out.
    for (qi, u) in uniques.into_iter().enumerate() {
        let batch_results = std::mem::take(&mut results[qi]);
        let neighbors = knn::merge_results(&u.plan, batch_results.into_iter());
        let mut report = RunReport::new("knn_join", &u.q.src.name, "accd-serve");
        report.filter.merge(&u.plan.filter_stats);
        report.layout = u.plan.layout_stats.clone();
        // Device/wall accounting is cohort-scoped: tile execution is
        // deliberately shared, so per-query attribution would lie.
        report.device = cohort_device.clone();
        report.device_wall_secs = cohort_device.wall_secs;
        report.device_modeled_secs = cohort_device.modeled_secs;
        report.wall_secs = cohort_secs;
        report.iterations = 1;
        report.quality = knn::quality_of(&neighbors);
        let result = knn::KnnResult { neighbors, k: u.q.k, report };

        let has_dups = !u.dups.is_empty();
        delta.stats.tiles_total += tiles_by_query[qi];
        delta.stats.tiles_shared += if has_dups {
            tiles_by_query[qi]
        } else {
            shared_tiles_by_query[qi]
        };
        delta.stats.knn_queries += 1 + u.dups.len() as u64;
        delta.stats.queries += 1 + u.dups.len() as u64;
        delta.stats.dedup_hits += u.dups.len() as u64;
        for &pos in &u.dups {
            delta.responses.push((pos, ServeResponse::Knn(result.clone())));
        }
        delta.responses.push((u.q.pos, ServeResponse::Knn(result)));
    }
    Ok(())
}

fn run_kmeans_job(
    engine: &mut Engine,
    state: &mut ShardState,
    job: KmeansJob,
    delta: &mut ShardDelta,
) -> Result<()> {
    let seed = engine.config.seed;
    let groups = engine.src_groups(job.ds.n());
    let pg = cached_grouping(
        engine,
        &mut state.grouping_cache,
        &job.ds,
        job.ds_fp,
        groups,
        seed,
        Metric::L2,
    )?;
    let result = kmeans::run_shared(engine, &job.ds, job.k, job.max_iters, Some(&pg))?;
    // `run_shared` resets device stats on entry, so this is the
    // query's own tile count.
    let tiles = engine.device.stats().tiles;
    let has_dups = !job.dups.is_empty();
    delta.stats.tiles_total += tiles;
    if has_dups {
        delta.stats.tiles_shared += tiles;
    }
    delta.stats.kmeans_queries += 1 + job.dups.len() as u64;
    delta.stats.queries += 1 + job.dups.len() as u64;
    delta.stats.dedup_hits += job.dups.len() as u64;
    for &pos in &job.dups {
        delta.responses.push((pos, ServeResponse::Kmeans(result.clone())));
    }
    delta.responses.push((job.pos, ServeResponse::Kmeans(result)));
    Ok(())
}

fn run_nbody_job(
    engine: &mut Engine,
    state: &mut ShardState,
    job: NbodyJob,
    delta: &mut ShardDelta,
) -> Result<()> {
    let seed = engine.config.seed;
    let groups = engine.src_groups(job.ds.n());
    let pg = cached_grouping(
        engine,
        &mut state.grouping_cache,
        &job.ds,
        job.ds_fp,
        groups,
        seed,
        Metric::L2,
    )?;
    let result = nbody::run_shared(
        engine,
        &job.ds,
        &job.masses,
        job.steps,
        job.dt,
        job.radius,
        Some(&pg),
    )?;
    let tiles = engine.device.stats().tiles;
    let has_dups = !job.dups.is_empty();
    delta.stats.tiles_total += tiles;
    if has_dups {
        delta.stats.tiles_shared += tiles;
    }
    delta.stats.nbody_queries += 1 + job.dups.len() as u64;
    delta.stats.queries += 1 + job.dups.len() as u64;
    delta.stats.dedup_hits += job.dups.len() as u64;
    for &pos in &job.dups {
        delta.responses.push((pos, ServeResponse::Nbody(result.clone())));
    }
    delta.responses.push((job.pos, ServeResponse::Nbody(result)));
    Ok(())
}
