//! Execution layer of the serving runtime: stepwise cohort programs on
//! engine shards.
//!
//! Each shard owns a [`ShardState`] — its grouping cache, its
//! persistent cross-flush [`SlabCache`] and its lifetime
//! [`ServeStats`] — and executes work units pulled from the flush's
//! shared [`WorkPool`].  Every unit is *planned* into a stepwise
//! program (`coordinator::program`): KNN cohorts become one-shot
//! [`KnnCohortProgram`]s streaming every member query's surviving
//! tiles through ONE tagged [`pipeline`] run with per-query demux;
//! K-means / N-body jobs become the coordinator's iterative
//! [`kmeans::KmeansProgram`] / [`nbody::NbodyProgram`].
//!
//! With `serve.lockstep` on, a shard runs a **lockstep step
//! scheduler**: each round it claims at most one new own unit from the
//! pool — most urgent deadline first ([`WorkPool::claim_own`]) —
//! planning it against the shard caches (same-dataset programs share
//! groupings, packed K-means assignment tiles and KNN target slabs
//! through the persistent [`SlabCache`]) and then advances every
//! resident program by exactly one iteration, in deadline-slack order
//! (earliest inherited deadline first, admission order among equals),
//! so an urgent program converges — and its response lands — as early
//! as the round structure allows.  Converged programs retire into
//! responses.  Off, units run to completion serially (the pre-lockstep
//! schedule).  Either way results are bit-identical to solo runs:
//! programs own all their state, so the step schedule cannot perturb
//! any result.
//!
//! When the placement's cost estimates misfire, an **idle** shard
//! (nothing resident, own queue empty) steals whole not-yet-started
//! units from a busy victim ([`WorkPool::steal`];
//! `serve.steal_threshold` gates it) — preferring the most urgent
//! at-risk unit (deadline inside its calibrated predicted service
//! window at the flush's clock reading; expired, absent predictions)
//! over the max-cost one.  [`execute_plan`] fans the shards out on scoped
//! OS threads and joins them in shard order, so result assembly stays
//! deterministic (responses carry their submission slots; stats and
//! latency attribution follow the executing shard).
//!
//! Failure is all-or-nothing per flush: a shard error aborts the whole
//! flush; per-shard deltas are only applied by the facade on full
//! success, so no partial accounting can leak.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::ServeConfig;
use crate::coordinator::knn::SlabKind;
use crate::coordinator::program::{self, CohortProgram, StepCtx, StepOutcome};
use crate::coordinator::{kmeans, knn, nbody, pipeline, rangejoin};
use crate::coordinator::{Engine, SlabCache, SlabScope};
use crate::data::Dataset;
use crate::fpga::device::DeviceStats;
use crate::fpga::{DmaModel, TileResult};
use crate::gti::Metric;
use crate::layout::PackedGrouping;
use crate::metrics::{RunReport, ServeStats};
use crate::runtime::TileInfo;
use crate::{Error, Result};

use super::admission::{KnnCohort, KnnQ, RangeJoinCohort, RangeJoinQ, ServeResponse, WorkUnit};
use super::cache::{GroupingCache, GroupingKey};
use super::calibrate::{AlgoKind, Observation};
use super::clock::Tick;
use super::placement::{EnginePool, WorkPool};

/// Per-shard serving state: caches survive across flushes (that is
/// the point), stats accumulate over the shard's lifetime.
pub(crate) struct ShardState {
    pub grouping_cache: GroupingCache,
    pub slab_cache: SlabCache,
    pub stats: ServeStats,
}

impl ShardState {
    pub fn new(cfg: &ServeConfig) -> Self {
        Self::with_budget(cfg, cfg.slab_cache_bytes)
    }

    /// Like [`ShardState::new`] but with the slab budget already
    /// clamped to the shard's share of its device's memory
    /// ([`DeviceTopology::shard_slab_budget`](crate::runtime::DeviceTopology::shard_slab_budget)).
    pub fn with_budget(cfg: &ServeConfig, slab_budget: usize) -> Self {
        Self {
            grouping_cache: GroupingCache::new(cfg.grouping_cache_cap),
            // slab_cache_bytes == 0 means DISABLED (build fresh every
            // time), not unbounded — `ServeConfig::validate` documents
            // the zero semantics.
            slab_cache: if slab_budget == 0 {
                SlabCache::disabled()
            } else {
                SlabCache::with_budget(slab_budget)
            },
            stats: ServeStats::default(),
        }
    }
}

/// What one shard produced for one flush: response fan-out slots and
/// the execution-counter delta (cache counters as before/after
/// differences, so a failed flush drops them with the delta).
#[derive(Default)]
pub(crate) struct ShardDelta {
    pub stats: ServeStats,
    pub responses: Vec<(usize, ServeResponse)>,
    /// One entry per unit this shard retired: the calibrator feedback
    /// (kind, planner cost, actual modeled ns) the batcher folds into
    /// its [`super::calibrate::CostCalibrator`] after a successful
    /// commit — in retirement order, so the fold is deterministic.
    pub observations: Vec<Observation>,
}

/// Execute one flush's placed units across the pool, concurrently when
/// more than one shard has (or can steal) work.  `costs` and
/// `deadlines` are the same per-unit values the planner balanced on
/// (computed once per flush; the steal threshold compares against the
/// costs, claim order and at-risk steals against the deadlines);
/// `move_units` is the same per-unit x per-shard movement table the
/// planner placed with (empty when movement-awareness is off) so
/// steals are discounted by the thief's cold bytes; `pred_ns` is the
/// calibrator's per-unit predicted service time (empty when no
/// predictions were made) driving predicted-slack steals and the
/// predicted-vs-actual error telemetry; `now` is the flush's clock
/// reading.  Returns the filled response slots, which shard answered
/// each slot (latency attribution), and one delta per shard (empty for
/// idle shards); `Err` aborts the whole flush (first erroring shard in
/// shard order).
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_plan(
    pool: &mut EnginePool,
    states: &mut [ShardState],
    units: Vec<WorkUnit>,
    costs: Vec<u64>,
    deadlines: Vec<Option<Tick>>,
    move_units: Vec<Vec<u64>>,
    pred_ns: Vec<u64>,
    assignments: &[Vec<usize>],
    n_slots: usize,
    cfg: &ServeConfig,
    now: Tick,
) -> Result<(Vec<Option<ServeResponse>>, Vec<Option<usize>>, Vec<ShardDelta>)> {
    debug_assert_eq!(pool.shard_count(), assignments.len());
    let n_shards = pool.shard_count();
    let topology = pool.topology().clone();
    let costs_by_unit = costs.clone();
    let mut work_pool = WorkPool::with_movement(units, costs, deadlines, move_units, assignments);
    work_pool.set_predictions(pred_ns.clone());
    let tables = UnitTables { costs: &costs_by_unit, pred_ns: &pred_ns };
    // Idle shards spawn as thieves only when stealing could ever fire
    // this flush (the eligibility policy lives in WorkPool).
    let thieves = cfg.steal_threshold > 0
        && n_shards > 1
        && work_pool.any_tail_prospect(cfg.steal_threshold);
    let work = Mutex::new(work_pool);
    let workers: Vec<bool> =
        (0..n_shards).map(|s| thieves || !assignments[s].is_empty()).collect();

    let engines = pool.engines_mut();
    let mut outcomes: Vec<Result<ShardDelta>> = Vec::with_capacity(engines.len());
    if workers.iter().filter(|&&w| w).count() <= 1 {
        // Inline fast path: nothing to overlap, so skip thread spawn.
        for (s, (engine, state)) in engines.iter_mut().zip(states.iter_mut()).enumerate() {
            outcomes.push(if workers[s] {
                let dma = *topology.dma_for_shard(s);
                run_shard(engine, state, &work, s, cfg, now, dma, tables)
            } else {
                Ok(ShardDelta::default())
            });
        }
    } else {
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(engines.len());
            let work_ref = &work;
            for (s, (engine, state)) in engines.iter_mut().zip(states.iter_mut()).enumerate() {
                handles.push(if workers[s] {
                    let dma = *topology.dma_for_shard(s);
                    Some(scope.spawn(move || {
                        run_shard(engine, state, work_ref, s, cfg, now, dma, tables)
                    }))
                } else {
                    None
                });
            }
            for handle in handles {
                outcomes.push(match handle {
                    Some(h) => match h.join() {
                        Ok(outcome) => outcome,
                        Err(panic) => std::panic::resume_unwind(panic),
                    },
                    None => Ok(ShardDelta::default()),
                });
            }
        });
    }

    let mut deltas = Vec::with_capacity(outcomes.len());
    for outcome in outcomes {
        deltas.push(outcome?);
    }
    let mut responses: Vec<Option<ServeResponse>> = (0..n_slots).map(|_| None).collect();
    let mut shard_of: Vec<Option<usize>> = vec![None; n_slots];
    for (s, delta) in deltas.iter_mut().enumerate() {
        for (pos, resp) in delta.responses.drain(..) {
            responses[pos] = Some(resp);
            shard_of[pos] = Some(s);
        }
    }
    Ok((responses, shard_of, deltas))
}

/// Commit one successful flush's deltas: fold execution counters into
/// each shard's lifetime stats and the merged view, then re-publish
/// the cache gauges (hit/miss/collision/eviction counters and resident
/// bytes) as *absolute* values read from the caches themselves — so
/// the stats can never drift from cache reality, even across a failed
/// flush whose cache warm-up had no committable delta.
pub(crate) fn commit_deltas(
    states: &mut [ShardState],
    deltas: &[ShardDelta],
    merged: &mut ServeStats,
) {
    let mut gauges = ServeStats::default();
    for (state, delta) in states.iter_mut().zip(deltas) {
        merged.absorb_exec(&delta.stats);
        state.stats.absorb_exec(&delta.stats);
        if delta.stats.queries > 0 {
            state.stats.flushes += 1;
            state.stats.wall_secs += delta.stats.wall_secs;
        }
        let s = &mut state.stats;
        s.grouping_cache_hits = state.grouping_cache.hits;
        s.grouping_cache_misses = state.grouping_cache.misses;
        s.grouping_probe_collisions = state.grouping_cache.probe_collisions;
        s.slab_cache_hits = state.slab_cache.hits;
        s.slab_cache_misses = state.slab_cache.misses;
        s.slab_cache_evictions = state.slab_cache.evictions;
        s.slab_cache_bytes = state.slab_cache.resident_bytes() as u64;
        gauges.grouping_cache_hits += s.grouping_cache_hits;
        gauges.grouping_cache_misses += s.grouping_cache_misses;
        gauges.grouping_probe_collisions += s.grouping_probe_collisions;
        gauges.slab_cache_hits += s.slab_cache_hits;
        gauges.slab_cache_misses += s.slab_cache_misses;
        gauges.slab_cache_evictions += s.slab_cache_evictions;
        gauges.slab_cache_bytes += s.slab_cache_bytes;
    }
    merged.grouping_cache_hits = gauges.grouping_cache_hits;
    merged.grouping_cache_misses = gauges.grouping_cache_misses;
    merged.grouping_probe_collisions = gauges.grouping_probe_collisions;
    merged.slab_cache_hits = gauges.slab_cache_hits;
    merged.slab_cache_misses = gauges.slab_cache_misses;
    merged.slab_cache_evictions = gauges.slab_cache_evictions;
    merged.slab_cache_bytes = gauges.slab_cache_bytes;
}

// --- the per-shard schedulers ----------------------------------------------

/// Flush-scoped modeled transfer/compute timeline of one shard's
/// emulated device: double-buffered (ping-pong) uploads on a second
/// DMA channel when `serve.overlap` is on, fully serialized when off.
///
/// Pure accounting over the same modeled quantities the cost model and
/// the slab cache already produce — `upload_bytes` is the shard's
/// cold-slab DMA traffic (the SlabCache miss-bytes delta around a
/// plan), `compute_ns` the device's modeled tile time — so turning
/// overlap on or off can only change the three counters it feeds into
/// [`ServeStats`], never a result (the parity property test pins
/// this).
struct XferClock {
    dma: DmaModel,
    overlap: bool,
    /// When the (second) DMA channel frees up, ns since flush start.
    dma_free: u64,
    /// When the compute engine frees up, ns since flush start.
    compute_free: u64,
    transfer_ns: u64,
    compute_ns: u64,
}

impl XferClock {
    fn new(dma: DmaModel, overlap: bool) -> Self {
        Self { dma, overlap, dma_free: 0, compute_free: 0, transfer_ns: 0, compute_ns: 0 }
    }

    /// One plan-or-step's worth of modeled work: upload its cold bytes,
    /// then compute.  With overlap the upload streams on the dedicated
    /// channel while the previous compute still runs (ping-pong
    /// buffers); compute of THIS work still waits for its own upload —
    /// data dependencies are never violated, only inter-unit transfer
    /// time hides.
    fn record(&mut self, upload_bytes: u64, compute_ns: u64) {
        let t = self.dma.transfer_ns(upload_bytes);
        if self.overlap {
            let upload_done = self.dma_free + t;
            self.dma_free = upload_done;
            self.compute_free = upload_done.max(self.compute_free) + compute_ns;
        } else {
            // Single serialized timeline: the link and the engine never
            // run at the same time.
            self.compute_free += t + compute_ns;
            self.dma_free = self.compute_free;
        }
        self.transfer_ns += t;
        self.compute_ns += compute_ns;
    }

    /// Fold the flush's timeline into the shard delta.  `overlap_ns`
    /// is the modeled time double-buffering saved: total work minus
    /// makespan — exactly 0 when overlap is off.
    fn flush_into(&self, stats: &mut ServeStats) {
        stats.transfer_ns += self.transfer_ns;
        stats.compute_ns += self.compute_ns;
        let makespan = self.dma_free.max(self.compute_free);
        stats.overlap_ns += (self.transfer_ns + self.compute_ns).saturating_sub(makespan);
    }
}

/// Modeled device-nanoseconds consumed since snapshot `secs0` (the
/// XferClock's compute currency).
fn modeled_ns_since(engine: &Engine, secs0: f64) -> u64 {
    ((engine.device.stats().modeled_secs - secs0).max(0.0) * 1e9).round() as u64
}

/// Flush-scoped per-unit lookup tables shared (read-only) by every
/// shard: the planner costs and the calibrated service-time
/// predictions, keyed by the flush index `claim` returns alongside the
/// unit.  `pred_ns` is empty when the flush made no predictions.
#[derive(Clone, Copy)]
struct UnitTables<'a> {
    costs: &'a [u64],
    pred_ns: &'a [u64],
}

/// Predicted-vs-actual bookkeeping of one resident unit, carried from
/// claim to retirement: what the calibrator predicted for the unit and
/// the modeled nanoseconds its plan + steps + finish actually charged
/// (the same deltas the [`XferClock`] records).
struct UnitTally {
    kind: AlgoKind,
    cost_units: u64,
    pred_ns: u64,
    /// Whether a prediction existed for this flush at all — separates
    /// "predicted 0 ns" from "nothing was predicted".
    predicted: bool,
    actual_ns: u64,
}

impl UnitTally {
    fn new(kind: AlgoKind, unit_index: usize, tables: UnitTables<'_>) -> Self {
        Self {
            kind,
            cost_units: tables.costs.get(unit_index).copied().unwrap_or(0),
            pred_ns: tables.pred_ns.get(unit_index).copied().unwrap_or(0),
            predicted: !tables.pred_ns.is_empty(),
            actual_ns: 0,
        }
    }
}

/// Retire one unit's tally: the prediction-error sample (permille of
/// actual, so over- and under-prediction weigh alike) and the
/// calibrator observation.
fn retire_tally(delta: &mut ShardDelta, t: UnitTally) {
    if t.predicted {
        let err = t.pred_ns.abs_diff(t.actual_ns).saturating_mul(1000) / t.actual_ns.max(1);
        delta.stats.record_predict_error(err);
    }
    delta.observations.push(Observation {
        kind: t.kind,
        cost_units: t.cost_units,
        actual_ns: t.actual_ns,
    });
}

/// Run one shard's share of a flush — lockstep rounds or serial
/// run-to-completion — collecting the delta.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    engine: &mut Engine,
    state: &mut ShardState,
    work: &Mutex<WorkPool<WorkUnit>>,
    shard: usize,
    cfg: &ServeConfig,
    now: Tick,
    dma: DmaModel,
    tables: UnitTables<'_>,
) -> Result<ShardDelta> {
    let t0 = Instant::now();
    let mut delta = ShardDelta::default();
    let mut xfer = XferClock::new(dma, cfg.overlap);
    if cfg.lockstep {
        run_lockstep(engine, state, work, shard, cfg, now, &mut delta, &mut xfer, tables)?;
    } else {
        run_serial(engine, state, work, shard, cfg, now, &mut delta, &mut xfer, tables)?;
    }
    xfer.flush_into(&mut delta.stats);
    delta.stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok(delta)
}

/// Pull one unit from the pool — own queue first (most urgent
/// deadline), then — only when the shard is otherwise idle — a steal —
/// together with its flush index (the tally/prediction key).
fn claim(
    work: &Mutex<WorkPool<WorkUnit>>,
    shard: usize,
    cfg: &ServeConfig,
    idle: bool,
    now: Tick,
    delta: &mut ShardDelta,
) -> Option<(usize, WorkUnit)> {
    let mut pool = work.lock().expect("work pool poisoned");
    if let Some(hit) = pool.claim_own_indexed(shard) {
        return Some(hit);
    }
    if idle && cfg.steal_threshold > 0 {
        if let Some(hit) = pool.steal_indexed(shard, cfg.steal_threshold, now) {
            delta.stats.steals += 1;
            return Some(hit);
        }
    }
    None
}

/// Whether some victim still holds a qualifying pending unit this
/// shard could steal once the victim starts (see
/// [`WorkPool::stealable_prospect`]).  While true, an idle shard
/// yields and retries instead of exiting the flush: the only way a
/// prospect disappears is a shard claiming it — owner or thief — so
/// the wait is always bounded by live progress.
fn steal_prospect(work: &Mutex<WorkPool<WorkUnit>>, shard: usize, cfg: &ServeConfig) -> bool {
    cfg.steal_threshold > 0
        && work
            .lock()
            .expect("work pool poisoned")
            .stealable_prospect(shard, cfg.steal_threshold)
}

/// Per-round step priority of one resident program: earliest inherited
/// deadline first; among equal deadlines (and the deadline-free),
/// highest observed prune rate first — a high-pruning K-means step is
/// cheap and tightens its bounds further, so running it early retires
/// it (and frees its slab residency) soonest; admission order breaks
/// the remaining ties.  Pure function of scheduler-visible metadata:
/// it reorders steps of independent programs only, so it can never
/// perturb a result.
fn step_priority(
    deadline: Option<Tick>,
    prune_permille: u64,
    admitted: usize,
) -> (Tick, u64, usize) {
    (
        deadline.unwrap_or(Tick::MAX),
        1000u64.saturating_sub(prune_permille.min(1000)),
        admitted,
    )
}

/// The lockstep step scheduler: one round = claim at most one new own
/// unit (most urgent deadline first; plan it against the shard
/// caches), then advance every resident program by one step in
/// [`step_priority`] order — earliest inherited deadline first, then
/// observed prune rate, then admission order — so the program whose
/// deadline is tightest is also the first to make progress (and to
/// retire) each round.  Claiming one unit per round keeps the tail of
/// the queue stealable while co-residency (and the persistent caches)
/// still shares packed tiles across same-dataset programs.  The step
/// order cannot perturb results (programs own their state); it only
/// decides which response exists earliest.
#[allow(clippy::too_many_arguments)]
fn run_lockstep(
    engine: &mut Engine,
    state: &mut ShardState,
    work: &Mutex<WorkPool<WorkUnit>>,
    shard: usize,
    cfg: &ServeConfig,
    now: Tick,
    delta: &mut ShardDelta,
    xfer: &mut XferClock,
    tables: UnitTables<'_>,
) -> Result<()> {
    // (inherited deadline, admission sequence, program, tally): the
    // first two plus the program's own prune rate are the per-round
    // step priority; the tally carries the predicted-vs-actual
    // bookkeeping to retirement.
    let mut resident: Vec<Option<(Option<Tick>, usize, Resident, UnitTally)>> = Vec::new();
    let mut admitted = 0usize;
    loop {
        let idle = resident.is_empty();
        if let Some((ui, unit)) = claim(work, shard, cfg, idle, now, delta) {
            let deadline = unit.deadline();
            let mut tally = UnitTally::new(unit.kind(), ui, tables);
            let hits0 = state.slab_cache.hits;
            let miss_bytes0 = state.slab_cache.miss_bytes;
            let secs0 = engine.device.stats().modeled_secs;
            let planned = plan_unit(engine, state, unit, cfg)?;
            // Plan-time slab builds are this unit's cold DMA traffic;
            // plan-time device work (e.g. K-means iteration 0) is its
            // first compute burst.
            let plan_ns = modeled_ns_since(engine, secs0);
            xfer.record(state.slab_cache.miss_bytes.saturating_sub(miss_bytes0), plan_ns);
            tally.actual_ns += plan_ns;
            // Slab-cache hits while planning ALONGSIDE resident
            // programs are the lockstep scheduler's own cross-program
            // sharing; hits on an idle shard are the persistent
            // cache's cross-flush reuse and stay out of this counter
            // (they show in the slab_cache_* gauges).
            if !idle {
                delta.stats.lockstep_shared_tiles +=
                    state.slab_cache.hits.saturating_sub(hits0);
            }
            resident.push(Some((deadline, admitted, planned, tally)));
            admitted += 1;
        } else if resident.is_empty() {
            // Nothing to run and nothing stealable *yet*: if a victim
            // still holds a qualifying pending unit (it merely has not
            // started), wait for it to claim its first unit rather
            // than exiting and leaving the imbalance uncorrected.
            if steal_prospect(work, shard, cfg) {
                std::thread::yield_now();
                continue;
            }
            break;
        }
        delta.stats.lockstep_rounds += 1;
        let mut order: Vec<usize> = (0..resident.len()).collect();
        order.sort_by_key(|&i| {
            let entry = resident[i].as_ref().expect("resident before stepping");
            step_priority(entry.0, entry.2.prune_permille(), entry.1)
        });
        for i in order {
            let slot = &mut resident[i];
            let converged = match slot.as_mut() {
                Some((_, _, prog, tally)) => {
                    let secs0 = engine.device.stats().modeled_secs;
                    let outcome = step_resident(engine, prog)?;
                    let step_ns = modeled_ns_since(engine, secs0);
                    xfer.record(0, step_ns);
                    tally.actual_ns += step_ns;
                    matches!(outcome, StepOutcome::Converged)
                }
                None => false,
            };
            if converged {
                let (_, _, prog, mut tally) = slot.take().expect("stepped program present");
                let secs0 = engine.device.stats().modeled_secs;
                finish_resident(engine, prog, delta)?;
                let finish_ns = modeled_ns_since(engine, secs0);
                xfer.record(0, finish_ns);
                tally.actual_ns += finish_ns;
                retire_tally(delta, tally);
            }
        }
        resident.retain(|slot| slot.is_some());
    }
    Ok(())
}

/// The serial schedule (lockstep off): claim (most urgent first), run
/// to completion, repeat — stealing still applies between units (with
/// the same wait-for-a-late-victim retry as the lockstep path).
#[allow(clippy::too_many_arguments)]
fn run_serial(
    engine: &mut Engine,
    state: &mut ShardState,
    work: &Mutex<WorkPool<WorkUnit>>,
    shard: usize,
    cfg: &ServeConfig,
    now: Tick,
    delta: &mut ShardDelta,
    xfer: &mut XferClock,
    tables: UnitTables<'_>,
) -> Result<()> {
    loop {
        let Some((ui, unit)) = claim(work, shard, cfg, true, now, delta) else {
            if steal_prospect(work, shard, cfg) {
                std::thread::yield_now();
                continue;
            }
            return Ok(());
        };
        let mut tally = UnitTally::new(unit.kind(), ui, tables);
        let miss_bytes0 = state.slab_cache.miss_bytes;
        let secs0 = engine.device.stats().modeled_secs;
        let mut prog = plan_unit(engine, state, unit, cfg)?;
        let plan_ns = modeled_ns_since(engine, secs0);
        xfer.record(state.slab_cache.miss_bytes.saturating_sub(miss_bytes0), plan_ns);
        tally.actual_ns += plan_ns;
        loop {
            let secs0 = engine.device.stats().modeled_secs;
            let outcome = step_resident(engine, &mut prog)?;
            let step_ns = modeled_ns_since(engine, secs0);
            xfer.record(0, step_ns);
            tally.actual_ns += step_ns;
            if let StepOutcome::Converged = outcome {
                break;
            }
        }
        let secs0 = engine.device.stats().modeled_secs;
        finish_resident(engine, prog, delta)?;
        let finish_ns = modeled_ns_since(engine, secs0);
        xfer.record(0, finish_ns);
        tally.actual_ns += finish_ns;
        retire_tally(delta, tally);
    }
}

// --- resident programs ------------------------------------------------------

/// One planned program resident on a shard, with the response-slot
/// metadata the coordinator programs do not know about.  Boxed:
/// residents move between rounds (and, stolen, between shards), so
/// keep the moves pointer-sized.
enum Resident {
    Knn(Box<KnnCohortProgram>),
    RangeJoin(Box<RangeJoinCohortProgram>),
    Kmeans { prog: Box<kmeans::KmeansProgram>, pos: usize, dups: Vec<usize> },
    Nbody { prog: Box<nbody::NbodyProgram>, pos: usize, dups: Vec<usize> },
}

impl Resident {
    /// Observed prune rate of the program, permille of
    /// point-iterations — the [`step_priority`] tiebreaker.  Only
    /// K-means carries a cross-iteration prune signal today; one-shot
    /// KNN / range-join cohorts and N-body (dense per step) report 0.
    fn prune_permille(&self) -> u64 {
        match self {
            Resident::Kmeans { prog, .. } => prog.observed_prune_permille(),
            Resident::Knn(_) | Resident::RangeJoin(_) | Resident::Nbody { .. } => 0,
        }
    }
}

/// Plan one work unit into a resident program against this shard's
/// caches.
fn plan_unit(
    engine: &Engine,
    state: &mut ShardState,
    unit: WorkUnit,
    cfg: &ServeConfig,
) -> Result<Resident> {
    match unit {
        WorkUnit::Knn(cohort) => {
            Ok(Resident::Knn(Box::new(plan_knn_cohort(engine, state, cohort, cfg)?)))
        }
        WorkUnit::RangeJoin(cohort) => Ok(Resident::RangeJoin(Box::new(plan_rangejoin_cohort(
            engine, state, cohort, cfg,
        )?))),
        WorkUnit::Kmeans(job) => {
            let seed = engine.config.seed;
            let groups = engine.src_groups(job.ds.n());
            let pg = cached_grouping(
                engine,
                &mut state.grouping_cache,
                &job.ds,
                job.ds_fp,
                groups,
                seed,
                Metric::L2,
            )?;
            let prog = kmeans::plan(
                engine,
                &job.ds,
                job.k,
                job.max_iters,
                Some((pg, job.ds_fp)),
                &mut state.slab_cache,
            )?;
            Ok(Resident::Kmeans { prog: Box::new(prog), pos: job.pos, dups: job.dups })
        }
        WorkUnit::Nbody(job) => {
            let seed = engine.config.seed;
            let groups = engine.src_groups(job.ds.n());
            let pg = cached_grouping(
                engine,
                &mut state.grouping_cache,
                &job.ds,
                job.ds_fp,
                groups,
                seed,
                Metric::L2,
            )?;
            let prog = nbody::plan(
                engine,
                &job.ds,
                job.masses.clone(),
                job.steps,
                job.dt,
                job.radius,
                Some(pg),
            )?;
            Ok(Resident::Nbody { prog: Box::new(prog), pos: job.pos, dups: job.dups })
        }
    }
}

/// Advance one resident program by one step.
fn step_resident(engine: &Engine, resident: &mut Resident) -> Result<StepOutcome> {
    let mut ctx = StepCtx { engine };
    match resident {
        Resident::Knn(prog) => prog.step(&mut ctx),
        Resident::RangeJoin(prog) => prog.step(&mut ctx),
        Resident::Kmeans { prog, .. } => prog.step(&mut ctx),
        Resident::Nbody { prog, .. } => prog.step(&mut ctx),
    }
}

/// Retire one converged program: final pass, response fan-out, stats.
fn finish_resident(engine: &Engine, resident: Resident, delta: &mut ShardDelta) -> Result<()> {
    let mut ctx = StepCtx { engine };
    match resident {
        Resident::Knn(prog) => (*prog).finish_into(&mut ctx, delta),
        Resident::RangeJoin(prog) => (*prog).finish_into(&mut ctx, delta),
        Resident::Kmeans { prog, pos, dups } => {
            let result = (*prog).finish(&mut ctx)?;
            delta.stats.kmeans_queries += 1 + dups.len() as u64;
            retire_job(delta, result, pos, &dups, ServeResponse::Kmeans);
            Ok(())
        }
        Resident::Nbody { prog, pos, dups } => {
            let result = (*prog).finish(&mut ctx)?;
            delta.stats.nbody_queries += 1 + dups.len() as u64;
            retire_job(delta, result, pos, &dups, ServeResponse::Nbody);
            Ok(())
        }
    }
}

/// The shared retirement bookkeeping of a K-means / N-body job: tile
/// accounting from the program's OWN device counters (snapshot diffs,
/// so interleaved neighbors never pollute the count) and response
/// fan-out to the job's slot plus its deduplicated duplicates.
fn retire_job<R>(
    delta: &mut ShardDelta,
    result: R,
    pos: usize,
    dups: &[usize],
    wrap: impl Fn(R) -> ServeResponse,
) where
    R: Clone + HasReport,
{
    let tiles = result.report().device.tiles;
    delta.stats.tiles_total += tiles;
    if !dups.is_empty() {
        // Every tile of a deduplicated job served >1 query.
        delta.stats.tiles_shared += tiles;
    }
    // Incremental TI pruning counters travel with the program's own
    // filter stats; fold them into the shard delta so the per-shard
    // and merged `ServeStats` views both see them (absorb_exec sums).
    let f = &result.report().filter;
    delta.stats.tiles_skipped += f.tiles_skipped;
    delta.stats.points_pruned += f.points_pruned;
    delta.stats.bound_recomputes += f.bound_recomputes;
    delta.stats.queries += 1 + dups.len() as u64;
    delta.stats.dedup_hits += dups.len() as u64;
    for &p in dups {
        delta.responses.push((p, wrap(result.clone())));
    }
    delta.responses.push((pos, wrap(result)));
}

/// The one thing `retire_job` needs from a result type.
trait HasReport {
    fn report(&self) -> &RunReport;
}

impl HasReport for kmeans::KmeansResult {
    fn report(&self) -> &RunReport {
        &self.report
    }
}

impl HasReport for nbody::NbodyResult {
    fn report(&self) -> &RunReport {
        &self.report
    }
}

/// Grouping-cache lookup with the engine's config baked into the key.
/// The fingerprint pair was computed once at admission; no hashing
/// happens here.
fn cached_grouping(
    engine: &Engine,
    cache: &mut GroupingCache,
    ds: &Dataset,
    fp: (u64, u64),
    groups: usize,
    seed: u64,
    metric: Metric,
) -> Result<Arc<PackedGrouping>> {
    let iters = engine.config.gti.grouping_iters;
    let sample = engine.config.gti.grouping_sample;
    let key = GroupingKey { fingerprint: fp.0, groups, iters, sample, seed, metric };
    let points = &ds.points;
    cache.get_or_build(key, fp.1, || {
        PackedGrouping::build(points, groups, iters, sample, seed, metric, 8)
    })
}

// --- the KNN cohort program -------------------------------------------------

/// One planned unique query inside a cohort.
struct UniqueQuery {
    q: KnnQ,
    src_pg: Arc<PackedGrouping>,
    plan: knn::KnnPlan,
    dups: Vec<usize>,
}

/// A whole KNN cohort as a one-shot stepwise program: planning shares
/// the target grouping + packed slabs (served through the shard's
/// persistent caches) across every member query, the single step
/// streams every unique query's dispatch batches through one tagged
/// bounded pipeline, and `finish_into` demuxes per-query merges into
/// response slots.
struct KnnCohortProgram {
    uniques: Vec<UniqueQuery>,
    tile: TileInfo,
    depth: usize,
    /// (unique index, batch index) in query-major dispatch order.
    flat: Vec<(usize, usize)>,
    results: Vec<Vec<(usize, TileResult)>>,
    tiles_by_query: Vec<u64>,
    shared_tiles_by_query: Vec<u64>,
    /// Dispatch batches whose packed target slab came from the cache.
    slabs_shared: u64,
    /// Cohort-scoped device counters (tile execution is deliberately
    /// shared; per-query attribution would lie).
    device: DeviceStats,
    /// Wall seconds spent inside THIS cohort's plan/step calls
    /// (per-call accumulation, so interleaved neighbor programs never
    /// inflate it; within the cohort the accounting stays deliberately
    /// cohort-scoped).
    wall_secs: f64,
    executed: bool,
}

/// Plan one KNN cohort: shared target grouping + slabs (served through
/// the shard's persistent caches), one plan per unique query, dedup
/// under the admission identity.
fn plan_knn_cohort(
    engine: &Engine,
    state: &mut ShardState,
    cohort: KnnCohort,
    cfg: &ServeConfig,
) -> Result<KnnCohortProgram> {
    let t0 = Instant::now();
    let KnnCohort { trg, trg_fp, metric, queries, .. } = cohort;
    let seed = engine.config.seed;
    let (iters, sample) = (engine.config.gti.grouping_iters, engine.config.gti.grouping_sample);
    let tile = engine.runtime.manifest().tile.clone();

    let trg_groups = engine.trg_groups(trg.n());
    let trg_seed = seed ^ 0x7267;
    let trg_pg = cached_grouping(
        engine,
        &mut state.grouping_cache,
        &trg,
        trg_fp,
        trg_groups,
        trg_seed,
        metric,
    )?;
    // Slab scope: the target grouping's full identity + tile geometry,
    // so the persistent cache can never serve a slab across distinct
    // targets, parameters or paddings.
    let d_pad = tile.pad_d(trg.d())?;
    let slab_scope = SlabScope {
        kind: SlabKind::KnnTarget,
        fingerprint: trg_fp.0,
        probe: trg_fp.1,
        groups: trg_groups,
        iters,
        sample,
        seed: trg_seed,
        metric,
        d_pad,
        tile_n: tile.n,
    };

    // Plan every unique query, sharing packed target slabs.
    let mut uniques: Vec<UniqueQuery> = Vec::new();
    let mut slabs_shared = 0u64;
    for q in queries {
        if cfg.dedup {
            // The ONE within-cohort identity (KnnQ::same_query):
            // parameters + dataset name (report.dataset carries it) +
            // content via the admission-computed fingerprints — never
            // a point scan.
            if let Some(ui) = uniques.iter().position(|u| u.q.same_query(&q)) {
                uniques[ui].dups.push(q.pos);
                continue;
            }
        }
        let src_groups = engine.src_groups(q.src.n());
        let src_pg = cached_grouping(
            engine,
            &mut state.grouping_cache,
            &q.src,
            q.src_fp,
            src_groups,
            seed,
            metric,
        )?;
        let plan = knn::plan_metric(
            &tile,
            &q.src,
            q.k,
            metric,
            &src_pg,
            &trg_pg,
            &slab_scope,
            &mut state.slab_cache,
        )?;
        slabs_shared += plan.batches.iter().filter(|b| b.shared).count() as u64;
        uniques.push(UniqueQuery { q, src_pg, plan, dups: Vec::new() });
    }

    // Query-major dispatch order: per-tag FIFO makes each query's
    // merge identical to its solo run.
    let flat: Vec<(usize, usize)> = uniques
        .iter()
        .enumerate()
        .flat_map(|(qi, u)| (0..u.plan.batches.len()).map(move |bi| (qi, bi)))
        .collect();
    let results = uniques.iter().map(|_| Vec::new()).collect();
    let tiles_by_query = vec![0u64; uniques.len()];
    let shared_tiles_by_query = vec![0u64; uniques.len()];

    Ok(KnnCohortProgram {
        uniques,
        tile,
        depth: cfg.pipeline_depth,
        flat,
        results,
        tiles_by_query,
        shared_tiles_by_query,
        slabs_shared,
        device: DeviceStats::default(),
        wall_secs: t0.elapsed().as_secs_f64(),
        executed: false,
    })
}

impl CohortProgram for KnnCohortProgram {
    type Output = ShardDelta;

    /// The device stage: every unique query's batches through one
    /// tagged bounded pipeline.  One-shot — converges on the first
    /// call.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.executed {
            return Ok(StepOutcome::Converged);
        }
        self.executed = true;
        let step_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        {
            let flat = &self.flat;
            let uniques_ref = &self.uniques;
            let tile = &self.tile;
            let results = &mut self.results;
            let tiles_by_query = &mut self.tiles_by_query;
            let shared_tiles_by_query = &mut self.shared_tiles_by_query;
            pipeline::run_tagged(
                self.depth,
                |i| {
                    let &(qi, bi) = flat.get(i as usize)?;
                    let u = &uniques_ref[qi];
                    Some((
                        qi as u64,
                        (bi, knn::build_job(&u.plan.batches[bi], &u.src_pg, &u.plan, tile)),
                    ))
                },
                |tag, (bi, job)| {
                    if job_err.is_some() {
                        return;
                    }
                    if job.src_rows == 0 || job.trg_rows == 0 {
                        return;
                    }
                    let qi = tag as usize;
                    let before = device.stats().tiles;
                    match device.distance_block(&job) {
                        Ok(res) => {
                            let tiles = device.stats().tiles - before;
                            tiles_by_query[qi] += tiles;
                            if uniques_ref[qi].plan.batches[bi].shared {
                                shared_tiles_by_query[qi] += tiles;
                            }
                            results[qi].push((bi, res));
                        }
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        self.wall_secs += step_t0.elapsed().as_secs_f64();
        Ok(StepOutcome::Converged)
    }

    /// The trait-level finish returns the cohort's whole delta
    /// (responses + stats) so no driver can lose responses; the
    /// serving layer uses [`KnnCohortProgram::finish_into`] to write
    /// into the shard's accumulating delta directly.
    fn finish(self, ctx: &mut StepCtx<'_>) -> Result<ShardDelta> {
        let mut delta = ShardDelta::default();
        self.finish_into(ctx, &mut delta)?;
        Ok(delta)
    }
}

impl KnnCohortProgram {
    /// Per-query merge + response fan-out into `delta`.
    fn finish_into(self, _ctx: &mut StepCtx<'_>, delta: &mut ShardDelta) -> Result<()> {
        let KnnCohortProgram {
            uniques,
            mut results,
            tiles_by_query,
            shared_tiles_by_query,
            slabs_shared,
            device: cohort_device,
            wall_secs: cohort_secs,
            ..
        } = self;
        delta.stats.slabs_shared += slabs_shared;
        for (qi, u) in uniques.into_iter().enumerate() {
            let batch_results = std::mem::take(&mut results[qi]);
            let neighbors = knn::merge_results(&u.plan, batch_results.into_iter());
            let mut report = RunReport::new("knn_join", &u.q.src.name, "accd-serve");
            report.filter.merge(&u.plan.filter_stats);
            report.layout = u.plan.layout_stats.clone();
            // Device/wall accounting is cohort-scoped: tile execution
            // is deliberately shared, so per-query attribution would
            // lie.
            report.device = cohort_device.clone();
            report.device_wall_secs = cohort_device.wall_secs;
            report.device_modeled_secs = cohort_device.modeled_secs;
            report.wall_secs = cohort_secs;
            report.iterations = 1;
            report.quality = knn::quality_of(&neighbors);
            let result = knn::KnnResult { neighbors, k: u.q.k, report };

            let has_dups = !u.dups.is_empty();
            delta.stats.tiles_total += tiles_by_query[qi];
            delta.stats.tiles_shared += if has_dups {
                tiles_by_query[qi]
            } else {
                shared_tiles_by_query[qi]
            };
            delta.stats.knn_queries += 1 + u.dups.len() as u64;
            delta.stats.queries += 1 + u.dups.len() as u64;
            delta.stats.dedup_hits += u.dups.len() as u64;
            for &pos in &u.dups {
                delta.responses.push((pos, ServeResponse::Knn(result.clone())));
            }
            delta.responses.push((u.q.pos, ServeResponse::Knn(result)));
        }
        Ok(())
    }
}

// --- the range-join cohort program ------------------------------------------

/// One planned unique range-join query inside a cohort.
struct RangeJoinUniqueQuery {
    q: RangeJoinQ,
    src_pg: Arc<PackedGrouping>,
    plan: rangejoin::RangeJoinPlan,
    dups: Vec<usize>,
}

/// A whole range-join cohort as a one-shot stepwise program.  Mirror of
/// [`KnnCohortProgram`]: planning shares the target grouping + packed
/// slabs through the same `SlabKind::KnnTarget` scope (so range-join
/// and KNN cohorts over one target set share slabs), the single step
/// streams every unique query's straddling batches through one tagged
/// bounded pipeline, and `finish_into` demuxes per-query merges into
/// response slots.
struct RangeJoinCohortProgram {
    uniques: Vec<RangeJoinUniqueQuery>,
    tile: TileInfo,
    depth: usize,
    /// (unique index, batch index) in query-major dispatch order.
    flat: Vec<(usize, usize)>,
    results: Vec<Vec<(usize, TileResult)>>,
    tiles_by_query: Vec<u64>,
    shared_tiles_by_query: Vec<u64>,
    /// Dispatch batches whose packed target slab came from the cache.
    slabs_shared: u64,
    /// Cohort-scoped device counters (tile execution is deliberately
    /// shared; per-query attribution would lie).
    device: DeviceStats,
    /// Wall seconds spent inside THIS cohort's plan/step calls.
    wall_secs: f64,
    executed: bool,
}

/// Plan one range-join cohort: shared target grouping + slabs (served
/// through the shard's persistent caches), one plan per unique query,
/// dedup under the admission identity.
fn plan_rangejoin_cohort(
    engine: &Engine,
    state: &mut ShardState,
    cohort: RangeJoinCohort,
    cfg: &ServeConfig,
) -> Result<RangeJoinCohortProgram> {
    let t0 = Instant::now();
    let RangeJoinCohort { trg, trg_fp, metric, queries, .. } = cohort;
    let seed = engine.config.seed;
    let (iters, sample) = (engine.config.gti.grouping_iters, engine.config.gti.grouping_sample);
    let tile = engine.runtime.manifest().tile.clone();

    let trg_groups = engine.trg_groups(trg.n());
    let trg_seed = seed ^ 0x7267;
    let trg_pg = cached_grouping(
        engine,
        &mut state.grouping_cache,
        &trg,
        trg_fp,
        trg_groups,
        trg_seed,
        metric,
    )?;
    // Identical slab scope to the KNN cohort over the same target —
    // that identity (not the algorithm) keys the cache, so range-join
    // and KNN queries against one target set serve each other's slabs.
    let d_pad = tile.pad_d(trg.d())?;
    let slab_scope = SlabScope {
        kind: SlabKind::KnnTarget,
        fingerprint: trg_fp.0,
        probe: trg_fp.1,
        groups: trg_groups,
        iters,
        sample,
        seed: trg_seed,
        metric,
        d_pad,
        tile_n: tile.n,
    };

    let mut uniques: Vec<RangeJoinUniqueQuery> = Vec::new();
    let mut slabs_shared = 0u64;
    for q in queries {
        if cfg.dedup {
            if let Some(ui) = uniques.iter().position(|u| u.q.same_query(&q)) {
                uniques[ui].dups.push(q.pos);
                continue;
            }
        }
        let src_groups = engine.src_groups(q.src.n());
        let src_pg = cached_grouping(
            engine,
            &mut state.grouping_cache,
            &q.src,
            q.src_fp,
            src_groups,
            seed,
            metric,
        )?;
        let plan = rangejoin::plan_metric(
            &tile,
            &q.src,
            q.threshold,
            metric,
            &src_pg,
            &trg_pg,
            &slab_scope,
            &mut state.slab_cache,
        )?;
        slabs_shared += plan.batches.iter().filter(|b| b.shared).count() as u64;
        uniques.push(RangeJoinUniqueQuery { q, src_pg, plan, dups: Vec::new() });
    }

    // Query-major dispatch order: per-tag FIFO makes each query's
    // merge identical to its solo run.
    let flat: Vec<(usize, usize)> = uniques
        .iter()
        .enumerate()
        .flat_map(|(qi, u)| (0..u.plan.batches.len()).map(move |bi| (qi, bi)))
        .collect();
    let results = uniques.iter().map(|_| Vec::new()).collect();
    let tiles_by_query = vec![0u64; uniques.len()];
    let shared_tiles_by_query = vec![0u64; uniques.len()];

    Ok(RangeJoinCohortProgram {
        uniques,
        tile,
        depth: cfg.pipeline_depth,
        flat,
        results,
        tiles_by_query,
        shared_tiles_by_query,
        slabs_shared,
        device: DeviceStats::default(),
        wall_secs: t0.elapsed().as_secs_f64(),
        executed: false,
    })
}

impl CohortProgram for RangeJoinCohortProgram {
    type Output = ShardDelta;

    /// The device stage: every unique query's straddling batches
    /// through one tagged bounded pipeline.  One-shot — converges on
    /// the first call.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.executed {
            return Ok(StepOutcome::Converged);
        }
        self.executed = true;
        let step_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        {
            let flat = &self.flat;
            let uniques_ref = &self.uniques;
            let tile = &self.tile;
            let results = &mut self.results;
            let tiles_by_query = &mut self.tiles_by_query;
            let shared_tiles_by_query = &mut self.shared_tiles_by_query;
            pipeline::run_tagged(
                self.depth,
                |i| {
                    let &(qi, bi) = flat.get(i as usize)?;
                    let u = &uniques_ref[qi];
                    Some((
                        qi as u64,
                        (
                            bi,
                            rangejoin::build_job_range(
                                &u.plan.batches[bi],
                                &u.src_pg,
                                &u.plan,
                                tile,
                            ),
                        ),
                    ))
                },
                |tag, (bi, job)| {
                    if job_err.is_some() {
                        return;
                    }
                    if job.src_rows == 0 || job.trg_rows == 0 {
                        return;
                    }
                    let qi = tag as usize;
                    let before = device.stats().tiles;
                    match device.distance_block(&job) {
                        Ok(res) => {
                            let tiles = device.stats().tiles - before;
                            tiles_by_query[qi] += tiles;
                            if uniques_ref[qi].plan.batches[bi].shared {
                                shared_tiles_by_query[qi] += tiles;
                            }
                            results[qi].push((bi, res));
                        }
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        self.wall_secs += step_t0.elapsed().as_secs_f64();
        Ok(StepOutcome::Converged)
    }

    /// The trait-level finish returns the cohort's whole delta so no
    /// driver can lose responses; the serving layer uses
    /// [`RangeJoinCohortProgram::finish_into`] to write into the
    /// shard's accumulating delta directly.
    fn finish(self, ctx: &mut StepCtx<'_>) -> Result<ShardDelta> {
        let mut delta = ShardDelta::default();
        self.finish_into(ctx, &mut delta)?;
        Ok(delta)
    }
}

impl RangeJoinCohortProgram {
    /// Per-query merge + response fan-out into `delta`.
    fn finish_into(self, _ctx: &mut StepCtx<'_>, delta: &mut ShardDelta) -> Result<()> {
        let RangeJoinCohortProgram {
            uniques,
            mut results,
            tiles_by_query,
            shared_tiles_by_query,
            slabs_shared,
            device: cohort_device,
            wall_secs: cohort_secs,
            ..
        } = self;
        delta.stats.slabs_shared += slabs_shared;
        for (qi, u) in uniques.into_iter().enumerate() {
            let batch_results = std::mem::take(&mut results[qi]);
            let neighbors = rangejoin::merge_results(&u.plan, batch_results.into_iter());
            let mut report = RunReport::new("range_join", &u.q.src.name, "accd-serve");
            report.filter.merge(&u.plan.filter_stats);
            report.layout = u.plan.layout_stats.clone();
            // Device/wall accounting is cohort-scoped: tile execution
            // is deliberately shared, so per-query attribution would
            // lie.
            report.device = cohort_device.clone();
            report.device_wall_secs = cohort_device.wall_secs;
            report.device_modeled_secs = cohort_device.modeled_secs;
            report.wall_secs = cohort_secs;
            report.iterations = 1;
            report.quality = rangejoin::quality_of(&neighbors);
            let result = rangejoin::RangeJoinResult {
                neighbors,
                threshold: u.q.threshold,
                report,
            };

            let has_dups = !u.dups.is_empty();
            delta.stats.tiles_total += tiles_by_query[qi];
            delta.stats.tiles_shared += if has_dups {
                tiles_by_query[qi]
            } else {
                shared_tiles_by_query[qi]
            };
            // Sure-within rectangles answered on the CPU count as
            // skipped tiles, same as every other GTI skip.
            delta.stats.tiles_skipped += u.plan.filter_stats.tiles_skipped;
            delta.stats.rangejoin_queries += 1 + u.dups.len() as u64;
            delta.stats.queries += 1 + u.dups.len() as u64;
            delta.stats.dedup_hits += u.dups.len() as u64;
            for &pos in &u.dups {
                delta.responses.push((pos, ServeResponse::RangeJoin(result.clone())));
            }
            delta.responses.push((u.q.pos, ServeResponse::RangeJoin(result)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_priority_orders_deadline_then_prune_then_admission() {
        // Deadline dominates: an urgent low-pruner beats a lazy
        // high-pruner.
        assert!(step_priority(Some(10), 0, 5) < step_priority(Some(20), 999, 0));
        // Equal deadlines: higher prune rate steps first.
        assert!(step_priority(Some(10), 800, 5) < step_priority(Some(10), 100, 0));
        // Deadline-free programs rank behind any deadline and among
        // themselves by prune rate, then admission order.
        assert!(step_priority(Some(u64::MAX - 1), 0, 9) < step_priority(None, 1000, 0));
        assert!(step_priority(None, 500, 3) < step_priority(None, 500, 4));
        // Out-of-range prune rates clamp instead of underflowing.
        assert_eq!(step_priority(None, 5000, 0).1, 0);
    }

    #[test]
    fn xfer_clock_overlap_hides_transfers_and_off_serializes() {
        let dma = DmaModel::new(16.0); // 16 bytes/ns
        // Two units: unit A uploads then computes long; unit B's
        // upload fits entirely under A's compute.
        let mut on = XferClock::new(dma, true);
        on.record(16 * 1024, 500_000); // t = 2000 + 1024 = 3024 ns
        on.record(16 * 1024, 500_000);
        let mut stats_on = ServeStats::default();
        on.flush_into(&mut stats_on);
        assert_eq!(stats_on.transfer_ns, 2 * 3024);
        assert_eq!(stats_on.compute_ns, 1_000_000);
        // B's whole upload hides under A's compute.
        assert_eq!(stats_on.overlap_ns, 3024);

        let mut off = XferClock::new(dma, false);
        off.record(16 * 1024, 500_000);
        off.record(16 * 1024, 500_000);
        let mut stats_off = ServeStats::default();
        off.flush_into(&mut stats_off);
        assert_eq!(stats_off.transfer_ns, stats_on.transfer_ns);
        assert_eq!(stats_off.compute_ns, stats_on.compute_ns);
        assert_eq!(stats_off.overlap_ns, 0, "serialized timeline saves nothing");
    }

    #[test]
    fn xfer_clock_warm_units_transfer_nothing() {
        let mut clk = XferClock::new(DmaModel::new(16.0), true);
        clk.record(0, 250_000); // warm slab: no transfer issued at all
        let mut stats = ServeStats::default();
        clk.flush_into(&mut stats);
        assert_eq!(stats.transfer_ns, 0);
        assert_eq!(stats.compute_ns, 250_000);
        assert_eq!(stats.overlap_ns, 0);
    }
}
