//! Bounded-queue dataflow executor — the coordinator's streaming core.
//!
//! The paper's host application overlaps CPU-side filtering with
//! FPGA-side tile execution.  The PJRT handles in the `xla` crate are
//! not `Send`, so instead of OS threads this executor interleaves a
//! *producer* (filter stage) and a *consumer* (device stage) over a
//! bounded FIFO with explicit backpressure: the producer is invoked
//! only while the queue has room, otherwise the consumer drains.  The
//! schedule is deterministic, the backpressure behaviour is real (and
//! property-tested), and occupancy statistics feed the perf report.

use std::collections::VecDeque;

/// Queue occupancy statistics of one pipeline run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineStats {
    pub produced: u64,
    pub consumed: u64,
    /// Times the producer was blocked by a full queue (backpressure).
    pub stalls: u64,
    /// Sum of queue depth observed at each consume (for mean depth).
    pub depth_sum: u64,
}

impl PipelineStats {
    pub fn mean_depth(&self) -> f64 {
        if self.consumed == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.consumed as f64
        }
    }
}

/// Run a two-stage pipeline.
///
/// `producer(i)` returns the i-th job or `None` when exhausted;
/// `consumer(job)` processes one job.  `capacity` bounds the in-flight
/// queue.  Jobs are consumed in FIFO order.
pub fn run<J>(
    capacity: usize,
    mut producer: impl FnMut(u64) -> Option<J>,
    mut consumer: impl FnMut(J),
) -> PipelineStats {
    assert!(capacity > 0, "pipeline capacity must be positive");
    let mut q: VecDeque<J> = VecDeque::with_capacity(capacity);
    let mut stats = PipelineStats::default();
    let mut next = 0u64;
    let mut exhausted = false;
    loop {
        // Fill phase: produce until full or exhausted.
        while !exhausted && q.len() < capacity {
            match producer(next) {
                Some(job) => {
                    q.push_back(job);
                    next += 1;
                    stats.produced += 1;
                }
                None => exhausted = true,
            }
        }
        if !exhausted && q.len() == capacity {
            stats.stalls += 1;
        }
        // Drain phase: consume one job (keeps the queue warm so the
        // producer can continue next round).
        match q.pop_front() {
            Some(job) => {
                stats.depth_sum += q.len() as u64 + 1;
                stats.consumed += 1;
                consumer(job);
            }
            None if exhausted => break,
            None => unreachable!("empty queue with active producer"),
        }
    }
    stats
}

/// Statistics of one tagged pipeline run: the base queue stats plus
/// per-tag job counts (one tag per client query in the serving layer).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TaggedStats {
    pub inner: PipelineStats,
    /// Jobs consumed per tag, in tag order.
    pub per_tag: std::collections::BTreeMap<u64, u64>,
}

/// Run a two-stage pipeline over *tagged* jobs.
///
/// Identical scheduling to [`run`], but every job carries a `u64` tag
/// that is handed back to the consumer for demultiplexing — this is how
/// the serving layer streams many queries' tile jobs through ONE
/// bounded queue and routes each result to its query.  FIFO order is
/// global, so jobs of one tag are consumed in production order (the
/// per-query determinism the batched-equals-sequential contract needs).
pub fn run_tagged<J>(
    capacity: usize,
    mut producer: impl FnMut(u64) -> Option<(u64, J)>,
    mut consumer: impl FnMut(u64, J),
) -> TaggedStats {
    let mut per_tag = std::collections::BTreeMap::new();
    let inner = run(capacity, &mut producer, |(tag, job): (u64, J)| {
        *per_tag.entry(tag).or_insert(0u64) += 1;
        consumer(tag, job);
    });
    TaggedStats { inner, per_tag }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn processes_all_jobs_in_order() {
        let jobs: Vec<u32> = (0..100).collect();
        let mut seen = Vec::new();
        let stats = run(
            4,
            |i| jobs.get(i as usize).copied(),
            |j| seen.push(j),
        );
        assert_eq!(seen, jobs);
        assert_eq!(stats.produced, 100);
        assert_eq!(stats.consumed, 100);
    }

    #[test]
    fn empty_input_is_fine() {
        let stats = run(2, |_| None::<u32>, |_| {});
        assert_eq!(stats.produced, 0);
        assert_eq!(stats.consumed, 0);
    }

    #[test]
    fn backpressure_stalls_counted() {
        let stats = run(2, |i| if i < 10 { Some(i) } else { None }, |_| {});
        assert!(stats.stalls > 0, "{stats:?}");
    }

    #[test]
    fn queue_depth_bounded_by_capacity() {
        for cap in [1usize, 3, 7] {
            let stats = run(cap, |i| if i < 50 { Some(i) } else { None }, |_| {});
            // depth_sum accumulates one observation per consume, each
            // at most `cap`.
            assert!(
                stats.depth_sum <= stats.consumed * cap as u64,
                "depth exceeded capacity {cap}: {stats:?}"
            );
            assert!(stats.mean_depth() <= cap as f64);
        }
    }

    #[test]
    fn tagged_run_demuxes_in_fifo_order() {
        // Three interleaved "queries" of different lengths.
        let jobs: Vec<(u64, u32)> =
            vec![(0, 0), (1, 0), (0, 1), (2, 0), (1, 1), (0, 2), (2, 1)];
        let mut per_tag: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
        let stats = run_tagged(
            2,
            |i| jobs.get(i as usize).copied(),
            |tag, j| per_tag.entry(tag).or_default().push(j),
        );
        assert_eq!(stats.inner.produced, 7);
        assert_eq!(stats.inner.consumed, 7);
        assert_eq!(stats.per_tag.get(&0), Some(&3));
        assert_eq!(stats.per_tag.get(&1), Some(&2));
        assert_eq!(stats.per_tag.get(&2), Some(&2));
        // Per-tag order preserved despite interleaving.
        assert_eq!(per_tag[&0], vec![0, 1, 2]);
        assert_eq!(per_tag[&1], vec![0, 1]);
        assert_eq!(per_tag[&2], vec![0, 1]);
    }

    #[test]
    fn prop_conservation_and_fifo() {
        prop::check(
            &prop::Config { cases: 32, max_size: 200, ..Default::default() },
            |rng, size| (size, 1 + rng.below(8)),
            |&(n, cap)| {
                let mut seen = Vec::new();
                let stats = run(
                    cap,
                    |i| if (i as usize) < n { Some(i as usize) } else { None },
                    |j| seen.push(j),
                );
                if stats.produced != n as u64 || stats.consumed != n as u64 {
                    return Err(format!("conservation violated: {stats:?} for n={n}"));
                }
                if seen != (0..n).collect::<Vec<_>>() {
                    return Err("FIFO order violated".into());
                }
                Ok(())
            },
        );
    }
}
