//! AccD range join (radius query): Two-landmark + Group-level GTI with
//! a *fixed* threshold, reusing the KNN plan/execute/merge seam.
//!
//! Semantics: for every source point, all target points whose metric
//! distance is within `threshold`, as `(device-space value, id)` pairs
//! sorted ascending by `(value, id)` — the same value space as the KNN
//! join (squared distances for L2, plain sums for L1).
//!
//! The group-level filter classifies every (source group, target
//! group) pair against the threshold T using the Eq. 2 bounds:
//!
//! * `lb > T` — **pruned**: no member pair can be within T, the pair
//!   is discarded without touching point data.
//! * `ub <= T` — **sure-within**: every member pair is within T; the
//!   rectangle is emitted on the CPU ([`Metric::device_dist`], the
//!   tile's accumulation order) with *no device work*, counted as a
//!   skipped tile.
//! * otherwise — **straddling**: the rectangle goes to the device as a
//!   dense tile (through the same slab cache / dispatch merging /
//!   bounded pipeline as KNN) and results are filtered by
//!   `v <= to_device(T)` on merge.
//!
//! The final per-point sort makes the output order canonical, so
//! batched serving is bit-identical to the solo path regardless of
//! emission or tile arrival order.  NaN distances (corrupt rows) are
//! never within any threshold — `NaN <= T` is false — so range-join
//! output is always NaN-free.

use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::fpga::device::DeviceStats;
use crate::fpga::TileJob;
use crate::gti::{bounds, FilterStats, Metric};
use crate::layout::{self, LayoutStats, PackedGrouping};
use crate::metrics::RunReport;
use crate::runtime::TileInfo;
use crate::{Error, Result};

use super::engine::Engine;
use super::knn::{build_trg_slab, KnnBatch, SlabCache, SlabScope};
use super::pipeline;
use super::program::{self, CohortProgram, StepCtx, StepOutcome};

/// Result of a range join: for each source point, every target point
/// within the threshold.
#[derive(Debug, Clone)]
pub struct RangeJoinResult {
    /// `neighbors[i]` = (device-space value, target id) pairs with
    /// metric distance <= threshold, ascending by (value, id).
    pub neighbors: Vec<Vec<(f32, u32)>>,
    /// The metric-space threshold the join ran with.
    pub threshold: f32,
    pub report: RunReport,
}

/// The CPU filter stage's output: straddling dispatch batches for the
/// device plus the sure-within pairs already answered on the CPU.
#[derive(Debug, Clone)]
pub(crate) struct RangeJoinPlan {
    pub threshold: f32,
    pub n_src: usize,
    pub d: usize,
    pub d_pad: usize,
    pub metric: Metric,
    /// Straddling rectangles, merged + slab-shared like KNN batches.
    pub batches: Vec<KnnBatch>,
    /// Per original source id: pairs emitted from sure-within group
    /// rectangles (unsorted; the merge sorts canonically).
    pub sure: Vec<Vec<(f32, u32)>>,
    pub filter_stats: FilterStats,
    pub layout_stats: LayoutStats,
}

/// Validate a range-join request (shared by solo and batched paths).
pub(crate) fn validate(src: &Dataset, trg: &Dataset, threshold: f32) -> Result<()> {
    if !(threshold.is_finite() && threshold > 0.0) {
        return Err(Error::Data(format!(
            "range join: threshold {threshold} must be finite and positive"
        )));
    }
    if src.d() != trg.d() {
        return Err(Error::Shape(format!(
            "range join: dim mismatch {} vs {}",
            src.d(),
            trg.d()
        )));
    }
    Ok(())
}

pub(super) fn run(
    engine: &mut Engine,
    src: &Dataset,
    trg: &Dataset,
    threshold: f32,
) -> Result<RangeJoinResult> {
    run_metric(engine, src, trg, threshold, Metric::L2)
}

/// Metric-aware range join.  Drives the one-shot [`RangeJoinProgram`]
/// to completion — plan / execute / merge as a single-step
/// [`CohortProgram`].
pub(super) fn run_metric(
    engine: &mut Engine,
    src: &Dataset,
    trg: &Dataset,
    threshold: f32,
    metric: Metric,
) -> Result<RangeJoinResult> {
    validate(src, trg, threshold)?;
    engine.device.reset_stats();
    let program = plan_program(&*engine, src, trg, threshold, metric)?;
    let mut ctx = StepCtx { engine: &*engine };
    program::run_to_completion(program, &mut ctx)
}

/// One solo range-join query as a stepwise program, mirroring
/// `knn::KnnProgram`: plan is the CPU filter stage, the single step is
/// the device stage over the straddling batches, finish merges.
pub(crate) struct RangeJoinProgram {
    plan: RangeJoinPlan,
    src_pg: Arc<PackedGrouping>,
    tile: TileInfo,
    results: Vec<(usize, crate::fpga::TileResult)>,
    report: RunReport,
    device: DeviceStats,
    t0: Instant,
    executed: bool,
}

/// CPU filter stage of one solo range-join query.  Groupings use the
/// same seeds as the KNN path (`cfg.seed` / `cfg.seed ^ 0x7267`), so
/// serving cohorts over the same target set share slabs with KNN.
pub(crate) fn plan_program(
    engine: &Engine,
    src: &Dataset,
    trg: &Dataset,
    threshold: f32,
    metric: Metric,
) -> Result<RangeJoinProgram> {
    validate(src, trg, threshold)?;
    let t0 = Instant::now();
    let mut report = RunReport::new("range_join", &src.name, "accd");
    let cfg = engine.config.clone();
    let tile = engine.runtime.manifest().tile.clone();

    let filt0 = Instant::now();
    let src_pg = PackedGrouping::build(
        &src.points,
        engine.src_groups(src.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed,
        metric,
        8,
    )?;
    let trg_pg = PackedGrouping::build(
        &trg.points,
        engine.trg_groups(trg.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed ^ 0x7267, // "tg"
        metric,
        8,
    )?;
    let mut slab_cache = SlabCache::unbounded();
    let scope = SlabScope::transient(metric);
    let plan =
        plan_metric(&tile, src, threshold, metric, &src_pg, &trg_pg, &scope, &mut slab_cache)?;
    report.filter.merge(&plan.filter_stats);
    report.layout = plan.layout_stats.clone();
    report.filter_secs += filt0.elapsed().as_secs_f64();

    Ok(RangeJoinProgram {
        plan,
        src_pg: Arc::new(src_pg),
        tile,
        results: Vec::new(),
        report,
        device: DeviceStats::default(),
        t0,
        executed: false,
    })
}

impl CohortProgram for RangeJoinProgram {
    type Output = RangeJoinResult;

    /// The device stage: every straddling dispatch batch through the
    /// bounded pipeline.  One-shot — converges on the first call.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.executed {
            return Ok(StepOutcome::Converged);
        }
        self.executed = true;
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        {
            let plan_ref = &self.plan;
            let src_pg_ref = &self.src_pg;
            let tile = &self.tile;
            let results = &mut self.results;
            pipeline::run(
                4,
                |i| -> Option<(usize, TileJob)> {
                    let bi = i as usize;
                    let batch = plan_ref.batches.get(bi)?;
                    Some((bi, build_job_range(batch, src_pg_ref, plan_ref, tile)))
                },
                |(bi, job): (usize, TileJob)| {
                    if job_err.is_some() {
                        return;
                    }
                    if job.src_rows == 0 || job.trg_rows == 0 {
                        return;
                    }
                    match device.distance_block(&job) {
                        Ok(res) => results.push((bi, res)),
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        Ok(StepOutcome::Converged)
    }

    /// Merge stage (CPU): threshold filter + canonical sort + report.
    fn finish(mut self, ctx: &mut StepCtx<'_>) -> Result<RangeJoinResult> {
        let engine = ctx.engine;
        let results = std::mem::take(&mut self.results);
        let neighbors = merge_results(&self.plan, results.into_iter());

        let mut report = self.report;
        report.wall_secs = self.t0.elapsed().as_secs_f64();
        report.device = self.device.clone();
        report.device_wall_secs = report.device.wall_secs;
        report.device_modeled_secs = report.device.modeled_secs;
        report.iterations = 1;
        report.quality = quality_of(&neighbors);
        report.energy_j = engine.power.accd_joules(
            report.wall_secs,
            report.filter_secs,
            1.0,
            report.device.wall_secs,
        );
        report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

        Ok(RangeJoinResult { neighbors, threshold: self.plan.threshold, report })
    }
}

/// CPU filter stage: classify every group pair against the threshold,
/// emit sure-within rectangles on the CPU, and build the straddling
/// dispatch batches through the caller's [`SlabCache`] (the same
/// `SlabKind::KnnTarget` scope family, so rangejoin and KNN cohorts
/// over one target set share packed slabs).  Deterministic in all
/// inputs; the canonical per-point sort at merge makes results
/// independent of emission order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_metric(
    tile: &TileInfo,
    src: &Dataset,
    threshold: f32,
    metric: Metric,
    src_pg: &PackedGrouping,
    trg_pg: &PackedGrouping,
    scope: &SlabScope,
    slab_cache: &mut SlabCache,
) -> Result<RangeJoinPlan> {
    let d = src.d();
    let d_pad = tile.pad_d(d)?;
    let t_dev = metric.to_device(threshold);

    let pair_bounds =
        bounds::group_pair_bounds_metric(&src_pg.grouping, &trg_pg.grouping, metric);
    let zs = src_pg.grouping.num_groups();
    let zt = trg_pg.grouping.num_groups();
    let mut stats = FilterStats { bound_comps: (zs * zt) as u64, ..Default::default() };
    let trg_sizes: Vec<usize> = (0..zt).map(|b| trg_pg.packed.group_len(b)).collect();
    let n_trg_total: usize = trg_sizes.iter().sum();

    let mut sure: Vec<Vec<(f32, u32)>> = vec![Vec::new(); src.n()];
    let mut candidates: Vec<Vec<u32>> = Vec::with_capacity(zs);
    for a in 0..zs {
        let src_len = src_pg.packed.group_len(a);
        let mut cand: Vec<u32> = Vec::new();
        for b in 0..zt {
            stats.group_pairs += 1;
            let bd = pair_bounds[a][b];
            if bd.lb > threshold {
                // Pruned: no member pair of (a, b) can be within T.
                continue;
            }
            stats.surviving_group_pairs += 1;
            stats.surviving_pairs += (src_len * trg_sizes[b]) as u64;
            if bd.ub <= threshold {
                // Sure-within: the whole rectangle is inside T; answer
                // it on the CPU with the tile's own accumulation order
                // and skip the device entirely.
                stats.tiles_skipped += 1;
                emit_rectangle(src_pg, a, trg_pg, b, metric, t_dev, &mut sure);
            } else {
                cand.push(b as u32);
            }
        }
        stats.total_pairs += (src_len * n_trg_total) as u64;
        candidates.push(cand);
    }

    // Straddling rectangles ride the KNN dispatch seam: Fig. 4b
    // schedule, adjacent same-candidate-set merging, shared slabs.
    let order = layout::schedule_source_groups(&candidates);
    let layout_stats = layout::measure_reuse(&order, &candidates);
    let mut merged: Vec<(Vec<usize>, Vec<u32>)> = Vec::new();
    for &g in &order {
        let g = g as usize;
        if candidates[g].is_empty() {
            continue;
        }
        match merged.last_mut() {
            Some((groups, cand)) if *cand == candidates[g] => groups.push(g),
            _ => merged.push((vec![g], candidates[g].clone())),
        }
    }

    let mut batches = Vec::with_capacity(merged.len());
    for (groups, cand) in merged {
        let row_ids: Vec<u32> = groups
            .iter()
            .flat_map(|&g| {
                let (s, l) = (src_pg.packed.group_start(g), src_pg.packed.group_len(g));
                src_pg.packed.new2old[s..s + l].iter().copied()
            })
            .collect();
        let (trg, shared) = slab_cache
            .get_or_build(scope, &cand, || build_trg_slab(trg_pg, &cand, d, d_pad, tile.n));
        batches.push(KnnBatch { groups, row_ids, trg, shared });
    }

    Ok(RangeJoinPlan {
        threshold,
        n_src: src.n(),
        d,
        d_pad,
        metric,
        batches,
        sure,
        filter_stats: stats,
        layout_stats,
    })
}

/// CPU emission of one sure-within rectangle: every (member of source
/// group `a`, member of target group `b`) pair, valued with the
/// device's accumulation order.  The `v <= t_dev` check keeps the
/// output exactly equal to a brute-force scan even when the float
/// bound was marginally loose.
fn emit_rectangle(
    src_pg: &PackedGrouping,
    a: usize,
    trg_pg: &PackedGrouping,
    b: usize,
    metric: Metric,
    t_dev: f32,
    sure: &mut [Vec<(f32, u32)>],
) {
    let d = src_pg.packed.points.cols();
    let (ss, sl) = (src_pg.packed.group_start(a), src_pg.packed.group_len(a));
    let (ts, tl) = (trg_pg.packed.group_start(b), trg_pg.packed.group_len(b));
    let src_rows = src_pg.packed.group_rows(a);
    let trg_rows = trg_pg.packed.group_rows(b);
    let src_ids = &src_pg.packed.new2old[ss..ss + sl];
    let trg_ids = &trg_pg.packed.new2old[ts..ts + tl];
    for (r, &sid) in src_ids.iter().enumerate() {
        let srow = &src_rows[r * d..(r + 1) * d];
        let out = &mut sure[sid as usize];
        for (c, &tid) in trg_ids.iter().enumerate() {
            let v = metric.device_dist(srow, &trg_rows[c * d..(c + 1) * d]);
            if v <= t_dev {
                out.push((v, tid));
            }
        }
    }
}

/// Build the dense rectangle job for one straddling dispatch batch
/// (same layout as the KNN job builder).
pub(crate) fn build_job_range(
    batch: &KnnBatch,
    src_pg: &PackedGrouping,
    plan: &RangeJoinPlan,
    tile: &TileInfo,
) -> TileJob {
    use crate::util::round_up;
    let (d, d_pad) = (plan.d, plan.d_pad);
    let len: usize = batch.groups.iter().map(|&g| src_pg.packed.group_len(g)).sum();
    let rows_pad = round_up(len.max(1), tile.m);
    let mut src_slab = vec![0.0f32; rows_pad * d_pad];
    let mut row = 0usize;
    for &g in &batch.groups {
        let rows = src_pg.packed.group_len(g);
        let slab = src_pg.packed.group_rows(g);
        for r in 0..rows {
            src_slab[(row + r) * d_pad..(row + r) * d_pad + d]
                .copy_from_slice(&slab[r * d..(r + 1) * d]);
        }
        row += rows;
    }
    TileJob {
        src: src_slab,
        src_rows: len,
        trg: batch.trg.slab.clone(),
        trg_rows: batch.trg.rows,
        d,
        d_padded: d_pad,
        metric: plan.metric.device_name(),
    }
}

/// Merge stage: seed each point with its sure-within emissions, filter
/// device tiles by `v <= to_device(T)`, then sort canonically by
/// `(total_cmp value, id)` — the output is identical for any tile
/// arrival or emission order, which is what makes batched serving
/// bit-for-bit equal to the solo path.
pub(crate) fn merge_results(
    plan: &RangeJoinPlan,
    results: impl Iterator<Item = (usize, crate::fpga::TileResult)>,
) -> Vec<Vec<(f32, u32)>> {
    let t_dev = plan.metric.to_device(plan.threshold);
    let mut out: Vec<Vec<(f32, u32)>> = plan.sure.clone();
    for (bi, res) in results {
        let batch = &plan.batches[bi];
        for (r, &orig_src) in batch.row_ids.iter().enumerate() {
            let row = &res.dist[r * res.trg_rows..(r + 1) * res.trg_rows];
            let nb = &mut out[orig_src as usize];
            for (c, &v) in row.iter().enumerate() {
                if v <= t_dev {
                    nb.push((v, batch.trg.col_ids[c]));
                }
            }
        }
    }
    for nb in &mut out {
        nb.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    }
    out
}

/// Headline quality number: mean within-threshold neighbor count.
pub(crate) fn quality_of(neighbors: &[Vec<(f32, u32)>]) -> f64 {
    neighbors.iter().map(|nb| nb.len() as f64).sum::<f64>() / neighbors.len().max(1) as f64
}
