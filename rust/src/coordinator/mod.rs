//! The L3 coordinator: AccD's heterogeneous execution engine.
//!
//! This is where the paper's CPU-FPGA split lives (§V intro): the
//! engine runs GTI grouping/filtering and all control flow on the CPU,
//! and streams the surviving dense distance blocks to the accelerator
//! device.  One submodule per algorithm family:
//!
//! * [`kmeans`] — Trace-based + Group-level GTI (paper's K-means).
//! * [`knn`] — Two-landmark + Group-level GTI (paper's KNN-join).
//! * [`rangejoin`] — Two-landmark + Group-level GTI against a fixed
//!   threshold (radius query / range join).
//! * [`nbody`] — Two-landmark + Trace-based + Group-level (N-body).
//! * [`pipeline`] — bounded-queue dataflow executor used to stream
//!   jobs between the filter stage and the device stage.
//! * `program` — the stepwise `CohortProgram` contract every
//!   algorithm compiles to (`plan` / `step` / `finish`), so the
//!   runtime — solo driver or the serving layer's lockstep scheduler —
//!   owns execution order, not the algorithm.
//!
//! [`Engine`] owns the runtime + device and exposes the public API the
//! examples and benches call.

pub mod engine;
pub mod kmeans;
pub mod knn;
pub mod nbody;
pub mod pipeline;
pub(crate) mod program;
pub mod rangejoin;

pub use engine::Engine;
pub use kmeans::KmeansResult;
pub use knn::{KnnResult, SlabCache, SlabKind, SlabScope};
pub use nbody::NbodyResult;
pub use rangejoin::RangeJoinResult;
