//! Stepwise cohort programs — the contract between the coordinator's
//! algorithms and whoever drives them.
//!
//! Every algorithm compiles to a *program*: a `plan` constructor (one
//! per algorithm module — grouping, packing, initialization, any
//! iteration-0 work), a [`CohortProgram::step`] that advances exactly
//! one iteration and reports whether the program converged, and a
//! [`CohortProgram::finish`] that runs the final exact pass and
//! assembles the result.  The split exists so the *runtime* owns
//! execution order, not the algorithm: a solo engine call drives one
//! program to completion ([`run_to_completion`]); the serving layer's
//! lockstep scheduler (`serve::exec`) advances many resident programs
//! one step per round, sharing cached groupings and packed slabs
//! across same-dataset programs (the KPynq-style per-iteration tile is
//! the batching unit).
//!
//! Correctness: a program's state is fully owned (or `Arc`-shared and
//! immutable), so interleaving steps of independent programs on one
//! engine cannot perturb any result — the bit-for-bit serving parity
//! contract extends to any step schedule.  Owned state may span
//! iterations: `KmeansProgram` carries incremental TI bounds from one
//! `step` to the next (widened, not recomputed — see
//! `coordinator::kmeans`), which is only possible because the
//! contract guarantees no one else mutates the program between steps.
//!
//! Device accounting: programs interleave on one engine, so a program
//! cannot read `engine.device.stats()` as its own.  Instead every
//! `plan`/`step`/`finish` snapshots the device counters around its own
//! device calls ([`device_delta`]) and accumulates the difference into
//! the program's private [`DeviceStats`] ([`absorb_device`]) — exact,
//! because steps on one engine are serial.

use crate::coordinator::Engine;
use crate::fpga::device::DeviceStats;
use crate::Result;

/// What one [`CohortProgram::step`] reports back to its driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// More iterations remain; call `step` again.
    Continue,
    /// The program converged (or exhausted its iteration budget);
    /// `finish` may be called.
    Converged,
}

/// Everything a program may touch while stepping: the engine it
/// executes on.  Passed per call — programs own all their state, so a
/// program can migrate between calls (work stealing moves whole
/// not-yet-started programs across shards).
pub(crate) struct StepCtx<'a> {
    pub engine: &'a Engine,
}

/// The stepwise execution contract every coordinator algorithm
/// implements: `step` advances one iteration, `finish` consumes the
/// program into its result.  One-shot algorithms (KNN) execute in a
/// single step and converge immediately.
pub(crate) trait CohortProgram {
    type Output;

    /// Advance one iteration.  Must be callable again after
    /// `Converged` (idempotently returning `Converged`), so drivers
    /// need no extra bookkeeping.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome>;

    /// Final exact pass + result assembly.
    fn finish(self, ctx: &mut StepCtx<'_>) -> Result<Self::Output>;
}

/// Drive a program to completion — the solo-engine schedule (and the
/// reference semantics every other schedule must reproduce exactly).
pub(crate) fn run_to_completion<P: CohortProgram>(
    mut program: P,
    ctx: &mut StepCtx<'_>,
) -> Result<P::Output> {
    loop {
        match program.step(ctx)? {
            StepOutcome::Converged => break,
            StepOutcome::Continue => {}
        }
    }
    program.finish(ctx)
}

/// Counter-wise difference `after - before` of two device snapshots
/// (saturating: a mid-flight `reset_stats` can only under-count, never
/// underflow).
pub(crate) fn device_delta(before: &DeviceStats, after: &DeviceStats) -> DeviceStats {
    DeviceStats {
        jobs: after.jobs.saturating_sub(before.jobs),
        tiles: after.tiles.saturating_sub(before.tiles),
        padded_pairs: after.padded_pairs.saturating_sub(before.padded_pairs),
        valid_pairs: after.valid_pairs.saturating_sub(before.valid_pairs),
        wall_secs: (after.wall_secs - before.wall_secs).max(0.0),
        modeled_secs: (after.modeled_secs - before.modeled_secs).max(0.0),
        bytes_moved: after.bytes_moved.saturating_sub(before.bytes_moved),
    }
}

/// Fold one delta into a program's private device accumulator.
pub(crate) fn absorb_device(acc: &mut DeviceStats, delta: &DeviceStats) {
    acc.jobs += delta.jobs;
    acc.tiles += delta.tiles;
    acc.padded_pairs += delta.padded_pairs;
    acc.valid_pairs += delta.valid_pairs;
    acc.wall_secs += delta.wall_secs;
    acc.modeled_secs += delta.modeled_secs;
    acc.bytes_moved += delta.bytes_moved;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_delta_and_absorb_are_counterwise() {
        let before = DeviceStats { jobs: 2, tiles: 10, wall_secs: 1.0, ..Default::default() };
        let after = DeviceStats { jobs: 5, tiles: 14, wall_secs: 1.5, ..Default::default() };
        let d = device_delta(&before, &after);
        assert_eq!(d.jobs, 3);
        assert_eq!(d.tiles, 4);
        assert!((d.wall_secs - 0.5).abs() < 1e-12);
        let mut acc = DeviceStats::default();
        absorb_device(&mut acc, &d);
        absorb_device(&mut acc, &d);
        assert_eq!(acc.tiles, 8);
    }

    #[test]
    fn device_delta_saturates_across_a_reset() {
        let before = DeviceStats { tiles: 100, wall_secs: 3.0, ..Default::default() };
        let after = DeviceStats::default(); // reset happened in between
        let d = device_delta(&before, &after);
        assert_eq!(d.tiles, 0);
        assert_eq!(d.wall_secs, 0.0);
    }
}
