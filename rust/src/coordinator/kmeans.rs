//! AccD K-means: incremental (Elkan/Hamerly-style) cross-iteration TI
//! pruning over the stepwise contract, on top of the trace-based +
//! group-level GTI filter and fused assignment tiles.
//!
//! Algorithm outline (paper §IV-B-b/c, the "hierarchy bound" of §VII):
//!
//! 1. Group the points once (`z_src` groups, membership fixed) and pack
//!    them contiguously (layout §V-A).  Group the k centers into
//!    `z_trg` center-groups (membership fixed across iterations).
//! 2. Iteration 0 assigns every point exactly via the fused
//!    distance+argmin tiles.  With `kmeans.incremental_ti` (the
//!    default) the tiles also return each point's distance to its
//!    *second*-closest center — the seed of a per-point Hamerly lower
//!    bound — and the Eq. 2 (source group x center group) lower bounds
//!    are computed once, exactly, at plan time.
//! 3. Each later iteration: move centers to member means, compute
//!    per-center drifts, then *widen* the carried bounds O(1) per
//!    point/pair (`ub[i] += drift[assign[i]]`,
//!    `lb[i] -= max_other_drift`, pair lbs by max member drift per
//!    center group) instead of recomputing them.  A point with
//!    `ub[i] <= lb[i]` — after one cheap CPU ub-tighten — is provably
//!    still assigned to the same center and is dropped from the device
//!    submission (`points_pruned`); a group whose every member is
//!    stable drops its whole candidate rectangle set (`tiles_skipped`).
//!    Unstable rows go to the device against the surviving candidate
//!    center-groups, and come back with fresh exact ub + second-best
//!    lb (floored by the pruned center-groups' pair lbs).
//!
//! With `kmeans.incremental_ti = false` every iteration instead widens
//! only the upper bounds, recenters the center grouping and recomputes
//! the Eq. 2 group-pair bounds from scratch — the pre-incremental
//! behavior, kept as the A/B lever for the bench.
//!
//! Soundness argument for the prune rules is spelled out in
//! `gti::bounds` / `gti::filter` and exercised by
//! `rust/tests/integration_algorithms.rs` (exact agreement with the
//! naive CPU baseline) and `rust/tests/prop_gti_bounds.rs` (the
//! incremental bound algebra under random drift sequences).

use std::sync::Arc;
use std::time::Instant;

use crate::data::{Dataset, Matrix};
use crate::fpga::device::DeviceStats;
use crate::fpga::FpgaDevice;
use crate::gti::{bounds, filter, Grouping};
use crate::layout::{PackedGrouping, PackedSet};
use crate::metrics::RunReport;
use crate::runtime::TileInfo;
use crate::util::rng::Rng;
use crate::{Error, Result};

use super::engine::Engine;
use super::knn::{SharedSlab, SlabCache, SlabKind, SlabScope};
use super::pipeline;
use super::program::{self, CohortProgram, StepCtx, StepOutcome};

/// Result of a K-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Final cluster centers, `(k, d)`.
    pub centers: Matrix,
    /// Assignment of every input point to a center.
    pub assign: Vec<u32>,
    /// Sum of squared distances to assigned centers (exact).
    pub sse: f64,
    /// Iterations executed (excluding the init pass).
    pub iterations: usize,
    pub report: RunReport,
}

pub(super) fn run(
    engine: &mut Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
) -> Result<KmeansResult> {
    run_shared(engine, ds, k, max_iters, None)
}

/// One K-means query as a stepwise program.
///
/// [`plan`] groups + packs the points, initializes centers and runs the
/// exact iteration-0 assignment; [`CohortProgram::step`] is one Lloyd
/// iteration under the trace-based + group-level filter, converging
/// when no assignment changed and center drift vanished (or the
/// iteration cap is reached — the cap belongs to the program, not the
/// driver, so every driver observes identical iteration counts);
/// [`CohortProgram::finish`] is the exact SSE pass + unpacking.
pub(crate) struct KmeansProgram {
    k: usize,
    max_iters: usize,
    pg: Arc<PackedGrouping>,
    centers: Matrix,
    center_grouping: Grouping,
    z_trg: usize,
    /// Assignment + upper bounds in packed-row order.
    assign: Vec<u32>,
    ub: Vec<f32>,
    /// Incremental TI mode (`kmeans.incremental_ti` at plan time).
    incremental: bool,
    /// Per-point Hamerly lower bound to the closest *non-assigned*
    /// center, packed-row order (incremental mode only; empty in
    /// legacy mode).
    lb: Vec<f32>,
    /// Carried (source group x center group) lower bounds: exact at
    /// plan time, widened O(1) per step by max member drift per center
    /// group (incremental mode only; empty in legacy mode).
    pair_lb: Vec<Vec<f32>>,
    k_pad: usize,
    d_pad: usize,
    tile: TileInfo,
    /// Padded full packed-points slab — the assignment tile's row
    /// input, fetched through the caller's [`SlabCache`] so every
    /// same-dataset K-means program in a serving cohort shares one
    /// build.
    points_slab: SharedSlab,
    iterations: usize,
    /// Converged via the drift criterion — makes `step` after
    /// `Converged` an idempotent no-op, as the contract requires.
    converged: bool,
    report: RunReport,
    /// Wall seconds spent inside THIS program's plan/step/finish calls
    /// (per-call accumulation — like the device counters, exact even
    /// when the lockstep scheduler interleaves other programs).
    wall_secs: f64,
    /// This program's own device counters (snapshot diffs — exact even
    /// when the lockstep scheduler interleaves other programs' steps
    /// on the same engine).
    device: DeviceStats,
}

/// Validate a K-means request (shared by the solo path and the serving
/// layer's admission check, so the two can never silently diverge).
pub(crate) fn validate(ds: &Dataset, k: usize) -> Result<()> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range for n={}", ds.n())));
    }
    Ok(())
}

/// K-means with an optionally pre-built (cached) source grouping —
/// the solo driver: plan, step to convergence, finish.
///
/// `shared` must be exactly what [`PackedGrouping::build`] would
/// produce for this dataset and the engine's config — the serving
/// layer's cache guarantees this by keying on the dataset fingerprint
/// and the build parameters, so injecting it cannot change any result.
pub(crate) fn run_shared(
    engine: &mut Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
    shared: Option<Arc<PackedGrouping>>,
) -> Result<KmeansResult> {
    validate(ds, k)?;
    engine.device.reset_stats();
    // Run-local scratch cache: identity fields are irrelevant (nothing
    // outlives this run), only key consistency matters.
    let mut slab_cache = SlabCache::unbounded();
    let program =
        plan(&*engine, ds, k, max_iters, shared.map(|pg| (pg, (0, 0))), &mut slab_cache)?;
    let mut ctx = StepCtx { engine: &*engine };
    program::run_to_completion(program, &mut ctx)
}

/// CPU-side planning + exact iteration-0 assignment.
///
/// `shared` carries a cached `(grouping, content fingerprint)` pair
/// from the serving layer; `None` builds the grouping here (solo path,
/// fingerprint fields zeroed — the run-local cache never aliases).
/// The padded full points slab is fetched through `slab_cache`, so
/// same-dataset programs sharing a persistent cache share one build.
pub(crate) fn plan(
    engine: &Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
    shared: Option<(Arc<PackedGrouping>, (u64, u64))>,
    slab_cache: &mut SlabCache,
) -> Result<KmeansProgram> {
    validate(ds, k)?;
    let t0 = Instant::now();
    let mut report = RunReport::new("kmeans", &ds.name, "accd");
    let cfg = engine.config.clone();
    let tile = engine.runtime.manifest().tile.clone();
    let d = ds.d();
    let d_pad = tile.pad_d(d)?;

    // --- CPU side: grouping + packing (filter stage) -------------------
    let filt0 = Instant::now();
    let z_src = engine.src_groups(ds.n());
    let (pg, ds_fp) = match shared {
        Some((pg, fp)) => (pg, fp),
        None => (
            Arc::new(PackedGrouping::build(
                &ds.points,
                z_src,
                cfg.gti.grouping_iters,
                cfg.gti.grouping_sample,
                cfg.seed,
                crate::gti::Metric::L2,
                8,
            )?),
            (0, 0),
        ),
    };

    // Initial centers: k distinct random points.
    let mut rng = Rng::new(cfg.seed ^ 0x6B6D_6561_6E73); // "kmeans" salt
    let centers = ds.points.gather_rows(&rng.sample_indices(ds.n(), k));

    // Group the centers (membership fixed; positions will drift).
    let z_trg = engine.trg_groups(k).min(k);
    let center_grouping =
        Grouping::build(&centers, z_trg, cfg.gti.grouping_iters, k, cfg.seed ^ 0xC0)?;
    report.filter_secs += filt0.elapsed().as_secs_f64();

    // --- Iteration 0: exact assignment of everything -------------------
    let k_pad = tile.pad_kmeans_k(k)?;
    let n = pg.packed.points.rows();
    let rows_pad = crate::util::round_up(n.max(1), tile.m);
    // The assignment tile's row input depends only on the packed
    // points and the tile geometry — identical for every program over
    // this dataset under this grouping, so it lives in the slab cache.
    let scope = SlabScope {
        kind: SlabKind::KmeansPoints,
        fingerprint: ds_fp.0,
        probe: ds_fp.1,
        groups: z_src,
        iters: cfg.gti.grouping_iters,
        sample: cfg.gti.grouping_sample,
        seed: cfg.seed,
        metric: crate::gti::Metric::L2,
        d_pad,
        tile_n: tile.m,
    };
    let points = &pg.packed.points;
    let (points_slab, _hit) = slab_cache.get_or_build(&scope, &[], || SharedSlab {
        slab: Arc::new(FpgaDevice::pad_slab(points.as_slice(), n, d, rows_pad, d_pad)),
        col_ids: Arc::new(Vec::new()),
        rows: n,
    });

    let centers_slab = pad_centers(&centers, k_pad, d_pad);
    let incremental = cfg.kmeans.incremental_ti;
    let mut assign = vec![0u32; n]; // packed-row order
    let mut ub = vec![0.0f32; n]; // upper bound on dist to assigned
    let mut lb = Vec::new(); // Hamerly lb to second-closest (incremental)
    let dev0 = engine.device.stats();
    if incremental {
        lb = vec![0.0f32; n];
        assign2_full(
            &engine.device,
            &points_slab.slab,
            n,
            &centers_slab,
            k,
            k_pad,
            d_pad,
            &mut assign,
            &mut ub,
            &mut lb,
        )?;
    } else {
        assign_full(
            &engine.device,
            &points_slab.slab,
            n,
            &centers_slab,
            k,
            k_pad,
            d_pad,
            &mut assign,
            &mut ub,
        )?;
    }
    let mut device = DeviceStats::default();
    program::absorb_device(&mut device, &program::device_delta(&dev0, &engine.device.stats()));

    // Plan-time exact Eq. 2 group-pair lower bounds (incremental mode):
    // tightened once here, widened O(1) per step thereafter.
    let mut pair_lb: Vec<Vec<f32>> = Vec::new();
    if incremental {
        pair_lb = bounds::group_pair_bounds(&pg.grouping, &center_grouping)
            .iter()
            .map(|row| row.iter().map(|b| b.lb).collect())
            .collect();
        report.filter.bound_comps += (pg.grouping.num_groups() * z_trg) as u64;
    }

    Ok(KmeansProgram {
        k,
        max_iters,
        pg,
        centers,
        center_grouping,
        z_trg,
        assign,
        ub,
        incremental,
        lb,
        pair_lb,
        k_pad,
        d_pad,
        tile,
        points_slab,
        iterations: 0,
        converged: false,
        report,
        wall_secs: t0.elapsed().as_secs_f64(),
        device,
    })
}

impl KmeansProgram {
    /// Observed prune rate so far, in permille of point-iterations:
    /// `1000 * points_pruned / (n * iterations)`.  0 before the first
    /// step.  The lockstep scheduler uses this to step high-pruning
    /// programs first among equal deadlines — their steps are cheap
    /// and their bounds tighten fastest, so the shard's expensive work
    /// sees the freshest center positions.
    pub(crate) fn observed_prune_permille(&self) -> u64 {
        let denom = self.assign.len() as u64 * self.iterations as u64;
        if denom == 0 {
            return 0;
        }
        (1000 * self.report.filter.points_pruned) / denom
    }
}

impl CohortProgram for KmeansProgram {
    type Output = KmeansResult;

    /// One Lloyd iteration: center update, trace-based bound widening,
    /// group-level filter, surviving rectangles to the device.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.converged || self.iterations >= self.max_iters {
            return Ok(StepOutcome::Converged);
        }
        let step_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        self.iterations += 1;
        let k = self.k;
        let grouping = &self.pg.grouping;
        let packed = &self.pg.packed;
        let num_groups = grouping.num_groups();

        // Center update (CPU): means over packed points.
        let filt = Instant::now();
        let drift = update_centers(packed, &self.assign, &mut self.centers, k);
        let max_drift = drift.iter().cloned().fold(0.0f32, f32::max);

        // Candidate center-groups per source group.  Source groups
        // sharing the same candidate signature are merged into ONE
        // device batch (the paper's Fig. 4b inter-group schedule
        // applied to dispatch — perf pass §Perf): with z_trg small,
        // most groups share candidates, so the accelerator sees a few
        // large row slabs instead of thousands of 64-row tiles.
        let mut changed = 0usize;
        let mut batches: std::collections::BTreeMap<Vec<u32>, Vec<usize>> =
            std::collections::BTreeMap::new();
        // Incremental mode only: per group, the unstable packed rows
        // that still need a device recompute, and the lb floor over
        // pruned center-groups (a refreshed per-point lb may not claim
        // less than the tightest pruned pair bound).
        let mut rows_of: Vec<Vec<u32>> = Vec::new();
        let mut lb_floor: Vec<f32> = Vec::new();

        if self.incremental {
            // O(1) widening of the carried bounds — no recompute, no
            // recentering (center-group membership is fixed and only
            // `members`/`assign` are read below).
            let w = bounds::DriftWidening::from_drifts(&drift);
            bounds::widen_point_bounds(&mut self.ub, &mut self.lb, &self.assign, &drift, &w);
            let cg_drift =
                bounds::center_group_drift(&self.center_grouping.assign, self.z_trg, &drift);
            bounds::widen_pair_lbs(&mut self.pair_lb, &cg_drift);
            self.report.filter.bound_comps +=
                (num_groups * self.z_trg + self.assign.len()) as u64;

            rows_of = vec![Vec::new(); num_groups];
            lb_floor = vec![f32::INFINITY; num_groups];
            for g in 0..num_groups {
                let (start, len) = (packed.group_start(g), packed.group_len(g));
                if len == 0 {
                    continue;
                }
                self.report.filter.total_pairs += (len * k) as u64;
                // Point-level stability: a point failing the widened
                // test gets one cheap exact ub-tighten (CPU distance to
                // its assigned center) before it is declared unstable.
                let members: Vec<u32> = (start as u32..(start + len) as u32).collect();
                for &pi in &members {
                    let i = pi as usize;
                    if self.ub[i] > self.lb[i] {
                        let a = self.assign[i] as usize;
                        self.ub[i] = packed.points.dist2(i, &self.centers, a).max(0.0).sqrt();
                        self.report.filter.bound_recomputes += 1;
                    }
                }
                let (unstable, stable) = filter::unstable_members(&members, &self.ub, &self.lb);
                if unstable.is_empty() {
                    // Every member provably keeps its assignment: the
                    // whole candidate rectangle set is dropped.  Count
                    // the rectangles the legacy filter (full-member ub)
                    // would have submitted.
                    let ub_full =
                        members.iter().fold(0.0f32, |m, &pi| m.max(self.ub[pi as usize]));
                    for b in 0..self.z_trg {
                        self.report.filter.group_pairs += 1;
                        if self.pair_lb[g][b] <= ub_full {
                            self.report.filter.tiles_skipped += 1;
                        }
                    }
                    continue;
                }
                self.report.filter.points_pruned += stable;
                // Group filter over the unstable members only (their
                // max ub is tighter and still covers every submitted
                // row); pruned center-groups feed the lb floor.
                let ub_unstable =
                    unstable.iter().fold(0.0f32, |m, &pi| m.max(self.ub[pi as usize]));
                let mut cand_groups: Vec<u32> = Vec::new();
                for b in 0..self.z_trg {
                    self.report.filter.group_pairs += 1;
                    if self.pair_lb[g][b] <= ub_unstable {
                        self.report.filter.surviving_group_pairs += 1;
                        cand_groups.push(b as u32);
                    } else {
                        lb_floor[g] = lb_floor[g].min(self.pair_lb[g][b]);
                    }
                }
                if !cand_groups.is_empty() {
                    rows_of[g] = unstable;
                    batches.entry(cand_groups).or_default().push(g);
                }
            }
        } else {
            // Legacy per-iteration path: widen ubs by assigned center
            // drift (trace-based), recenter the center grouping and
            // recompute the Eq. 2 group-pair bounds from scratch.
            for (i, a) in self.assign.iter().enumerate() {
                self.ub[i] += drift[*a as usize];
            }
            recenter_center_groups(&mut self.center_grouping, &self.centers);
            let pair_bounds = bounds::group_pair_bounds(grouping, &self.center_grouping);
            self.report.filter.bound_comps += (num_groups * self.z_trg) as u64;
            // Per source group: ub = max member ub.
            let mut grp_ub = vec![0.0f32; num_groups];
            for g in 0..num_groups {
                let (start, len) = (packed.group_start(g), packed.group_len(g));
                let mut m = 0.0f32;
                for i in start..start + len {
                    m = m.max(self.ub[i]);
                }
                grp_ub[g] = m;
            }
            for g in 0..num_groups {
                let len = packed.group_len(g);
                if len == 0 {
                    continue;
                }
                let mut cand_groups: Vec<u32> = Vec::new();
                for b in 0..self.z_trg {
                    self.report.filter.group_pairs += 1;
                    if pair_bounds[g][b].lb <= grp_ub[g] {
                        self.report.filter.surviving_group_pairs += 1;
                        cand_groups.push(b as u32);
                    }
                }
                self.report.filter.total_pairs += (len * k) as u64;
                if !cand_groups.is_empty() {
                    batches.entry(cand_groups).or_default().push(g);
                }
            }
        }
        self.report.filter_secs += filt.elapsed().as_secs_f64();
        let jobs: Vec<(Vec<u32>, Vec<usize>)> = batches.into_iter().collect();

        // Stream merged batches through the bounded pipeline.
        let incremental = self.incremental;
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        // Per job: (rows, candidate centers, best idx, best squared
        // dist, second-best squared dist, per-row lb floor) — the last
        // two empty in legacy mode.
        type JobOut = (Vec<u32>, Vec<u32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>);
        let mut results: Vec<JobOut> = Vec::new();
        {
            let jobs_ref = &jobs;
            let center_grouping = &self.center_grouping;
            let centers = &self.centers;
            let report = &mut self.report;
            let tile = &self.tile;
            let d_pad = self.d_pad;
            let rows_of = &rows_of;
            let lb_floor = &lb_floor;
            pipeline::run(
                8,
                |i| jobs_ref.get(i as usize).cloned(),
                |(cand_groups, src_groups)| {
                    if job_err.is_some() {
                        return;
                    }
                    let cand_centers: Vec<u32> = cand_groups
                        .iter()
                        .flat_map(|&b| center_grouping.members[b as usize].iter().copied())
                        .collect();
                    // Packed-row list of the batch: unstable members
                    // only (incremental) or whole group ranges (legacy).
                    let mut rows: Vec<u32> = Vec::new();
                    let mut floors: Vec<f32> = Vec::new();
                    for &g in &src_groups {
                        if incremental {
                            rows.extend_from_slice(&rows_of[g]);
                            floors.resize(rows.len(), lb_floor[g]);
                        } else {
                            let (s, l) = (packed.group_start(g), packed.group_len(g));
                            rows.extend((s as u32)..(s + l) as u32);
                        }
                    }
                    report.filter.surviving_pairs +=
                        (rows.len() * cand_centers.len()) as u64;
                    if incremental {
                        match assign_rows2(
                            device,
                            &packed.points,
                            &rows,
                            centers,
                            &cand_centers,
                            &tile.kmeans_k_pad,
                            d_pad,
                        ) {
                            Ok((idx, dist, second)) => {
                                results.push((rows, cand_centers, idx, dist, second, floors))
                            }
                            Err(e) => job_err = Some(e),
                        }
                    } else {
                        match assign_rows(
                            device,
                            &packed.points,
                            &rows,
                            centers,
                            &cand_centers,
                            &tile.kmeans_k_pad,
                            d_pad,
                        ) {
                            Ok((idx, dist)) => results
                                .push((rows, cand_centers, idx, dist, Vec::new(), Vec::new())),
                            Err(e) => job_err = Some(e),
                        }
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        for (rows, cand, idx, dist, second, floors) in results {
            for (r, &packed_row) in rows.iter().enumerate() {
                let true_center = cand[idx[r] as usize];
                let i = packed_row as usize;
                if self.assign[i] != true_center {
                    self.assign[i] = true_center;
                    changed += 1;
                }
                self.ub[i] = dist[r].max(0.0).sqrt();
                if incremental {
                    // Refresh the Hamerly lb: exact second-best among
                    // the candidate centers, floored by the pruned
                    // center-groups' pair lbs (group-filter soundness:
                    // no pruned center can be closer than that floor).
                    self.lb[i] = second[r].max(0.0).sqrt().min(floors[r]);
                }
            }
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        self.wall_secs += step_t0.elapsed().as_secs_f64();

        self.converged = changed == 0 && max_drift < 1e-6;
        if self.converged || self.iterations >= self.max_iters {
            Ok(StepOutcome::Converged)
        } else {
            Ok(StepOutcome::Continue)
        }
    }

    /// Final exact pass: SSE + assignment validation + unpacking.
    fn finish(mut self, ctx: &mut StepCtx<'_>) -> Result<KmeansResult> {
        let finish_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let n = self.pg.packed.points.rows();
        let centers_slab = pad_centers(&self.centers, self.k_pad, self.d_pad);
        let mut final_dist = vec![0.0f32; n];
        assign_full(
            &engine.device,
            &self.points_slab.slab,
            n,
            &centers_slab,
            self.k,
            self.k_pad,
            self.d_pad,
            &mut self.assign,
            &mut final_dist,
        )?;
        let sse: f64 = final_dist.iter().map(|&x| (x * x) as f64).sum();

        // Unpack assignment to original point order.
        let mut assign_orig = vec![0u32; n];
        for (new_row, &old) in self.pg.packed.new2old.iter().enumerate() {
            assign_orig[old as usize] = self.assign[new_row];
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );

        // --- Report ------------------------------------------------------
        let iterations = self.iterations;
        let mut report = self.report;
        report.iterations = iterations;
        report.wall_secs = self.wall_secs + finish_t0.elapsed().as_secs_f64();
        report.device = self.device.clone();
        report.device_wall_secs = report.device.wall_secs;
        report.device_modeled_secs = report.device.modeled_secs;
        report.quality = sse;
        report.energy_j = engine.power.accd_joules(
            report.wall_secs,
            report.filter_secs,
            1.0,
            report.device.wall_secs,
        );
        report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

        Ok(KmeansResult { centers: self.centers, assign: assign_orig, sse, iterations, report })
    }
}

/// Exact assignment of every packed point against the full center
/// slab.  `points_slab` is the pre-padded full packed-points slab
/// (built once per program, shared across same-dataset programs
/// through the slab cache).
#[allow(clippy::too_many_arguments)]
fn assign_full(
    device: &FpgaDevice,
    points_slab: &[f32],
    n: usize,
    centers_slab: &[f32],
    k: usize,
    k_pad: usize,
    d_pad: usize,
    assign: &mut [u32],
    best_dist: &mut [f32],
) -> Result<()> {
    let (idx, dist) = device.kmeans_assign_block(points_slab, n, d_pad, centers_slab, k_pad)?;
    for i in 0..n {
        let ci = idx[i] as usize;
        debug_assert!(ci < k, "assignment hit a padded center slot");
        assign[i] = ci as u32;
        best_dist[i] = dist[i].max(0.0).sqrt();
    }
    Ok(())
}

/// Like [`assign_full`], but also seeds the per-point Hamerly lower
/// bound: the exact distance to the *second*-closest center (the
/// incremental TI path's plan-time tighten).
#[allow(clippy::too_many_arguments)]
fn assign2_full(
    device: &FpgaDevice,
    points_slab: &[f32],
    n: usize,
    centers_slab: &[f32],
    k: usize,
    k_pad: usize,
    d_pad: usize,
    assign: &mut [u32],
    best_dist: &mut [f32],
    second_dist: &mut [f32],
) -> Result<()> {
    let (idx, dist, second) =
        device.kmeans_assign2_block(points_slab, n, d_pad, centers_slab, k_pad)?;
    for i in 0..n {
        let ci = idx[i] as usize;
        debug_assert!(ci < k, "assignment hit a padded center slot");
        assign[i] = ci as u32;
        best_dist[i] = dist[i].max(0.0).sqrt();
        // With a single real center the second slot holds the padding
        // sentinel's distance — effectively infinite, which is the
        // correct "no other center" lower bound.
        second_dist[i] = second[i].max(0.0).sqrt();
    }
    Ok(())
}

/// Assignment of an arbitrary packed-row batch against a candidate
/// center list.  Returns per-row (index into candidates, squared
/// distance).  Candidates are chunked when they exceed the largest
/// padded-center artifact, with a running min across chunks.
fn assign_rows(
    device: &FpgaDevice,
    points: &Matrix,
    rows: &[u32],
    centers: &Matrix,
    candidates: &[u32],
    k_pads: &[usize],
    d_pad: usize,
) -> Result<(Vec<i32>, Vec<f32>)> {
    let len = rows.len();
    let kc = candidates.len();
    let max_pad = *k_pads.last().expect("kmeans_k_pad empty");
    let mut best_idx = vec![0i32; len];
    let mut best_dist = vec![f32::INFINITY; len];
    let tile_m = device.runtime().manifest().tile.m;
    let rows_pad = crate::util::round_up(len.max(1), tile_m);
    let slab = FpgaDevice::pad_rows(points, rows, rows_pad, d_pad);
    let mut off = 0usize;
    while off < kc {
        let chunk = (kc - off).min(max_pad);
        let chunk_ids = &candidates[off..off + chunk];
        let k_pad = k_pads
            .iter()
            .copied()
            .find(|&p| p >= chunk)
            .unwrap_or(max_pad);
        let idx: Vec<usize> = chunk_ids.iter().map(|&c| c as usize).collect();
        let cand_mat = centers.gather_rows(&idx);
        let cslab = pad_centers(&cand_mat, k_pad, d_pad);
        let (ti, td) = device.kmeans_assign_block(&slab, len, d_pad, &cslab, k_pad)?;
        for r in 0..len {
            if td[r] < best_dist[r] {
                best_dist[r] = td[r];
                best_idx[r] = (off + ti[r] as usize) as i32;
            }
        }
        off += chunk;
    }
    Ok((best_idx, best_dist))
}

/// Like [`assign_rows`], but also returns the squared distance to the
/// *second*-best candidate per row — the incremental TI path's lb
/// refresh.  The running (best, second) pair merges across candidate
/// chunks: the combined second-smallest of {old best, old second, new
/// best, new second}.
fn assign_rows2(
    device: &FpgaDevice,
    points: &Matrix,
    rows: &[u32],
    centers: &Matrix,
    candidates: &[u32],
    k_pads: &[usize],
    d_pad: usize,
) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
    let len = rows.len();
    let kc = candidates.len();
    let max_pad = *k_pads.last().expect("kmeans_k_pad empty");
    let mut best_idx = vec![0i32; len];
    let mut best_dist = vec![f32::INFINITY; len];
    let mut second_dist = vec![f32::INFINITY; len];
    let tile_m = device.runtime().manifest().tile.m;
    let rows_pad = crate::util::round_up(len.max(1), tile_m);
    let slab = FpgaDevice::pad_rows(points, rows, rows_pad, d_pad);
    let mut off = 0usize;
    while off < kc {
        let chunk = (kc - off).min(max_pad);
        let chunk_ids = &candidates[off..off + chunk];
        let k_pad = k_pads
            .iter()
            .copied()
            .find(|&p| p >= chunk)
            .unwrap_or(max_pad);
        let idx: Vec<usize> = chunk_ids.iter().map(|&c| c as usize).collect();
        let cand_mat = centers.gather_rows(&idx);
        let cslab = pad_centers(&cand_mat, k_pad, d_pad);
        let (ti, td, ts) = device.kmeans_assign2_block(&slab, len, d_pad, &cslab, k_pad)?;
        for r in 0..len {
            if td[r] < best_dist[r] {
                // New chunk's best wins: old best competes for second
                // with the new chunk's own runner-up.
                second_dist[r] = best_dist[r].min(ts[r]);
                best_dist[r] = td[r];
                best_idx[r] = (off + ti[r] as usize) as i32;
            } else {
                second_dist[r] = second_dist[r].min(td[r]);
            }
        }
        off += chunk;
    }
    Ok((best_idx, best_dist, second_dist))
}

/// Pad centers to `(k_pad, d_pad)` with far-away sentinel rows so the
/// fused argmin can never select padding.
fn pad_centers(centers: &Matrix, k_pad: usize, d_pad: usize) -> Vec<f32> {
    let (k, d) = (centers.rows(), centers.cols());
    let mut slab = vec![0.0f32; k_pad * d_pad];
    for c in 0..k {
        slab[c * d_pad..c * d_pad + d].copy_from_slice(centers.row(c));
    }
    // Sentinel: 1e18 squared distance dominates any real distance while
    // staying far from f32 overflow when squared... use 1e15 coordinate.
    for c in k..k_pad {
        slab[c * d_pad] = 1.0e15;
    }
    slab
}

/// Move centers to member means; returns per-center drift distances.
fn update_centers(packed: &PackedSet, assign: &[u32], centers: &mut Matrix, k: usize) -> Vec<f32> {
    let d = centers.cols();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    for (i, &a) in assign.iter().enumerate() {
        let row = packed.points.row(i);
        let a = a as usize;
        counts[a] += 1;
        for x in 0..d {
            sums[a * d + x] += row[x] as f64;
        }
    }
    let mut drift = vec![0.0f32; k];
    for c in 0..k {
        if counts[c] == 0 {
            continue; // empty cluster keeps its position
        }
        let inv = 1.0 / counts[c] as f64;
        let row = centers.row_mut(c);
        let mut d2 = 0.0f32;
        for x in 0..d {
            let nc = (sums[c * d + x] * inv) as f32;
            let delta = nc - row[x];
            d2 += delta * delta;
            row[x] = nc;
        }
        drift[c] = d2.sqrt();
    }
    drift
}

/// Recenter the center-grouping around the moved centers (legacy
/// per-iteration path only — the incremental path never recenters).
/// The landmark drift `Grouping::recenter` returns is deliberately
/// dropped here: it bounds the motion of the group's *centroid*, not
/// of its farthest member, so folding it into member-pair bounds would
/// be unsound (a sound widening needs per-center drifts — see
/// [`bounds::center_group_drift`]); the full Eq. 2 recompute that
/// follows every legacy recentering makes it redundant anyway.
fn recenter_center_groups(cg: &mut Grouping, centers: &Matrix) {
    let _landmark_drift: Vec<f32> = cg.recenter(centers);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empty-cluster edge case: a center that loses every member must
    /// keep its position exactly (zero drift) — the invariant the
    /// batched-equals-sequential contract relies on when clusters die
    /// mid-run (`rust/tests/serve_parity.rs` covers it end to end).
    #[test]
    fn update_centers_keeps_empty_cluster_position() {
        let pts =
            Matrix::from_vec(vec![0.0, 0.0, 0.0, 2.0, 10.0, 10.0, 10.0, 12.0], 4, 2).unwrap();
        let g = Grouping::build(&pts, 1, 2, 4096, 7).unwrap();
        let packed = PackedSet::pack(&pts, &g, 4);
        // 3 centers; center 2 never assigned.
        let mut centers = Matrix::from_vec(vec![0.0, 0.0, 10.0, 10.0, 50.0, 50.0], 3, 2).unwrap();
        let assign: Vec<u32> = packed.new2old.iter().map(|&old| u32::from(old >= 2)).collect();
        let drift = update_centers(&packed, &assign, &mut centers, 3);
        assert_eq!(drift[2], 0.0, "empty cluster must not drift");
        assert_eq!(centers.row(2).to_vec(), vec![50.0f32, 50.0]);
        // Non-empty centers moved exactly to their member means.
        assert_eq!(centers.row(0).to_vec(), vec![0.0f32, 1.0]);
        assert_eq!(centers.row(1).to_vec(), vec![10.0f32, 11.0]);
    }

    /// After centers move, BOTH recentering disciplines keep the
    /// (source group x center group) bounds sound: the incremental
    /// path's O(1) widening by max member drift per center group, and
    /// the legacy path's recenter + full Eq. 2 recompute (whose
    /// landmark drift is deliberately dropped — see
    /// [`recenter_center_groups`]).
    #[test]
    fn center_group_bounds_stay_sound_after_recentering() {
        use crate::data::synthetic;
        let pts = synthetic::clustered(240, 4, 5, 0.05, 21).points;
        let gs = Grouping::build(&pts, 6, 2, 240, 22).unwrap();
        let mut centers = synthetic::clustered(24, 4, 4, 0.05, 23).points;
        let mut gc = Grouping::build(&centers, 4, 2, 24, 24).unwrap();
        let mut pair_lb: Vec<Vec<f32>> = bounds::group_pair_bounds(&gs, &gc)
            .iter()
            .map(|row| row.iter().map(|b| b.lb).collect())
            .collect();

        // Move every center, recording per-center drift distances.
        let mut rng = Rng::new(25);
        let d = centers.cols();
        let mut drift = vec![0.0f32; centers.rows()];
        for c in 0..centers.rows() {
            let row = centers.row_mut(c);
            let mut d2 = 0.0f32;
            for x in 0..d {
                let delta = rng.range_f32(-0.1, 0.1);
                row[x] += delta;
                d2 += delta * delta;
            }
            drift[c] = d2.sqrt();
        }

        // Incremental discipline: widened pair lbs still lower-bound
        // every (member point, member center) distance.
        let cg_drift = bounds::center_group_drift(&gc.assign, gc.num_groups(), &drift);
        bounds::widen_pair_lbs(&mut pair_lb, &cg_drift);
        for (g, mem) in gs.members.iter().enumerate() {
            for &p in mem {
                for (b, cmem) in gc.members.iter().enumerate() {
                    for &c in cmem {
                        let dist =
                            pts.dist2(p as usize, &centers, c as usize).max(0.0).sqrt();
                        assert!(
                            pair_lb[g][b] <= dist + 1e-3,
                            "widened pair lb {} > dist {dist} for (g={g}, b={b})",
                            pair_lb[g][b],
                        );
                    }
                }
            }
        }

        // Legacy discipline: recenter + fresh Eq. 2 bounds contain
        // every pair distance on both sides.
        recenter_center_groups(&mut gc, &centers);
        let fresh = bounds::group_pair_bounds(&gs, &gc);
        for (g, mem) in gs.members.iter().enumerate() {
            for &p in mem {
                for (b, cmem) in gc.members.iter().enumerate() {
                    for &c in cmem {
                        let dist =
                            pts.dist2(p as usize, &centers, c as usize).max(0.0).sqrt();
                        assert!(fresh[g][b].lb <= dist + 1e-3);
                        assert!(dist <= fresh[g][b].ub + 1e-3);
                    }
                }
            }
        }
    }
}
