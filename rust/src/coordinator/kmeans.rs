//! AccD K-means: Trace-based + Group-level GTI + fused assignment tiles.
//!
//! Algorithm outline (paper §IV-B-b/c, the "hierarchy bound" of §VII):
//!
//! 1. Group the points once (`z_src` groups, membership fixed) and pack
//!    them contiguously (layout §V-A).  Group the k centers into
//!    `z_trg` center-groups (membership fixed across iterations).
//! 2. Iteration 0 assigns every point exactly via the fused
//!    distance+argmin tiles.
//! 3. Each later iteration: move centers to member means, compute per-
//!    center drifts; widen every point's upper bound by its assigned
//!    center's drift (trace-based, Fig. 2c); recompute the cheap Eq. 2
//!    group-pair lower bounds; a source group whose lb to some center-
//!    group exceeds its max member ub skips that center-group entirely
//!    (group-level filter, Fig. 3b).  Surviving (group x center-set)
//!    rectangles are dense and go to the device.
//!
//! Soundness argument for the prune rule is spelled out in
//! `gti::filter` and exercised by `rust/tests/integration_algorithms.rs`
//! which checks exact agreement with the naive CPU baseline.

use std::sync::Arc;
use std::time::Instant;

use crate::data::{Dataset, Matrix};
use crate::fpga::device::DeviceStats;
use crate::fpga::FpgaDevice;
use crate::gti::{bounds, Grouping};
use crate::layout::{PackedGrouping, PackedSet};
use crate::metrics::RunReport;
use crate::runtime::TileInfo;
use crate::util::rng::Rng;
use crate::{Error, Result};

use super::engine::Engine;
use super::knn::{SharedSlab, SlabCache, SlabKind, SlabScope};
use super::pipeline;
use super::program::{self, CohortProgram, StepCtx, StepOutcome};

/// Result of a K-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Final cluster centers, `(k, d)`.
    pub centers: Matrix,
    /// Assignment of every input point to a center.
    pub assign: Vec<u32>,
    /// Sum of squared distances to assigned centers (exact).
    pub sse: f64,
    /// Iterations executed (excluding the init pass).
    pub iterations: usize,
    pub report: RunReport,
}

pub(super) fn run(
    engine: &mut Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
) -> Result<KmeansResult> {
    run_shared(engine, ds, k, max_iters, None)
}

/// One K-means query as a stepwise program.
///
/// [`plan`] groups + packs the points, initializes centers and runs the
/// exact iteration-0 assignment; [`CohortProgram::step`] is one Lloyd
/// iteration under the trace-based + group-level filter, converging
/// when no assignment changed and center drift vanished (or the
/// iteration cap is reached — the cap belongs to the program, not the
/// driver, so every driver observes identical iteration counts);
/// [`CohortProgram::finish`] is the exact SSE pass + unpacking.
pub(crate) struct KmeansProgram {
    k: usize,
    max_iters: usize,
    pg: Arc<PackedGrouping>,
    centers: Matrix,
    center_grouping: Grouping,
    z_trg: usize,
    /// Assignment + upper bounds in packed-row order.
    assign: Vec<u32>,
    ub: Vec<f32>,
    k_pad: usize,
    d_pad: usize,
    tile: TileInfo,
    /// Padded full packed-points slab — the assignment tile's row
    /// input, fetched through the caller's [`SlabCache`] so every
    /// same-dataset K-means program in a serving cohort shares one
    /// build.
    points_slab: SharedSlab,
    iterations: usize,
    /// Converged via the drift criterion — makes `step` after
    /// `Converged` an idempotent no-op, as the contract requires.
    converged: bool,
    report: RunReport,
    /// Wall seconds spent inside THIS program's plan/step/finish calls
    /// (per-call accumulation — like the device counters, exact even
    /// when the lockstep scheduler interleaves other programs).
    wall_secs: f64,
    /// This program's own device counters (snapshot diffs — exact even
    /// when the lockstep scheduler interleaves other programs' steps
    /// on the same engine).
    device: DeviceStats,
}

/// Validate a K-means request (shared by the solo path and the serving
/// layer's admission check, so the two can never silently diverge).
pub(crate) fn validate(ds: &Dataset, k: usize) -> Result<()> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range for n={}", ds.n())));
    }
    Ok(())
}

/// K-means with an optionally pre-built (cached) source grouping —
/// the solo driver: plan, step to convergence, finish.
///
/// `shared` must be exactly what [`PackedGrouping::build`] would
/// produce for this dataset and the engine's config — the serving
/// layer's cache guarantees this by keying on the dataset fingerprint
/// and the build parameters, so injecting it cannot change any result.
pub(crate) fn run_shared(
    engine: &mut Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
    shared: Option<Arc<PackedGrouping>>,
) -> Result<KmeansResult> {
    validate(ds, k)?;
    engine.device.reset_stats();
    // Run-local scratch cache: identity fields are irrelevant (nothing
    // outlives this run), only key consistency matters.
    let mut slab_cache = SlabCache::unbounded();
    let program =
        plan(&*engine, ds, k, max_iters, shared.map(|pg| (pg, (0, 0))), &mut slab_cache)?;
    let mut ctx = StepCtx { engine: &*engine };
    program::run_to_completion(program, &mut ctx)
}

/// CPU-side planning + exact iteration-0 assignment.
///
/// `shared` carries a cached `(grouping, content fingerprint)` pair
/// from the serving layer; `None` builds the grouping here (solo path,
/// fingerprint fields zeroed — the run-local cache never aliases).
/// The padded full points slab is fetched through `slab_cache`, so
/// same-dataset programs sharing a persistent cache share one build.
pub(crate) fn plan(
    engine: &Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
    shared: Option<(Arc<PackedGrouping>, (u64, u64))>,
    slab_cache: &mut SlabCache,
) -> Result<KmeansProgram> {
    validate(ds, k)?;
    let t0 = Instant::now();
    let mut report = RunReport::new("kmeans", &ds.name, "accd");
    let cfg = engine.config.clone();
    let tile = engine.runtime.manifest().tile.clone();
    let d = ds.d();
    let d_pad = tile.pad_d(d)?;

    // --- CPU side: grouping + packing (filter stage) -------------------
    let filt0 = Instant::now();
    let z_src = engine.src_groups(ds.n());
    let (pg, ds_fp) = match shared {
        Some((pg, fp)) => (pg, fp),
        None => (
            Arc::new(PackedGrouping::build(
                &ds.points,
                z_src,
                cfg.gti.grouping_iters,
                cfg.gti.grouping_sample,
                cfg.seed,
                crate::gti::Metric::L2,
                8,
            )?),
            (0, 0),
        ),
    };

    // Initial centers: k distinct random points.
    let mut rng = Rng::new(cfg.seed ^ 0x6B6D_6561_6E73); // "kmeans" salt
    let centers = ds.points.gather_rows(&rng.sample_indices(ds.n(), k));

    // Group the centers (membership fixed; positions will drift).
    let z_trg = engine.trg_groups(k).min(k);
    let center_grouping =
        Grouping::build(&centers, z_trg, cfg.gti.grouping_iters, k, cfg.seed ^ 0xC0)?;
    report.filter_secs += filt0.elapsed().as_secs_f64();

    // --- Iteration 0: exact assignment of everything -------------------
    let k_pad = tile.pad_kmeans_k(k)?;
    let n = pg.packed.points.rows();
    let rows_pad = crate::util::round_up(n.max(1), tile.m);
    // The assignment tile's row input depends only on the packed
    // points and the tile geometry — identical for every program over
    // this dataset under this grouping, so it lives in the slab cache.
    let scope = SlabScope {
        kind: SlabKind::KmeansPoints,
        fingerprint: ds_fp.0,
        probe: ds_fp.1,
        groups: z_src,
        iters: cfg.gti.grouping_iters,
        sample: cfg.gti.grouping_sample,
        seed: cfg.seed,
        metric: crate::gti::Metric::L2,
        d_pad,
        tile_n: tile.m,
    };
    let points = &pg.packed.points;
    let (points_slab, _hit) = slab_cache.get_or_build(&scope, &[], || SharedSlab {
        slab: Arc::new(FpgaDevice::pad_slab(points.as_slice(), n, d, rows_pad, d_pad)),
        col_ids: Arc::new(Vec::new()),
        rows: n,
    });

    let centers_slab = pad_centers(&centers, k_pad, d_pad);
    let mut assign = vec![0u32; n]; // packed-row order
    let mut ub = vec![0.0f32; n]; // upper bound on dist to assigned
    let dev0 = engine.device.stats();
    assign_full(
        &engine.device,
        &points_slab.slab,
        n,
        &centers_slab,
        k,
        k_pad,
        d_pad,
        &mut assign,
        &mut ub,
    )?;
    let mut device = DeviceStats::default();
    program::absorb_device(&mut device, &program::device_delta(&dev0, &engine.device.stats()));

    Ok(KmeansProgram {
        k,
        max_iters,
        pg,
        centers,
        center_grouping,
        z_trg,
        assign,
        ub,
        k_pad,
        d_pad,
        tile,
        points_slab,
        iterations: 0,
        converged: false,
        report,
        wall_secs: t0.elapsed().as_secs_f64(),
        device,
    })
}

impl CohortProgram for KmeansProgram {
    type Output = KmeansResult;

    /// One Lloyd iteration: center update, trace-based bound widening,
    /// group-level filter, surviving rectangles to the device.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.converged || self.iterations >= self.max_iters {
            return Ok(StepOutcome::Converged);
        }
        let step_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        self.iterations += 1;
        let k = self.k;
        let grouping = &self.pg.grouping;
        let packed = &self.pg.packed;

        // Center update (CPU): means over packed points.
        let filt = Instant::now();
        let drift = update_centers(packed, &self.assign, &mut self.centers, k);
        let max_drift = drift.iter().cloned().fold(0.0f32, f32::max);
        // Trace-based: widen ubs by assigned center drift.
        for (i, a) in self.assign.iter().enumerate() {
            self.ub[i] += drift[*a as usize];
        }
        // Center grouping follows its members (recenter + radii).
        let cg_drift = recenter_center_groups(&mut self.center_grouping, &self.centers);
        let _ = cg_drift;
        // Group-level bounds: Eq. 2 on (source group, center group).
        let pair_bounds = bounds::group_pair_bounds(grouping, &self.center_grouping);
        self.report.filter.bound_comps += (grouping.num_groups() * self.z_trg) as u64;
        // Per source group: ub = max member ub.
        let mut grp_ub = vec![0.0f32; grouping.num_groups()];
        for g in 0..grouping.num_groups() {
            let (start, len) = (packed.group_start(g), packed.group_len(g));
            let mut m = 0.0f32;
            for i in start..start + len {
                m = m.max(self.ub[i]);
            }
            grp_ub[g] = m;
        }
        self.report.filter_secs += filt.elapsed().as_secs_f64();

        // Candidate center-groups per source group.  Source groups
        // sharing the same candidate signature are merged into ONE
        // device batch (the paper's Fig. 4b inter-group schedule
        // applied to dispatch — perf pass §Perf): with z_trg small,
        // most groups share candidates, so the accelerator sees a few
        // large row slabs instead of thousands of 64-row tiles.
        let mut changed = 0usize;
        let mut batches: std::collections::BTreeMap<Vec<u32>, Vec<usize>> =
            std::collections::BTreeMap::new();
        for g in 0..grouping.num_groups() {
            let len = packed.group_len(g);
            if len == 0 {
                continue;
            }
            let mut cand_groups: Vec<u32> = Vec::new();
            for b in 0..self.z_trg {
                self.report.filter.group_pairs += 1;
                if pair_bounds[g][b].lb <= grp_ub[g] {
                    self.report.filter.surviving_group_pairs += 1;
                    cand_groups.push(b as u32);
                }
            }
            self.report.filter.total_pairs += (len * k) as u64;
            if !cand_groups.is_empty() {
                batches.entry(cand_groups).or_default().push(g);
            }
        }
        let jobs: Vec<(Vec<u32>, Vec<usize>)> = batches.into_iter().collect();

        // Stream merged batches through the bounded pipeline.
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        let mut results: Vec<(Vec<u32>, Vec<u32>, Vec<i32>, Vec<f32>)> = Vec::new();
        {
            let jobs_ref = &jobs;
            let center_grouping = &self.center_grouping;
            let centers = &self.centers;
            let report = &mut self.report;
            let tile = &self.tile;
            let d_pad = self.d_pad;
            pipeline::run(
                8,
                |i| jobs_ref.get(i as usize).cloned(),
                |(cand_groups, src_groups)| {
                    if job_err.is_some() {
                        return;
                    }
                    let cand_centers: Vec<u32> = cand_groups
                        .iter()
                        .flat_map(|&b| center_grouping.members[b as usize].iter().copied())
                        .collect();
                    // Packed-row list of all member points of the batch.
                    let rows: Vec<u32> = src_groups
                        .iter()
                        .flat_map(|&g| {
                            let (s, l) = (packed.group_start(g), packed.group_len(g));
                            (s as u32)..(s + l) as u32
                        })
                        .collect();
                    report.filter.surviving_pairs +=
                        (rows.len() * cand_centers.len()) as u64;
                    match assign_rows(
                        device,
                        &packed.points,
                        &rows,
                        centers,
                        &cand_centers,
                        &tile.kmeans_k_pad,
                        d_pad,
                    ) {
                        Ok((idx, dist)) => results.push((rows, cand_centers, idx, dist)),
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        for (rows, cand, idx, dist) in results {
            for (r, &packed_row) in rows.iter().enumerate() {
                let true_center = cand[idx[r] as usize];
                let i = packed_row as usize;
                if self.assign[i] != true_center {
                    self.assign[i] = true_center;
                    changed += 1;
                }
                self.ub[i] = dist[r].max(0.0).sqrt();
            }
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        self.wall_secs += step_t0.elapsed().as_secs_f64();

        self.converged = changed == 0 && max_drift < 1e-6;
        if self.converged || self.iterations >= self.max_iters {
            Ok(StepOutcome::Converged)
        } else {
            Ok(StepOutcome::Continue)
        }
    }

    /// Final exact pass: SSE + assignment validation + unpacking.
    fn finish(mut self, ctx: &mut StepCtx<'_>) -> Result<KmeansResult> {
        let finish_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let n = self.pg.packed.points.rows();
        let centers_slab = pad_centers(&self.centers, self.k_pad, self.d_pad);
        let mut final_dist = vec![0.0f32; n];
        assign_full(
            &engine.device,
            &self.points_slab.slab,
            n,
            &centers_slab,
            self.k,
            self.k_pad,
            self.d_pad,
            &mut self.assign,
            &mut final_dist,
        )?;
        let sse: f64 = final_dist.iter().map(|&x| (x * x) as f64).sum();

        // Unpack assignment to original point order.
        let mut assign_orig = vec![0u32; n];
        for (new_row, &old) in self.pg.packed.new2old.iter().enumerate() {
            assign_orig[old as usize] = self.assign[new_row];
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );

        // --- Report ------------------------------------------------------
        let iterations = self.iterations;
        let mut report = self.report;
        report.iterations = iterations;
        report.wall_secs = self.wall_secs + finish_t0.elapsed().as_secs_f64();
        report.device = self.device.clone();
        report.device_wall_secs = report.device.wall_secs;
        report.device_modeled_secs = report.device.modeled_secs;
        report.quality = sse;
        report.energy_j = engine.power.accd_joules(
            report.wall_secs,
            report.filter_secs,
            1.0,
            report.device.wall_secs,
        );
        report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

        Ok(KmeansResult { centers: self.centers, assign: assign_orig, sse, iterations, report })
    }
}

/// Exact assignment of every packed point against the full center
/// slab.  `points_slab` is the pre-padded full packed-points slab
/// (built once per program, shared across same-dataset programs
/// through the slab cache).
#[allow(clippy::too_many_arguments)]
fn assign_full(
    device: &FpgaDevice,
    points_slab: &[f32],
    n: usize,
    centers_slab: &[f32],
    k: usize,
    k_pad: usize,
    d_pad: usize,
    assign: &mut [u32],
    best_dist: &mut [f32],
) -> Result<()> {
    let (idx, dist) = device.kmeans_assign_block(points_slab, n, d_pad, centers_slab, k_pad)?;
    for i in 0..n {
        let ci = idx[i] as usize;
        debug_assert!(ci < k, "assignment hit a padded center slot");
        assign[i] = ci as u32;
        best_dist[i] = dist[i].max(0.0).sqrt();
    }
    Ok(())
}

/// Assignment of an arbitrary packed-row batch against a candidate
/// center list.  Returns per-row (index into candidates, squared
/// distance).  Candidates are chunked when they exceed the largest
/// padded-center artifact, with a running min across chunks.
fn assign_rows(
    device: &FpgaDevice,
    points: &Matrix,
    rows: &[u32],
    centers: &Matrix,
    candidates: &[u32],
    k_pads: &[usize],
    d_pad: usize,
) -> Result<(Vec<i32>, Vec<f32>)> {
    let len = rows.len();
    let kc = candidates.len();
    let max_pad = *k_pads.last().expect("kmeans_k_pad empty");
    let mut best_idx = vec![0i32; len];
    let mut best_dist = vec![f32::INFINITY; len];
    let tile_m = device.runtime().manifest().tile.m;
    let rows_pad = crate::util::round_up(len.max(1), tile_m);
    let slab = FpgaDevice::pad_rows(points, rows, rows_pad, d_pad);
    let mut off = 0usize;
    while off < kc {
        let chunk = (kc - off).min(max_pad);
        let chunk_ids = &candidates[off..off + chunk];
        let k_pad = k_pads
            .iter()
            .copied()
            .find(|&p| p >= chunk)
            .unwrap_or(max_pad);
        let idx: Vec<usize> = chunk_ids.iter().map(|&c| c as usize).collect();
        let cand_mat = centers.gather_rows(&idx);
        let cslab = pad_centers(&cand_mat, k_pad, d_pad);
        let (ti, td) = device.kmeans_assign_block(&slab, len, d_pad, &cslab, k_pad)?;
        for r in 0..len {
            if td[r] < best_dist[r] {
                best_dist[r] = td[r];
                best_idx[r] = (off + ti[r] as usize) as i32;
            }
        }
        off += chunk;
    }
    Ok((best_idx, best_dist))
}

/// Pad centers to `(k_pad, d_pad)` with far-away sentinel rows so the
/// fused argmin can never select padding.
fn pad_centers(centers: &Matrix, k_pad: usize, d_pad: usize) -> Vec<f32> {
    let (k, d) = (centers.rows(), centers.cols());
    let mut slab = vec![0.0f32; k_pad * d_pad];
    for c in 0..k {
        slab[c * d_pad..c * d_pad + d].copy_from_slice(centers.row(c));
    }
    // Sentinel: 1e18 squared distance dominates any real distance while
    // staying far from f32 overflow when squared... use 1e15 coordinate.
    for c in k..k_pad {
        slab[c * d_pad] = 1.0e15;
    }
    slab
}

/// Move centers to member means; returns per-center drift distances.
fn update_centers(packed: &PackedSet, assign: &[u32], centers: &mut Matrix, k: usize) -> Vec<f32> {
    let d = centers.cols();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    for (i, &a) in assign.iter().enumerate() {
        let row = packed.points.row(i);
        let a = a as usize;
        counts[a] += 1;
        for x in 0..d {
            sums[a * d + x] += row[x] as f64;
        }
    }
    let mut drift = vec![0.0f32; k];
    for c in 0..k {
        if counts[c] == 0 {
            continue; // empty cluster keeps its position
        }
        let inv = 1.0 / counts[c] as f64;
        let row = centers.row_mut(c);
        let mut d2 = 0.0f32;
        for x in 0..d {
            let nc = (sums[c * d + x] * inv) as f32;
            let delta = nc - row[x];
            d2 += delta * delta;
            row[x] = nc;
        }
        drift[c] = d2.sqrt();
    }
    drift
}

/// Recenter the center-grouping around the moved centers; returns per
/// center-group drift (max member drift is folded into radii already).
fn recenter_center_groups(cg: &mut Grouping, centers: &Matrix) -> Vec<f32> {
    cg.recenter(centers)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empty-cluster edge case: a center that loses every member must
    /// keep its position exactly (zero drift) — the invariant the
    /// batched-equals-sequential contract relies on when clusters die
    /// mid-run (`rust/tests/serve_parity.rs` covers it end to end).
    #[test]
    fn update_centers_keeps_empty_cluster_position() {
        let pts =
            Matrix::from_vec(vec![0.0, 0.0, 0.0, 2.0, 10.0, 10.0, 10.0, 12.0], 4, 2).unwrap();
        let g = Grouping::build(&pts, 1, 2, 4096, 7).unwrap();
        let packed = PackedSet::pack(&pts, &g, 4);
        // 3 centers; center 2 never assigned.
        let mut centers = Matrix::from_vec(vec![0.0, 0.0, 10.0, 10.0, 50.0, 50.0], 3, 2).unwrap();
        let assign: Vec<u32> = packed.new2old.iter().map(|&old| u32::from(old >= 2)).collect();
        let drift = update_centers(&packed, &assign, &mut centers, 3);
        assert_eq!(drift[2], 0.0, "empty cluster must not drift");
        assert_eq!(centers.row(2).to_vec(), vec![50.0f32, 50.0]);
        // Non-empty centers moved exactly to their member means.
        assert_eq!(centers.row(0).to_vec(), vec![0.0f32, 1.0]);
        assert_eq!(centers.row(1).to_vec(), vec![10.0f32, 11.0]);
    }
}
