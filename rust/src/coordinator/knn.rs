//! AccD KNN-join: Two-landmark + Group-level GTI + dense distance tiles.
//!
//! Per paper §IV-B-a: source and target sets get *disjoint* landmark
//! sets (their group centers), so bound computation costs
//! `m + n + z_src*z_trg` instead of `m*z + n`.  The group-level filter
//! (`gti::filter::KnnFilter`) keeps, per source group, only target
//! groups that can hold a Top-K neighbor of some member; surviving
//! rectangles are densely executed on the device and merged into
//! per-point bounded heaps on the CPU.
//!
//! The inter-group layout schedule (Fig. 4b) orders source groups by
//! candidate-set similarity so consecutive dispatches reuse target
//! slabs; the measured reuse ratio lands in the run report.
//!
//! Execution is split into three stages so the batched serving runtime
//! ([`crate::serve`]) can drive them across *many* queries at once:
//!
//! 1. [`plan_metric`] — CPU filter stage: groupings in, a [`KnnPlan`]
//!    of merged dispatch batches out.  Packed target slabs are obtained
//!    through a [`SlabCache`], so queries in one serving cohort (and,
//!    with the serving layer's persistent per-shard caches, across
//!    flushes) share slabs for identical candidate sets.
//! 2. job building + device execution — [`build_job`] per batch,
//!    streamed through the bounded [`super::pipeline`] (solo runs use
//!    their own queue; the serving layer streams all queries' batches
//!    through one tagged queue).
//! 3. [`merge_results`] — per-point bounded-heap merge, identical
//!    regardless of which pipeline carried the tiles.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::fpga::device::DeviceStats;
use crate::fpga::TileJob;
use crate::gti::{FilterStats, KnnFilter, Metric};
use crate::layout::{self, LayoutStats, PackedGrouping};
use crate::metrics::RunReport;
use crate::runtime::TileInfo;
use crate::util::topk::TopK;
use crate::{Error, Result};

use super::engine::Engine;
use super::pipeline;
use super::program::{self, CohortProgram, StepCtx, StepOutcome};

/// Result of a KNN-join: for each source point, its K nearest target
/// points (ascending by distance).
#[derive(Debug, Clone)]
pub struct KnnResult {
    /// `neighbors[i]` = Vec of (distance^2, target id), len K.
    pub neighbors: Vec<Vec<(f32, u32)>>,
    pub k: usize,
    pub report: RunReport,
}

/// A packed, padded target slab shared by every dispatch batch (of any
/// query in a serving cohort) with the same candidate target-group set.
#[derive(Debug, Clone)]
pub(crate) struct SharedSlab {
    /// Row-major `(round_up(rows, tile.n), d_pad)` padded slab.
    pub slab: Arc<Vec<f32>>,
    /// Original target ids of the slab's valid rows.
    pub col_ids: Arc<Vec<u32>>,
    /// Valid (unpadded) row count.
    pub rows: usize,
}

/// What family of packed slab a [`SlabScope`] identifies — the
/// namespace that keeps different algorithms' cache entries from ever
/// aliasing, even for one dataset under identical grouping parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SlabKind {
    /// Packed candidate-target-group slab of a KNN query.
    KnnTarget,
    /// Full padded packed-points slab of a K-means dataset (the
    /// assignment tile's row input, shared by every same-dataset
    /// K-means program in a serving cohort).
    KmeansPoints,
}

/// Everything a packed slab's bytes are determined by, besides the
/// candidate group set: the slab family, the grouping's identity
/// (content fingerprint pair + build parameters — the same 128-bit
/// guarantee [`crate::serve::GroupingCache`] relies on) and the tile
/// geometry the slab was padded for.  Two equal scopes imply
/// bit-identical groupings, so a slab cached under one scope can be
/// served to any later query in the same scope without perturbing
/// results.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SlabScope {
    pub(crate) kind: SlabKind,
    pub(crate) fingerprint: u64,
    pub(crate) probe: u64,
    pub(crate) groups: usize,
    pub(crate) iters: usize,
    pub(crate) sample: usize,
    pub(crate) seed: u64,
    pub(crate) metric: Metric,
    pub(crate) d_pad: usize,
    pub(crate) tile_n: usize,
}

impl SlabScope {
    /// Scope for a throwaway per-run cache (the solo engine path): the
    /// cache never outlives one target grouping, so its identity
    /// fields are irrelevant — only key consistency within the run
    /// matters.
    pub(crate) fn transient(metric: Metric) -> Self {
        Self {
            kind: SlabKind::KnnTarget,
            fingerprint: 0,
            probe: 0,
            groups: 0,
            iters: 0,
            sample: 0,
            seed: 0,
            metric,
            d_pad: 0,
            tile_n: 0,
        }
    }
}

struct SlabEntry {
    slab: SharedSlab,
    bytes: usize,
    last_used: u64,
}

/// Byte-budgeted LRU cache of packed target slabs, keyed by
/// ([`SlabScope`], candidate target-group set).
///
/// Grown out of the per-flush cohort memo (`TrgSlabCache`): within one
/// query candidate sets are unique (the Fig. 4b schedule merges
/// duplicates), so every *hit* is cross-query — or, now that the
/// serving layer keeps one instance per engine shard across flushes,
/// cross-*flush* — sharing.  Hot cohorts' slabs stay resident until
/// LRU-evicted over the byte budget, trading memory for the repeated
/// packing cost (the ROADMAP "slab cache persistence" follow-up).
pub struct SlabCache {
    /// Max resident bytes (0 = unbounded).
    budget: usize,
    /// Disabled: every lookup builds fresh and nothing is retained
    /// (the serving layer's `slab_cache_bytes == 0` setting).  Results
    /// are unchanged — cached slabs are bit-identical to fresh builds
    /// — only the reuse disappears.
    disabled: bool,
    /// Nested so the hot hit path borrows `cand` (`Vec<u32>: Borrow<[u32]>`)
    /// instead of allocating an owned key per lookup.
    map: HashMap<SlabScope, HashMap<Vec<u32>, SlabEntry>>,
    bytes: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Total bytes of slabs *built* on misses — the modeled DMA upload
    /// traffic of this cache's device (a hit is device-resident, a
    /// miss must cross the link).  Accumulates even when disabled:
    /// disabled means nothing is retained, not that uploads are free.
    pub miss_bytes: u64,
}

impl SlabCache {
    /// Unbounded cache — the per-run scratch the solo path uses.
    pub fn unbounded() -> Self {
        Self::with_budget(0)
    }

    /// Cache bounded to `budget` resident bytes (0 = unbounded).
    pub fn with_budget(budget: usize) -> Self {
        Self {
            budget,
            disabled: false,
            map: HashMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            miss_bytes: 0,
        }
    }

    /// A cache that never retains anything: every fetch is a counted
    /// miss that builds fresh.
    pub fn disabled() -> Self {
        Self { disabled: true, ..Self::with_budget(0) }
    }

    /// Resident slab count (across all scopes).
    pub fn len(&self) -> usize {
        self.map.values().map(|inner| inner.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Bytes currently resident (slab payloads + column-id tables).
    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    /// Resident bytes belonging to dataset `fingerprint` — the warmth
    /// signal of the movement-aware planner: a work unit whose
    /// dataset's slabs are resident here would skip (up to) this many
    /// bytes of modeled DMA upload by running on this cache's shard.
    /// All `SlabScope`s key `fingerprint` to the *content* fingerprint
    /// of the slab's source dataset (KNN target / K-means points), so
    /// one u64 addresses every slab family at once.
    pub fn warm_bytes_for(&self, fingerprint: u64) -> u64 {
        self.map
            .iter()
            .filter(|(scope, _)| scope.fingerprint == fingerprint)
            .flat_map(|(_, inner)| inner.values())
            .map(|e| e.bytes as u64)
            .sum()
    }

    /// Fetch the slab for `(scope, cand)`, building it on a miss.
    /// Returns the slab and whether it was served from cache.  A hit
    /// allocates nothing; keys are cloned only on insert.
    pub(crate) fn get_or_build(
        &mut self,
        scope: &SlabScope,
        cand: &[u32],
        build: impl FnOnce() -> SharedSlab,
    ) -> (SharedSlab, bool) {
        if self.disabled {
            self.misses += 1;
            let slab = build();
            self.miss_bytes += (slab.slab.len() * 4 + slab.col_ids.len() * 4) as u64;
            return (slab, false);
        }
        self.tick += 1;
        if let Some(entry) = self.map.get_mut(scope).and_then(|inner| inner.get_mut(cand)) {
            entry.last_used = self.tick;
            self.hits += 1;
            return (entry.slab.clone(), true);
        }
        self.misses += 1;
        let slab = build();
        let bytes = slab.slab.len() * 4 + slab.col_ids.len() * 4;
        self.miss_bytes += bytes as u64;
        self.map
            .entry(scope.clone())
            .or_default()
            .insert(cand.to_vec(), SlabEntry { slab: slab.clone(), bytes, last_used: self.tick });
        self.bytes += bytes;
        self.evict_to_budget();
        (slab, false)
    }

    /// Evict least-recently-used entries until under budget, in one
    /// pass: collect every resident entry's age, sort oldest-first,
    /// and remove until the budget holds — O(resident log resident)
    /// per eviction *event*, not per evicted entry.  Evicting never
    /// invalidates outstanding slabs (they are `Arc`-shared); it only
    /// forgets them for future reuse.
    fn evict_to_budget(&mut self) {
        if self.budget == 0 || self.bytes <= self.budget {
            return;
        }
        let mut ages: Vec<(u64, usize, SlabScope, Vec<u32>)> = self
            .map
            .iter()
            .flat_map(|(scope, inner)| {
                inner
                    .iter()
                    .map(move |(cand, e)| (e.last_used, e.bytes, scope.clone(), cand.clone()))
            })
            .collect();
        ages.sort_unstable_by_key(|&(last_used, ..)| last_used);
        for (_, bytes, scope, cand) in ages {
            if self.bytes <= self.budget {
                break;
            }
            if let Some(inner) = self.map.get_mut(&scope) {
                if inner.remove(&cand).is_some() {
                    self.bytes -= bytes;
                    self.evictions += 1;
                }
                if inner.is_empty() {
                    self.map.remove(&scope);
                }
            }
        }
    }
}

/// One merged dispatch batch: a run of source groups sharing one
/// candidate target set.
#[derive(Debug, Clone)]
pub(crate) struct KnnBatch {
    /// Source groups concatenated into the rectangle's rows.
    pub groups: Vec<usize>,
    /// Original source ids of the rectangle's rows.
    pub row_ids: Vec<u32>,
    /// The (possibly shared) packed target slab.
    pub trg: SharedSlab,
    /// True when `trg` was served from the slab cache, i.e. an earlier
    /// query (or, under the serving layer's persistent cache, an
    /// earlier flush) already built this slab.
    pub shared: bool,
}

/// The CPU filter stage's output: everything needed to execute and
/// merge one KNN query, in deterministic dispatch order.
#[derive(Debug, Clone)]
pub(crate) struct KnnPlan {
    pub k: usize,
    pub n_src: usize,
    pub d: usize,
    pub d_pad: usize,
    pub metric: Metric,
    pub batches: Vec<KnnBatch>,
    pub filter_stats: FilterStats,
    pub layout_stats: LayoutStats,
}

pub(super) fn run(
    engine: &mut Engine,
    src: &Dataset,
    trg: &Dataset,
    k: usize,
) -> Result<KnnResult> {
    run_metric(engine, src, trg, k, Metric::L2)
}

/// Validate a KNN-join request (shared by solo and batched paths).
pub(crate) fn validate(src: &Dataset, trg: &Dataset, k: usize) -> Result<()> {
    if k == 0 || k > trg.n() {
        return Err(Error::Data(format!("knn: k={k} out of range for target n={}", trg.n())));
    }
    if src.d() != trg.d() {
        return Err(Error::Shape(format!("knn: dim mismatch {} vs {}", src.d(), trg.d())));
    }
    Ok(())
}

/// Metric-aware KNN-join (paper Table I `mtr`): neighbor values are in
/// *device space* — squared distances for L2, plain sums for L1 — so
/// the ordering is metric-correct either way.  Drives the one-shot
/// [`KnnProgram`] to completion — plan / execute / merge as a
/// single-step [`CohortProgram`].
pub(super) fn run_metric(
    engine: &mut Engine,
    src: &Dataset,
    trg: &Dataset,
    k: usize,
    metric: Metric,
) -> Result<KnnResult> {
    validate(src, trg, k)?;
    engine.device.reset_stats();
    let program = plan_program(&*engine, src, trg, k, metric)?;
    let mut ctx = StepCtx { engine: &*engine };
    program::run_to_completion(program, &mut ctx)
}

/// One solo KNN query as a stepwise program: `plan_program` is the CPU
/// filter stage (groupings + [`plan_metric`]), the single `step` is
/// the device stage (bounded pipeline over the dispatch batches), and
/// `finish` is the Top-K merge + report.
pub(crate) struct KnnProgram {
    plan: KnnPlan,
    src_pg: Arc<PackedGrouping>,
    tile: TileInfo,
    results: Vec<(usize, crate::fpga::TileResult)>,
    report: RunReport,
    /// This program's own device counters (snapshot diffs — safe under
    /// interleaved execution).
    device: DeviceStats,
    t0: Instant,
    executed: bool,
}

/// CPU filter stage of one solo KNN query (serving cohorts build their
/// shared plans in `serve::exec` instead, where the per-shard caches
/// live).
pub(crate) fn plan_program(
    engine: &Engine,
    src: &Dataset,
    trg: &Dataset,
    k: usize,
    metric: Metric,
) -> Result<KnnProgram> {
    validate(src, trg, k)?;
    let t0 = Instant::now();
    let mut report = RunReport::new("knn_join", &src.name, "accd");
    let cfg = engine.config.clone();
    let tile = engine.runtime.manifest().tile.clone();

    let filt0 = Instant::now();
    let src_pg = PackedGrouping::build(
        &src.points,
        engine.src_groups(src.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed,
        metric,
        8,
    )?;
    let trg_pg = PackedGrouping::build(
        &trg.points,
        engine.trg_groups(trg.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed ^ 0x7267, // "tg"
        metric,
        8,
    )?;
    let mut slab_cache = SlabCache::unbounded();
    let scope = SlabScope::transient(metric);
    let plan = plan_metric(&tile, src, k, metric, &src_pg, &trg_pg, &scope, &mut slab_cache)?;
    report.filter.merge(&plan.filter_stats);
    report.layout = plan.layout_stats.clone();
    report.filter_secs += filt0.elapsed().as_secs_f64();

    Ok(KnnProgram {
        plan,
        src_pg: Arc::new(src_pg),
        tile,
        results: Vec::new(),
        report,
        device: DeviceStats::default(),
        t0,
        executed: false,
    })
}

impl CohortProgram for KnnProgram {
    type Output = KnnResult;

    /// The device stage: every surviving dispatch batch through the
    /// bounded pipeline.  One-shot — converges on the first call.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.executed {
            return Ok(StepOutcome::Converged);
        }
        self.executed = true;
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        {
            let plan_ref = &self.plan;
            let src_pg_ref = &self.src_pg;
            let tile = &self.tile;
            let results = &mut self.results;
            pipeline::run(
                4,
                |i| -> Option<(usize, TileJob)> {
                    let bi = i as usize;
                    let batch = plan_ref.batches.get(bi)?;
                    Some((bi, build_job(batch, src_pg_ref, plan_ref, tile)))
                },
                |(bi, job): (usize, TileJob)| {
                    if job_err.is_some() {
                        return;
                    }
                    if job.src_rows == 0 || job.trg_rows == 0 {
                        return;
                    }
                    match device.distance_block(&job) {
                        Ok(res) => results.push((bi, res)),
                        Err(e) => job_err = Some(e),
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        Ok(StepOutcome::Converged)
    }

    /// Merge stage (CPU): per-point Top-K heaps + report assembly.
    fn finish(mut self, ctx: &mut StepCtx<'_>) -> Result<KnnResult> {
        let engine = ctx.engine;
        let results = std::mem::take(&mut self.results);
        let neighbors = merge_results(&self.plan, results.into_iter());

        let mut report = self.report;
        report.wall_secs = self.t0.elapsed().as_secs_f64();
        report.device = self.device.clone();
        report.device_wall_secs = report.device.wall_secs;
        report.device_modeled_secs = report.device.modeled_secs;
        report.iterations = 1;
        report.quality = quality_of(&neighbors);
        report.energy_j = engine.power.accd_joules(
            report.wall_secs,
            report.filter_secs,
            1.0,
            report.device.wall_secs,
        );
        report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

        Ok(KnnResult { neighbors, k: self.plan.k, report })
    }
}

/// CPU filter stage: GTI candidate selection + Fig. 4b schedule +
/// dispatch merging, with target slabs resolved through the (possibly
/// cohort-shared, possibly flush-persistent) [`SlabCache`] under the
/// caller's [`SlabScope`].  Deterministic in all inputs: a cached slab
/// is bit-identical to the one `build_trg_slab` would produce, so
/// reuse can never change results.
///
/// Memory note: target slabs are materialized eagerly here (one per
/// *distinct* candidate set, shared by every batch and cohort query
/// that needs it) and live at least until the query's merge completes
/// — longer when the serving layer's persistent cache keeps them
/// resident for future flushes, bounded by its byte budget.
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_metric(
    tile: &TileInfo,
    src: &Dataset,
    k: usize,
    metric: Metric,
    src_pg: &PackedGrouping,
    trg_pg: &PackedGrouping,
    scope: &SlabScope,
    slab_cache: &mut SlabCache,
) -> Result<KnnPlan> {
    let d = src.d();
    let d_pad = tile.pad_d(d)?;
    let mut filter = KnnFilter::new();
    let (candidates, _bounds) =
        filter.candidates_metric(&src_pg.grouping, &trg_pg.grouping, k, metric);

    // Inter-group schedule (Fig. 4b) + reuse measurement.
    let order = layout::schedule_source_groups(&candidates);
    let layout_stats = layout::measure_reuse(&order, &candidates);
    // Dispatch batching (perf pass §Perf): adjacent source groups in
    // the schedule with *identical* candidate sets share one device
    // job, so their rows fill large source tiles instead of one
    // sub-64-row job per group.
    let mut merged: Vec<(Vec<usize>, Vec<u32>)> = Vec::new();
    for &g in &order {
        let g = g as usize;
        match merged.last_mut() {
            Some((groups, cand)) if *cand == candidates[g] => groups.push(g),
            _ => merged.push((vec![g], candidates[g].clone())),
        }
    }

    let mut batches = Vec::with_capacity(merged.len());
    for (groups, cand) in merged {
        let row_ids: Vec<u32> = groups
            .iter()
            .flat_map(|&g| {
                let (s, l) = (src_pg.packed.group_start(g), src_pg.packed.group_len(g));
                src_pg.packed.new2old[s..s + l].iter().copied()
            })
            .collect();
        let (trg, shared) = slab_cache
            .get_or_build(scope, &cand, || build_trg_slab(trg_pg, &cand, d, d_pad, tile.n));
        batches.push(KnnBatch { groups, row_ids, trg, shared });
    }

    Ok(KnnPlan {
        k,
        n_src: src.n(),
        d,
        d_pad,
        metric,
        batches,
        filter_stats: filter.stats,
        layout_stats,
    })
}

/// Pack the candidate target groups into one padded slab.  Shared with
/// the range-join planner (`super::rangejoin`), which batches its
/// straddling rectangles through the same slab cache.
pub(crate) fn build_trg_slab(
    trg_pg: &PackedGrouping,
    cand: &[u32],
    d: usize,
    d_pad: usize,
    tile_n: usize,
) -> SharedSlab {
    use crate::util::round_up;
    let total: usize = cand.iter().map(|&b| trg_pg.packed.group_len(b as usize)).sum();
    let cols_pad = round_up(total.max(1), tile_n);
    let mut slab = vec![0.0f32; cols_pad * d_pad];
    let mut col_ids = Vec::with_capacity(total);
    let mut row = 0usize;
    for &b in cand {
        let b = b as usize;
        let rows = trg_pg.packed.group_len(b);
        let packed_rows = trg_pg.packed.group_rows(b);
        for r in 0..rows {
            slab[(row + r) * d_pad..(row + r) * d_pad + d]
                .copy_from_slice(&packed_rows[r * d..(r + 1) * d]);
        }
        let (s, l) = (trg_pg.packed.group_start(b), trg_pg.packed.group_len(b));
        col_ids.extend_from_slice(&trg_pg.packed.new2old[s..s + l]);
        row += rows;
    }
    SharedSlab { slab: Arc::new(slab), col_ids: Arc::new(col_ids), rows: total }
}

/// Build the dense rectangle job for one dispatch batch (source slab
/// copied fresh, target slab shared).
pub(crate) fn build_job(
    batch: &KnnBatch,
    src_pg: &PackedGrouping,
    plan: &KnnPlan,
    tile: &TileInfo,
) -> TileJob {
    use crate::util::round_up;
    let (d, d_pad) = (plan.d, plan.d_pad);
    let len: usize = batch.groups.iter().map(|&g| src_pg.packed.group_len(g)).sum();
    let rows_pad = round_up(len.max(1), tile.m);
    let mut src_slab = vec![0.0f32; rows_pad * d_pad];
    let mut row = 0usize;
    for &g in &batch.groups {
        let rows = src_pg.packed.group_len(g);
        let slab = src_pg.packed.group_rows(g);
        for r in 0..rows {
            src_slab[(row + r) * d_pad..(row + r) * d_pad + d]
                .copy_from_slice(&slab[r * d..(r + 1) * d]);
        }
        row += rows;
    }
    TileJob {
        src: src_slab,
        src_rows: len,
        trg: batch.trg.slab.clone(),
        trg_rows: batch.trg.rows,
        d,
        d_padded: d_pad,
        metric: plan.metric.device_name(),
    }
}

/// Merge device results into per-point Top-K heaps.  `results` must
/// arrive in production (batch) order per query — both the solo
/// pipeline and the serving layer's tagged pipeline guarantee this —
/// so the merge is bit-identical no matter which queue carried the
/// tiles.
pub(crate) fn merge_results(
    plan: &KnnPlan,
    results: impl Iterator<Item = (usize, crate::fpga::TileResult)>,
) -> Vec<Vec<(f32, u32)>> {
    let mut heaps: Vec<TopK> = (0..plan.n_src).map(|_| TopK::new(plan.k)).collect();
    for (bi, res) in results {
        let batch = &plan.batches[bi];
        for (r, &orig_src) in batch.row_ids.iter().enumerate() {
            let heap = &mut heaps[orig_src as usize];
            let row = &res.dist[r * res.trg_rows..(r + 1) * res.trg_rows];
            for (c, &dist) in row.iter().enumerate() {
                heap.push(dist, batch.trg.col_ids[c]);
            }
        }
    }
    heaps.into_iter().map(|h| h.into_sorted()).collect()
}

/// Headline quality number: mean K-th neighbor distance (stable across
/// implementations).
pub(crate) fn quality_of(neighbors: &[Vec<(f32, u32)>]) -> f64 {
    neighbors
        .iter()
        .filter_map(|nb| nb.last().map(|&(d2, _)| d2 as f64))
        .sum::<f64>()
        / neighbors.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(rows: usize) -> SharedSlab {
        SharedSlab {
            slab: Arc::new(vec![0.0; rows * 8]),
            col_ids: Arc::new((0..rows as u32).collect()),
            rows,
        }
    }

    fn scope_with_seed(seed: u64) -> SlabScope {
        SlabScope { seed, ..SlabScope::transient(Metric::L2) }
    }

    #[test]
    fn slab_cache_hits_same_scope_and_cand() {
        let mut cache = SlabCache::unbounded();
        let scope = scope_with_seed(1);
        let (a, hit_a) = cache.get_or_build(&scope, &[1, 2], || slab(4));
        let (b, hit_b) = cache.get_or_build(&scope, &[1, 2], || slab(4));
        assert!(!hit_a);
        assert!(hit_b);
        assert!(Arc::ptr_eq(&a.slab, &b.slab));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), 4 * 8 * 4 + 4 * 4);
    }

    #[test]
    fn slab_cache_scopes_do_not_alias() {
        // Same candidate set under different scopes (e.g. two target
        // datasets, or two seeds) must not share slabs.
        let mut cache = SlabCache::unbounded();
        let (_, _) = cache.get_or_build(&scope_with_seed(1), &[1, 2], || slab(4));
        let (_, hit) = cache.get_or_build(&scope_with_seed(2), &[1, 2], || slab(4));
        assert!(!hit);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn slab_cache_evicts_lru_over_byte_budget() {
        // Each slab: 4 rows * 8 f32 * 4B + 4 ids * 4B = 144 bytes.
        let mut cache = SlabCache::with_budget(300);
        let scope = scope_with_seed(1);
        cache.get_or_build(&scope, &[1], || slab(4));
        cache.get_or_build(&scope, &[2], || slab(4));
        // Touch [1] so [2] becomes the LRU victim.
        cache.get_or_build(&scope, &[1], || slab(4));
        cache.get_or_build(&scope, &[3], || slab(4));
        assert_eq!(cache.evictions, 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.resident_bytes() <= 300);
        let (_, hit1) = cache.get_or_build(&scope, &[1], || slab(4));
        assert!(hit1, "recently-used entry must survive eviction");
        // [2] was evicted: rebuilding it is a miss.
        let misses = cache.misses;
        cache.get_or_build(&scope, &[2], || slab(4));
        assert_eq!(cache.misses, misses + 1);
    }

    #[test]
    fn slab_cache_zero_budget_is_unbounded() {
        let mut cache = SlabCache::with_budget(0);
        for i in 0..16u32 {
            cache.get_or_build(&scope_with_seed(1), &[i], || slab(64));
        }
        assert_eq!(cache.len(), 16);
        assert_eq!(cache.evictions, 0);
    }
}
