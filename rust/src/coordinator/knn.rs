//! AccD KNN-join: Two-landmark + Group-level GTI + dense distance tiles.
//!
//! Per paper §IV-B-a: source and target sets get *disjoint* landmark
//! sets (their group centers), so bound computation costs
//! `m + n + z_src*z_trg` instead of `m*z + n`.  The group-level filter
//! (`gti::filter::KnnFilter`) keeps, per source group, only target
//! groups that can hold a Top-K neighbor of some member; surviving
//! rectangles are densely executed on the device and merged into
//! per-point bounded heaps on the CPU.
//!
//! The inter-group layout schedule (Fig. 4b) orders source groups by
//! candidate-set similarity so consecutive dispatches reuse target
//! slabs; the measured reuse ratio lands in the run report.

use crate::data::Dataset;
use crate::fpga::TileJob;
use crate::gti::{Grouping, KnnFilter};
use crate::layout::{self, PackedSet};
use crate::metrics::RunReport;
use crate::util::topk::TopK;
use crate::{Error, Result};

use super::engine::Engine;
use super::pipeline;

/// Result of a KNN-join: for each source point, its K nearest target
/// points (ascending by distance).
#[derive(Debug, Clone)]
pub struct KnnResult {
    /// `neighbors[i]` = Vec of (distance^2, target id), len K.
    pub neighbors: Vec<Vec<(f32, u32)>>,
    pub k: usize,
    pub report: RunReport,
}

pub(super) fn run(engine: &mut Engine, src: &Dataset, trg: &Dataset, k: usize) -> Result<KnnResult> {
    run_metric(engine, src, trg, k, crate::gti::Metric::L2)
}

/// Metric-aware KNN-join (paper Table I `mtr`): neighbor values are in
/// *device space* — squared distances for L2, plain sums for L1 — so
/// the ordering is metric-correct either way.
pub(super) fn run_metric(
    engine: &mut Engine,
    src: &Dataset,
    trg: &Dataset,
    k: usize,
    metric: crate::gti::Metric,
) -> Result<KnnResult> {
    if k == 0 || k > trg.n() {
        return Err(Error::Data(format!("knn: k={k} out of range for target n={}", trg.n())));
    }
    if src.d() != trg.d() {
        return Err(Error::Shape(format!("knn: dim mismatch {} vs {}", src.d(), trg.d())));
    }
    let t0 = std::time::Instant::now();
    engine.device.reset_stats();
    let mut report = RunReport::new("knn_join", &src.name, "accd");
    let cfg = engine.config.clone();
    let tile = engine.runtime.manifest().tile.clone();
    let d = src.d();
    let d_pad = tile.pad_d(d)?;

    // --- Filter stage (CPU) ---------------------------------------------
    let filt0 = std::time::Instant::now();
    let src_grouping = Grouping::build_with_metric(
        &src.points,
        engine.src_groups(src.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed,
        metric,
    )?;
    let trg_grouping = Grouping::build_with_metric(
        &trg.points,
        engine.trg_groups(trg.n()),
        cfg.gti.grouping_iters,
        cfg.gti.grouping_sample,
        cfg.seed ^ 0x7267, // "tg"
        metric,
    )?;
    let src_packed = PackedSet::pack(&src.points, &src_grouping, 8);
    let trg_packed = PackedSet::pack(&trg.points, &trg_grouping, 8);

    let mut filter = KnnFilter::new();
    let (candidates, _bounds) =
        filter.candidates_metric(&src_grouping, &trg_grouping, k, metric);
    report.filter.merge(&filter.stats);

    // Inter-group schedule (Fig. 4b) + reuse measurement.
    let order = layout::schedule_source_groups(&candidates);
    report.layout = layout::measure_reuse(&order, &candidates);
    // Dispatch batching (perf pass §Perf): adjacent source groups in
    // the schedule with *identical* candidate sets share one device
    // job, so their rows fill large source tiles instead of one
    // sub-64-row job per group.
    let mut merged: Vec<(Vec<usize>, Vec<u32>)> = Vec::new();
    for &g in &order {
        let g = g as usize;
        match merged.last_mut() {
            Some((groups, cand)) if *cand == candidates[g] => groups.push(g),
            _ => merged.push((vec![g], candidates[g].clone())),
        }
    }
    report.filter_secs += filt0.elapsed().as_secs_f64();

    // --- Device stage -----------------------------------------------------
    // Per merged batch: dense rectangle (concatenated source groups x
    // concatenated candidate target slabs); CPU merges rows into
    // per-point bounded heaps.
    let mut heaps: Vec<TopK> = (0..src.n()).map(|_| TopK::new(k)).collect();
    let device = &engine.device;
    let mut job_err: Option<Error> = None;
    struct BatchJob {
        job: TileJob,
        /// Original source ids of the rectangle's rows.
        row_ids: Vec<u32>,
        /// Original target ids of the rectangle's columns.
        col_ids: Vec<u32>,
    }
    let merged_ref = &merged;
    let mut results: Vec<(Vec<u32>, Vec<u32>, crate::fpga::TileResult)> = Vec::new();
    {
        pipeline::run(
            4,
            |i| -> Option<BatchJob> {
                let (groups, cand) = merged_ref.get(i as usize)?;
                let row_ids: Vec<u32> = groups
                    .iter()
                    .flat_map(|&g| {
                        let (s, l) = (src_packed.group_start(g), src_packed.group_len(g));
                        src_packed.new2old[s..s + l].iter().copied()
                    })
                    .collect();
                Some(BatchJob {
                    job: build_job(&src_packed, groups, &trg_packed, cand, d, d_pad, &tile, metric),
                    row_ids,
                    col_ids: cand
                        .iter()
                        .flat_map(|&b| {
                            let (s, l) = (
                                trg_packed.group_start(b as usize),
                                trg_packed.group_len(b as usize),
                            );
                            trg_packed.new2old[s..s + l].iter().copied()
                        })
                        .collect(),
                })
            },
            |bj: BatchJob| {
                if job_err.is_some() {
                    return;
                }
                if bj.job.src_rows == 0 || bj.job.trg_rows == 0 {
                    return;
                }
                match device.distance_block(&bj.job) {
                    Ok(res) => results.push((bj.row_ids, bj.col_ids, res)),
                    Err(e) => job_err = Some(e),
                }
            },
        );
    }
    if let Some(e) = job_err {
        return Err(e);
    }

    // --- Merge stage (CPU) -------------------------------------------------
    for (row_ids, col_ids, res) in results {
        for (r, &orig_src) in row_ids.iter().enumerate() {
            let heap = &mut heaps[orig_src as usize];
            let row = &res.dist[r * res.trg_rows..(r + 1) * res.trg_rows];
            for (c, &dist) in row.iter().enumerate() {
                heap.push(dist, col_ids[c]);
            }
        }
    }

    let neighbors: Vec<Vec<(f32, u32)>> =
        heaps.into_iter().map(|h| h.into_sorted()).collect();

    report.wall_secs = t0.elapsed().as_secs_f64();
    report.device = engine.device.stats();
    report.device_wall_secs = report.device.wall_secs;
    report.device_modeled_secs = report.device.modeled_secs;
    report.iterations = 1;
    // Quality: mean K-th neighbor distance (stable across impls).
    report.quality = neighbors
        .iter()
        .filter_map(|nb| nb.last().map(|&(d2, _)| d2 as f64))
        .sum::<f64>()
        / neighbors.len().max(1) as f64;
    report.energy_j = engine.power.accd_joules(
        report.wall_secs,
        report.filter_secs,
        1.0,
        report.device.wall_secs,
    );
    report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

    Ok(KnnResult { neighbors, k, report })
}

/// Build the dense rectangle job for a batch of source groups sharing
/// one candidate target set.
#[allow(clippy::too_many_arguments)]
fn build_job(
    src_packed: &PackedSet,
    groups: &[usize],
    trg_packed: &PackedSet,
    cand: &[u32],
    d: usize,
    d_pad: usize,
    tile: &crate::runtime::TileInfo,
    metric: crate::gti::Metric,
) -> TileJob {
    use crate::util::round_up;
    // Concatenate the source groups' packed slabs.
    let len: usize = groups.iter().map(|&g| src_packed.group_len(g)).sum();
    let rows_pad = round_up(len.max(1), tile.m);
    let mut src_slab = vec![0.0f32; rows_pad * d_pad];
    let mut row = 0usize;
    for &g in groups {
        let rows = src_packed.group_len(g);
        let slab = src_packed.group_rows(g);
        for r in 0..rows {
            src_slab[(row + r) * d_pad..(row + r) * d_pad + d]
                .copy_from_slice(&slab[r * d..(r + 1) * d]);
        }
        row += rows;
    }
    // Concatenate candidate target groups (already contiguous each).
    let total: usize = cand.iter().map(|&b| trg_packed.group_len(b as usize)).sum();
    let cols_pad = round_up(total.max(1), tile.n);
    let mut trg_slab = vec![0.0f32; cols_pad * d_pad];
    let mut row = 0usize;
    for &b in cand {
        let b = b as usize;
        let rows = trg_packed.group_len(b);
        let slab = trg_packed.group_rows(b);
        for r in 0..rows {
            trg_slab[(row + r) * d_pad..(row + r) * d_pad + d]
                .copy_from_slice(&slab[r * d..(r + 1) * d]);
        }
        row += rows;
    }
    TileJob {
        src: src_slab,
        src_rows: len,
        trg: trg_slab,
        trg_rows: total,
        d,
        d_padded: d_pad,
        metric: metric.device_name(),
    }
}
