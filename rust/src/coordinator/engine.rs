//! The public engine: owns runtime + device + config.

use std::sync::Arc;

use crate::config::AccdConfig;
use crate::data::Dataset;
use crate::fpga::{FpgaDevice, PowerModel};
use crate::runtime::Runtime;
use crate::Result;

use super::{
    kmeans, knn, nbody, rangejoin, KmeansResult, KnnResult, NbodyResult, RangeJoinResult,
};

/// AccD execution engine (one per process).
///
/// Construction loads the artifact manifest and creates the PJRT
/// client; executables compile lazily per algorithm.  All entry points
/// are `&mut self` because runs accumulate device statistics that each
/// call resets.
pub struct Engine {
    pub config: AccdConfig,
    pub runtime: Arc<Runtime>,
    pub device: FpgaDevice,
    pub power: PowerModel,
}

impl Engine {
    pub fn new(config: AccdConfig) -> Result<Self> {
        config.validate()?;
        let runtime = Arc::new(Runtime::load_or_builtin(&config.artifact_dir)?);
        Self::with_runtime(config, runtime)
    }

    /// Build an engine over an existing runtime (shared across engines
    /// by the serving layer so the kernel cache is paid for once).
    /// Enforces the same config validation as [`Engine::new`].
    pub fn with_runtime(config: AccdConfig, runtime: Arc<Runtime>) -> Result<Self> {
        config.validate()?;
        let device = FpgaDevice::new(runtime.clone(), config.hw.clone());
        Ok(Self { config, runtime, device, power: PowerModel::default() })
    }

    /// K-means clustering with Trace-based + Group-level GTI.
    pub fn kmeans(&mut self, ds: &Dataset, k: usize, max_iters: usize) -> Result<KmeansResult> {
        kmeans::run(self, ds, k, max_iters)
    }

    /// KNN-join with Two-landmark + Group-level GTI (Euclidean).
    pub fn knn_join(&mut self, src: &Dataset, trg: &Dataset, k: usize) -> Result<KnnResult> {
        knn::run(self, src, trg, k)
    }

    /// Metric-aware KNN-join (paper Table I `mtr`): neighbor values are
    /// squared distances for [`crate::gti::Metric::L2`] and plain sums
    /// for [`crate::gti::Metric::L1`].
    pub fn knn_join_metric(
        &mut self,
        src: &Dataset,
        trg: &Dataset,
        k: usize,
        metric: crate::gti::Metric,
    ) -> Result<KnnResult> {
        knn::run_metric(self, src, trg, k, metric)
    }

    /// Range join (radius query) with Two-landmark + Group-level GTI
    /// (Euclidean): for each source point, every target point within
    /// `threshold` of it.
    pub fn range_join(
        &mut self,
        src: &Dataset,
        trg: &Dataset,
        threshold: f32,
    ) -> Result<RangeJoinResult> {
        rangejoin::run(self, src, trg, threshold)
    }

    /// Metric-aware range join: neighbor values are in device space —
    /// squared distances for [`crate::gti::Metric::L2`] and plain sums
    /// for [`crate::gti::Metric::L1`] — while `threshold` stays in
    /// metric units.
    pub fn range_join_metric(
        &mut self,
        src: &Dataset,
        trg: &Dataset,
        threshold: f32,
        metric: crate::gti::Metric,
    ) -> Result<RangeJoinResult> {
        rangejoin::run_metric(self, src, trg, threshold, metric)
    }

    /// N-body simulation with the full hybrid GTI.
    pub fn nbody(
        &mut self,
        ds: &Dataset,
        masses: &[f32],
        steps: usize,
        dt: f32,
        radius: f32,
    ) -> Result<NbodyResult> {
        nbody::run(self, ds, masses, steps, dt, radius)
    }

    /// Effective source-group count for a dataset (config override or
    /// auto heuristic).
    pub fn src_groups(&self, n: usize) -> usize {
        if self.config.gti.src_groups > 0 {
            self.config.gti.src_groups.min(n)
        } else {
            crate::gti::Grouping::auto_groups(n)
        }
    }

    /// Effective target-group count.
    pub fn trg_groups(&self, n: usize) -> usize {
        if self.config.gti.trg_groups > 0 {
            self.config.gti.trg_groups.min(n)
        } else {
            crate::gti::Grouping::auto_groups(n)
        }
    }
}
