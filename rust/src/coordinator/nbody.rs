//! AccD N-body: the full hybrid GTI (Two-landmark + Trace-based +
//! Group-level) on an iterative, self-joining workload.
//!
//! Per time step (paper §IV-B-b, Fig. 2d): groups are fixed-membership;
//! each group's *previous* center acts as the landmark, and accumulated
//! per-group drift widens the cached center-pair distances instead of
//! recomputing them (`gti::filter::NbodyFilter`).  Surviving group
//! pairs run on the device's radius-masked force tile; positions
//! integrate with leapfrog on the CPU.

use std::sync::Arc;
use std::time::Instant;

use crate::data::{Dataset, Matrix};
use crate::fpga::device::DeviceStats;
use crate::gti::{Grouping, NbodyFilter};
use crate::layout::PackedGrouping;
use crate::metrics::RunReport;
use crate::util::round_up;
use crate::{Error, Result};

use super::engine::Engine;
use super::pipeline;
use super::program::{self, CohortProgram, StepCtx, StepOutcome};

/// Result of an N-body run.
#[derive(Debug, Clone)]
pub struct NbodyResult {
    /// Final positions `(n, 3)` in the original particle order.
    pub positions: Matrix,
    /// Final velocities `(n, 3)`.
    pub velocities: Matrix,
    pub steps: usize,
    pub report: RunReport,
}

/// Softening constant: keeps close encounters finite, standard for
/// collisionless N-body integrators.
const EPS2: f32 = 1e-4;

pub(super) fn run(
    engine: &mut Engine,
    ds: &Dataset,
    masses: &[f32],
    steps: usize,
    dt: f32,
    radius: f32,
) -> Result<NbodyResult> {
    run_shared(engine, ds, masses, steps, dt, radius, None)
}

/// Validate an N-body request (shared by the solo path and the serving
/// layer's admission check, so the two can never silently diverge).
pub(crate) fn validate(ds: &Dataset, masses: &[f32]) -> Result<()> {
    if ds.d() != 3 {
        return Err(Error::Shape(format!("nbody requires 3-D positions, got d={}", ds.d())));
    }
    if masses.len() != ds.n() {
        return Err(Error::Data("masses length != particle count".into()));
    }
    Ok(())
}

/// N-body with an optionally pre-built (cached) grouping — the solo
/// driver: plan, step through every time step, finish.  The grouping
/// is *cloned* before use — the integrator recenters it every step —
/// so a cached instance stays pristine for the next query.
pub(crate) fn run_shared(
    engine: &mut Engine,
    ds: &Dataset,
    masses: &[f32],
    steps: usize,
    dt: f32,
    radius: f32,
    shared: Option<Arc<PackedGrouping>>,
) -> Result<NbodyResult> {
    validate(ds, masses)?;
    engine.device.reset_stats();
    let program = plan(&*engine, ds, Arc::new(masses.to_vec()), steps, dt, radius, shared)?;
    let mut ctx = StepCtx { engine: &*engine };
    program::run_to_completion(program, &mut ctx)
}

/// One N-body query as a stepwise program: [`plan`] groups + packs the
/// particles and seeds the hybrid GTI filter; [`CohortProgram::step`]
/// is one time step (filter → force tiles → leapfrog integration →
/// trace update), converging after the requested step count;
/// [`CohortProgram::finish`] unpacks to original order and assembles
/// the report.
pub(crate) struct NbodyProgram {
    steps: usize,
    dt: f32,
    radius: f32,
    rmax2: f32,
    pg: Arc<PackedGrouping>,
    /// Private clone of the packed grouping (recentered every step; a
    /// cached instance stays pristine for the next query).
    grouping: Grouping,
    /// Positions/velocities in packed order for slab locality.
    pos: Matrix,
    vel: Matrix,
    mass_packed: Vec<f32>,
    /// Masses in original order (finish's kinetic-energy quality
    /// number sums in original order, bit-for-bit like the solo path
    /// always did).  `Arc`-shared with the serving layer's job, so
    /// co-resident programs never hold private copies.
    masses_orig: Arc<Vec<f32>>,
    filter: NbodyFilter,
    acc: Vec<f32>,
    tile_n: usize,
    n: usize,
    steps_done: usize,
    report: RunReport,
    /// Wall seconds spent inside THIS program's plan/step/finish calls
    /// (per-call accumulation — like the device counters, exact even
    /// when the lockstep scheduler interleaves other programs).
    wall_secs: f64,
    /// This program's own device counters (snapshot diffs — exact even
    /// when the lockstep scheduler interleaves other programs' steps
    /// on the same engine).
    device: DeviceStats,
}

/// CPU-side planning: grouping (built or cached), packing, filter
/// seeding.
pub(crate) fn plan(
    engine: &Engine,
    ds: &Dataset,
    masses: Arc<Vec<f32>>,
    steps: usize,
    dt: f32,
    radius: f32,
    shared: Option<Arc<PackedGrouping>>,
) -> Result<NbodyProgram> {
    validate(ds, &masses)?;
    let t0 = Instant::now();
    let mut report = RunReport::new("nbody", &ds.name, "accd");
    let cfg = engine.config.clone();
    let tile_n = engine.runtime.manifest().tile.nbody;

    // --- Grouping (once) ---------------------------------------------------
    let filt0 = Instant::now();
    let z = engine.src_groups(ds.n());
    let pg: Arc<PackedGrouping> = match shared {
        Some(pg) => pg,
        None => Arc::new(PackedGrouping::build(
            &ds.points,
            z,
            cfg.gti.grouping_iters,
            cfg.gti.grouping_sample,
            cfg.seed,
            crate::gti::Metric::L2,
            8,
        )?),
    };
    let mut grouping = pg.grouping.clone();
    let packed = &pg.packed;
    // Positions/velocities live in packed order for slab locality.
    let pos = packed.points.clone();
    let vel = Matrix::zeros(ds.n(), 3);
    let mass_packed: Vec<f32> =
        packed.new2old.iter().map(|&old| masses[old as usize]).collect();
    // Re-index grouping members/assignment to packed rows: positions
    // live in packed order from here on, and `recenter` indexes the
    // position matrix through `members`.  Packing lays group g's
    // members out contiguously at rows start..start+len in member
    // order, so the remap is exactly that range.
    for g in 0..grouping.num_groups() {
        let start = packed.group_start(g) as u32;
        for (r, m) in grouping.members[g].iter_mut().enumerate() {
            *m = start + r as u32;
        }
    }
    let assign_packed: Vec<u32> =
        packed.new2old.iter().map(|&old| grouping.assign[old as usize]).collect();
    grouping.assign = assign_packed;
    let filter = NbodyFilter::new(&grouping, 0.25);
    report.filter_secs += filt0.elapsed().as_secs_f64();

    let n = ds.n();
    Ok(NbodyProgram {
        steps,
        dt,
        radius,
        rmax2: radius * radius,
        pg,
        grouping,
        pos,
        vel,
        mass_packed,
        masses_orig: masses,
        filter,
        acc: vec![0.0f32; n * 3],
        tile_n,
        n,
        steps_done: 0,
        report,
        wall_secs: t0.elapsed().as_secs_f64(),
        device: DeviceStats::default(),
    })
}

impl CohortProgram for NbodyProgram {
    type Output = NbodyResult;

    /// One time step: surviving group pairs → radius-masked force
    /// tiles → symplectic-Euler integration → trace update.
    fn step(&mut self, ctx: &mut StepCtx<'_>) -> Result<StepOutcome> {
        if self.steps_done >= self.steps {
            return Ok(StepOutcome::Converged);
        }
        let step_t0 = Instant::now();
        let engine = ctx.engine;
        let dev0 = engine.device.stats();
        self.steps_done += 1;

        // --- Filter: surviving group pairs (CPU) ---------------------------
        let filt = Instant::now();
        let candidates = self.filter.candidates(&self.grouping, self.radius);
        self.report.filter_secs += filt.elapsed().as_secs_f64();

        // --- Device: radius-masked force tiles -----------------------------
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        let device = &engine.device;
        let mut job_err: Option<Error> = None;
        struct ForceJob {
            /// Padded (tile_n x 3) source tile.
            pos_i: Vec<f32>,
            valid_i: usize,
            /// Packed row offset of the source tile.
            row0: usize,
            /// Padded target slab + masses.
            pos_j: Vec<f32>,
            mass_j: Vec<f32>,
        }
        let mut jobs: Vec<ForceJob> = Vec::new();
        {
            let packed = &self.pg.packed;
            let pos = &self.pos;
            let mass_packed = &self.mass_packed;
            let tile_n = self.tile_n;
            for g in 0..self.grouping.num_groups() {
                let len = packed.group_len(g);
                if len == 0 || candidates[g].is_empty() {
                    continue;
                }
                let start = packed.group_start(g);
                // Target slab: concatenation of candidate groups.
                let total: usize =
                    candidates[g].iter().map(|&b| packed.group_len(b as usize)).sum();
                let cols_pad = round_up(total.max(1), tile_n);
                let mut pos_j = vec![0.0f32; cols_pad * 3];
                let mut mass_j = vec![0.0f32; cols_pad];
                let mut row = 0usize;
                for &b in &candidates[g] {
                    let b = b as usize;
                    let (bs, bl) = (packed.group_start(b), packed.group_len(b));
                    for r in 0..bl {
                        pos_j[(row + r) * 3..(row + r) * 3 + 3]
                            .copy_from_slice(pos.row(bs + r));
                        mass_j[row + r] = mass_packed[bs + r];
                    }
                    row += bl;
                }
                // One job per group: the device segments the slab over its
                // tile variants internally (perf pass).
                let rows_pad = round_up(len, tile_n);
                let mut pos_i = vec![0.0f32; rows_pad * 3];
                for r in 0..len {
                    pos_i[r * 3..r * 3 + 3].copy_from_slice(pos.row(start + r));
                }
                jobs.push(ForceJob { pos_i, valid_i: len, row0: start, pos_j, mass_j });
            }
        }
        {
            let jobs_ref = &mut jobs;
            let acc_ref = &mut self.acc;
            let rmax2 = self.rmax2;
            pipeline::run(
                4,
                |_| if jobs_ref.is_empty() { None } else { Some(jobs_ref.remove(0)) },
                |job: ForceJob| {
                    if job_err.is_some() {
                        return;
                    }
                    let mut local = vec![0.0f32; job.valid_i * 3];
                    if let Err(e) = device.nbody_accumulate(
                        &job.pos_i,
                        job.valid_i,
                        &job.pos_j,
                        &job.mass_j,
                        EPS2,
                        rmax2,
                        &mut local,
                    ) {
                        job_err = Some(e);
                        return;
                    }
                    for r in 0..job.valid_i {
                        let i = job.row0 + r;
                        acc_ref[i * 3] += local[r * 3];
                        acc_ref[i * 3 + 1] += local[r * 3 + 1];
                        acc_ref[i * 3 + 2] += local[r * 3 + 2];
                    }
                },
            );
        }
        if let Some(e) = job_err {
            return Err(e);
        }

        // --- Integrate (CPU, leapfrog KDK collapsed to symplectic Euler) ---
        let filt = Instant::now();
        let dt = self.dt;
        for i in 0..self.n {
            let v = self.vel.row_mut(i);
            v[0] += self.acc[i * 3] * dt;
            v[1] += self.acc[i * 3 + 1] * dt;
            v[2] += self.acc[i * 3 + 2] * dt;
        }
        for i in 0..self.n {
            let (vx, vy, vz) = {
                let v = self.vel.row(i);
                (v[0], v[1], v[2])
            };
            let p = self.pos.row_mut(i);
            p[0] += vx * dt;
            p[1] += vy * dt;
            p[2] += vz * dt;
        }
        // --- Trace update: recenter groups, accumulate drift ---------------
        let drifts = self.grouping.recenter(&self.pos);
        self.filter.step(&self.grouping, &drifts, self.radius);
        self.report.filter_secs += filt.elapsed().as_secs_f64();

        program::absorb_device(
            &mut self.device,
            &program::device_delta(&dev0, &engine.device.stats()),
        );
        self.wall_secs += step_t0.elapsed().as_secs_f64();
        if self.steps_done >= self.steps {
            Ok(StepOutcome::Converged)
        } else {
            Ok(StepOutcome::Continue)
        }
    }

    /// Unpack to original order + assemble the report.
    fn finish(mut self, ctx: &mut StepCtx<'_>) -> Result<NbodyResult> {
        let finish_t0 = Instant::now();
        let engine = ctx.engine;
        // Final filter stats once (they accumulate inside the filter;
        // per-step merging would double-count).
        self.report.filter = self.filter.stats.clone();

        let n = self.n;
        let mut pos_orig = Matrix::zeros(n, 3);
        let mut vel_orig = Matrix::zeros(n, 3);
        for (new_row, &old) in self.pg.packed.new2old.iter().enumerate() {
            pos_orig.row_mut(old as usize).copy_from_slice(self.pos.row(new_row));
            vel_orig.row_mut(old as usize).copy_from_slice(self.vel.row(new_row));
        }

        let mut report = self.report;
        report.wall_secs = self.wall_secs + finish_t0.elapsed().as_secs_f64();
        report.device = self.device.clone();
        report.device_wall_secs = report.device.wall_secs;
        report.device_modeled_secs = report.device.modeled_secs;
        report.iterations = self.steps;
        // Quality: total kinetic energy (cross-impl comparable).
        let masses = &self.masses_orig;
        report.quality = (0..n)
            .map(|i| {
                let v = vel_orig.row(i);
                0.5 * masses[i] as f64 * (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]) as f64
            })
            .sum();
        report.energy_j = engine.power.accd_joules(
            report.wall_secs,
            report.filter_secs,
            1.0,
            report.device.wall_secs,
        );
        report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);

        Ok(NbodyResult { positions: pos_orig, velocities: vel_orig, steps: self.steps, report })
    }
}
