//! Memory-layout optimization — paper §V-A.
//!
//! Two transformations, both measured by the `layout` ablation bench:
//!
//! 1. **Intra-group packing** (Fig. 5): points of each group are copied
//!    into contiguous rows and assigned to memory banks, so a group is
//!    one dense slab that a tile fetch streams linearly.
//! 2. **Inter-group scheduling** (Fig. 4): source groups that require
//!    the *same* candidate target-group set are placed adjacently in
//!    the dispatch order, so the target slabs just fetched stay hot.
//!
//! On the real FPGA these drive external-memory coalescing; in this
//! reproduction they equally drive CPU cache locality of the PJRT tile
//! path, and [`LayoutStats`] exposes the reuse metrics the memory model
//! consumes.

use crate::data::Matrix;
use crate::gti::Grouping;

/// A packed (reordered) point set: group members contiguous.
#[derive(Debug, Clone)]
pub struct PackedSet {
    /// Reordered points: rows of group 0, then group 1, ...
    pub points: Matrix,
    /// `new2old[new_row] = original point id`.
    pub new2old: Vec<u32>,
    /// `old2new[original id] = new row`.
    pub old2new: Vec<u32>,
    /// Row range of each group in `points`: `(start, len)`.
    pub group_range: Vec<(u32, u32)>,
    /// Bank id per group (round-robin over `n_banks`).
    pub bank: Vec<u16>,
}

impl PackedSet {
    /// Pack `points` so each group's members are contiguous (Fig. 5c)
    /// and assign groups to `n_banks` memory banks.
    pub fn pack(points: &Matrix, grouping: &Grouping, n_banks: usize) -> Self {
        let n = points.rows();
        let mut new2old = Vec::with_capacity(n);
        let mut group_range = Vec::with_capacity(grouping.num_groups());
        let mut bank = Vec::with_capacity(grouping.num_groups());
        for (gi, members) in grouping.members.iter().enumerate() {
            group_range.push((new2old.len() as u32, members.len() as u32));
            bank.push((gi % n_banks.max(1)) as u16);
            new2old.extend_from_slice(members);
        }
        let mut old2new = vec![0u32; n];
        for (new, &old) in new2old.iter().enumerate() {
            old2new[old as usize] = new as u32;
        }
        let idx: Vec<usize> = new2old.iter().map(|&i| i as usize).collect();
        PackedSet { points: points.gather_rows(&idx), new2old, old2new, group_range, bank }
    }

    /// Contiguous rows of one group.
    pub fn group_rows(&self, g: usize) -> &[f32] {
        let (start, len) = self.group_range[g];
        let c = self.points.cols();
        &self.points.as_slice()[start as usize * c..(start + len) as usize * c]
    }

    pub fn group_len(&self, g: usize) -> usize {
        self.group_range[g].1 as usize
    }

    pub fn group_start(&self, g: usize) -> usize {
        self.group_range[g].0 as usize
    }
}

/// A grouping bundled with its packed point set — the unit the
/// coordinator algorithms consume and the unit the serving layer's
/// grouping cache stores.  Building one is the dominant CPU cost of a
/// query's filter stage (`Latency_filt`), which is exactly why
/// [`crate::serve`] memoizes them across queries.
#[derive(Debug, Clone)]
pub struct PackedGrouping {
    pub grouping: Grouping,
    pub packed: PackedSet,
}

impl PackedGrouping {
    /// Group `points` and pack them contiguously.  Deterministic in all
    /// arguments: two calls with identical inputs produce bit-identical
    /// results (the property the serving cache's correctness rests on).
    pub fn build(
        points: &Matrix,
        g: usize,
        iters: usize,
        sample: usize,
        seed: u64,
        metric: crate::gti::Metric,
        n_banks: usize,
    ) -> crate::Result<Self> {
        let grouping = Grouping::build_with_metric(points, g, iters, sample, seed, metric)?;
        let packed = PackedSet::pack(points, &grouping, n_banks);
        Ok(Self { grouping, packed })
    }
}

/// Reuse statistics of a dispatch schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayoutStats {
    /// Total target-group fetches a schedule performs.
    pub fetches: u64,
    /// Fetches served by the previous source group having loaded the
    /// same target set (temporal reuse, Fig. 4b).
    pub reused: u64,
}

impl LayoutStats {
    pub fn reuse_ratio(&self) -> f64 {
        if self.fetches == 0 {
            0.0
        } else {
            self.reused as f64 / self.fetches as f64
        }
    }
}

/// Order source groups so that identical candidate target sets are
/// adjacent (Fig. 4b): sort by the candidate list itself (candidates
/// are kept sorted by construction).  Returns the dispatch order.
pub fn schedule_source_groups(candidates: &[Vec<u32>]) -> Vec<u32> {
    let mut order: Vec<u32> = (0..candidates.len() as u32).collect();
    order.sort_by(|&a, &b| {
        candidates[a as usize]
            .cmp(&candidates[b as usize])
            .then(a.cmp(&b))
    });
    order
}

/// Measure temporal reuse of a dispatch order (used by the memory
/// model and the layout ablation bench).
pub fn measure_reuse(order: &[u32], candidates: &[Vec<u32>]) -> LayoutStats {
    let mut stats = LayoutStats::default();
    let mut prev: Option<&Vec<u32>> = None;
    for &g in order {
        let cand = &candidates[g as usize];
        stats.fetches += cand.len() as u64;
        if let Some(p) = prev {
            if p == cand {
                stats.reused += cand.len() as u64;
            } else {
                // Partial reuse: intersection with previous set.
                let mut i = 0;
                let mut j = 0;
                while i < p.len() && j < cand.len() {
                    match p[i].cmp(&cand[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            stats.reused += 1;
                            i += 1;
                            j += 1;
                        }
                    }
                }
            }
        }
        prev = Some(cand);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::prop;

    #[test]
    fn pack_preserves_point_values() {
        let ds = synthetic::clustered(200, 5, 4, 0.05, 1);
        let g = Grouping::build(&ds.points, 8, 2, 200, 2).unwrap();
        let packed = PackedSet::pack(&ds.points, &g, 4);
        for old in 0..200usize {
            let new = packed.old2new[old] as usize;
            assert_eq!(packed.points.row(new), ds.points.row(old));
            assert_eq!(packed.new2old[new] as usize, old);
        }
    }

    #[test]
    fn pack_groups_are_contiguous_and_cover() {
        let ds = synthetic::uniform(150, 3, 3);
        let g = Grouping::build(&ds.points, 6, 2, 150, 4).unwrap();
        let packed = PackedSet::pack(&ds.points, &g, 2);
        let total: u32 = packed.group_range.iter().map(|&(_, l)| l).sum();
        assert_eq!(total, 150);
        // Ranges tile [0, n) in order.
        let mut cursor = 0u32;
        for &(start, len) in &packed.group_range {
            assert_eq!(start, cursor);
            cursor += len;
        }
        // Banks round-robin.
        assert_eq!(packed.bank[0], 0);
        assert_eq!(packed.bank[1], 1);
        assert_eq!(packed.bank[2], 0);
    }

    #[test]
    fn schedule_clusters_identical_candidate_sets() {
        let cands = vec![
            vec![1, 4, 6],
            vec![8, 10, 12],
            vec![2, 4, 6],
            vec![8, 10, 12],
        ];
        let order = schedule_source_groups(&cands);
        // The two {8,10,12} groups (1 and 3) must be adjacent.
        let pos1 = order.iter().position(|&g| g == 1).unwrap();
        let pos3 = order.iter().position(|&g| g == 3).unwrap();
        assert_eq!(pos1.abs_diff(pos3), 1, "identical sets not adjacent: {order:?}");
    }

    #[test]
    fn scheduled_order_never_reuses_less() {
        let cands = vec![
            vec![0, 1],
            vec![5, 6],
            vec![0, 1],
            vec![5, 6],
            vec![0, 1],
        ];
        let natural = measure_reuse(&[0, 1, 2, 3, 4], &cands);
        let order = schedule_source_groups(&cands);
        let scheduled = measure_reuse(&order, &cands);
        assert!(scheduled.reused > natural.reused);
        assert_eq!(scheduled.fetches, natural.fetches);
    }

    #[test]
    fn prop_schedule_is_permutation_and_reuse_monotone() {
        prop::check(
            &prop::Config { cases: 32, max_size: 40, ..Default::default() },
            |rng, size| {
                let zs = size.max(2);
                let zt = 8;
                (0..zs)
                    .map(|_| {
                        let mut c: Vec<u32> = (0..zt as u32)
                            .filter(|_| rng.f32() < 0.4)
                            .collect();
                        c.sort_unstable();
                        c
                    })
                    .collect::<Vec<_>>()
            },
            |cands| {
                let order = schedule_source_groups(cands);
                let mut sorted = order.clone();
                sorted.sort_unstable();
                if sorted != (0..cands.len() as u32).collect::<Vec<_>>() {
                    return Err("order is not a permutation".into());
                }
                let natural: Vec<u32> = (0..cands.len() as u32).collect();
                let s_nat = measure_reuse(&natural, cands);
                let s_sch = measure_reuse(&order, cands);
                if s_sch.reused + 1 < s_nat.reused {
                    // Allow equality-ish; scheduled should not be
                    // meaningfully worse than natural order.
                    return Err(format!(
                        "scheduled reuse {} << natural {}",
                        s_sch.reused, s_nat.reused
                    ));
                }
                Ok(())
            },
        );
    }
}
