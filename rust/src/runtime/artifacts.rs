//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  The manifest enumerates every HLO module, its
//! input shapes and tile metadata; the runtime refuses to start on a
//! missing or mismatched manifest rather than guessing shapes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json;
use crate::{Error, Result};

/// What a compiled artifact computes (mirrors `kind` in aot.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(bm,d) x (bn,d) -> (bm,bn)` distance tile.
    Distance,
    /// `(bm,d) x (k,d) -> idx,(bm,) dist` fused K-means assignment.
    KmeansAssign,
    /// `(bm,d) x (bn,d) -> vals(bm,k), idx(bm,k)` fused KNN tile.
    KnnTile,
    /// `(bm,3) x (bn,3) x mass -> (bm,3)` N-body acceleration tile.
    NbodyAccel,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "distance" => Self::Distance,
            "kmeans_assign" => Self::KmeansAssign,
            "knn_tile" => Self::KnnTile,
            "nbody_accel" => Self::NbodyAccel,
            other => return Err(Error::Artifact(format!("unknown kind {other:?}"))),
        })
    }
}

/// One entry of the manifest after validation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub inputs: Vec<Vec<usize>>,
    pub metric: Option<String>,
    pub bm: usize,
    pub bn: usize,
    pub d: usize,
    pub k: usize,
}

/// Global tiling parameters shared by all artifacts.
#[derive(Debug, Clone)]
pub struct TileInfo {
    /// Base source-tile rows of the distance kernels (smallest variant).
    pub m: usize,
    /// Base target-tile rows of the distance kernels.
    pub n: usize,
    /// Available padded feature dimensions, ascending.
    pub d_pad: Vec<usize>,
    /// Per-tile Top-K width of the fused KNN tile.
    pub knn_k: usize,
    /// Available padded center counts for the fused K-means tile.
    pub kmeans_k_pad: Vec<usize>,
    /// N-body tile edge (particles per tile, both axes).
    pub nbody: usize,
    /// Available tile-edge variants, ascending (e.g. [64, 512]): the
    /// device mixes large and base tiles greedily so one PJRT call
    /// carries as much work as possible (perf pass, §Perf).
    pub variants: Vec<usize>,
}

impl TileInfo {
    /// The shipped kernel catalogue's tiling parameters — the geometry
    /// `python/compile/aot.py` emits.  Used as the built-in manifest
    /// when no artifact directory is deployed (reference backend).
    pub fn builtin() -> Self {
        Self {
            m: 64,
            n: 64,
            d_pad: vec![4, 8, 16, 32, 64, 128],
            knn_k: 32,
            kmeans_k_pad: vec![64, 128, 256, 512, 1024],
            nbody: 64,
            variants: vec![64, 512],
        }
    }

    /// Smallest padded feature dimension that fits `d`.
    pub fn pad_d(&self, d: usize) -> Result<usize> {
        self.d_pad
            .iter()
            .copied()
            .find(|&p| p >= d)
            .ok_or_else(|| Error::Shape(format!("d={d} exceeds max padded dim {:?}", self.d_pad)))
    }

    /// Smallest padded center count that fits `k`.
    pub fn pad_kmeans_k(&self, k: usize) -> Result<usize> {
        self.kmeans_k_pad
            .iter()
            .copied()
            .find(|&p| p >= k)
            .ok_or_else(|| {
                Error::Shape(format!("k={k} exceeds max padded centers {:?}", self.kmeans_k_pad))
            })
    }
}

/// Parsed + validated `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tile: TileInfo,
    pub entries: Vec<ArtifactEntry>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    /// Synthesize the built-in manifest (no artifact files on disk):
    /// the standard tile geometry with an empty entry table.  The
    /// runtime's reference backend resolves kernels from tile names
    /// against `tile` instead of the entry table.
    pub fn builtin() -> Self {
        Self {
            dir: PathBuf::from("<builtin>"),
            tile: TileInfo::builtin(),
            entries: Vec::new(),
            by_name: HashMap::new(),
        }
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let raw = json::parse(&text)?;
        let version = raw.req_usize("version")?;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (want 1)"
            )));
        }
        let usize_arr = |v: &json::Value, key: &str| -> Result<Vec<usize>> {
            v.req_arr(key)?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| Error::Json(format!("bad integer in {key:?}")))
                })
                .collect()
        };
        let tile_v = raw.get("tile").clone();
        let m = tile_v.req_usize("m")?;
        let variants = match tile_v.get("variants") {
            json::Value::Null => vec![m], // pre-variant manifests
            _ => usize_arr(&tile_v, "variants")?,
        };
        let tile = TileInfo {
            m,
            n: tile_v.req_usize("n")?,
            d_pad: usize_arr(&tile_v, "d_pad")?,
            knn_k: tile_v.req_usize("knn_k")?,
            kmeans_k_pad: usize_arr(&tile_v, "kmeans_k_pad")?,
            nbody: tile_v.req_usize("nbody")?,
            variants,
        };
        let raw_entries = raw.req_arr("artifacts")?;
        let mut entries = Vec::with_capacity(raw_entries.len());
        let mut by_name = HashMap::new();
        for e in raw_entries {
            let file = e.req_str("file")?;
            let path = dir.join(file);
            if !path.exists() {
                return Err(Error::Artifact(format!("missing artifact file {}", path.display())));
            }
            let kind = ArtifactKind::parse(e.req_str("kind")?)?;
            let inputs: Vec<Vec<usize>> = e
                .req_arr("inputs")?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .ok_or_else(|| Error::Json("bad shape array".into()))?
                        .iter()
                        .map(|x| {
                            x.as_usize().ok_or_else(|| Error::Json("bad shape dim".into()))
                        })
                        .collect()
                })
                .collect::<Result<_>>()?;
            let meta = e.get("meta");
            let entry = ArtifactEntry {
                kind,
                path,
                metric: meta.get("metric").as_str().map(str::to_string),
                bm: meta.get("bm").as_usize().unwrap_or(inputs[0][0]),
                bn: meta
                    .get("bn")
                    .as_usize()
                    .unwrap_or_else(|| inputs.get(1).map(|s| s[0]).unwrap_or(0)),
                d: meta
                    .get("d")
                    .as_usize()
                    .unwrap_or_else(|| inputs[0].get(1).copied().unwrap_or(0)),
                k: meta.get("k").as_usize().unwrap_or(0),
                name: e.req_str("name")?.to_string(),
                inputs,
            };
            by_name.insert(entry.name.clone(), entries.len());
            entries.push(entry);
        }
        Ok(Self { dir, tile, entries, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Name of the distance tile artifact for a metric, tile edges and
    /// padded dim.
    pub fn distance_name_sized(
        &self,
        metric: &str,
        tm: usize,
        tn: usize,
        d_padded: usize,
    ) -> String {
        format!("distance_{metric}_m{tm}_n{tn}_d{d_padded}")
    }

    /// Base-tile distance artifact (back-compat convenience).
    pub fn distance_name(&self, metric: &str, d_padded: usize) -> String {
        self.distance_name_sized(metric, self.tile.m, self.tile.n, d_padded)
    }

    pub fn kmeans_name_sized(&self, tm: usize, k_padded: usize, d_padded: usize) -> String {
        format!("kmeans_assign_m{tm}_k{k_padded}_d{d_padded}")
    }

    pub fn kmeans_name(&self, k_padded: usize, d_padded: usize) -> String {
        self.kmeans_name_sized(self.tile.m, k_padded, d_padded)
    }

    pub fn knn_name(&self, d_padded: usize) -> String {
        format!(
            "knn_tile_m{}_n{}_d{d_padded}_k{}",
            self.tile.m, self.tile.n, self.tile.knn_k
        )
    }

    pub fn nbody_name_sized(&self, tm: usize, tn: usize) -> String {
        format!("nbody_accel_m{tm}_n{tn}")
    }

    pub fn nbody_name(&self) -> String {
        self.nbody_name_sized(self.tile.nbody, self.tile.nbody)
    }

    /// Greedy segmentation of `rows` into tile-variant segments:
    /// largest variants first, base tiles for the remainder.  Returns
    /// `(offset, edge)` pairs covering `round_up(rows, base)`.
    pub fn segments(&self, rows: usize) -> Vec<(usize, usize)> {
        let base = *self.tile.variants.first().unwrap_or(&self.tile.m);
        let mut out = Vec::new();
        let mut off = 0usize;
        let mut remaining = crate::util::round_up(rows.max(1), base);
        for &v in self.tile.variants.iter().rev() {
            while remaining >= v {
                out.push((off, v));
                off += v;
                remaining -= v;
            }
        }
        debug_assert_eq!(remaining, 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_d_picks_smallest_fit() {
        let t = TileInfo {
            m: 64,
            n: 64,
            d_pad: vec![4, 8, 16, 32, 64, 128],
            knn_k: 32,
            kmeans_k_pad: vec![64, 128],
            nbody: 64,
            variants: vec![64, 512],
        };
        assert_eq!(t.pad_d(3).unwrap(), 4);
        assert_eq!(t.pad_d(4).unwrap(), 4);
        assert_eq!(t.pad_d(5).unwrap(), 8);
        assert_eq!(t.pad_d(74).unwrap(), 128);
        assert!(t.pad_d(200).is_err());
    }

    #[test]
    fn kind_parse_rejects_unknown() {
        assert!(ArtifactKind::parse("distance").is_ok());
        assert!(ArtifactKind::parse("bogus").is_err());
    }

    #[test]
    fn segments_mix_variants_greedily() {
        let m = Manifest {
            dir: std::path::PathBuf::new(),
            tile: TileInfo {
                m: 64,
                n: 64,
                d_pad: vec![4],
                knn_k: 32,
                kmeans_k_pad: vec![64],
                nbody: 64,
                variants: vec![64, 512],
            },
            entries: vec![],
            by_name: Default::default(),
        };
        // 1100 rows -> round_up 1152 = 2x512 + 2x64.
        assert_eq!(m.segments(1100), vec![(0, 512), (512, 512), (1024, 64), (1088, 64)]);
        // Small inputs use base tiles only.
        assert_eq!(m.segments(1), vec![(0, 64)]);
        assert_eq!(m.segments(130), vec![(0, 64), (64, 64), (128, 64)]);
        // Exact large multiple.
        assert_eq!(m.segments(512), vec![(0, 512)]);
        // Segments always cover round_up(rows, base).
        for rows in [1usize, 63, 64, 65, 500, 513, 7000] {
            let segs = m.segments(rows);
            let covered: usize = segs.iter().map(|&(_, e)| e).sum();
            assert_eq!(covered, rows.div_ceil(64) * 64, "rows={rows}");
        }
    }
}
