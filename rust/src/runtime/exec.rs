//! Kernel cache + typed tile execution (reference backend).
//!
//! The original deployment compiled AOT-lowered HLO artifacts on a PJRT
//! CPU client.  The offline vendored registry carries no PJRT/XLA
//! native closure, so [`Runtime`] now executes tiles with in-tree
//! reference kernels that are *bit-deterministic* and semantically
//! pinned by `rust/tests/runtime_roundtrip.rs` (the same scalar oracles
//! the HLO modules were validated against).  The artifact manifest is
//! still honoured: with a deployed `artifacts/` directory the runtime
//! resolves kernels through the manifest (validating files and shapes,
//! failing lazily at first use exactly like PJRT compilation did);
//! without one, [`Runtime::load_or_builtin`] falls back to the built-in
//! tile catalogue so the engine works out of the box.
//!
//! All tile entry points take *padded* buffers: callers go through
//! [`crate::layout`] / the coordinator, which pad group batches to the
//! manifest's tile multiples.  The padding conventions are:
//!
//! * feature axis: zero padding (distance-neutral for L2^2 and L1);
//! * source/target rows: zero rows, results discarded by the caller;
//! * K-means padded centers: large sentinel coordinates so the fused
//!   argmin never selects a padding slot;
//! * N-body padding rows: zero mass, so they contribute no force.

use std::collections::HashMap;
use std::sync::Mutex;

use super::artifacts::{ArtifactKind, Manifest};
use crate::{Error, Result};

/// Output of one fused KNN tile: per-source-row top-k values + indices
/// (indices are *tile-local* target rows; the coordinator remaps them).
#[derive(Debug, Clone)]
pub struct KnnTileOut {
    pub vals: Vec<f32>,
    pub idx: Vec<i32>,
    pub rows: usize,
    pub k: usize,
}

/// Distance metric a device kernel computes (device value space).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefMetric {
    /// Squared Euclidean (the paper's Eq. 4 decomposition target).
    L2Sq,
    /// Manhattan sum.
    L1,
}

impl RefMetric {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "l2sq" => Some(Self::L2Sq),
            "l1" => Some(Self::L1),
            _ => None,
        }
    }
}

/// A resolved ("compiled") kernel: shape-validated semantics for one
/// artifact name.  Mirrors what a PJRT executable was for the HLO path.
#[derive(Debug, Clone, PartialEq)]
enum KernelSpec {
    Distance { metric: RefMetric, m: usize, n: usize, d: usize },
    KmeansAssign { m: usize, k: usize, d: usize },
    KnnTile { m: usize, n: usize, d: usize, k: usize },
    NbodyAccel { m: usize, n: usize },
}

/// Tile runtime: kernel cache over the artifact manifest (or the
/// built-in catalogue).
pub struct Runtime {
    manifest: Manifest,
    /// True when running from the built-in catalogue (no artifact dir):
    /// kernel names resolve against the tile geometry instead of the
    /// manifest entry table.
    builtin: bool,
    /// Lazily resolved kernels, keyed by artifact name.  Lazy so a
    /// process that only runs K-means never validates the KNN modules,
    /// and so malformed artifact files fail at first *use* (the PJRT
    /// compile-time contract `failure_injection.rs` pins).
    kernels: Mutex<HashMap<String, KernelSpec>>,
    /// Execution counters for the metrics endpoint.
    pub stats: RuntimeStats,
}

/// Cheap atomic counters describing runtime activity.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub tiles_executed: std::sync::atomic::AtomicU64,
    pub bytes_h2d: std::sync::atomic::AtomicU64,
    pub bytes_d2h: std::sync::atomic::AtomicU64,
}

impl RuntimeStats {
    fn record(&self, h2d: usize, d2h: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        self.tiles_executed.fetch_add(1, Relaxed);
        self.bytes_h2d.fetch_add(h2d as u64, Relaxed);
        self.bytes_d2h.fetch_add(d2h as u64, Relaxed);
    }
}

impl Runtime {
    /// Parse the manifest of a deployed artifact directory.  Kernels
    /// resolve lazily on first use; call [`Runtime::warmup`] to force.
    ///
    /// Errors when the directory carries no (or a broken) manifest —
    /// use [`Runtime::load_or_builtin`] for the graceful fallback.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Self {
            manifest,
            builtin: false,
            kernels: Mutex::new(HashMap::new()),
            stats: RuntimeStats::default(),
        })
    }

    /// Like [`Runtime::load`], but when `artifact_dir` has no
    /// `manifest.json` at all, fall back to the built-in tile catalogue
    /// (reference backend).  A *present but invalid* manifest is still
    /// a hard error — a corrupted deployment must fail loudly.
    pub fn load_or_builtin(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        if artifact_dir.as_ref().join("manifest.json").exists() {
            Self::load(artifact_dir)
        } else {
            Ok(Self::builtin())
        }
    }

    /// Runtime over the built-in kernel catalogue (no artifact files).
    pub fn builtin() -> Self {
        Self {
            manifest: Manifest::builtin(),
            builtin: true,
            kernels: Mutex::new(HashMap::new()),
            stats: RuntimeStats::default(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        if self.builtin {
            "reference-cpu (builtin catalogue)".to_string()
        } else {
            "reference-cpu (artifact manifest)".to_string()
        }
    }

    /// Resolve (or fetch cached) kernel for an artifact name.
    fn kernel(&self, name: &str) -> Result<KernelSpec> {
        if let Some(spec) = self.kernels.lock().unwrap().get(name) {
            return Ok(spec.clone());
        }
        let spec = if self.builtin {
            self.resolve_builtin(name)?
        } else {
            self.resolve_entry(name)?
        };
        self.kernels.lock().unwrap().insert(name.to_string(), spec.clone());
        Ok(spec)
    }

    /// Resolve a kernel through the manifest entry table (deployed
    /// artifact directory): the HLO text file must exist and look like
    /// an HLO module, and the entry metadata fixes the shapes.
    fn resolve_entry(&self, name: &str) -> Result<KernelSpec> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named {name:?}")))?;
        let text = std::fs::read_to_string(&entry.path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", entry.path.display()))
        })?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error::Artifact(format!(
                "cannot parse {} as HLO text (missing HloModule header)",
                entry.path.display()
            )));
        }
        Ok(match entry.kind {
            ArtifactKind::Distance => {
                let metric_str = entry.metric.as_deref().unwrap_or("l2sq");
                let metric = RefMetric::parse(metric_str).ok_or_else(|| {
                    Error::Artifact(format!("unsupported metric {metric_str:?} in {name:?}"))
                })?;
                KernelSpec::Distance { metric, m: entry.bm, n: entry.bn, d: entry.d }
            }
            ArtifactKind::KmeansAssign => {
                KernelSpec::KmeansAssign { m: entry.bm, k: entry.k.max(entry.bn), d: entry.d }
            }
            ArtifactKind::KnnTile => KernelSpec::KnnTile {
                m: entry.bm,
                n: entry.bn,
                d: entry.d,
                k: if entry.k > 0 { entry.k } else { self.manifest.tile.knn_k },
            },
            ArtifactKind::NbodyAccel => KernelSpec::NbodyAccel { m: entry.bm, n: entry.bn },
        })
    }

    /// Resolve a kernel from its name against the built-in catalogue.
    /// Shapes outside the catalogue fail exactly like a missing
    /// artifact would.
    fn resolve_builtin(&self, name: &str) -> Result<KernelSpec> {
        let missing = || Error::Artifact(format!("no artifact named {name:?}"));
        let t = &self.manifest.tile;
        let spec = parse_kernel_name(name).ok_or_else(&missing)?;
        let in_variants = |x: usize| t.variants.contains(&x) || x == t.m;
        let valid = match &spec {
            KernelSpec::Distance { m, n, d, .. } => {
                in_variants(*m) && in_variants(*n) && t.d_pad.contains(d)
            }
            KernelSpec::KmeansAssign { m, k, d } => {
                in_variants(*m) && t.kmeans_k_pad.contains(k) && t.d_pad.contains(d)
            }
            KernelSpec::KnnTile { m, n, d, k } => {
                *m == t.m && *n == t.n && t.d_pad.contains(d) && *k == t.knn_k
            }
            KernelSpec::NbodyAccel { m, n } => in_variants(*m) && in_variants(*n),
        };
        if valid {
            Ok(spec)
        } else {
            Err(missing())
        }
    }

    /// Force-resolve a set of artifacts (e.g. everything a plan needs).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.kernel(n)?;
        }
        Ok(())
    }

    /// Number of kernels resolved so far.
    pub fn compiled_count(&self) -> usize {
        self.kernels.lock().unwrap().len()
    }

    fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
        if got != want {
            return Err(Error::Shape(format!("{what}: buffer len {got}, expected {want}")));
        }
        Ok(())
    }

    /// Distance tile of explicit edges: `a (tm x d_pad)`,
    /// `b (tn x d_pad)` -> row-major `(tm x tn)` distances.
    pub fn distance_tile_sized(
        &self,
        metric: &str,
        tm: usize,
        tn: usize,
        d_padded: usize,
        a: &[f32],
        b: &[f32],
    ) -> Result<Vec<f32>> {
        let name = self.manifest.distance_name_sized(metric, tm, tn, d_padded);
        let spec = self.kernel(&name)?;
        let KernelSpec::Distance { metric, m, n, d } = spec else {
            return Err(Error::Artifact(format!("{name:?} is not a distance kernel")));
        };
        Self::check_len("distance src", a.len(), m * d)?;
        Self::check_len("distance trg", b.len(), n * d)?;
        let mut dist = vec![0.0f32; m * n];
        for i in 0..m {
            let ra = &a[i * d..(i + 1) * d];
            let out = &mut dist[i * n..(i + 1) * n];
            for (j, o) in out.iter_mut().enumerate() {
                let rb = &b[j * d..(j + 1) * d];
                let mut s = 0.0f32;
                match metric {
                    RefMetric::L2Sq => {
                        for k in 0..d {
                            let diff = ra[k] - rb[k];
                            s += diff * diff;
                        }
                    }
                    RefMetric::L1 => {
                        for k in 0..d {
                            s += (ra[k] - rb[k]).abs();
                        }
                    }
                }
                *o = s;
            }
        }
        self.stats.record((a.len() + b.len()) * 4, dist.len() * 4);
        Ok(dist)
    }

    /// Base-tile distance (`tile.m x tile.n`) — the pre-perf-pass entry
    /// point, still used by tests and micro benches.
    pub fn distance_tile(
        &self,
        metric: &str,
        d_padded: usize,
        a: &[f32],
        b: &[f32],
    ) -> Result<Vec<f32>> {
        let t = self.manifest.tile.clone();
        self.distance_tile_sized(metric, t.m, t.n, d_padded, a, b)
    }

    /// Fused K-means assignment tile of explicit row count `tm`:
    /// per-row argmin over `k_padded` centers (first minimum wins).
    pub fn kmeans_assign_tile_sized(
        &self,
        tm: usize,
        k_padded: usize,
        d_padded: usize,
        points: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let name = self.manifest.kmeans_name_sized(tm, k_padded, d_padded);
        let spec = self.kernel(&name)?;
        let KernelSpec::KmeansAssign { m, k, d } = spec else {
            return Err(Error::Artifact(format!("{name:?} is not a kmeans kernel")));
        };
        Self::check_len("kmeans points", points.len(), m * d)?;
        Self::check_len("kmeans centers", centers.len(), k * d)?;
        let mut idx = vec![0i32; m];
        let mut dist = vec![0.0f32; m];
        for i in 0..m {
            let row = &points[i * d..(i + 1) * d];
            let mut best_c = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let cr = &centers[c * d..(c + 1) * d];
                let mut s = 0.0f32;
                for x in 0..d {
                    let diff = row[x] - cr[x];
                    s += diff * diff;
                }
                if s < best_d {
                    best_d = s;
                    best_c = c;
                }
            }
            idx[i] = best_c as i32;
            dist[i] = best_d;
        }
        self.stats
            .record((points.len() + centers.len()) * 4, idx.len() * 4 + dist.len() * 4);
        Ok((idx, dist))
    }

    /// Fused K-means assignment tile that also returns the
    /// second-closest distance per row: the seed of the Hamerly lower
    /// bound the incremental TI path carries across iterations.  Same
    /// kernel resolution and padding contract as
    /// [`Runtime::kmeans_assign_tile_sized`]; padded sentinel centers
    /// can win the second slot only when a single real center exists,
    /// in which case the "lower bound to the second-closest center" is
    /// effectively infinite — exactly the sentinel's value.
    pub fn kmeans_assign2_tile_sized(
        &self,
        tm: usize,
        k_padded: usize,
        d_padded: usize,
        points: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
        let name = self.manifest.kmeans_name_sized(tm, k_padded, d_padded);
        let spec = self.kernel(&name)?;
        let KernelSpec::KmeansAssign { m, k, d } = spec else {
            return Err(Error::Artifact(format!("{name:?} is not a kmeans kernel")));
        };
        Self::check_len("kmeans points", points.len(), m * d)?;
        Self::check_len("kmeans centers", centers.len(), k * d)?;
        let mut idx = vec![0i32; m];
        let mut dist = vec![0.0f32; m];
        let mut second = vec![0.0f32; m];
        for i in 0..m {
            let row = &points[i * d..(i + 1) * d];
            let mut best_c = 0usize;
            let mut best_d = f32::INFINITY;
            let mut second_d = f32::INFINITY;
            for c in 0..k {
                let cr = &centers[c * d..(c + 1) * d];
                let mut s = 0.0f32;
                for x in 0..d {
                    let diff = row[x] - cr[x];
                    s += diff * diff;
                }
                if s < best_d {
                    second_d = best_d;
                    best_d = s;
                    best_c = c;
                } else if s < second_d {
                    second_d = s;
                }
            }
            idx[i] = best_c as i32;
            dist[i] = best_d;
            second[i] = second_d;
        }
        self.stats.record((points.len() + centers.len()) * 4, m * 12);
        Ok((idx, dist, second))
    }

    /// Base-tile fused K-means assignment.
    pub fn kmeans_assign_tile(
        &self,
        k_padded: usize,
        d_padded: usize,
        points: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let m = self.manifest.tile.m;
        self.kmeans_assign_tile_sized(m, k_padded, d_padded, points, centers)
    }

    /// Fused KNN tile: per-source-row top-`tile.knn_k` (value, local
    /// idx), ascending by value with ties broken by lower index.
    pub fn knn_tile(&self, d_padded: usize, a: &[f32], b: &[f32]) -> Result<KnnTileOut> {
        let name = self.manifest.knn_name(d_padded);
        let spec = self.kernel(&name)?;
        let KernelSpec::KnnTile { m, n, d, k } = spec else {
            return Err(Error::Artifact(format!("{name:?} is not a knn kernel")));
        };
        Self::check_len("knn src", a.len(), m * d)?;
        Self::check_len("knn trg", b.len(), n * d)?;
        let mut vals = vec![0.0f32; m * k];
        let mut idx = vec![0i32; m * k];
        let mut row_d: Vec<(f32, i32)> = Vec::with_capacity(n);
        for i in 0..m {
            let ra = &a[i * d..(i + 1) * d];
            row_d.clear();
            for j in 0..n {
                let rb = &b[j * d..(j + 1) * d];
                let mut s = 0.0f32;
                for x in 0..d {
                    let diff = ra[x] - rb[x];
                    s += diff * diff;
                }
                row_d.push((s, j as i32));
            }
            // total_cmp: NaN distances (NaN input data) sort last
            // instead of panicking, matching the XLA sort semantics
            // this kernel replaces.
            row_d.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
            for (r, &(v, j)) in row_d.iter().take(k).enumerate() {
                vals[i * k + r] = v;
                idx[i * k + r] = j;
            }
        }
        self.stats.record((a.len() + b.len()) * 4, vals.len() * 8);
        Ok(KnnTileOut { vals, idx, rows: m, k })
    }

    /// Radius-limited N-body acceleration tile of explicit edges:
    /// `pos_i (tm x 3)`, `pos_j (tn x 3)`, `mass_j (tn)`, softening^2,
    /// radius^2 -> `(tm x 3)` acceleration (only neighbors with
    /// r^2 <= rmax2 contribute; padding rows carry mass 0).
    #[allow(clippy::too_many_arguments)]
    pub fn nbody_accel_sized(
        &self,
        tm: usize,
        tn: usize,
        pos_i: &[f32],
        pos_j: &[f32],
        mass_j: &[f32],
        eps2: f32,
        rmax2: f32,
    ) -> Result<Vec<f32>> {
        let name = self.manifest.nbody_name_sized(tm, tn);
        let spec = self.kernel(&name)?;
        let KernelSpec::NbodyAccel { m, n } = spec else {
            return Err(Error::Artifact(format!("{name:?} is not an nbody kernel")));
        };
        Self::check_len("nbody pos_i", pos_i.len(), m * 3)?;
        Self::check_len("nbody pos_j", pos_j.len(), n * 3)?;
        Self::check_len("nbody mass_j", mass_j.len(), n)?;
        let mut acc = vec![0.0f32; m * 3];
        for i in 0..m {
            let (xi, yi, zi) = (pos_i[i * 3], pos_i[i * 3 + 1], pos_i[i * 3 + 2]);
            let (mut ax, mut ay, mut az) = (0.0f32, 0.0f32, 0.0f32);
            for j in 0..n {
                let dx = xi - pos_j[j * 3];
                let dy = yi - pos_j[j * 3 + 1];
                let dz = zi - pos_j[j * 3 + 2];
                let r2 = dx * dx + dy * dy + dz * dz;
                if r2 > rmax2 {
                    continue;
                }
                let r2s = r2 + eps2;
                let inv_r3 = 1.0 / (r2s.sqrt() * r2s);
                let w = mass_j[j] * inv_r3;
                ax -= dx * w;
                ay -= dy * w;
                az -= dz * w;
            }
            acc[i * 3] = ax;
            acc[i * 3 + 1] = ay;
            acc[i * 3 + 2] = az;
        }
        self.stats
            .record((pos_i.len() + pos_j.len() + mass_j.len() + 2) * 4, acc.len() * 4);
        Ok(acc)
    }

    /// Base-tile N-body acceleration (back-compat entry point).
    pub fn nbody_accel_tile_masked(
        &self,
        pos_i: &[f32],
        pos_j: &[f32],
        mass_j: &[f32],
        eps2: f32,
        rmax2: f32,
    ) -> Result<Vec<f32>> {
        let t = self.manifest.tile.nbody;
        self.nbody_accel_sized(t, t, pos_i, pos_j, mass_j, eps2, rmax2)
    }

    /// Artifact names a given kind/d combination resolves to (for warmup).
    pub fn names_for(&self, kind: ArtifactKind, d_padded: usize, k_padded: usize) -> Vec<String> {
        match kind {
            ArtifactKind::Distance => vec![
                self.manifest.distance_name("l2sq", d_padded),
                self.manifest.distance_name("l1", d_padded),
            ],
            ArtifactKind::KmeansAssign => vec![self.manifest.kmeans_name(k_padded, d_padded)],
            ArtifactKind::KnnTile => vec![self.manifest.knn_name(d_padded)],
            ArtifactKind::NbodyAccel => vec![self.manifest.nbody_name()],
        }
    }
}

/// Parse a kernel name of the shipped naming scheme into a spec.
fn parse_kernel_name(name: &str) -> Option<KernelSpec> {
    fn params<'a>(rest: &'a str, keys: &[&str]) -> Option<Vec<usize>> {
        let parts: Vec<&'a str> = rest.split('_').collect();
        if parts.len() != keys.len() {
            return None;
        }
        let mut out = Vec::with_capacity(keys.len());
        for (p, key) in parts.iter().zip(keys) {
            let v = p.strip_prefix(key)?;
            out.push(v.parse::<usize>().ok()?);
        }
        Some(out)
    }
    if let Some(rest) = name.strip_prefix("distance_") {
        let (metric_str, shape) = rest.split_once('_')?;
        let metric = RefMetric::parse(metric_str)?;
        let p = params(shape, &["m", "n", "d"])?;
        Some(KernelSpec::Distance { metric, m: p[0], n: p[1], d: p[2] })
    } else if let Some(rest) = name.strip_prefix("kmeans_assign_") {
        let p = params(rest, &["m", "k", "d"])?;
        Some(KernelSpec::KmeansAssign { m: p[0], k: p[1], d: p[2] })
    } else if let Some(rest) = name.strip_prefix("knn_tile_") {
        let p = params(rest, &["m", "n", "d", "k"])?;
        Some(KernelSpec::KnnTile { m: p[0], n: p[1], d: p[2], k: p[3] })
    } else if let Some(rest) = name.strip_prefix("nbody_accel_") {
        let p = params(rest, &["m", "n"])?;
        Some(KernelSpec::NbodyAccel { m: p[0], n: p[1] })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_parse_and_validate() {
        let rt = Runtime::builtin();
        assert!(rt.kernel("distance_l2sq_m64_n64_d4").is_ok());
        assert!(rt.kernel("distance_l2sq_m64_n512_d16").is_ok());
        assert!(rt.kernel("distance_l1_m512_n64_d128").is_ok());
        assert!(rt.kernel("kmeans_assign_m64_k64_d8").is_ok());
        assert!(rt.kernel("kmeans_assign_m512_k128_d16").is_ok());
        assert!(rt.kernel("knn_tile_m64_n64_d16_k32").is_ok());
        assert!(rt.kernel("nbody_accel_m64_n512").is_ok());
        // Shapes outside the catalogue behave like missing artifacts.
        for bad in [
            "distance_l2sq_m64_n64_d7",
            "distance_linf_m64_n64_d4",
            "kmeans_assign_m64_k100_d8",
            "knn_tile_m64_n64_d16_k5",
            "nbody_accel_m64_n100",
            "totally_unknown",
        ] {
            let err = rt.kernel(bad).unwrap_err();
            assert!(err.to_string().contains("no artifact"), "{bad}: {err}");
        }
    }

    #[test]
    fn builtin_distance_matches_scalar_math() {
        let rt = Runtime::builtin();
        let d = 4usize;
        let a = vec![0.5f32; 64 * d];
        let mut b = vec![0.0f32; 64 * d];
        b[0] = 1.0; // first target row differs in one coordinate
        let l2 = rt.distance_tile("l2sq", d, &a, &b).unwrap();
        // row 0 vs col 0: (0.5-1)^2 + 3*(0.5)^2 = 0.25 + 0.75 = 1.0
        assert!((l2[0] - 1.0).abs() < 1e-6);
        // every other column: 4 * 0.25 = 1.0 ... col 1 uses zeros only.
        assert!((l2[1] - 1.0).abs() < 1e-6);
        let l1 = rt.distance_tile("l1", d, &a, &b).unwrap();
        assert!((l1[0] - 2.0).abs() < 1e-6); // 0.5 + 3*0.5
    }

    #[test]
    fn builtin_counts_resolved_kernels_once() {
        let rt = Runtime::builtin();
        let d = 4usize;
        let a = vec![0.0f32; 64 * d];
        let b = vec![0.0f32; 64 * d];
        let _ = rt.distance_tile("l2sq", d, &a, &b).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        let _ = rt.distance_tile("l2sq", d, &a, &b).unwrap();
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn assign2_second_distance_matches_scalar_oracle() {
        let rt = Runtime::builtin();
        let (m, k, d) = (64usize, 64usize, 4usize);
        // Deterministic pseudo-random points/centers (no RNG dep here).
        let mut points = vec![0.0f32; m * d];
        for (i, p) in points.iter_mut().enumerate() {
            *p = ((i * 2654435761) % 1000) as f32 / 250.0;
        }
        let mut centers = vec![0.0f32; k * d];
        for (i, c) in centers.iter_mut().enumerate() {
            *c = ((i * 40503 + 7) % 1000) as f32 / 250.0;
        }
        let (idx, best, second) =
            rt.kmeans_assign2_tile_sized(m, k, d, &points, &centers).unwrap();
        let (idx1, best1) = rt.kmeans_assign_tile_sized(m, k, d, &points, &centers).unwrap();
        assert_eq!(idx, idx1, "assign2 argmin must match the plain assignment kernel");
        assert_eq!(best, best1);
        for i in 0..m {
            // Oracle: exhaustive two smallest distances.
            let mut ds: Vec<f32> = (0..k)
                .map(|c| {
                    (0..d)
                        .map(|x| {
                            let diff = points[i * d + x] - centers[c * d + x];
                            diff * diff
                        })
                        .sum()
                })
                .collect();
            ds.sort_by(f32::total_cmp);
            assert!((best[i] - ds[0]).abs() <= 1e-5, "row {i}: best {} vs {}", best[i], ds[0]);
            assert!(
                (second[i] - ds[1]).abs() <= 1e-5,
                "row {i}: second {} vs {}",
                second[i],
                ds[1]
            );
            assert!(second[i] >= best[i]);
        }
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let rt = Runtime::builtin();
        let a = vec![0.0f32; 64 * 4];
        let short = vec![0.0f32; 63 * 4];
        assert!(rt.distance_tile("l2sq", 4, &a, &short).is_err());
    }
}
