//! Executable cache + typed tile execution.
//!
//! [`Runtime`] owns the PJRT CPU client and one compiled
//! `PjRtLoadedExecutable` per manifest entry.  Compilation happens once
//! at [`Runtime::load`]; the hot path is literal-in / literal-out.
//!
//! All tile entry points take *padded* buffers: callers go through
//! [`crate::layout`] / the coordinator, which pad group batches to the
//! manifest's tile multiples.  The padding conventions are:
//!
//! * feature axis: zero padding (distance-neutral for L2^2 and L1);
//! * source/target rows: zero rows, results discarded by the caller;
//! * K-means padded centers: `f32::MAX/4` sentinel coordinates so the
//!   fused argmin never selects a padding slot.

use std::collections::HashMap;
use std::sync::Mutex;

use super::artifacts::{ArtifactKind, Manifest};
use crate::{Error, Result};

/// Output of one fused KNN tile: per-source-row top-k values + indices
/// (indices are *tile-local* target rows; the coordinator remaps them).
#[derive(Debug, Clone)]
pub struct KnnTileOut {
    pub vals: Vec<f32>,
    pub idx: Vec<i32>,
    pub rows: usize,
    pub k: usize,
}

/// PJRT runtime: compiled-executable cache over the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazily compiled executables, keyed by artifact name.  Lazy so a
    /// process that only runs K-means never pays for the KNN modules
    /// (compilation of all 40+ modules is noticeable on one core).
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Execution counters for the metrics endpoint.
    pub stats: RuntimeStats,
}

/// Cheap atomic counters describing runtime activity.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub tiles_executed: std::sync::atomic::AtomicU64,
    pub bytes_h2d: std::sync::atomic::AtomicU64,
    pub bytes_d2h: std::sync::atomic::AtomicU64,
}

impl RuntimeStats {
    fn record(&self, h2d: usize, d2h: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        self.tiles_executed.fetch_add(1, Relaxed);
        self.bytes_h2d.fetch_add(h2d as u64, Relaxed);
        self.bytes_d2h.fetch_add(d2h as u64, Relaxed);
    }
}

impl Runtime {
    /// Create the PJRT CPU client and parse the manifest.  Executables
    /// compile lazily on first use; call [`Runtime::warmup`] to force.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, executables: Mutex::new(HashMap::new()), stats: RuntimeStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for a manifest entry.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named {name:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(&entry.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.executables.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Force-compile a set of artifacts (e.g. everything a plan needs).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.executables.lock().unwrap().len()
    }

    fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// Execute a raw artifact by name with 2-D f32 inputs, returning the
    /// flattened tuple elements.  Generic fallback used by tests and the
    /// DDSL interpreter; the typed wrappers below are the hot path.
    pub fn execute_raw(
        &self,
        name: &str,
        inputs: &[(&[f32], usize, usize)],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(d, r, c)| Self::literal_2d(d, *r, *c))
            .collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let tuple = out.to_tuple()?;
        let h2d: usize = inputs.iter().map(|(d, _, _)| d.len() * 4).sum();
        self.stats.record(h2d, 0);
        Ok(tuple)
    }

    /// Distance tile of explicit edges: `a (tm x d_pad)`,
    /// `b (tn x d_pad)` -> row-major `(tm x tn)` distances.
    pub fn distance_tile_sized(
        &self,
        metric: &str,
        tm: usize,
        tn: usize,
        d_padded: usize,
        a: &[f32],
        b: &[f32],
    ) -> Result<Vec<f32>> {
        let name = self.manifest.distance_name_sized(metric, tm, tn, d_padded);
        let exe = self.executable(&name)?;
        let la = Self::literal_2d(a, tm, d_padded)?;
        let lb = Self::literal_2d(b, tn, d_padded)?;
        let out = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let dist = out.to_tuple1()?.to_vec::<f32>()?;
        self.stats.record((a.len() + b.len()) * 4, dist.len() * 4);
        Ok(dist)
    }

    /// Base-tile distance (`tile.m x tile.n`) — the pre-perf-pass entry
    /// point, still used by tests and micro benches.
    pub fn distance_tile(
        &self,
        metric: &str,
        d_padded: usize,
        a: &[f32],
        b: &[f32],
    ) -> Result<Vec<f32>> {
        let t = self.manifest.tile.clone();
        self.distance_tile_sized(metric, t.m, t.n, d_padded, a, b)
    }

    /// Fused K-means assignment tile of explicit row count `tm`.
    pub fn kmeans_assign_tile_sized(
        &self,
        tm: usize,
        k_padded: usize,
        d_padded: usize,
        points: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let name = self.manifest.kmeans_name_sized(tm, k_padded, d_padded);
        let exe = self.executable(&name)?;
        let lp = Self::literal_2d(points, tm, d_padded)?;
        let lc = Self::literal_2d(centers, k_padded, d_padded)?;
        let out = exe.execute::<xla::Literal>(&[lp, lc])?[0][0].to_literal_sync()?;
        let (idx_l, dist_l) = out.to_tuple2()?;
        let idx = idx_l.to_vec::<i32>()?;
        let dist = dist_l.to_vec::<f32>()?;
        self.stats
            .record((points.len() + centers.len()) * 4, idx.len() * 4 + dist.len() * 4);
        Ok((idx, dist))
    }

    /// Base-tile fused K-means assignment.
    pub fn kmeans_assign_tile(
        &self,
        k_padded: usize,
        d_padded: usize,
        points: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let m = self.manifest.tile.m;
        self.kmeans_assign_tile_sized(m, k_padded, d_padded, points, centers)
    }

    /// Fused KNN tile: per-source-row top-`tile.knn_k` (value, local idx).
    pub fn knn_tile(&self, d_padded: usize, a: &[f32], b: &[f32]) -> Result<KnnTileOut> {
        let t = &self.manifest.tile;
        let name = self.manifest.knn_name(d_padded);
        let exe = self.executable(&name)?;
        let la = Self::literal_2d(a, t.m, d_padded)?;
        let lb = Self::literal_2d(b, t.n, d_padded)?;
        let out = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let (vals_l, idx_l) = out.to_tuple2()?;
        let vals = vals_l.to_vec::<f32>()?;
        let idx = idx_l.to_vec::<i32>()?;
        self.stats.record((a.len() + b.len()) * 4, vals.len() * 8);
        Ok(KnnTileOut { vals, idx, rows: t.m, k: t.knn_k })
    }

    /// Radius-limited N-body acceleration tile of explicit edges:
    /// `pos_i (tm x 3)`, `pos_j (tn x 3)`, `mass_j (tn)`, softening^2,
    /// radius^2 -> `(tm x 3)` acceleration (only neighbors with
    /// r^2 <= rmax2 contribute; padding rows carry mass 0).
    pub fn nbody_accel_sized(
        &self,
        tm: usize,
        tn: usize,
        pos_i: &[f32],
        pos_j: &[f32],
        mass_j: &[f32],
        eps2: f32,
        rmax2: f32,
    ) -> Result<Vec<f32>> {
        let name = self.manifest.nbody_name_sized(tm, tn);
        let exe = self.executable(&name)?;
        let li = Self::literal_2d(pos_i, tm, 3)?;
        let lj = Self::literal_2d(pos_j, tn, 3)?;
        let lm = xla::Literal::vec1(mass_j);
        let le = xla::Literal::vec1(&[eps2, rmax2]);
        let out = exe.execute::<xla::Literal>(&[li, lj, lm, le])?[0][0].to_literal_sync()?;
        let acc = out.to_tuple1()?.to_vec::<f32>()?;
        self.stats
            .record((pos_i.len() + pos_j.len() + mass_j.len() + 2) * 4, acc.len() * 4);
        Ok(acc)
    }

    /// Base-tile N-body acceleration (back-compat entry point).
    pub fn nbody_accel_tile_masked(
        &self,
        pos_i: &[f32],
        pos_j: &[f32],
        mass_j: &[f32],
        eps2: f32,
        rmax2: f32,
    ) -> Result<Vec<f32>> {
        let t = self.manifest.tile.nbody;
        self.nbody_accel_sized(t, t, pos_i, pos_j, mass_j, eps2, rmax2)
    }

    /// Artifact names a given kind/d combination resolves to (for warmup).
    pub fn names_for(&self, kind: ArtifactKind, d_padded: usize, k_padded: usize) -> Vec<String> {
        match kind {
            ArtifactKind::Distance => vec![
                self.manifest.distance_name("l2sq", d_padded),
                self.manifest.distance_name("l1", d_padded),
            ],
            ArtifactKind::KmeansAssign => vec![self.manifest.kmeans_name(k_padded, d_padded)],
            ArtifactKind::KnnTile => vec![self.manifest.knn_name(d_padded)],
            ArtifactKind::NbodyAccel => vec![self.manifest.nbody_name()],
        }
    }
}
