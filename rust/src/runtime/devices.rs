//! Emulated multi-device topology: N deterministic devices behind the
//! one shared reference [`Runtime`](crate::runtime::Runtime).
//!
//! The paper's platform is a CPU+FPGA pair where the PCIe/DMA link is
//! a first-class cost (§VI-B); production multi-accelerator hosts are
//! the same picture N times.  The reference backend computes every
//! tile on the host, so the emulation models the part that actually
//! changes results *placement* decisions: **where data lives and what
//! moving it costs**.  Each [`EmulatedDevice`] carries a memory budget
//! (which clamps the slab budgets of the shards pinned to it) and a
//! [`DmaModel`] link (which prices cold-slab uploads for the
//! movement-aware planner/stealer and drives the double-buffered
//! transfer/compute overlap accounting in `serve::exec`).
//!
//! Compute itself still runs through the shared `Runtime`, so results
//! stay bit-for-bit identical for any device count — the serve parity
//! contract extends over the device axis for free, and the manifest
//! contract is untouched: a real PJRT/FPGA backend slots in by giving
//! each [`EmulatedDevice`] a real runtime instead of a model.

use crate::config::ServeConfig;
use crate::fpga::cost::DmaModel;

/// One emulated accelerator: an identity, a memory budget and a DMA
/// link.  Deterministic by construction — it holds no state, only
/// model parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct EmulatedDevice {
    pub id: usize,
    /// Modeled device memory in bytes; 0 = unlimited.
    pub mem_bytes: usize,
    /// Modeled host<->device DMA link.
    pub dma: DmaModel,
}

/// The device pool shards are pinned onto: `shard % device_count()`.
///
/// Round-robin pinning is deterministic and independent of load, so
/// the shard→device map is a pure function of the config — a
/// prerequisite for the parity contract (placement may consult the
/// topology, execution may account against it, neither may let it
/// perturb results).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTopology {
    devices: Vec<EmulatedDevice>,
}

impl DeviceTopology {
    /// `devices` identical devices of `mem_bytes` memory behind
    /// `gbps` DMA links.  `devices` is clamped to ≥ 1 (a pool with no
    /// devices cannot execute anything).
    pub fn new(devices: usize, mem_bytes: usize, gbps: f64) -> Self {
        let dma = DmaModel::new(gbps);
        Self {
            devices: (0..devices.max(1))
                .map(|id| EmulatedDevice { id, mem_bytes, dma })
                .collect(),
        }
    }

    /// The topology the serving knobs describe (`serve.devices`,
    /// `serve.device_mem_bytes`, `serve.dma_gbps`).
    pub fn from_serve(cfg: &ServeConfig) -> Self {
        Self::new(cfg.devices, cfg.device_mem_bytes, cfg.dma_gbps)
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    pub fn devices(&self) -> &[EmulatedDevice] {
        &self.devices
    }

    /// The device shard `shard` is pinned to (round-robin).
    pub fn device_for_shard(&self, shard: usize) -> usize {
        shard % self.devices.len()
    }

    /// How many of `total_shards` shards are pinned to `device`.
    pub fn shards_on_device(&self, device: usize, total_shards: usize) -> usize {
        let n = self.devices.len();
        if device >= n {
            return 0;
        }
        total_shards / n + usize::from(device < total_shards % n)
    }

    /// The DMA link of the device `shard` is pinned to.
    pub fn dma_for_shard(&self, shard: usize) -> &DmaModel {
        &self.devices[self.device_for_shard(shard)].dma
    }

    /// The slab-cache byte budget of one shard: the configured
    /// per-shard budget (`cfg_bytes`, 0 = the cache is DISABLED and
    /// stays disabled) clamped to the shard's even share of its
    /// device's memory (device `mem_bytes` 0 = unlimited, no clamp).
    /// Residency is therefore tracked against real device capacity:
    /// two shards on one 8 MiB device get 4 MiB of slab residency
    /// each, however generous `serve.slab_cache_bytes` is.
    pub fn shard_slab_budget(&self, shard: usize, total_shards: usize, cfg_bytes: usize) -> usize {
        if cfg_bytes == 0 {
            return 0; // disabled stays disabled
        }
        let dev = self.device_for_shard(shard);
        let mem = self.devices[dev].mem_bytes;
        if mem == 0 {
            return cfg_bytes;
        }
        let tenants = self.shards_on_device(dev, total_shards).max(1);
        cfg_bytes.min(mem / tenants)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_pinning_is_deterministic() {
        let topo = DeviceTopology::new(2, 0, 16.0);
        assert_eq!(topo.device_count(), 2);
        assert_eq!(
            (0..5).map(|s| topo.device_for_shard(s)).collect::<Vec<_>>(),
            vec![0, 1, 0, 1, 0]
        );
        // Zero devices is clamped up, never a division by zero.
        assert_eq!(DeviceTopology::new(0, 0, 16.0).device_count(), 1);
    }

    #[test]
    fn shards_on_device_counts_the_round_robin() {
        let topo = DeviceTopology::new(2, 0, 16.0);
        // 3 shards over 2 devices: device 0 gets shards {0, 2}.
        assert_eq!(topo.shards_on_device(0, 3), 2);
        assert_eq!(topo.shards_on_device(1, 3), 1);
        assert_eq!(topo.shards_on_device(7, 3), 0, "unknown device hosts nothing");
        let even = DeviceTopology::new(4, 0, 16.0);
        assert_eq!(even.shards_on_device(3, 8), 2);
    }

    #[test]
    fn slab_budget_clamps_to_the_device_share() {
        // 8 MiB device, 2 shards pinned to it -> 4 MiB each, even
        // though the config asks for 64 MiB.
        let topo = DeviceTopology::new(1, 8 << 20, 16.0);
        assert_eq!(topo.shard_slab_budget(0, 2, 64 << 20), 4 << 20);
        assert_eq!(topo.shard_slab_budget(1, 2, 64 << 20), 4 << 20);
        // A small config budget is NOT inflated to the device share.
        assert_eq!(topo.shard_slab_budget(0, 2, 1 << 20), 1 << 20);
        // Unlimited device memory -> the config budget passes through.
        let unlimited = DeviceTopology::new(2, 0, 16.0);
        assert_eq!(unlimited.shard_slab_budget(1, 4, 64 << 20), 64 << 20);
        // Disabled stays disabled regardless of device memory.
        assert_eq!(topo.shard_slab_budget(0, 2, 0), 0);
    }

    #[test]
    fn from_serve_reads_the_knobs() {
        let cfg = ServeConfig {
            devices: 3,
            device_mem_bytes: 123,
            dma_gbps: 4.0,
            ..ServeConfig::default()
        };
        let topo = DeviceTopology::from_serve(&cfg);
        assert_eq!(topo.device_count(), 3);
        assert_eq!(topo.devices()[2], EmulatedDevice {
            id: 2,
            mem_bytes: 123,
            dma: DmaModel::new(4.0)
        });
        assert_eq!(topo.dma_for_shard(5), &DmaModel::new(4.0));
    }
}
