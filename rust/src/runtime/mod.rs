//! Tile runtime: loads AOT artifact manifests and executes tile kernels.
//!
//! This is the boundary between the Rust coordinator and the
//! accelerator kernels authored in JAX/Pallas.  [`Runtime::load`] reads
//! `artifacts/manifest.json` and resolves every module lazily at first
//! use; [`Runtime::load_or_builtin`] additionally falls back to the
//! built-in tile catalogue when no artifact directory is deployed, so
//! the engine (and the serving runtime on top of it) work out of the
//! box.  The hot path then only calls [`Runtime::distance_tile`] &
//! friends.
//!
//! Execution is the in-tree **reference backend**: the offline vendored
//! registry carries no PJRT/XLA native closure, so tiles are computed
//! by bit-deterministic scalar kernels with the exact semantics the HLO
//! modules were validated against (`rust/tests/runtime_roundtrip.rs`).
//! Python never runs here.

mod artifacts;
mod devices;
mod exec;

pub use artifacts::{ArtifactEntry, ArtifactKind, Manifest, TileInfo};
pub use devices::{DeviceTopology, EmulatedDevice};
pub use exec::{KnnTileOut, Runtime};
