//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! This is the boundary between the Rust coordinator and the accelerator
//! kernels authored in JAX/Pallas.  At startup [`Runtime::load`] reads
//! `artifacts/manifest.json`, compiles every HLO-text module on the PJRT
//! CPU client, and caches the executables; the hot path then only calls
//! [`Runtime::distance_tile`] & friends, which copy literals in/out.
//!
//! Python never runs here — the artifacts are self-contained HLO.

mod artifacts;
mod exec;

pub use artifacts::{ArtifactEntry, ArtifactKind, Manifest, TileInfo};
pub use exec::{KnnTileOut, Runtime};
