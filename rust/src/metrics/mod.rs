//! Execution metrics: the single report structure every algorithm run
//! fills in, printed by the CLI and serialized into bench results.
//!
//! The same struct backs the paper-figure harnesses: Fig. 8 consumes
//! `wall_secs` ratios, Fig. 9 `energy_j` ratios, Fig. 10 the breakdown
//! fields, and the ablation benches the filter/layout sub-stats.

use crate::fpga::device::DeviceStats;
use crate::gti::FilterStats;
use crate::layout::LayoutStats;
use crate::util::json::{self, Value};

/// Complete accounting of one algorithm execution.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub algorithm: String,
    pub dataset: String,
    pub implementation: String,
    /// End-to-end wall time.
    pub wall_secs: f64,
    /// CPU-side filter/group time (the paper's Latency_filt share).
    pub filter_secs: f64,
    /// Accelerator wall time (PJRT execution, measured).
    pub device_wall_secs: f64,
    /// Accelerator modeled time (DE10-Pro cost model).
    pub device_modeled_secs: f64,
    /// Modeled energy (joules) for the run.
    pub energy_j: f64,
    /// Modeled average power (watts).
    pub avg_watts: f64,
    /// Iterations executed (iterative algorithms).
    pub iterations: usize,
    pub filter: FilterStats,
    pub layout: LayoutStats,
    pub device: DeviceStats,
    /// Algorithm-specific headline quality number (e.g. K-means
    /// objective, N-body total energy drift) for cross-impl checking.
    pub quality: f64,
}

impl RunReport {
    pub fn new(algorithm: &str, dataset: &str, implementation: &str) -> Self {
        Self {
            algorithm: algorithm.into(),
            dataset: dataset.into(),
            implementation: implementation.into(),
            ..Default::default()
        }
    }

    /// Speedup of this run relative to a baseline run.
    pub fn speedup_vs(&self, baseline: &RunReport) -> f64 {
        if self.wall_secs > 0.0 {
            baseline.wall_secs / self.wall_secs
        } else {
            f64::INFINITY
        }
    }

    /// End-to-end time with the accelerator's *measured* (CPU-PJRT
    /// testbed) execution replaced by the DE10-Pro cost model's time —
    /// the projection used for the "modeled" columns of the figure
    /// harnesses.  CPU-side phases stay measured.
    pub fn modeled_wall_secs(&self) -> f64 {
        (self.wall_secs - self.device_wall_secs + self.device_modeled_secs).max(1e-12)
    }

    /// Speedup using the modeled accelerator time (DE10-Pro projection).
    pub fn modeled_speedup_vs(&self, baseline: &RunReport) -> f64 {
        baseline.wall_secs / self.modeled_wall_secs()
    }

    /// Energy under the DE10-Pro projection: host share at measured
    /// filter time, FPGA share busy for the modeled device time, over
    /// the modeled wall time.
    pub fn modeled_energy_j(&self) -> f64 {
        crate::fpga::PowerModel::default().accd_joules(
            self.modeled_wall_secs(),
            self.filter_secs,
            1.0,
            self.device_modeled_secs,
        )
    }

    /// Energy-efficiency ratio vs baseline using the modeled energy.
    pub fn modeled_energy_eff_vs(&self, baseline: &RunReport) -> f64 {
        baseline.energy_j / self.modeled_energy_j().max(1e-12)
    }

    /// Energy-efficiency ratio vs a baseline (higher = better).
    pub fn energy_eff_vs(&self, baseline: &RunReport) -> f64 {
        if self.energy_j > 0.0 {
            baseline.energy_j / self.energy_j
        } else {
            f64::INFINITY
        }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("algorithm", json::s(self.algorithm.clone())),
            ("dataset", json::s(self.dataset.clone())),
            ("implementation", json::s(self.implementation.clone())),
            ("wall_secs", json::num(self.wall_secs)),
            ("filter_secs", json::num(self.filter_secs)),
            ("device_wall_secs", json::num(self.device_wall_secs)),
            ("device_modeled_secs", json::num(self.device_modeled_secs)),
            ("energy_j", json::num(self.energy_j)),
            ("avg_watts", json::num(self.avg_watts)),
            ("iterations", json::num(self.iterations as f64)),
            ("quality", json::num(self.quality)),
            ("filter_total_pairs", json::num(self.filter.total_pairs as f64)),
            ("filter_surviving_pairs", json::num(self.filter.surviving_pairs as f64)),
            ("filter_bound_comps", json::num(self.filter.bound_comps as f64)),
            ("filter_saving_ratio", json::num(self.filter.saving_ratio())),
            ("filter_tiles_skipped", json::num(self.filter.tiles_skipped as f64)),
            ("filter_points_pruned", json::num(self.filter.points_pruned as f64)),
            ("filter_bound_recomputes", json::num(self.filter.bound_recomputes as f64)),
            ("layout_reuse_ratio", json::num(self.layout.reuse_ratio())),
            ("device_tiles", json::num(self.device.tiles as f64)),
            ("device_pad_efficiency", json::num(self.device.pad_efficiency())),
            ("device_bytes_moved", json::num(self.device.bytes_moved as f64)),
        ])
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{} / {} [{}]\n  wall {:.3}s (filter {:.3}s, device {:.3}s wall / {:.3}s modeled)\n  \
             energy {:.1} J @ {:.1} W avg | iterations {} | quality {:.6}\n  \
             filter: {:.1}% saved ({} of {} pairs survive, {} bound comps)\n  \
             device: {} tiles, pad eff {:.1}%, {:.1} MB moved | layout reuse {:.1}%",
            self.algorithm,
            self.dataset,
            self.implementation,
            self.wall_secs,
            self.filter_secs,
            self.device_wall_secs,
            self.device_modeled_secs,
            self.energy_j,
            self.avg_watts,
            self.iterations,
            self.quality,
            100.0 * self.filter.saving_ratio(),
            self.filter.surviving_pairs,
            self.filter.total_pairs,
            self.filter.bound_comps,
            self.device.tiles,
            100.0 * self.device.pad_efficiency(),
            self.device.bytes_moved as f64 / 1e6,
            100.0 * self.layout.reuse_ratio(),
        )
    }
}

/// Retained latency samples per [`ServeStats`] view: a bounded ring
/// (newest overwrites oldest past the cap) keeping a long-lived
/// server's stats O(1) in memory; at 8 bytes a sample this is 512 KiB
/// per view, and the percentile accessors describe the most recent
/// window.
pub const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Nearest-rank percentile (`q` in 0..=100) over ascending-sorted
/// nanosecond samples, in milliseconds.  The single shared formula
/// behind every `ServeStats` latency accessor.
///
/// Nearest rank is the smallest `r` in `1..=n` with `r/n >= q/100`,
/// checked as `r * 100 >= q * n` so no division can smuggle in a
/// rounding error: `ceil(q/100 * n)` overshoots by one whenever
/// `q/100` rounds up an ulp (q=7, n=100: `0.07 * 100` lands at
/// `7.000000000000001`, ceil said rank 8 where rank 7 satisfies the
/// defining inequality exactly).  The ceil estimate is kept as the
/// starting point and corrected against the inequality itself.
fn percentile_of_sorted_ms(sorted: &[u64], q: f64) -> f64 {
    percentile_of_sorted(sorted, q) as f64 / 1e6
}

/// Nearest-rank percentile over ascending-sorted samples, in the
/// samples' own unit (see [`percentile_of_sorted_ms`] for the rank
/// arithmetic rationale).
fn percentile_of_sorted(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len();
    let target = q * n as f64;
    let mut rank = (((q / 100.0) * n as f64).ceil() as usize).clamp(1, n);
    while rank > 1 && ((rank - 1) as f64) * 100.0 >= target {
        rank -= 1;
    }
    while rank < n && ((rank as f64) * 100.0) < target {
        rank += 1;
    }
    sorted[rank - 1]
}

/// Accounting of the batched serving runtime (`accd::serve`).
///
/// Two views exist: each engine shard accumulates one instance over
/// its own executions ([`crate::serve::QueryBatcher::shard_stats`]),
/// and the batcher maintains the merged lifetime view
/// ([`crate::serve::QueryBatcher::stats`]).  Per-flush deltas are
/// folded in with [`ServeStats::absorb_exec`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// Queries answered (including deduplicated ones).
    pub queries: u64,
    /// Flushes executed (merged view) / participated in (shard view).
    pub flushes: u64,
    /// Flushes triggered by an expired admission deadline (`poll`).
    pub deadline_flushes: u64,
    pub knn_queries: u64,
    pub rangejoin_queries: u64,
    pub kmeans_queries: u64,
    pub nbody_queries: u64,
    /// Queries answered from an identical in-flight query's result.
    pub dedup_hits: u64,
    /// Grouping-cache hits / misses (a hit skips a whole
    /// `Latency_filt` grouping build).
    pub grouping_cache_hits: u64,
    pub grouping_cache_misses: u64,
    /// Grouping-cache probe collisions: a fingerprint matched but the
    /// secondary content probe did not, forcing an uncached rebuild.
    pub grouping_probe_collisions: u64,
    /// Dispatch batches whose packed target slab was served from the
    /// slab cache (built by an earlier query or an earlier flush).
    pub slabs_shared: u64,
    /// Cross-flush slab-cache hits / misses / LRU evictions.
    pub slab_cache_hits: u64,
    pub slab_cache_misses: u64,
    pub slab_cache_evictions: u64,
    /// Bytes currently resident in the slab cache(s).
    pub slab_cache_bytes: u64,
    /// Full O(n) content comparisons performed where the fingerprint
    /// fast path did not apply (today: only N-body mass vectors —
    /// dataset identity always resolves via pointer or fingerprint).
    pub content_full_scans: u64,
    /// Lockstep rounds executed (summed over shards): one round
    /// advances every resident iterative program on a shard by one
    /// step.
    pub lockstep_rounds: u64,
    /// Packed slabs (K-means assignment-tile inputs, KNN target
    /// slabs) served from a shard's slab cache while planning a
    /// program *alongside co-resident programs* under the lockstep
    /// scheduler.  Mostly the scheduler's own cross-program sharing;
    /// a warm persistent cache can also contribute when its entries
    /// are re-hit during co-resident planning (hits on an idle shard
    /// are never counted — those are purely cross-flush reuse and
    /// show in the `slab_cache_*` gauges).
    pub lockstep_shared_tiles: u64,
    /// Not-yet-started work units an idle shard stole from a busy one
    /// after the LPT placement's cost estimates misfired.
    pub steals: u64,
    /// Modeled host→device DMA nanoseconds spent uploading cold slabs
    /// (per the shard's device [`DmaModel`](crate::fpga::DmaModel);
    /// warm slabs transfer nothing).
    pub transfer_ns: u64,
    /// Modeled device compute nanoseconds (the cost model's tile time,
    /// summed over the shard's plans/steps).
    pub compute_ns: u64,
    /// Modeled nanoseconds the double-buffered second DMA channel
    /// saved by hiding uploads under compute: total transfer + compute
    /// work minus the overlapped timeline's makespan.  Exactly 0 when
    /// `serve.overlap` is off (the timeline is serialized).
    pub overlap_ns: u64,
    /// Queries that carried a deadline and whose service STARTED at or
    /// before it (the flush that answered them was selected by the
    /// deadline — a deadline-triggered `poll` fires exactly at expiry
    /// and counts as met; completion tail shows in the latency
    /// percentiles instead).
    pub deadline_met: u64,
    /// Queries that carried a deadline the scheduler had not even
    /// started serving by expiry (backlog / capacity shortfall).  A
    /// late query is still answered — never dropped — but the miss is
    /// counted here, merged and per executing shard.
    pub deadline_misses: u64,
    /// Queries the server's bounded intake turned away under the
    /// `reject` overload policy.  A shed query was never accepted: it
    /// gets no response, no latency sample and no deadline judgement —
    /// this counter is its only trace.  Server-level (merged view
    /// only); shard views stay 0.
    pub shed: u64,
    /// Queries shed by predictive early deadline shedding
    /// (`serve.predictive_shed`): at flush selection their calibrated
    /// predicted completion already overshot an expired deadline, so
    /// no device time was spent on a guaranteed miss.  A predicted
    /// shed gets no response, no latency sample and no met/miss count
    /// — distinct from the server's overload `shed` (never admitted)
    /// and from `deadline_misses` (served late).  Batcher-level
    /// (merged view only); shard views stay 0.
    pub predicted_sheds: u64,
    /// Predicted-vs-actual service-time error per retired program, in
    /// permille of the actual modeled nanoseconds
    /// (`|predicted - actual| * 1000 / actual`).  Bounded ring like
    /// `latency_ns`; the `predict_err_p*_permille` accessors report
    /// percentiles over the most recent window — the calibrator's
    /// observable quality gauge, merged and per shard.
    pub predict_err_permille: Vec<u64>,
    /// Ring write position within `predict_err_permille` past the cap.
    predict_err_cursor: usize,
    /// High-water mark of accepted-but-unanswered queries (intake
    /// backlog + admitted pending) observed by the server — how close
    /// the bounded queue came to `serve.queue_cap`.  Server-level
    /// gauge (merged view only), republished absolutely, never summed.
    pub queue_depth_watermark: u64,
    /// Service attempts that failed mid-flush under the always-on
    /// server (the batch was requeued in order and retried at the next
    /// wake event; shutdown drains count their retries here too).  No
    /// query is lost on a failure — this counter is how operators see
    /// the engine misbehaving.  Server-level (merged view only).
    pub flush_failures: u64,
    /// Per-query completion-latency samples in clock ticks
    /// (nanoseconds; submit-to-response on the batcher's injected
    /// `serve::Clock`).  Every answered query contributes one sample,
    /// deadline or not; the `latency_p*_ms` accessors report
    /// percentiles over them.  Bounded: a ring of the most recent
    /// [`LATENCY_SAMPLE_CAP`] samples, so a long-lived server's stats
    /// stay O(1) in memory.
    pub latency_ns: Vec<u64>,
    /// Ring write position within `latency_ns` once the cap is hit.
    latency_cursor: usize,
    /// Device tiles dispatched across all flushes...
    pub tiles_total: u64,
    /// ...of which this many served more than one query: tiles of
    /// shared-slab batches plus tiles re-served to deduplicated
    /// queries.
    pub tiles_shared: u64,
    /// Candidate tile rectangles the incremental TI filter dropped
    /// from device submission because every member point was provably
    /// stable (`gti::FilterStats::tiles_skipped`, summed over retired
    /// programs).
    pub tiles_skipped: u64,
    /// Points whose K-means assignment was proven unchanged by the
    /// carried bounds and skipped device recompute
    /// (`gti::FilterStats::points_pruned`, summed over retired
    /// programs).
    pub points_pruned: u64,
    /// Cheap exact CPU upper-bound tightenings spent deciding
    /// stability (`gti::FilterStats::bound_recomputes`, summed over
    /// retired programs) — the CPU price paid for the pruning above.
    pub bound_recomputes: u64,
    /// Wall-clock seconds spent inside `flush` (merged view) /
    /// executing assigned cohorts (shard view).
    pub wall_secs: f64,
}

impl ServeStats {
    /// Sustained throughput over all flushes so far.
    pub fn queries_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.queries as f64 / self.wall_secs
        }
    }

    /// Fraction of dispatched tiles that served more than one query.
    pub fn tiles_shared_ratio(&self) -> f64 {
        if self.tiles_total == 0 {
            0.0
        } else {
            self.tiles_shared as f64 / self.tiles_total as f64
        }
    }

    /// Grouping-cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.grouping_cache_hits + self.grouping_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.grouping_cache_hits as f64 / total as f64
        }
    }

    /// Cross-flush slab-cache hit rate.
    pub fn slab_hit_rate(&self) -> f64 {
        let total = self.slab_cache_hits + self.slab_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.slab_cache_hits as f64 / total as f64
        }
    }

    /// Record one answered query's latency and — when the query
    /// carried a deadline — whether it was met.  `missed` is `None`
    /// for deadline-free queries (they contribute a latency sample but
    /// no met/miss count).  The batcher calls this once per answered
    /// query, on the merged view and on the executing shard's view, so
    /// both report percentiles (latencies are recorded at commit time,
    /// not through `absorb_exec`).  Samples beyond
    /// [`LATENCY_SAMPLE_CAP`] overwrite the oldest (ring), so
    /// percentiles always describe the most recent window.
    pub fn record_latency(&mut self, latency_ns: u64, missed: Option<bool>) {
        if self.latency_ns.len() < LATENCY_SAMPLE_CAP {
            self.latency_ns.push(latency_ns);
        } else {
            self.latency_ns[self.latency_cursor] = latency_ns;
            self.latency_cursor = (self.latency_cursor + 1) % LATENCY_SAMPLE_CAP;
        }
        match missed {
            Some(true) => self.deadline_misses += 1,
            Some(false) => self.deadline_met += 1,
            None => {}
        }
    }

    /// Record one retired program's predicted-vs-actual error sample
    /// (permille of actual).  Ring-bounded like `record_latency`.
    pub fn record_predict_error(&mut self, err_permille: u64) {
        if self.predict_err_permille.len() < LATENCY_SAMPLE_CAP {
            self.predict_err_permille.push(err_permille);
        } else {
            self.predict_err_permille[self.predict_err_cursor] = err_permille;
            self.predict_err_cursor = (self.predict_err_cursor + 1) % LATENCY_SAMPLE_CAP;
        }
    }

    /// Nearest-rank percentile of the predicted-vs-actual error window
    /// (permille of actual); 0 with no samples.
    pub fn predict_err_permille_at(&self, q: f64) -> u64 {
        if self.predict_err_permille.is_empty() {
            return 0;
        }
        let mut sorted = self.predict_err_permille.clone();
        sorted.sort_unstable();
        percentile_of_sorted(&sorted, q)
    }

    pub fn predict_err_p50_permille(&self) -> u64 {
        self.predict_err_permille_at(50.0)
    }

    pub fn predict_err_p95_permille(&self) -> u64 {
        self.predict_err_permille_at(95.0)
    }

    /// The sorted latency window, or `None` when no samples exist —
    /// the one place the clone+sort happens.
    fn sorted_latencies(&self) -> Option<Vec<u64>> {
        if self.latency_ns.is_empty() {
            return None;
        }
        let mut sorted = self.latency_ns.clone();
        sorted.sort_unstable();
        Some(sorted)
    }

    /// `(p50, p95, p99)` latency in milliseconds with ONE sort of the
    /// sample window — what `to_json`/`summary` (and the bench) use,
    /// instead of three independent sort passes.
    pub fn latency_percentiles_ms(&self) -> (f64, f64, f64) {
        match self.sorted_latencies() {
            None => (0.0, 0.0, 0.0),
            Some(sorted) => (
                percentile_of_sorted_ms(&sorted, 50.0),
                percentile_of_sorted_ms(&sorted, 95.0),
                percentile_of_sorted_ms(&sorted, 99.0),
            ),
        }
    }

    /// Nearest-rank latency percentile in milliseconds (`q` in 0..=100);
    /// 0.0 with no samples.
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        match self.sorted_latencies() {
            None => 0.0,
            Some(sorted) => percentile_of_sorted_ms(&sorted, q),
        }
    }

    pub fn latency_p50_ms(&self) -> f64 {
        self.latency_percentile_ms(50.0)
    }

    pub fn latency_p95_ms(&self) -> f64 {
        self.latency_percentile_ms(95.0)
    }

    pub fn latency_p99_ms(&self) -> f64 {
        self.latency_percentile_ms(99.0)
    }

    /// Fold one flush's execution counters into this accumulator.
    ///
    /// Sums what a shard's execution produces per flush (queries,
    /// per-kind counts, dedup hits, shared slabs/tiles).  Deliberately
    /// NOT summed: `flushes` / `deadline_flushes` / `content_full_scans`
    /// (batcher-level events), `wall_secs` (a shard's wall overlaps
    /// other shards', so the batcher adds its own flush wall to the
    /// merged view instead), and every cache gauge (`grouping_cache_*`,
    /// `grouping_probe_collisions`, `slab_cache_*`) — those are
    /// re-published as absolute values read from the caches after each
    /// successful flush, so they can never drift from cache reality.
    /// Latency samples and `deadline_met` / `deadline_misses` are also
    /// not summed here: the batcher records them per answered query via
    /// [`ServeStats::record_latency`] (a shard's delta never carries
    /// them — only the batcher knows submit times).  `shed`,
    /// `queue_depth_watermark` and `flush_failures` are server-level
    /// (the admission front end owns them; no shard ever sees a shed
    /// query or a requeued batch).
    pub fn absorb_exec(&mut self, d: &ServeStats) {
        self.queries += d.queries;
        self.knn_queries += d.knn_queries;
        self.rangejoin_queries += d.rangejoin_queries;
        self.kmeans_queries += d.kmeans_queries;
        self.nbody_queries += d.nbody_queries;
        self.dedup_hits += d.dedup_hits;
        self.slabs_shared += d.slabs_shared;
        self.tiles_total += d.tiles_total;
        self.tiles_shared += d.tiles_shared;
        self.tiles_skipped += d.tiles_skipped;
        self.points_pruned += d.points_pruned;
        self.bound_recomputes += d.bound_recomputes;
        self.lockstep_rounds += d.lockstep_rounds;
        self.lockstep_shared_tiles += d.lockstep_shared_tiles;
        self.steals += d.steals;
        self.transfer_ns += d.transfer_ns;
        self.compute_ns += d.compute_ns;
        self.overlap_ns += d.overlap_ns;
        // Error samples ARE absorbed (the shard's exec loop is where
        // predictions meet actuals); `predicted_sheds` is not — like
        // `shed`, the admission side owns it.
        for &e in &d.predict_err_permille {
            self.record_predict_error(e);
        }
    }

    pub fn to_json(&self) -> Value {
        let (p50, p95, p99) = self.latency_percentiles_ms();
        json::obj(vec![
            ("queries", json::num(self.queries as f64)),
            ("flushes", json::num(self.flushes as f64)),
            ("deadline_flushes", json::num(self.deadline_flushes as f64)),
            ("knn_queries", json::num(self.knn_queries as f64)),
            ("rangejoin_queries", json::num(self.rangejoin_queries as f64)),
            ("kmeans_queries", json::num(self.kmeans_queries as f64)),
            ("nbody_queries", json::num(self.nbody_queries as f64)),
            ("dedup_hits", json::num(self.dedup_hits as f64)),
            ("grouping_cache_hits", json::num(self.grouping_cache_hits as f64)),
            ("grouping_cache_misses", json::num(self.grouping_cache_misses as f64)),
            ("grouping_probe_collisions", json::num(self.grouping_probe_collisions as f64)),
            ("cache_hit_rate", json::num(self.cache_hit_rate())),
            ("slabs_shared", json::num(self.slabs_shared as f64)),
            ("slab_cache_hits", json::num(self.slab_cache_hits as f64)),
            ("slab_cache_misses", json::num(self.slab_cache_misses as f64)),
            ("slab_cache_evictions", json::num(self.slab_cache_evictions as f64)),
            ("slab_cache_bytes", json::num(self.slab_cache_bytes as f64)),
            ("slab_hit_rate", json::num(self.slab_hit_rate())),
            ("content_full_scans", json::num(self.content_full_scans as f64)),
            ("lockstep_rounds", json::num(self.lockstep_rounds as f64)),
            ("lockstep_shared_tiles", json::num(self.lockstep_shared_tiles as f64)),
            ("steals", json::num(self.steals as f64)),
            ("transfer_ns", json::num(self.transfer_ns as f64)),
            ("compute_ns", json::num(self.compute_ns as f64)),
            ("overlap_ns", json::num(self.overlap_ns as f64)),
            ("deadline_met", json::num(self.deadline_met as f64)),
            ("deadline_misses", json::num(self.deadline_misses as f64)),
            ("shed", json::num(self.shed as f64)),
            ("predicted_sheds", json::num(self.predicted_sheds as f64)),
            ("predict_err_p50_permille", json::num(self.predict_err_p50_permille() as f64)),
            ("predict_err_p95_permille", json::num(self.predict_err_p95_permille() as f64)),
            ("predict_err_samples", json::num(self.predict_err_permille.len() as f64)),
            ("queue_depth_watermark", json::num(self.queue_depth_watermark as f64)),
            ("flush_failures", json::num(self.flush_failures as f64)),
            ("latency_p50_ms", json::num(p50)),
            ("latency_p95_ms", json::num(p95)),
            ("latency_p99_ms", json::num(p99)),
            ("tiles_total", json::num(self.tiles_total as f64)),
            ("tiles_shared", json::num(self.tiles_shared as f64)),
            ("tiles_shared_ratio", json::num(self.tiles_shared_ratio())),
            ("tiles_skipped", json::num(self.tiles_skipped as f64)),
            ("points_pruned", json::num(self.points_pruned as f64)),
            ("bound_recomputes", json::num(self.bound_recomputes as f64)),
            ("wall_secs", json::num(self.wall_secs)),
            ("queries_per_sec", json::num(self.queries_per_sec())),
        ])
    }

    /// Human-readable summary for CLIs and benches.
    pub fn summary(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles_ms();
        format!(
            "serve: {} queries in {} flushes ({:.1} q/s, {} deadline-driven)\n  \
             mix: {} knn / {} rangejoin / {} kmeans / {} nbody | dedup {} ({} full scans)\n  \
             grouping cache: {} hits / {} misses ({:.1}% hit rate, {} probe collisions)\n  \
             slab cache: {} hits / {} misses ({:.1}% hit rate), {} evictions, {:.1} MB resident\n  \
             lockstep: {} rounds, {} shared tiles | {} units stolen\n  \
             device timeline: {:.3} ms transfer / {:.3} ms compute, {:.3} ms overlapped\n  \
             latency: p50 {:.3} ms / p95 {:.3} ms / p99 {:.3} ms | \
             deadlines: {} met / {} missed | shed {} (depth high-water {})\n  \
             calibration: {} predicted sheds | predict error p50 {}‰ / p95 {}‰ ({} samples)\n  \
             tiles: {} shared of {} total ({:.1}%) | shared slabs {}\n  \
             incremental TI: {} tiles skipped, {} points pruned, {} bound recomputes",
            self.queries,
            self.flushes,
            self.queries_per_sec(),
            self.deadline_flushes,
            self.knn_queries,
            self.rangejoin_queries,
            self.kmeans_queries,
            self.nbody_queries,
            self.dedup_hits,
            self.content_full_scans,
            self.grouping_cache_hits,
            self.grouping_cache_misses,
            100.0 * self.cache_hit_rate(),
            self.grouping_probe_collisions,
            self.slab_cache_hits,
            self.slab_cache_misses,
            100.0 * self.slab_hit_rate(),
            self.slab_cache_evictions,
            self.slab_cache_bytes as f64 / 1e6,
            self.lockstep_rounds,
            self.lockstep_shared_tiles,
            self.steals,
            self.transfer_ns as f64 / 1e6,
            self.compute_ns as f64 / 1e6,
            self.overlap_ns as f64 / 1e6,
            p50,
            p95,
            p99,
            self.deadline_met,
            self.deadline_misses,
            self.shed,
            self.queue_depth_watermark,
            self.predicted_sheds,
            self.predict_err_p50_permille(),
            self.predict_err_p95_permille(),
            self.predict_err_permille.len(),
            self.tiles_shared,
            self.tiles_total,
            100.0 * self.tiles_shared_ratio(),
            self.slabs_shared,
            self.tiles_skipped,
            self.points_pruned,
            self.bound_recomputes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_stats_ratios_and_json() {
        let mut s = ServeStats::default();
        assert_eq!(s.queries_per_sec(), 0.0);
        assert_eq!(s.tiles_shared_ratio(), 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        s.queries = 10;
        s.wall_secs = 2.0;
        s.tiles_total = 100;
        s.tiles_shared = 25;
        s.grouping_cache_hits = 3;
        s.grouping_cache_misses = 1;
        s.slab_cache_hits = 6;
        s.slab_cache_misses = 2;
        assert_eq!(s.queries_per_sec(), 5.0);
        assert_eq!(s.tiles_shared_ratio(), 0.25);
        assert_eq!(s.cache_hit_rate(), 0.75);
        assert_eq!(s.slab_hit_rate(), 0.75);
        let v = s.to_json();
        assert_eq!(v.get("queries").as_usize(), Some(10));
        assert_eq!(v.get("tiles_shared_ratio").as_f64(), Some(0.25));
        assert_eq!(v.get("slab_cache_hits").as_usize(), Some(6));
        assert!(v.get("grouping_probe_collisions").as_f64().is_some());
        assert!(s.summary().contains("10 queries"));
        assert!(s.summary().contains("slab cache"));
    }

    #[test]
    fn latency_percentiles_and_deadline_counters() {
        let mut s = ServeStats::default();
        assert_eq!(s.latency_p50_ms(), 0.0, "no samples -> 0");
        // 10 samples: 1..=10 ms.
        for ms in 1..=10u64 {
            let missed = match ms {
                1..=3 => Some(false),
                4 => Some(true),
                _ => None,
            };
            s.record_latency(ms * 1_000_000, missed);
        }
        assert_eq!(s.deadline_met, 3);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.latency_ns.len(), 10);
        // Nearest-rank: p50 of 1..=10 ms is the 5th sample.
        assert_eq!(s.latency_p50_ms(), 5.0);
        assert_eq!(s.latency_p95_ms(), 10.0);
        assert_eq!(s.latency_p99_ms(), 10.0);
        assert_eq!(s.latency_percentiles_ms(), (5.0, 10.0, 10.0), "single-sort triple agrees");
        assert_eq!(s.latency_percentile_ms(0.0), 1.0, "floor clamps to the first sample");
        s.shed = 2;
        s.queue_depth_watermark = 17;
        let v = s.to_json();
        assert_eq!(v.get("deadline_met").as_usize(), Some(3));
        assert_eq!(v.get("deadline_misses").as_usize(), Some(1));
        assert_eq!(v.get("shed").as_usize(), Some(2));
        assert_eq!(v.get("queue_depth_watermark").as_usize(), Some(17));
        assert_eq!(v.get("latency_p50_ms").as_f64(), Some(5.0));
        assert!(s.summary().contains("p50"));
        assert!(s.summary().contains("3 met / 1 missed"));
        assert!(s.summary().contains("shed 2 (depth high-water 17)"));
    }

    /// The defining nearest-rank inequality, evaluated directly: the
    /// smallest rank `r` with `r * 100 >= q * n`.  O(n) and obviously
    /// correct — the reference the fast path must match everywhere.
    fn naive_percentile_ms(sorted: &[u64], q: f64) -> f64 {
        let n = sorted.len();
        let target = q * n as f64;
        let r = (1..=n).find(|&r| (r as f64) * 100.0 >= target).unwrap_or(n);
        sorted[r - 1] as f64 / 1e6
    }

    #[test]
    fn percentile_rank_is_exact_at_float_boundaries() {
        // Regression: `ceil(q/100 * n)` overshot the nearest rank by
        // one whenever q/100 rounded up an ulp.  With samples
        // 1..=100 ms, the q-th percentile of n=100 IS the q-th sample.
        let mut s = ServeStats::default();
        for ms in 1..=100u64 {
            s.record_latency(ms * 1_000_000, None);
        }
        assert_eq!(s.latency_percentile_ms(7.0), 7.0, "q=7: 0.07*100 ceils to 8");
        assert_eq!(s.latency_percentile_ms(55.0), 55.0, "q=55: 0.55*100 ceils to 56");
        for q in 1..=100u64 {
            assert_eq!(s.latency_percentile_ms(q as f64), q as f64, "q={q}");
        }
    }

    #[test]
    fn percentile_boundaries_and_degenerate_windows() {
        // Single sample: every q reports it.
        let mut one = ServeStats::default();
        one.record_latency(3_000_000, None);
        for q in [-5.0, 0.0, 0.5, 50.0, 99.9, 100.0, 250.0] {
            assert_eq!(one.latency_percentile_ms(q), 3.0, "single sample, q={q}");
        }
        // Out-of-range q clamps to the extremes instead of panicking.
        let mut s = ServeStats::default();
        for ms in 1..=10u64 {
            s.record_latency(ms * 1_000_000, None);
        }
        assert_eq!(s.latency_percentile_ms(-1.0), 1.0);
        assert_eq!(s.latency_percentile_ms(0.0), 1.0);
        assert_eq!(s.latency_percentile_ms(100.0), 10.0);
        assert_eq!(s.latency_percentile_ms(400.0), 10.0);
        // q just above a rank boundary moves to the next sample.
        assert_eq!(s.latency_percentile_ms(50.0), 5.0);
        assert_eq!(s.latency_percentile_ms(50.1), 6.0);
    }

    #[test]
    fn prop_percentile_matches_naive_reference() {
        use crate::util::prop::{self, Config};
        prop::check(
            &Config { cases: 128, max_size: 200, seed: 0xbeef, ..Default::default() },
            |rng, size| {
                let n = 1 + rng.below(size.max(1));
                let samples: Vec<u64> =
                    (0..n).map(|_| rng.below(50) as u64 * 1_000_000).collect();
                // Integer, fractional, boundary and out-of-range q.
                let q = match rng.below(4) {
                    0 => rng.below(101) as f64,
                    1 => rng.below(1000) as f64 / 10.0,
                    2 => [0.0, 100.0, -3.0, 180.0][rng.below(4)],
                    _ => rng.below(101) as f64 + 1.0 / 3.0,
                };
                (samples, q)
            },
            |(samples, q)| {
                let mut s = ServeStats::default();
                for &ns in samples {
                    s.record_latency(ns, None);
                }
                let mut sorted = samples.clone();
                sorted.sort_unstable();
                let want = naive_percentile_ms(&sorted, *q);
                let got = s.latency_percentile_ms(*q);
                if got != want {
                    return Err(format!("n={}, q={q}: got {got}, want {want}", samples.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn percentiles_after_ring_wrap_describe_the_window() {
        // Fill past the cap so the ring has wrapped, then check the
        // percentile formula against the naive reference over the
        // window that is actually retained.
        let mut s = ServeStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP + 137) {
            s.record_latency(i as u64, None);
        }
        assert_eq!(s.latency_ns.len(), LATENCY_SAMPLE_CAP);
        let mut sorted = s.latency_ns.clone();
        sorted.sort_unstable();
        for q in [0.0, 1.0, 7.0, 50.0, 55.0, 95.0, 99.0, 100.0] {
            assert_eq!(
                s.latency_percentile_ms(q),
                naive_percentile_ms(&sorted, q),
                "q={q} after ring wrap"
            );
        }
    }

    #[test]
    fn latency_samples_are_ring_bounded() {
        let mut s = ServeStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP + 10) {
            s.record_latency(i as u64, None);
        }
        assert_eq!(s.latency_ns.len(), LATENCY_SAMPLE_CAP, "ring never grows past the cap");
        // The 10 overflow samples overwrote the 10 oldest slots.
        assert_eq!(s.latency_ns[0], LATENCY_SAMPLE_CAP as u64);
        assert_eq!(s.latency_ns[9], LATENCY_SAMPLE_CAP as u64 + 9);
        assert_eq!(s.latency_ns[10], 10);
    }

    #[test]
    fn absorb_exec_sums_counters_but_not_batcher_fields() {
        let mut total = ServeStats { flushes: 2, wall_secs: 1.5, ..Default::default() };
        let delta = ServeStats {
            queries: 4,
            knn_queries: 2,
            rangejoin_queries: 1,
            kmeans_queries: 1,
            dedup_hits: 1,
            grouping_cache_hits: 2,
            grouping_cache_misses: 2,
            grouping_probe_collisions: 1,
            slabs_shared: 5,
            slab_cache_hits: 5,
            slab_cache_misses: 3,
            slab_cache_evictions: 1,
            slab_cache_bytes: 999,
            tiles_total: 40,
            tiles_shared: 10,
            tiles_skipped: 12,
            points_pruned: 33,
            bound_recomputes: 21,
            lockstep_rounds: 6,
            lockstep_shared_tiles: 4,
            steals: 2,
            transfer_ns: 1_000,
            compute_ns: 2_000,
            overlap_ns: 500,
            flushes: 7,
            wall_secs: 9.0,
            deadline_met: 5,
            deadline_misses: 6,
            shed: 3,
            predicted_sheds: 9,
            queue_depth_watermark: 11,
            latency_ns: vec![1, 2, 3],
            predict_err_permille: vec![100, 300],
            ..Default::default()
        };
        total.absorb_exec(&delta);
        // A second delta stacks on top — merged view keeps summing.
        total.absorb_exec(&ServeStats {
            tiles_skipped: 3,
            points_pruned: 7,
            bound_recomputes: 4,
            ..Default::default()
        });
        total.absorb_exec(&ServeStats::default());
        assert_eq!(total.queries, 4);
        assert_eq!(total.knn_queries, 2);
        assert_eq!(total.rangejoin_queries, 1);
        assert_eq!(total.dedup_hits, 1);
        assert_eq!(total.slabs_shared, 5);
        assert_eq!(total.tiles_total, 40);
        assert_eq!(total.tiles_skipped, 15, "prune counters are flush-delta summed");
        assert_eq!(total.points_pruned, 40);
        assert_eq!(total.bound_recomputes, 25);
        assert_eq!(total.lockstep_rounds, 6);
        assert_eq!(total.lockstep_shared_tiles, 4);
        assert_eq!(total.steals, 2);
        // Modeled device-timeline counters are flush-delta summed too.
        assert_eq!(total.transfer_ns, 1_000);
        assert_eq!(total.compute_ns, 2_000);
        assert_eq!(total.overlap_ns, 500);
        // Batcher-level fields and cache gauges untouched (gauges are
        // re-published absolutely from the caches, not delta-summed).
        assert_eq!(total.flushes, 2);
        assert_eq!(total.wall_secs, 1.5);
        assert_eq!(total.grouping_probe_collisions, 0);
        assert_eq!(total.slab_cache_hits, 0);
        assert_eq!(total.slab_cache_evictions, 0);
        assert_eq!(total.slab_cache_bytes, 0);
        // Latency/deadline accounting is recorded per answered query by
        // the batcher (record_latency), never delta-summed.
        assert_eq!(total.deadline_met, 0);
        assert_eq!(total.deadline_misses, 0);
        assert!(total.latency_ns.is_empty());
        // Server-level fields: the admission front end owns them.
        assert_eq!(total.shed, 0);
        assert_eq!(total.queue_depth_watermark, 0);
        // Predicted sheds are batcher-level too; error samples travel
        // with the exec delta.
        assert_eq!(total.predicted_sheds, 0);
        assert_eq!(total.predict_err_permille, vec![100, 300]);
    }

    #[test]
    fn predict_error_ring_and_percentiles() {
        let mut s = ServeStats::default();
        assert_eq!(s.predict_err_p95_permille(), 0, "no samples -> 0");
        for e in [10u64, 20, 30, 40, 1_000] {
            s.record_predict_error(e);
        }
        assert_eq!(s.predict_err_p50_permille(), 30);
        assert_eq!(s.predict_err_p95_permille(), 1_000);
        s.predicted_sheds = 4;
        let v = s.to_json();
        assert_eq!(v.get("predicted_sheds").as_usize(), Some(4));
        assert_eq!(v.get("predict_err_p50_permille").as_usize(), Some(30));
        assert_eq!(v.get("predict_err_p95_permille").as_usize(), Some(1_000));
        assert_eq!(v.get("predict_err_samples").as_usize(), Some(5));
        assert!(s.summary().contains("4 predicted sheds"));
        // Ring-bounded like the latency window.
        let mut s = ServeStats::default();
        for i in 0..(LATENCY_SAMPLE_CAP + 10) {
            s.record_predict_error(i as u64);
        }
        assert_eq!(s.predict_err_permille.len(), LATENCY_SAMPLE_CAP);
        assert_eq!(s.predict_err_permille[0], LATENCY_SAMPLE_CAP as u64);
    }

    #[test]
    fn speedup_and_energy_ratios() {
        let mut base = RunReport::new("kmeans", "ds", "baseline");
        base.wall_secs = 10.0;
        base.energy_j = 250.0;
        let mut fast = RunReport::new("kmeans", "ds", "accd");
        fast.wall_secs = 0.5;
        fast.energy_j = 5.0;
        assert_eq!(fast.speedup_vs(&base), 20.0);
        assert_eq!(fast.energy_eff_vs(&base), 50.0);
    }

    #[test]
    fn json_has_headline_fields() {
        let r = RunReport::new("knn", "ds", "accd");
        let v = r.to_json();
        assert_eq!(v.get("algorithm").as_str(), Some("knn"));
        assert!(v.get("wall_secs").as_f64().is_some());
        assert!(v.get("filter_saving_ratio").as_f64().is_some());
    }

    #[test]
    fn summary_is_printable() {
        let s = RunReport::new("nbody", "P-1", "accd").summary();
        assert!(s.contains("nbody"));
        assert!(s.contains("filter"));
    }
}
