//! Tiny property-testing runner (proptest substitute).
//!
//! Generates `cases` random inputs from a seeded [`Rng`], runs the
//! property, and on failure retries with a halved "size" parameter to
//! give a crude shrink before reporting the failing seed.  Used by the
//! coordinator-invariant suites in `rust/tests/prop_coordinator.rs` and
//! the in-module `#[cfg(test)]` property tests.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Upper bound passed to generators as the "size" hint.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xACCD, max_size: 256 }
    }
}

/// Run `prop` on `cases` generated inputs.
///
/// `gen` receives (rng, size) and builds one case; `prop` returns
/// `Err(msg)` to fail.  On failure the case is re-generated at smaller
/// sizes to find a more minimal reproduction, then panics with the
/// failing seed + size so the case can be replayed exactly.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Crude shrink: retry the same seed at halved sizes and report
            // the smallest size that still fails.
            let mut min_fail: (usize, String, String) = (size, msg, format!("{input:?}"));
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                let smaller = gen(&mut rng, s);
                match prop(&smaller) {
                    Err(m) => {
                        min_fail = (s, m, format!("{smaller:?}"));
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            let (fs, fmsg, frepr) = min_fail;
            let repr = if frepr.len() > 800 { format!("{}…", &frepr[..800]) } else { frepr };
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, size {fs}): {fmsg}\ninput: {repr}"
            );
        }
    }
}

/// Generate a random f32 point set of `n` rows x `d` cols in [-r, r].
pub fn gen_points(rng: &mut Rng, n: usize, d: usize, r: f32) -> Vec<f32> {
    (0..n * d).map(|_| rng.range_f32(-r, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            &Config { cases: 10, ..Default::default() },
            |rng, size| rng.below(size.max(1)),
            |&x| {
                if x < 256 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &Config { cases: 8, ..Default::default() },
            |rng, size| rng.below(size.max(1)),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    fn gen_points_shape_and_range() {
        let mut rng = Rng::new(9);
        let pts = gen_points(&mut rng, 10, 3, 2.0);
        assert_eq!(pts.len(), 30);
        assert!(pts.iter().all(|x| (-2.0..2.0).contains(x)));
    }
}
