//! Minimal CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands — enough for the `accd` launcher's surface.  Unknown
//! flags are hard errors so typos never silently fall back to defaults.

use std::collections::HashMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` given the set of value-taking options and boolean
    /// flags this command accepts.
    pub fn parse(
        argv: &[String],
        value_opts: &[&str],
        bool_flags: &[&str],
    ) -> Result<Self, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if bool_flags.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} does not take a value"));
                    }
                    out.flags.push(key);
                } else if value_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    out.opts.insert(key, val);
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = Args::parse(
            &argv(&["run", "--size", "100", "--dim=8", "--verbose"]),
            &["size", "dim"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get_usize("size", 0).unwrap(), 100);
        assert_eq!(a.get_usize("dim", 0).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse(&argv(&["--nope"]), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--size"]), &["size"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&[]), &["k"], &[]).unwrap();
        assert_eq!(a.get_usize("k", 7).unwrap(), 7);
        assert_eq!(a.get_or("k", "x"), "x");
    }
}
