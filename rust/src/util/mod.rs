//! In-tree substrates that a typical project would pull from crates.io.
//!
//! The build environment is fully offline and the vendored registry only
//! carries the `xla` crate's closure, so JSON, CLI parsing, RNG,
//! benchmarking and property-testing are implemented here from scratch
//! (see `Cargo.toml` for the inventory).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod topk;

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean of strictly-positive values (paper-style "average
/// speedup" aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }
}
