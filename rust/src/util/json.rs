//! Minimal JSON parser + serializer.
//!
//! Replaces `serde_json` (unavailable in the offline vendored registry).
//! Supports the full JSON grammar except for `\u` surrogate pairs being
//! passed through unvalidated.  Numbers parse as `f64`; integer readers
//! round-trip exactly for |x| < 2^53, which covers every value in the
//! artifact manifest and result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// BTreeMap keeps serialization deterministic (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Value::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Required typed accessors with contextual errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| Error::Json(format!("missing/invalid string field {key:?}")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| Error::Json(format!("missing/invalid integer field {key:?}")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| Error::Json(format!("missing/invalid array field {key:?}")))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result/manifest documents.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(vals: Vec<Value>) -> Value {
    Value::Arr(vals)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!("trailing input at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {lit})")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut vals = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(vals));
        }
        loop {
            vals.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(vals)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-decode multi-byte UTF-8 starting at c.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("invalid number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_document() {
        let doc = r#"{"version": 1, "tile": {"m": 64, "d_pad": [4, 8]}, "artifacts": [{"name": "a", "ok": true, "x": null}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").as_usize(), Some(1));
        assert_eq!(v.get("tile").get("m").as_usize(), Some(64));
        assert_eq!(v.get("tile").req_arr("d_pad").unwrap().len(), 2);
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\tAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\tAé");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
