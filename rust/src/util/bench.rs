//! Minimal benchmarking harness (criterion substitute).
//!
//! Provides warmup + repeated timed runs with median/mean/stddev
//! reporting and a tabular printer the `rust/benches/fig*` harnesses use
//! to emit the paper's rows.  Benches are registered in `Cargo.toml`
//! with `harness = false` and call [`Bencher::run`] directly.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Self {
        samples.sort_unstable();
        let n = samples.len();
        let mean_ns = samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / n as f64;
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Self {
            iters: n,
            mean: Duration::from_nanos(mean_ns as u64),
            median: samples[n / 2],
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum wall time to spend measuring one benchmark.
    pub measure_time: Duration,
    /// Warmup wall time before measurement starts.
    pub warmup_time: Duration,
    /// Hard cap on measured iterations (long end-to-end runs).
    pub max_iters: usize,
    /// Minimum measured iterations, even if over time budget.
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_secs(3),
            warmup_time: Duration::from_millis(300),
            max_iters: 50,
            min_iters: 3,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches: one warmup run,
    /// few measured runs.  Controlled by env `ACCD_BENCH_FAST=1`.
    pub fn from_env() -> Self {
        if std::env::var("ACCD_BENCH_FAST").as_deref() == Ok("1") {
            Self {
                measure_time: Duration::from_millis(500),
                warmup_time: Duration::ZERO,
                max_iters: 3,
                min_iters: 1,
            }
        } else {
            Self::default()
        }
    }

    /// Time `f` and return stats.  The closure's return value is passed
    /// through `std::hint::black_box` so work is not optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup_time {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        loop {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
            let enough_time = mstart.elapsed() >= self.measure_time;
            if (enough_time && samples.len() >= self.min_iters) || samples.len() >= self.max_iters
            {
                break;
            }
        }
        let stats = Stats::from_samples(samples);
        eprintln!(
            "bench {name:<48} median {:>12?} mean {:>12?} ±{:>10?} ({} iters)",
            stats.median, stats.mean, stats.stddev, stats.iters
        );
        stats
    }
}

/// Fixed-width table printer for the paper-figure harnesses.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format a speedup factor the way the paper reports them (e.g. "31.42x").
pub fn fmt_x(factor: f64) -> String {
    format!("{factor:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples(vec![Duration::from_millis(5); 7]);
        assert_eq!(s.iters, 7);
        assert_eq!(s.median, Duration::from_millis(5));
        assert_eq!(s.stddev, Duration::ZERO);
    }

    #[test]
    fn bencher_respects_max_iters() {
        let b = Bencher {
            measure_time: Duration::from_millis(1),
            warmup_time: Duration::ZERO,
            max_iters: 5,
            min_iters: 1,
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.iters <= 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
