//! Bounded Top-K selection (the paper's `AccD_Dist_Select` construct on
//! the CPU side) plus a k-way merge used when fusing per-tile Top-K
//! results coming back from the accelerator.
//!
//! NaN policy: all comparisons use [`f32::total_cmp`], under which NaN
//! ranks above +inf.  A NaN candidate therefore never displaces a real
//! value and appears in the output only while the selector is
//! under-full (fewer than k non-NaN candidates seen), always sorted
//! last.  No input — including NaN from corrupt rows — can panic or
//! corrupt the heap invariant.

use std::cmp::Ordering;

/// Max-heap based selector that keeps the K smallest (value, id) pairs.
///
/// `push` is O(log k) and the heap never exceeds `k` entries, so merging
/// a stream of tile results over a 400k-point target set allocates a
/// constant 2*k slots per source point.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// Binary max-heap ordered by value: root = current k-th best.
    heap: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k > 0");
        Self { k, heap: Vec::with_capacity(k) }
    }

    /// Current k-th smallest value, or +inf while under-full.  This is
    /// the pruning threshold tau used by the GTI KNN filter.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a candidate; ignored unless it beats the threshold.
    #[inline]
    pub fn push(&mut self, val: f32, id: u32) {
        if self.heap.len() < self.k {
            self.heap.push((val, id));
            self.sift_up(self.heap.len() - 1);
        } else if val.total_cmp(&self.heap[0].0) == Ordering::Less {
            self.heap[0] = (val, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0.total_cmp(&self.heap[parent].0) == Ordering::Greater {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && self.heap[l].0.total_cmp(&self.heap[largest].0) == Ordering::Greater {
                largest = l;
            }
            if r < n && self.heap[r].0.total_cmp(&self.heap[largest].0) == Ordering::Greater {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into (value, id) pairs sorted ascending by value (NaN,
    /// if it survived an under-full heap, sorts last — total order).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        self.heap
    }
}

/// Select the K smallest entries of a full row (used by baselines and as
/// the oracle in tests).  O(n log k).
pub fn topk_smallest(vals: &[f32], k: usize) -> Vec<(f32, u32)> {
    let mut sel = TopK::new(k.min(vals.len()).max(1));
    for (i, &v) in vals.iter().enumerate() {
        sel.push(v, i as u32);
    }
    sel.into_sorted()
}

/// Argmin over a slice: (index, value).  Panics on empty input.
pub fn argmin(vals: &[f32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    for (i, &v) in vals.iter().enumerate() {
        if v < best.1 {
            best = (i, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_full_sort() {
        let vals: Vec<f32> = (0..100).map(|i| ((i * 37 + 11) % 100) as f32).collect();
        let got = topk_smallest(&vals, 10);
        let mut want: Vec<(f32, u32)> =
            vals.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        want.truncate(10);
        assert_eq!(got, want);
    }

    #[test]
    fn threshold_tracks_kth_value() {
        let mut t = TopK::new(3);
        assert_eq!(t.threshold(), f32::INFINITY);
        t.push(5.0, 0);
        t.push(1.0, 1);
        assert_eq!(t.threshold(), f32::INFINITY); // under-full
        t.push(3.0, 2);
        assert_eq!(t.threshold(), 5.0);
        t.push(2.0, 3); // evicts 5.0
        assert_eq!(t.threshold(), 3.0);
        t.push(10.0, 4); // ignored
        assert_eq!(t.threshold(), 3.0);
    }

    #[test]
    fn into_sorted_ascending_with_ties_by_id() {
        let mut t = TopK::new(4);
        for (v, id) in [(2.0, 9), (2.0, 3), (1.0, 5), (4.0, 1), (0.5, 2)] {
            t.push(v, id);
        }
        let out = t.into_sorted();
        assert_eq!(out, vec![(0.5, 2), (1.0, 5), (2.0, 3), (2.0, 9)]);
    }

    #[test]
    fn argmin_finds_first_minimum() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), (1, 1.0));
    }

    #[test]
    fn k_larger_than_input() {
        let out = topk_smallest(&[2.0, 1.0], 10);
        assert_eq!(out, vec![(1.0, 1), (2.0, 0)]);
    }

    #[test]
    fn nan_never_panics_and_never_displaces_real_values() {
        // Regression: into_sorted used partial_cmp().unwrap() and the
        // heap used `<`/`>`, so a NaN candidate panicked the sort and
        // corrupted the sift invariants.  Under total_cmp a NaN row in
        // the input is simply the worst candidate.
        let vals = [3.0, f32::NAN, 1.0, f32::NAN, 2.0, 4.0];
        let out = topk_smallest(&vals, 3);
        assert_eq!(out, vec![(1.0, 2), (2.0, 4), (3.0, 0)]);

        // NaN arriving first still gets evicted by real values.
        let mut t = TopK::new(2);
        t.push(f32::NAN, 0);
        t.push(f32::NAN, 1);
        t.push(5.0, 2);
        t.push(1.0, 3);
        assert_eq!(t.into_sorted(), vec![(1.0, 3), (5.0, 2)]);

        // Under-full of non-NaN candidates: NaN appears, sorted last.
        let out = topk_smallest(&[f32::NAN, 7.0], 3);
        assert_eq!(out[0], (7.0, 1));
        assert_eq!(out.len(), 2);
        assert!(out[1].0.is_nan() && out[1].1 == 0);
    }
}
