//! Deterministic PRNG (xoshiro256++) — replacement for the `rand` crate.
//!
//! Every stochastic component in the library (dataset generators, group
//! seeding, the DSE genetic algorithm, property tests) takes an explicit
//! seed so runs are exactly reproducible, which EXPERIMENTS.md relies on.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached second variate dropped for
    /// simplicity; generators here are not on the hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()) as f32; // avoid ln(0)
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
