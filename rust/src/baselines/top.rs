//! "TOP" column: point-level triangle-inequality optimization (CPU),
//! plus the TOP-on-CPU-FPGA hybrid used in the Fig. 10 breakdown.
//!
//! TOP [Ding et al., VLDB'15] applies TI at *point* granularity:
//! maximal pruning, but per-point candidate sets diverge, which is
//! exactly the irregularity the paper's Fig. 3a criticizes.  The three
//! implementations here are faithful to that granularity:
//!
//! * K-means — Hamerly-style single lower bound + upper bound per
//!   point, tightened by center drifts.
//! * KNN-join — landmark (group-center) bounds per (point, target
//!   group), pruned against the point's evolving K-th-best threshold.
//! * N-body — per-point neighbor lists with a Verlet skin, rebuilt
//!   when accumulated displacement invalidates them.
//!
//! `kmeans_fpga` additionally routes TOP's surviving per-point
//! computations through the accelerator: points are batched into tiles
//! whose candidate set is the *union* of the members' candidate sets —
//! the padding/divergence cost of that union is what Fig. 10 measures.

use crate::data::{Dataset, Matrix};
use crate::fpga::{Platform, PowerModel};
use crate::util::rng::Rng;
use crate::util::topk::TopK;
use crate::{Error, Result};

use super::naive::{base_report, finish_seq_power, KmeansOut, KnnOut, NbodyOut};

// ---------------------------------------------------------------------------
// K-means (Hamerly bounds)
// ---------------------------------------------------------------------------

/// TOP K-means on CPU: Hamerly's algorithm (one upper bound to the
/// assigned center, one lower bound to the second-nearest center).
pub fn kmeans(ds: &Dataset, k: usize, max_iters: usize, seed: u64) -> Result<KmeansOut> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    let (n, d) = (ds.n(), ds.d());
    let mut rng = Rng::new(seed ^ 0x6B6D_6561_6E73);
    let mut centers = ds.points.gather_rows(&rng.sample_indices(n, k));
    let mut assign = vec![0u32; n];
    let mut ub = vec![0.0f32; n]; // dist to assigned center
    let mut lb = vec![0.0f32; n]; // dist to second-closest center
    let mut dist_comps = 0u64;
    let mut bound_comps = 0u64;

    // Initial full pass.
    for i in 0..n {
        let (a, da, d2nd) = two_nearest(&ds.points, i, &centers);
        dist_comps += k as u64;
        assign[i] = a as u32;
        ub[i] = da.sqrt();
        lb[i] = d2nd.sqrt();
    }

    let mut iterations = 0usize;
    for _ in 0..max_iters {
        iterations += 1;
        // Center update from current assignment.
        let (drift, moved_any) = update(&ds.points, &assign, &mut centers, k, d);
        let max_drift = drift.iter().cloned().fold(0.0f32, f32::max);
        // Bound maintenance (Hamerly).
        let mut changed = 0usize;
        for i in 0..n {
            ub[i] += drift[assign[i] as usize];
            lb[i] = (lb[i] - max_drift).max(0.0);
            bound_comps += 2;
            if ub[i] <= lb[i] {
                continue; // pruned: assignment provably unchanged
            }
            // Tighten ub with one exact distance; re-test.
            let a = assign[i] as usize;
            ub[i] = ds.points.dist2(i, &centers, a).sqrt();
            dist_comps += 1;
            if ub[i] <= lb[i] {
                continue;
            }
            // Full scan for this point.
            let (na, da, d2nd) = two_nearest(&ds.points, i, &centers);
            dist_comps += k as u64;
            if na as u32 != assign[i] {
                assign[i] = na as u32;
                changed += 1;
            }
            ub[i] = da.sqrt();
            lb[i] = d2nd.sqrt();
        }
        if changed == 0 && !moved_any {
            break;
        }
    }
    let sse: f64 =
        (0..n).map(|i| ds.points.dist2(i, &centers, assign[i] as usize) as f64).sum();
    let mut report = base_report("kmeans", &ds.name, "top", t0, iterations);
    report.filter.total_pairs = (n * k) as u64 * (iterations as u64 + 1);
    report.filter.surviving_pairs = dist_comps;
    report.filter.bound_comps = bound_comps;
    report.quality = sse;
    finish_seq_power(&mut report);
    Ok(KmeansOut { centers, assign, sse, iterations, report })
}

fn two_nearest(points: &Matrix, i: usize, centers: &Matrix) -> (usize, f32, f32) {
    let mut best = (0usize, f32::INFINITY);
    let mut second = f32::INFINITY;
    for c in 0..centers.rows() {
        let d2 = points.dist2(i, centers, c);
        if d2 < best.1 {
            second = best.1;
            best = (c, d2);
        } else if d2 < second {
            second = d2;
        }
    }
    (best.0, best.1, second)
}

fn update(
    points: &Matrix,
    assign: &[u32],
    centers: &mut Matrix,
    k: usize,
    d: usize,
) -> (Vec<f32>, bool) {
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    for (i, &a) in assign.iter().enumerate() {
        counts[a as usize] += 1;
        for (x, &v) in points.row(i).iter().enumerate() {
            sums[a as usize * d + x] += v as f64;
        }
    }
    let mut drift = vec![0.0f32; k];
    let mut moved = false;
    for c in 0..k {
        if counts[c] == 0 {
            continue;
        }
        let inv = 1.0 / counts[c] as f64;
        let row = centers.row_mut(c);
        let mut d2 = 0.0f32;
        for x in 0..d {
            let nc = (sums[c * d + x] * inv) as f32;
            let delta = nc - row[x];
            d2 += delta * delta;
            row[x] = nc;
        }
        drift[c] = d2.sqrt();
        if drift[c] > 1e-7 {
            moved = true;
        }
    }
    (drift, moved)
}

// ---------------------------------------------------------------------------
// KNN-join (landmark pruning per point)
// ---------------------------------------------------------------------------

/// TOP KNN-join on CPU: target points are bucketed under `z` landmarks;
/// per source point, buckets are visited in lower-bound order and
/// skipped once `lb > tau` (the point's current K-th best) — point-level
/// pruning with per-point divergent candidate sets.
pub fn knn_join(src: &Dataset, trg: &Dataset, k: usize, seed: u64) -> Result<KnnOut> {
    if k == 0 || k > trg.n() {
        return Err(Error::Data(format!("knn: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    let z = crate::gti::Grouping::auto_groups(trg.n());
    let grouping = crate::gti::Grouping::build(&trg.points, z, 3, 4096, seed)?;
    let mut dist_comps = grouping.build_dist_comps;
    let mut bound_comps = 0u64;
    let mut neighbors = Vec::with_capacity(src.n());
    for i in 0..src.n() {
        // Landmark distances for this source point.
        let mut ldist: Vec<(f32, u32)> = (0..z)
            .map(|g| (src.points.dist2(i, &grouping.centers, g).sqrt(), g as u32))
            .collect();
        dist_comps += z as u64;
        ldist.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut heap = TopK::new(k);
        for &(dl, g) in &ldist {
            let lb = (dl - grouping.radii[g as usize]).max(0.0);
            bound_comps += 1;
            let tau = heap.threshold();
            if heap.len() >= k && lb * lb > tau {
                break; // all later buckets have even larger lb
            }
            for &j in &grouping.members[g as usize] {
                heap.push(src.points.dist2(i, &trg.points, j as usize), j);
            }
            dist_comps += grouping.members[g as usize].len() as u64;
        }
        neighbors.push(heap.into_sorted());
    }
    let mut report = base_report("knn_join", &src.name, "top", t0, 1);
    report.filter.total_pairs = (src.n() * trg.n()) as u64;
    report.filter.surviving_pairs = dist_comps;
    report.filter.bound_comps = bound_comps;
    report.quality = neighbors
        .iter()
        .filter_map(|nb| nb.last().map(|&(d2, _)| d2 as f64))
        .sum::<f64>()
        / neighbors.len().max(1) as f64;
    finish_seq_power(&mut report);
    Ok(KnnOut { neighbors, k, report })
}

// ---------------------------------------------------------------------------
// N-body (Verlet neighbor lists)
// ---------------------------------------------------------------------------

/// TOP N-body on CPU: per-point neighbor lists with skin `0.5 * r`,
/// rebuilt when any particle's accumulated displacement exceeds half
/// the skin (the classic Verlet-list validity criterion).
pub fn nbody(
    ds: &Dataset,
    masses: &[f32],
    steps: usize,
    dt: f32,
    radius: f32,
) -> Result<NbodyOut> {
    if ds.d() != 3 {
        return Err(Error::Shape("nbody requires 3-D positions".into()));
    }
    let t0 = std::time::Instant::now();
    let n = ds.n();
    let mut pos = ds.points.clone();
    let mut vel = Matrix::zeros(n, 3);
    let eps2 = 1e-4f32;
    let rmax2 = radius * radius;
    let skin = 0.5 * radius;
    let reach2 = (radius + skin) * (radius + skin);
    let mut lists: Vec<Vec<u32>> = Vec::new();
    let mut disp = vec![0.0f32; n];
    let mut pairs = 0u64;
    for step in 0..steps {
        // (Re)build neighbor lists when invalid.
        let need_rebuild =
            step == 0 || disp.iter().any(|&s| s > 0.5 * skin);
        if need_rebuild {
            lists = (0..n)
                .map(|i| {
                    (0..n)
                        .filter(|&j| pos.dist2(i, &pos, j) <= reach2)
                        .map(|j| j as u32)
                        .collect()
                })
                .collect();
            pairs += (n * n) as u64;
            disp.iter_mut().for_each(|x| *x = 0.0);
        }
        // Forces over the lists only.
        let mut acc = vec![0.0f32; n * 3];
        for i in 0..n {
            let pi = [pos.row(i)[0], pos.row(i)[1], pos.row(i)[2]];
            let (mut ax, mut ay, mut az) = (0.0f32, 0.0, 0.0);
            for &j in &lists[i] {
                let pj = pos.row(j as usize);
                let dx = pi[0] - pj[0];
                let dy = pi[1] - pj[1];
                let dz = pi[2] - pj[2];
                let r2 = dx * dx + dy * dy + dz * dz;
                if r2 > rmax2 {
                    continue;
                }
                let r2s = r2 + eps2;
                let inv_r3 = 1.0 / (r2s.sqrt() * r2s);
                let w = masses[j as usize] * inv_r3;
                ax -= dx * w;
                ay -= dy * w;
                az -= dz * w;
            }
            pairs += lists[i].len() as u64;
            acc[i * 3] = ax;
            acc[i * 3 + 1] = ay;
            acc[i * 3 + 2] = az;
        }
        for i in 0..n {
            let v = vel.row_mut(i);
            v[0] += acc[i * 3] * dt;
            v[1] += acc[i * 3 + 1] * dt;
            v[2] += acc[i * 3 + 2] * dt;
        }
        for i in 0..n {
            let (vx, vy, vz) = {
                let v = vel.row(i);
                (v[0], v[1], v[2])
            };
            let step_len = (vx * vx + vy * vy + vz * vz).sqrt() * dt;
            disp[i] += step_len;
            let p = pos.row_mut(i);
            p[0] += vx * dt;
            p[1] += vy * dt;
            p[2] += vz * dt;
        }
    }
    let mut report = base_report("nbody", &ds.name, "top", t0, steps);
    report.filter.total_pairs = (n as u64 * n as u64) * steps as u64;
    report.filter.surviving_pairs = pairs;
    report.quality = (0..n)
        .map(|i| {
            let v = vel.row(i);
            0.5 * masses[i] as f64 * (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]) as f64
        })
        .sum();
    finish_seq_power(&mut report);
    Ok(NbodyOut { positions: pos, velocities: vel, steps, report })
}

// ---------------------------------------------------------------------------
// TOP on CPU-FPGA (Fig. 10's second bar)
// ---------------------------------------------------------------------------

/// TOP K-means routed through the accelerator.
///
/// Points that fail Hamerly's prune are batched into device tiles, but
/// because pruning is point-granular each tile's center set is the
/// union of its members' needs — with per-point divergence that union
/// degenerates toward "all k centers", so the accelerator computes
/// mostly-wasted columns.  This implements the memory/kernel
/// optimizations the paper grants the TOP hybrid for fairness
/// (§VII-C), and still shows the Fig. 10 slowdown.
pub fn kmeans_fpga(
    engine: &mut crate::coordinator::Engine,
    ds: &Dataset,
    k: usize,
    max_iters: usize,
    seed: u64,
) -> Result<KmeansOut> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    engine.device.reset_stats();
    let (n, d) = (ds.n(), ds.d());
    let tile = engine.runtime.manifest().tile.clone();
    let d_pad = tile.pad_d(d)?;
    let k_pad = tile.pad_kmeans_k(k)?;
    let mut rng = Rng::new(seed ^ 0x6B6D_6561_6E73);
    let mut centers = ds.points.gather_rows(&rng.sample_indices(n, k));
    let mut assign = vec![0u32; n];
    let mut ub = vec![0.0f32; n];
    let mut lb = vec![0.0f32; n];

    // Initial full pass on the device (dense & regular: fine).
    let rows_pad = crate::util::round_up(n.max(1), tile.m);
    let slab = crate::fpga::FpgaDevice::pad_slab(ds.points.as_slice(), n, d, rows_pad, d_pad);
    let cslab = pad_centers_sentinel(&centers, k_pad, d_pad);
    let (idx, dist) = engine.device.kmeans_assign_block(&slab, n, d_pad, &cslab, k_pad)?;
    for i in 0..n {
        assign[i] = idx[i] as u32;
        ub[i] = dist[i].max(0.0).sqrt();
    }
    // Second-nearest bound needs a second pass: derive lb from a CPU
    // scan ONCE (start loose: 0 => every point re-checks first round).
    lb.iter_mut().for_each(|x| *x = 0.0);

    let mut iterations = 0usize;
    for _ in 0..max_iters {
        iterations += 1;
        let (drift, moved_any) = update(&ds.points, &assign, &mut centers, k, d);
        let max_drift = drift.iter().cloned().fold(0.0f32, f32::max);
        // Identify survivors (points needing exact recomputation).
        let mut survivors: Vec<u32> = Vec::new();
        for i in 0..n {
            ub[i] += drift[assign[i] as usize];
            lb[i] = (lb[i] - max_drift).max(0.0);
            if ub[i] > lb[i] {
                survivors.push(i as u32);
            }
        }
        // Batch survivors through the device against ALL centers (the
        // per-point candidate union).  Tiles are (tile.m x k_pad).
        let cslab = pad_centers_sentinel(&centers, k_pad, d_pad);
        let mut changed = 0usize;
        for chunk in survivors.chunks(tile.m) {
            let rows_pad = crate::util::round_up(chunk.len().max(1), tile.m);
            let pslab = crate::fpga::FpgaDevice::pad_rows(&ds.points, chunk, rows_pad, d_pad);
            let (idx, dist) =
                engine.device.kmeans_assign_block(&pslab, chunk.len(), d_pad, &cslab, k_pad)?;
            for (r, &i) in chunk.iter().enumerate() {
                let i = i as usize;
                if assign[i] != idx[r] as u32 {
                    assign[i] = idx[r] as u32;
                    changed += 1;
                }
                ub[i] = dist[r].max(0.0).sqrt();
                // lb refresh would need second-best; keep loose (0) —
                // faithful to the hybrid's irregularity cost.
                lb[i] = 0.0;
            }
        }
        if changed == 0 && !moved_any {
            break;
        }
    }
    let sse: f64 =
        (0..n).map(|i| ds.points.dist2(i, &centers, assign[i] as usize) as f64).sum();
    let mut report = base_report("kmeans", &ds.name, "top_fpga", t0, iterations);
    report.device = engine.device.stats();
    report.device_wall_secs = report.device.wall_secs;
    report.device_modeled_secs = report.device.modeled_secs;
    report.quality = sse;
    let pm = PowerModel::default();
    report.energy_j =
        pm.accd_joules(report.wall_secs, report.wall_secs * 0.4, 1.0, report.device.wall_secs);
    report.avg_watts = report.energy_j / report.wall_secs.max(1e-9);
    let _ = Platform::AccdFpga; // platform handled inside accd_joules
    Ok(KmeansOut { centers, assign, sse, iterations, report })
}

fn pad_centers_sentinel(centers: &Matrix, k_pad: usize, d_pad: usize) -> Vec<f32> {
    let (k, d) = (centers.rows(), centers.cols());
    let mut slab = vec![0.0f32; k_pad * d_pad];
    for c in 0..k {
        slab[c * d_pad..c * d_pad + d].copy_from_slice(centers.row(c));
    }
    for c in k..k_pad {
        slab[c * d_pad] = 1.0e15;
    }
    slab
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn top_kmeans_matches_naive_trajectory() {
        let ds = synthetic::clustered(300, 5, 6, 0.03, 11);
        let a = super::super::naive::kmeans(&ds, 8, 12, 3).unwrap();
        let b = kmeans(&ds, 8, 12, 3).unwrap();
        assert!(
            (a.sse - b.sse).abs() <= 1e-3 * (1.0 + a.sse),
            "naive {} vs top {}",
            a.sse,
            b.sse
        );
        assert_eq!(a.assign, b.assign, "assignments diverge");
    }

    #[test]
    fn top_kmeans_actually_prunes() {
        let ds = synthetic::clustered(500, 5, 8, 0.02, 12);
        let out = kmeans(&ds, 8, 15, 3).unwrap();
        assert!(
            out.report.filter.surviving_pairs < out.report.filter.total_pairs / 2,
            "expected >2x pruning: {} of {}",
            out.report.filter.surviving_pairs,
            out.report.filter.total_pairs
        );
    }

    #[test]
    fn top_knn_matches_naive_exactly() {
        let s = synthetic::clustered(80, 4, 4, 0.05, 13);
        let t = synthetic::clustered(120, 4, 4, 0.05, 14);
        let a = super::super::naive::knn_join(&s, &t, 6).unwrap();
        let b = knn_join(&s, &t, 6, 99).unwrap();
        for i in 0..s.n() {
            for r in 0..6 {
                assert!(
                    (a.neighbors[i][r].0 - b.neighbors[i][r].0).abs() <= 1e-5,
                    "point {i} rank {r}: {} vs {}",
                    a.neighbors[i][r].0,
                    b.neighbors[i][r].0
                );
            }
        }
    }

    #[test]
    fn top_nbody_tracks_naive() {
        let ds = synthetic::plummer(50, 1.0, 15);
        let m = synthetic::equal_masses(50, 1.0);
        let a = super::super::naive::nbody(&ds, &m, 4, 1e-3, 0.8).unwrap();
        let b = nbody(&ds, &m, 4, 1e-3, 0.8).unwrap();
        for i in 0..50 {
            for c in 0..3 {
                let (x, y) = (a.positions.row(i)[c], b.positions.row(i)[c]);
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "particle {i} comp {c}");
            }
        }
    }
}
