//! "CBLAS" column: matrix-decomposed distance computation on the CPU.
//!
//! The paper's CBLAS baseline computes Eq. 4's cross term with a BLAS
//! SGEMM.  No BLAS library exists in the offline vendored registry, so
//! [`sgemm_nt`] is a hand-blocked, 8-way-unrolled `A * B^T` kernel —
//! register-tiled the same way OpenBLAS's micro-kernels are shaped,
//! which is what gives this baseline its paper-reported edge on
//! high-dimension datasets.

use crate::data::{Dataset, Matrix};
use crate::fpga::{Platform, PowerModel};
use crate::metrics::RunReport;
use crate::util::rng::Rng;
use crate::util::topk::TopK;
use crate::{Error, Result};

use super::naive::{base_report, KmeansOut, KnnOut};

/// Blocked C = A * B^T; A is (m, d), B is (n, d), C is (m, n) row-major.
///
/// Cache blocking (MC x NC panels) with a 4x4 register micro-tile; the
/// inner product over `d` is the unrolled hot loop.
pub fn sgemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, d: usize) {
    const MC: usize = 64;
    const NC: usize = 64;
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(b.len(), n * d);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(MC) {
        let i_max = (i0 + MC).min(m);
        for j0 in (0..n).step_by(NC) {
            let j_max = (j0 + NC).min(n);
            // 4x4 register tiles inside the cache block.
            let mut i = i0;
            while i < i_max {
                let ih = (i_max - i).min(4);
                let mut j = j0;
                while j < j_max {
                    let jh = (j_max - j).min(4);
                    let mut acc = [[0.0f32; 4]; 4];
                    for (ii, accr) in acc.iter_mut().enumerate().take(ih) {
                        let ar = &a[(i + ii) * d..(i + ii + 1) * d];
                        for (jj, accv) in accr.iter_mut().enumerate().take(jh) {
                            let br = &b[(j + jj) * d..(j + jj + 1) * d];
                            // 8-way unrolled dot product.
                            let mut s = [0.0f32; 8];
                            let chunks = d / 8;
                            for cidx in 0..chunks {
                                let o = cidx * 8;
                                for u in 0..8 {
                                    s[u] += ar[o + u] * br[o + u];
                                }
                            }
                            let mut tail = 0.0f32;
                            for x in chunks * 8..d {
                                tail += ar[x] * br[x];
                            }
                            *accv = s.iter().sum::<f32>() + tail;
                        }
                    }
                    for ii in 0..ih {
                        for jj in 0..jh {
                            c[(i + ii) * n + (j + jj)] = acc[ii][jj];
                        }
                    }
                    j += jh;
                }
                i += ih;
            }
        }
    }
}

/// Row-wise square sums (the RSS pre-compute of Eq. 4).
pub fn rss(points: &Matrix) -> Vec<f32> {
    (0..points.rows())
        .map(|i| points.row(i).iter().map(|x| x * x).sum())
        .collect()
}

/// Full squared-distance matrix via Eq. 4: RSS_a - 2 A.B^T + RSS_b.
pub fn distance_matrix(a: &Matrix, b: &Matrix) -> Vec<f32> {
    let (m, n, d) = (a.rows(), b.rows(), a.cols());
    let mut cross = vec![0.0f32; m * n];
    sgemm_nt(a.as_slice(), b.as_slice(), &mut cross, m, n, d);
    let ra = rss(a);
    let rb = rss(b);
    for i in 0..m {
        let base = i * n;
        for j in 0..n {
            cross[base + j] = (ra[i] - 2.0 * cross[base + j] + rb[j]).max(0.0);
        }
    }
    cross
}

/// CBLAS-style K-means: full distance matrix per iteration via SGEMM.
pub fn kmeans(ds: &Dataset, k: usize, max_iters: usize, seed: u64) -> Result<KmeansOut> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    let (n, d) = (ds.n(), ds.d());
    let mut rng = Rng::new(seed ^ 0x6B6D_6561_6E73);
    let mut centers = ds.points.gather_rows(&rng.sample_indices(n, k));
    let mut assign = vec![0u32; n];
    let mut iterations = 0usize;
    let mut dist_comps = 0u64;
    // Process points in row blocks so the distance matrix stays cache-sized.
    const ROWS: usize = 512;
    for _ in 0..=max_iters {
        let mut changed = 0usize;
        for i0 in (0..n).step_by(ROWS) {
            let rows = (n - i0).min(ROWS);
            let block = ds.points.gather_rows(&(i0..i0 + rows).collect::<Vec<_>>());
            let dm = distance_matrix(&block, &centers);
            dist_comps += (rows * k) as u64;
            for r in 0..rows {
                let row = &dm[r * k..(r + 1) * k];
                let (ci, _) = crate::util::topk::argmin(row);
                if assign[i0 + r] != ci as u32 {
                    assign[i0 + r] = ci as u32;
                    changed += 1;
                }
            }
        }
        if iterations > 0 && changed == 0 {
            break;
        }
        if iterations == max_iters {
            break;
        }
        iterations += 1;
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        for i in 0..n {
            let a = assign[i] as usize;
            counts[a] += 1;
            for (x, &v) in ds.points.row(i).iter().enumerate() {
                sums[a * d + x] += v as f64;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let row = centers.row_mut(c);
                for x in 0..d {
                    row[x] = (sums[c * d + x] / counts[c] as f64) as f32;
                }
            }
        }
    }
    let sse: f64 =
        (0..n).map(|i| ds.points.dist2(i, &centers, assign[i] as usize) as f64).sum();
    let mut report = base_report("kmeans", &ds.name, "cblas", t0, iterations);
    report.filter.total_pairs = dist_comps;
    report.filter.surviving_pairs = dist_comps;
    report.quality = sse;
    finish_parallel_power(&mut report);
    Ok(KmeansOut { centers, assign, sse, iterations, report })
}

/// CBLAS-style KNN-join: blocked distance matrix + per-row heaps.
pub fn knn_join(src: &Dataset, trg: &Dataset, k: usize) -> Result<KnnOut> {
    if k == 0 || k > trg.n() {
        return Err(Error::Data(format!("knn: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    const ROWS: usize = 256;
    const COLS: usize = 2048;
    let mut neighbors: Vec<TopK> = (0..src.n()).map(|_| TopK::new(k)).collect();
    for i0 in (0..src.n()).step_by(ROWS) {
        let rows = (src.n() - i0).min(ROWS);
        let a = src.points.gather_rows(&(i0..i0 + rows).collect::<Vec<_>>());
        for j0 in (0..trg.n()).step_by(COLS) {
            let cols = (trg.n() - j0).min(COLS);
            let b = trg.points.gather_rows(&(j0..j0 + cols).collect::<Vec<_>>());
            let dm = distance_matrix(&a, &b);
            for r in 0..rows {
                let heap = &mut neighbors[i0 + r];
                for c in 0..cols {
                    heap.push(dm[r * cols + c], (j0 + c) as u32);
                }
            }
        }
    }
    let neighbors: Vec<Vec<(f32, u32)>> =
        neighbors.into_iter().map(|h| h.into_sorted()).collect();
    let mut report = base_report("knn_join", &src.name, "cblas", t0, 1);
    report.filter.total_pairs = (src.n() * trg.n()) as u64;
    report.filter.surviving_pairs = report.filter.total_pairs;
    report.quality = neighbors
        .iter()
        .filter_map(|nb| nb.last().map(|&(d2, _)| d2 as f64))
        .sum::<f64>()
        / neighbors.len().max(1) as f64;
    finish_parallel_power(&mut report);
    Ok(KnnOut { neighbors, k, report })
}

/// Energy accounting for the multi-core/SIMD CPU platform.
fn finish_parallel_power(report: &mut RunReport) {
    let pm = PowerModel::default();
    report.energy_j = pm.joules(Platform::CpuParallel, report.wall_secs, 1.0);
    report.avg_watts = pm.watts(Platform::CpuParallel, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn sgemm_matches_scalar() {
        let a = synthetic::uniform(37, 19, 1).points;
        let b = synthetic::uniform(23, 19, 2).points;
        let mut c = vec![0.0f32; 37 * 23];
        sgemm_nt(a.as_slice(), b.as_slice(), &mut c, 37, 23, 19);
        for i in 0..37 {
            for j in 0..23 {
                let want: f32 = a.row(i).iter().zip(b.row(j)).map(|(x, y)| x * y).sum();
                assert!(
                    (c[i * 23 + j] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn distance_matrix_matches_dist2() {
        let a = synthetic::uniform(20, 7, 3).points;
        let b = synthetic::uniform(30, 7, 4).points;
        let dm = distance_matrix(&a, &b);
        for i in 0..20 {
            for j in 0..30 {
                let want = a.dist2(i, &b, j);
                assert!((dm[i * 30 + j] - want).abs() <= 1e-4 * (1.0 + want));
            }
        }
    }

    #[test]
    fn cblas_kmeans_agrees_with_naive() {
        let ds = synthetic::clustered(250, 6, 4, 0.03, 5);
        let a = super::super::naive::kmeans(&ds, 6, 15, 9).unwrap();
        let b = kmeans(&ds, 6, 15, 9).unwrap();
        // Same seed, same init, same Lloyd trajectory => same SSE.
        assert!(
            (a.sse - b.sse).abs() <= 1e-3 * (1.0 + a.sse),
            "naive {} vs cblas {}",
            a.sse,
            b.sse
        );
    }

    #[test]
    fn cblas_knn_agrees_with_naive() {
        let s = synthetic::uniform(50, 9, 6);
        let t = synthetic::uniform(80, 9, 7);
        let a = super::super::naive::knn_join(&s, &t, 4).unwrap();
        let b = knn_join(&s, &t, 4).unwrap();
        for i in 0..50 {
            for r in 0..4 {
                assert!(
                    (a.neighbors[i][r].0 - b.neighbors[i][r].0).abs() <= 1e-4,
                    "point {i} rank {r}"
                );
            }
        }
    }
}
