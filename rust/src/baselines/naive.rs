//! "Baseline" column: naive for-loop CPU implementations (Table IV).
//!
//! No pruning, no blocking, no vectorization beyond what rustc does on
//! its own — the normalization denominator for every paper figure.

use crate::data::{Dataset, Matrix};
use crate::fpga::{Platform, PowerModel};
use crate::metrics::RunReport;
use crate::util::rng::Rng;
use crate::util::topk::TopK;
use crate::{Error, Result};

/// Naive K-means: full `n x k` distance scan per iteration.
pub fn kmeans(ds: &Dataset, k: usize, max_iters: usize, seed: u64) -> Result<KmeansOut> {
    if k == 0 || k > ds.n() {
        return Err(Error::Data(format!("kmeans: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    let (n, d) = (ds.n(), ds.d());
    let mut rng = Rng::new(seed ^ 0x6B6D_6561_6E73);
    let mut centers = ds.points.gather_rows(&rng.sample_indices(n, k));
    let mut assign = vec![0u32; n];
    let mut iterations = 0usize;
    let mut dist_comps = 0u64;
    for _ in 0..=max_iters {
        // Assignment: exhaustive scan.
        let mut changed = 0usize;
        for i in 0..n {
            let mut best = (0usize, f32::INFINITY);
            for c in 0..k {
                let d2 = ds.points.dist2(i, &centers, c);
                if d2 < best.1 {
                    best = (c, d2);
                }
            }
            dist_comps += k as u64;
            if assign[i] != best.0 as u32 {
                assign[i] = best.0 as u32;
                changed += 1;
            }
        }
        if iterations > 0 && changed == 0 {
            break;
        }
        if iterations == max_iters {
            break;
        }
        iterations += 1;
        // Update.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        for i in 0..n {
            let a = assign[i] as usize;
            counts[a] += 1;
            for (x, &v) in ds.points.row(i).iter().enumerate() {
                sums[a * d + x] += v as f64;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let row = centers.row_mut(c);
                for x in 0..d {
                    row[x] = (sums[c * d + x] / counts[c] as f64) as f32;
                }
            }
        }
    }
    let sse: f64 =
        (0..n).map(|i| ds.points.dist2(i, &centers, assign[i] as usize) as f64).sum();
    let mut report = base_report("kmeans", &ds.name, "baseline", t0, iterations);
    report.filter.total_pairs = dist_comps;
    report.filter.surviving_pairs = dist_comps;
    report.quality = sse;
    finish_seq_power(&mut report);
    Ok(KmeansOut { centers, assign, sse, iterations, report })
}

/// Shared output shape with the coordinator's K-means.
#[derive(Debug, Clone)]
pub struct KmeansOut {
    pub centers: Matrix,
    pub assign: Vec<u32>,
    pub sse: f64,
    pub iterations: usize,
    pub report: RunReport,
}

/// Naive KNN-join: full `m x n` distance matrix row by row + heap.
pub fn knn_join(src: &Dataset, trg: &Dataset, k: usize) -> Result<KnnOut> {
    if k == 0 || k > trg.n() {
        return Err(Error::Data(format!("knn: k={k} out of range")));
    }
    let t0 = std::time::Instant::now();
    let mut neighbors = Vec::with_capacity(src.n());
    for i in 0..src.n() {
        let mut heap = TopK::new(k);
        for j in 0..trg.n() {
            heap.push(src.points.dist2(i, &trg.points, j), j as u32);
        }
        neighbors.push(heap.into_sorted());
    }
    let mut report = base_report("knn_join", &src.name, "baseline", t0, 1);
    report.filter.total_pairs = (src.n() * trg.n()) as u64;
    report.filter.surviving_pairs = report.filter.total_pairs;
    report.quality = neighbors
        .iter()
        .filter_map(|nb| nb.last().map(|&(d2, _)| d2 as f64))
        .sum::<f64>()
        / neighbors.len().max(1) as f64;
    finish_seq_power(&mut report);
    Ok(KnnOut { neighbors, k, report })
}

#[derive(Debug, Clone)]
pub struct KnnOut {
    pub neighbors: Vec<Vec<(f32, u32)>>,
    pub k: usize,
    pub report: RunReport,
}

/// Naive N-body: all-pairs radius-masked gravity + symplectic Euler.
pub fn nbody(
    ds: &Dataset,
    masses: &[f32],
    steps: usize,
    dt: f32,
    radius: f32,
) -> Result<NbodyOut> {
    if ds.d() != 3 {
        return Err(Error::Shape("nbody requires 3-D positions".into()));
    }
    let t0 = std::time::Instant::now();
    let n = ds.n();
    let mut pos = ds.points.clone();
    let mut vel = Matrix::zeros(n, 3);
    let eps2 = 1e-4f32;
    let rmax2 = radius * radius;
    let mut pairs = 0u64;
    for _ in 0..steps {
        let mut acc = vec![0.0f32; n * 3];
        for i in 0..n {
            let pi = pos.row(i);
            let (mut ax, mut ay, mut az) = (0.0f32, 0.0, 0.0);
            for j in 0..n {
                let pj = pos.row(j);
                let dx = pi[0] - pj[0];
                let dy = pi[1] - pj[1];
                let dz = pi[2] - pj[2];
                let r2 = dx * dx + dy * dy + dz * dz;
                if r2 > rmax2 {
                    continue;
                }
                let r2s = r2 + eps2;
                let inv_r3 = 1.0 / (r2s.sqrt() * r2s);
                let w = masses[j] * inv_r3;
                ax -= dx * w;
                ay -= dy * w;
                az -= dz * w;
            }
            pairs += n as u64;
            acc[i * 3] = ax;
            acc[i * 3 + 1] = ay;
            acc[i * 3 + 2] = az;
        }
        for i in 0..n {
            let v = vel.row_mut(i);
            v[0] += acc[i * 3] * dt;
            v[1] += acc[i * 3 + 1] * dt;
            v[2] += acc[i * 3 + 2] * dt;
        }
        for i in 0..n {
            let (vx, vy, vz) = {
                let v = vel.row(i);
                (v[0], v[1], v[2])
            };
            let p = pos.row_mut(i);
            p[0] += vx * dt;
            p[1] += vy * dt;
            p[2] += vz * dt;
        }
    }
    let mut report = base_report("nbody", &ds.name, "baseline", t0, steps);
    report.filter.total_pairs = pairs;
    report.filter.surviving_pairs = pairs;
    report.quality = (0..n)
        .map(|i| {
            let v = vel.row(i);
            0.5 * masses[i] as f64 * (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]) as f64
        })
        .sum();
    finish_seq_power(&mut report);
    Ok(NbodyOut { positions: pos, velocities: vel, steps, report })
}

#[derive(Debug, Clone)]
pub struct NbodyOut {
    pub positions: Matrix,
    pub velocities: Matrix,
    pub steps: usize,
    pub report: RunReport,
}

pub(crate) fn base_report(
    alg: &str,
    ds: &str,
    imp: &str,
    t0: std::time::Instant,
    iterations: usize,
) -> RunReport {
    let mut r = RunReport::new(alg, ds, imp);
    r.wall_secs = t0.elapsed().as_secs_f64();
    r.iterations = iterations;
    r
}

/// Fill energy fields for a sequential-CPU run at full utilization.
pub(crate) fn finish_seq_power(report: &mut RunReport) {
    let pm = PowerModel::default();
    report.energy_j = pm.joules(Platform::CpuSequential, report.wall_secs, 1.0);
    report.avg_watts = pm.watts(Platform::CpuSequential, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn kmeans_converges_and_sse_decreases() {
        let ds = synthetic::clustered(300, 4, 5, 0.02, 1);
        let one = kmeans(&ds, 5, 1, 7).unwrap();
        let many = kmeans(&ds, 5, 20, 7).unwrap();
        assert!(many.sse <= one.sse * 1.0001, "{} vs {}", many.sse, one.sse);
        assert!(many.iterations <= 20);
        // Every point assigned to its true nearest center.
        for i in 0..ds.n() {
            let a = many.assign[i] as usize;
            let da = ds.points.dist2(i, &many.centers, a);
            for c in 0..5 {
                assert!(da <= ds.points.dist2(i, &many.centers, c) + 1e-5);
            }
        }
    }

    #[test]
    fn knn_matches_exhaustive_sort() {
        let s = synthetic::uniform(40, 3, 2);
        let t = synthetic::uniform(60, 3, 3);
        let out = knn_join(&s, &t, 5).unwrap();
        for i in 0..s.n() {
            let mut all: Vec<(f32, u32)> =
                (0..t.n()).map(|j| (s.points.dist2(i, &t.points, j), j as u32)).collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            for (rank, &(d2, id)) in out.neighbors[i].iter().enumerate() {
                assert!((d2 - all[rank].0).abs() < 1e-6, "rank {rank} of point {i}");
                let _ = id;
            }
        }
    }

    #[test]
    fn nbody_momentum_roughly_conserved() {
        // Equal masses, no external force: total momentum stays ~0 when
        // the interaction is symmetric (radius covers everything).
        let ds = synthetic::plummer(60, 1.0, 4);
        let m = synthetic::equal_masses(60, 1.0);
        let out = nbody(&ds, &m, 3, 1e-3, 100.0).unwrap();
        let mut p = [0.0f64; 3];
        for i in 0..60 {
            for c in 0..3 {
                p[c] += (m[i] * out.velocities.row(i)[c]) as f64;
            }
        }
        for c in 0..3 {
            assert!(p[c].abs() < 1e-4, "momentum component {c} = {}", p[c]);
        }
    }
}
