//! Baseline implementations — the comparison columns of the paper's
//! evaluation (Table IV):
//!
//! * [`naive`] — "Baseline": straightforward for-loop CPU
//!   implementations with no optimization; every speedup in Figs. 8-10
//!   is normalized against these.
//! * [`top`] — "TOP": point-level triangle-inequality optimization on
//!   the CPU (Hamerly-style for K-means, landmark pruning for
//!   KNN-join, Verlet-style neighbor lists for N-body), plus the
//!   TOP-on-CPU-FPGA hybrid used in Fig. 10.
//! * [`cblas`] — "CBLAS": matrix-decomposed distance computation via a
//!   hand-blocked SGEMM on the CPU (the vendored registry has no BLAS,
//!   so the kernel is in-tree; see `cblas::sgemm_nt`).
//!
//! All baselines return the same result types as the AccD coordinator
//! so the integration tests can require exact (or tolerance-level)
//! agreement between implementations.

pub mod cblas;
pub mod naive;
pub mod top;
