//! Paper-figure regeneration: the workload sweeps behind Figs. 8-10.
//!
//! Each `fig*` function runs the implementations the figure compares on
//! the (scaled) Table V datasets and returns rows of
//! `(dataset, implementation, report)`.  The `rust/benches/fig*`
//! harnesses print them in the paper's layout; keeping the logic here
//! makes it unit-testable and reusable from the CLI.
//!
//! Scaling: the paper's full datasets (up to 434k x 3) are impractical
//! per-bench-iteration on this single-core testbed, so sweeps run at a
//! configurable `scale` (default 0.05 via `ACCD_BENCH_SCALE`) — the
//! *relative* speedups the figures report are what we reproduce, not
//! absolute runtimes.  EXPERIMENTS.md records the scale of every run.

use crate::baselines::{cblas, naive, top};
use crate::config::AccdConfig;
use crate::coordinator::Engine;
use crate::data::tablev::{self, DatasetSpec};
use crate::data::synthetic;
use crate::metrics::RunReport;
use crate::Result;

/// One figure row: dataset label, implementation, full report.
#[derive(Debug, Clone)]
pub struct FigRow {
    pub dataset: String,
    pub implementation: String,
    pub report: RunReport,
}

/// Read the dataset scale factor from the environment.
pub fn bench_scale() -> f64 {
    std::env::var("ACCD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05)
}

/// Iteration cap used for the iterative benchmarks (the paper runs to
/// convergence; a fixed cap keeps sweep time bounded and is identical
/// across implementations, so ratios are unaffected).
pub const BENCH_ITERS: usize = 8;
pub const BENCH_NBODY_STEPS: usize = 4;
pub const BENCH_NBODY_RADIUS: f32 = 0.08;

fn engine() -> Result<Engine> {
    Engine::new(AccdConfig::new())
}

/// Fig. 8a / Fig. 9a: K-means across the Table V datasets for
/// Baseline, TOP, CBLAS, and AccD.
pub fn fig8_kmeans(scale: f64, specs: &[DatasetSpec]) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    let mut eng = engine()?;
    for spec in specs {
        let s = spec.scaled(scale);
        let ds = s.generate();
        let k = s.k;
        let seed = 42;
        let base = naive::kmeans(&ds, k, BENCH_ITERS, seed)?;
        let top_r = top::kmeans(&ds, k, BENCH_ITERS, seed)?;
        let cblas_r = cblas::kmeans(&ds, k, BENCH_ITERS, seed)?;
        let accd_r = eng.kmeans(&ds, k, BENCH_ITERS)?;
        for (imp, rep) in [
            ("baseline", base.report),
            ("top", top_r.report),
            ("cblas", cblas_r.report),
            ("accd", accd_r.report),
        ] {
            rows.push(FigRow {
                dataset: spec.name.to_string(),
                implementation: imp.to_string(),
                report: rep,
            });
        }
    }
    Ok(rows)
}

/// Fig. 8b / 9b: KNN-join sweep.  The paper finds the Top-1000 of each
/// point against the same set; we scale K with the dataset.
pub fn fig8_knn(scale: f64, specs: &[DatasetSpec]) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    let mut eng = engine()?;
    for spec in specs {
        let s = spec.scaled(scale);
        let ds = s.generate();
        // Self-join flavor: sources are a quarter sample of targets.
        let mut src_spec = s.clone();
        src_spec.size = (s.size / 4).max(128);
        src_spec.seed ^= 0x77;
        let src = src_spec.generate();
        let k = s.k.min(s.size / 4).max(8);
        let seed = 42;
        let base = naive::knn_join(&src, &ds, k)?;
        let top_r = top::knn_join(&src, &ds, k, seed)?;
        let cblas_r = cblas::knn_join(&src, &ds, k)?;
        let accd_r = eng.knn_join(&src, &ds, k)?;
        for (imp, rep) in [
            ("baseline", base.report),
            ("top", top_r.report),
            ("cblas", cblas_r.report),
            ("accd", accd_r.report),
        ] {
            rows.push(FigRow {
                dataset: spec.name.to_string(),
                implementation: imp.to_string(),
                report: rep,
            });
        }
    }
    Ok(rows)
}

/// Fig. 8c / 9c: N-body sweep (no CBLAS variant, as in the paper the
/// CBLAS column is reported only where the decomposition applies).
pub fn fig8_nbody(scale: f64, specs: &[DatasetSpec]) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    let mut eng = engine()?;
    for spec in specs {
        let s = spec.scaled(scale);
        // Uniform box: the regime where a fixed interaction radius has
        // real pruning structure (see DESIGN.md §Substitutions).
        let ds = synthetic::uniform(s.size, 3, s.seed);
        let masses = synthetic::equal_masses(s.size, 1.0);
        let base = naive::nbody(&ds, &masses, BENCH_NBODY_STEPS, 1e-3, BENCH_NBODY_RADIUS)?;
        let top_r = top::nbody(&ds, &masses, BENCH_NBODY_STEPS, 1e-3, BENCH_NBODY_RADIUS)?;
        let accd_r = eng.nbody(&ds, &masses, BENCH_NBODY_STEPS, 1e-3, BENCH_NBODY_RADIUS)?;
        for (imp, rep) in [
            ("baseline", base.report),
            ("top", top_r.report),
            ("accd", accd_r.report),
        ] {
            rows.push(FigRow {
                dataset: spec.name.to_string(),
                implementation: imp.to_string(),
                report: rep,
            });
        }
    }
    Ok(rows)
}

/// Fig. 10: the K-means benefit breakdown — TOP/AccD x CPU/CPU-FPGA.
pub fn fig10_breakdown(scale: f64) -> Result<Vec<FigRow>> {
    let specs = tablev::kmeans_datasets();
    let mut rows = Vec::new();
    let mut eng = engine()?;
    for spec in &specs {
        let s = spec.scaled(scale);
        let ds = s.generate();
        let k = s.k;
        let seed = 42;
        let base = naive::kmeans(&ds, k, BENCH_ITERS, seed)?;
        // 1) TOP on CPU.
        let top_cpu = top::kmeans(&ds, k, BENCH_ITERS, seed)?;
        // 2) TOP on CPU-FPGA (point-level filter + device tiles).
        let top_fpga = top::kmeans_fpga(&mut eng, &ds, k, BENCH_ITERS, seed)?;
        // 3) AccD on CPU only (GTI filter, scalar distance kernel).
        let mut cpu_cfg = AccdConfig::new();
        cpu_cfg.use_fpga = false;
        let accd_cpu = accd_cpu_kmeans(&ds, k, BENCH_ITERS, seed)?;
        // 4) AccD on CPU-FPGA.
        let accd_fpga = eng.kmeans(&ds, k, BENCH_ITERS)?;
        for (imp, rep) in [
            ("baseline", base.report),
            ("top_cpu", top_cpu.report),
            ("top_fpga", top_fpga.report),
            ("accd_cpu", accd_cpu),
            ("accd_fpga", accd_fpga.report),
        ] {
            rows.push(FigRow {
                dataset: spec.name.to_string(),
                implementation: imp.to_string(),
                report: rep,
            });
        }
    }
    Ok(rows)
}

/// AccD's GTI filter with the surviving distances computed by the
/// scalar CPU kernel instead of the device (Fig. 10's "AccD (CPU)").
pub fn accd_cpu_kmeans(
    ds: &crate::data::Dataset,
    k: usize,
    max_iters: usize,
    seed: u64,
) -> Result<RunReport> {
    use crate::gti::Grouping;
    let t0 = std::time::Instant::now();
    let (n, d) = (ds.n(), ds.d());
    let z_src = Grouping::auto_groups(n);
    let grouping = Grouping::build(&ds.points, z_src, 3, 4096, seed)?;
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x6B6D_6561_6E73);
    let mut centers = ds.points.gather_rows(&rng.sample_indices(n, k));
    let z_trg = Grouping::auto_groups(k).min(k);
    let mut cg = Grouping::build(&centers, z_trg, 3, k, seed ^ 0xC0)?;
    let mut report = RunReport::new("kmeans", &ds.name, "accd_cpu");

    // Initial exact assignment (scalar).
    let mut assign = vec![0u32; n];
    let mut ub = vec![0.0f32; n];
    for i in 0..n {
        let mut best = (0usize, f32::INFINITY);
        for c in 0..k {
            let d2 = ds.points.dist2(i, &centers, c);
            if d2 < best.1 {
                best = (c, d2);
            }
        }
        assign[i] = best.0 as u32;
        ub[i] = best.1.sqrt();
        report.filter.surviving_pairs += k as u64;
    }
    report.filter.total_pairs += (n * k) as u64;

    let mut iterations = 0usize;
    for _ in 0..max_iters {
        iterations += 1;
        // Center update.
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        for i in 0..n {
            let a = assign[i] as usize;
            counts[a] += 1;
            for (x, &v) in ds.points.row(i).iter().enumerate() {
                sums[a * d + x] += v as f64;
            }
        }
        let mut drift = vec![0.0f32; k];
        let mut max_drift = 0.0f32;
        for c in 0..k {
            if counts[c] == 0 {
                continue;
            }
            let inv = 1.0 / counts[c] as f64;
            let row = centers.row_mut(c);
            let mut d2 = 0.0f32;
            for x in 0..d {
                let nc = (sums[c * d + x] * inv) as f32;
                let delta = nc - row[x];
                d2 += delta * delta;
                row[x] = nc;
            }
            drift[c] = d2.sqrt();
            max_drift = max_drift.max(drift[c]);
        }
        for i in 0..n {
            ub[i] += drift[assign[i] as usize];
        }
        let _ = cg.recenter(&centers);
        let bounds = crate::gti::bounds::group_pair_bounds(&grouping, &cg);
        report.filter.bound_comps += (grouping.num_groups() * cg.num_groups()) as u64;
        // Group-level filter + scalar exact recomputation.
        let mut changed = 0usize;
        for (g, members) in grouping.members.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let grp_ub = members.iter().map(|&i| ub[i as usize]).fold(0.0f32, f32::max);
            let mut cand: Vec<u32> = Vec::new();
            for (b, mem) in cg.members.iter().enumerate() {
                report.filter.group_pairs += 1;
                if bounds[g][b].lb <= grp_ub {
                    report.filter.surviving_group_pairs += 1;
                    cand.extend_from_slice(mem);
                }
            }
            report.filter.total_pairs += (members.len() * k) as u64;
            report.filter.surviving_pairs += (members.len() * cand.len()) as u64;
            for &pi in members {
                let i = pi as usize;
                let mut best = (assign[i] as usize, f32::INFINITY);
                for &c in &cand {
                    let d2 = ds.points.dist2(i, &centers, c as usize);
                    if d2 < best.1 {
                        best = (c as usize, d2);
                    }
                }
                if best.0 as u32 != assign[i] {
                    assign[i] = best.0 as u32;
                    changed += 1;
                }
                ub[i] = best.1.sqrt();
            }
        }
        if changed == 0 && max_drift < 1e-6 {
            break;
        }
    }
    let sse: f64 =
        (0..n).map(|i| ds.points.dist2(i, &centers, assign[i] as usize) as f64).sum();
    report.wall_secs = t0.elapsed().as_secs_f64();
    report.iterations = iterations;
    report.quality = sse;
    let pm = crate::fpga::PowerModel::default();
    report.energy_j =
        pm.joules(crate::fpga::Platform::CpuSequential, report.wall_secs, 1.0);
    report.avg_watts = pm.watts(crate::fpga::Platform::CpuSequential, 1.0);
    Ok(report)
}

/// Group rows by dataset and compute each implementation's speedup vs
/// the baseline row of the same dataset.
pub fn speedups(rows: &[FigRow]) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for row in rows {
        if row.implementation == "baseline" {
            continue;
        }
        if let Some(base) = rows
            .iter()
            .find(|r| r.dataset == row.dataset && r.implementation == "baseline")
        {
            out.push((
                row.dataset.clone(),
                row.implementation.clone(),
                row.report.speedup_vs(&base.report),
            ));
        }
    }
    out
}

/// Speedups using the modeled (DE10-Pro projection) accelerator time
/// for implementations that used the device; CPU-only implementations
/// are unchanged (their device time is zero).
pub fn modeled_speedups(rows: &[FigRow]) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for row in rows {
        if row.implementation == "baseline" {
            continue;
        }
        if let Some(base) = rows
            .iter()
            .find(|r| r.dataset == row.dataset && r.implementation == "baseline")
        {
            out.push((
                row.dataset.clone(),
                row.implementation.clone(),
                row.report.modeled_speedup_vs(&base.report),
            ));
        }
    }
    out
}

/// Same but for energy efficiency (Fig. 9).
pub fn energy_effs(rows: &[FigRow]) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for row in rows {
        if row.implementation == "baseline" {
            continue;
        }
        if let Some(base) = rows
            .iter()
            .find(|r| r.dataset == row.dataset && r.implementation == "baseline")
        {
            out.push((
                row.dataset.clone(),
                row.implementation.clone(),
                row.report.energy_eff_vs(&base.report),
            ));
        }
    }
    out
}

/// Energy efficiency under the DE10-Pro projection, for device-using
/// implementations (others fall back to the measured value).
pub fn modeled_energy_effs(rows: &[FigRow]) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for row in rows {
        if row.implementation == "baseline" {
            continue;
        }
        if let Some(base) = rows
            .iter()
            .find(|r| r.dataset == row.dataset && r.implementation == "baseline")
        {
            let eff = if row.report.device.tiles > 0 {
                row.report.modeled_energy_eff_vs(&base.report)
            } else {
                row.report.energy_eff_vs(&base.report)
            };
            out.push((row.dataset.clone(), row.implementation.clone(), eff));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_rows_exclude_baseline() {
        let mk = |ds: &str, imp: &str, wall: f64| {
            let mut r = RunReport::new("kmeans", ds, imp);
            r.wall_secs = wall;
            r.energy_j = wall * 20.0;
            FigRow { dataset: ds.into(), implementation: imp.into(), report: r }
        };
        let rows = vec![mk("a", "baseline", 10.0), mk("a", "accd", 2.0)];
        let sp = speedups(&rows);
        assert_eq!(sp.len(), 1);
        assert_eq!(sp[0].1, "accd");
        assert!((sp[0].2 - 5.0).abs() < 1e-12);
        let ee = energy_effs(&rows);
        assert!((ee[0].2 - 5.0).abs() < 1e-12);
    }

    #[test]
    fn accd_cpu_kmeans_matches_naive_sse() {
        let ds = crate::data::synthetic::clustered(400, 5, 8, 0.03, 3);
        let base = crate::baselines::naive::kmeans(&ds, 10, 8, 42).unwrap();
        let rep = accd_cpu_kmeans(&ds, 10, 8, 42).unwrap();
        let rel = (rep.quality - base.sse).abs() / (1.0 + base.sse);
        assert!(rel <= 1e-3, "accd_cpu {} vs naive {}", rep.quality, base.sse);
    }
}
