//! Resource model — paper §VI-B-c, Eq. 9.
//!
//! Estimates on-chip resource consumption of a kernel configuration by
//! scaling a calibrated single-block cost table.  The paper calibrates
//! `Resource_single` by micro-benchmarking synthesized kernels on the
//! DE10-Pro; without a synthesis toolchain we ship a calibration table
//! derived from the DE10-Pro datasheet arithmetic (documented per entry
//! below and in DESIGN.md §Substitutions) — the *structure* of the
//! model (Eq. 9 scaling + Eq. 10 validation) is exactly the paper's.

use crate::config::HwConfig;

/// Resource budget of the target board (paper §VII-A: DE10-Pro,
/// Stratix 10 GX: 378k LEs / 128,160 ALMs / 512,640 ALM registers /
/// 648 DSPs / 1,537 M20K blocks).
#[derive(Debug, Clone, PartialEq)]
pub struct StratixBudget {
    pub alms: f64,
    pub registers: f64,
    pub dsps: f64,
    pub m20k_blocks: f64,
    /// Usable external bandwidth, bytes/sec.
    pub bw_bytes: f64,
}

impl Default for StratixBudget {
    fn default() -> Self {
        Self {
            alms: 128_160.0,
            registers: 512_640.0,
            dsps: 648.0,
            m20k_blocks: 1_537.0,
            bw_bytes: 17.0e9,
        }
    }
}

/// Estimated consumption of a full design (same units as the budget).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceEstimate {
    pub alms: f64,
    pub registers: f64,
    pub dsps: f64,
    pub m20k_blocks: f64,
    pub bw_bytes: f64,
}

impl ResourceEstimate {
    /// Eq. 10 constraint validation.
    pub fn fits(&self, budget: &StratixBudget) -> bool {
        self.alms <= budget.alms
            && self.registers <= budget.registers
            && self.dsps <= budget.dsps
            && self.m20k_blocks <= budget.m20k_blocks
            && self.bw_bytes <= budget.bw_bytes
    }

    /// Worst utilization fraction across resource classes (DSE uses
    /// this as a soft penalty near the budget edge).
    pub fn max_utilization(&self, budget: &StratixBudget) -> f64 {
        [
            self.alms / budget.alms,
            self.registers / budget.registers,
            self.dsps / budget.dsps,
            self.m20k_blocks / budget.m20k_blocks,
            self.bw_bytes / budget.bw_bytes,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Calibrated per-unit costs of one distance-computation block
/// (`Resource_single` in Eq. 9).
///
/// Calibration provenance (datasheet arithmetic, not synthesis):
/// * one f32 MAC lane = 1 DSP (Stratix-10 DSPs are native f32) plus
///   ~45 ALMs of glue and ~180 registers of pipeline state;
/// * per-block control adds ~220 ALMs / ~400 registers;
/// * M20K = 20 kbit => one 64 x d x f32 tile buffer consumes
///   `ceil(64*d*32 / 20480)` blocks, double-buffered x2, two operands.
#[derive(Debug, Clone)]
pub struct ResourceModel {
    pub alms_per_lane: f64,
    pub regs_per_lane: f64,
    pub dsps_per_lane: f64,
    pub alms_per_block: f64,
    pub regs_per_block: f64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self {
            alms_per_lane: 45.0,
            regs_per_lane: 180.0,
            dsps_per_lane: 1.0,
            alms_per_block: 220.0,
            regs_per_block: 400.0,
        }
    }
}

impl ResourceModel {
    /// `Resource_single`: one computation block of the configured shape.
    pub fn single_block(&self, hw: &HwConfig, d: usize) -> ResourceEstimate {
        let lanes = (hw.simd * hw.unroll) as f64;
        // Two operand tile buffers (blk x d), double-buffered, plus the
        // (blk x blk) output accumulator.
        let bits_in = 2.0 * 2.0 * (hw.block * d * 32) as f64;
        let bits_out = (hw.block * hw.block * 32) as f64;
        let m20k = ((bits_in + bits_out) / 20_480.0).ceil();
        ResourceEstimate {
            alms: self.alms_per_block + lanes * self.alms_per_lane,
            registers: self.regs_per_block + lanes * self.regs_per_lane,
            dsps: lanes * self.dsps_per_lane,
            m20k_blocks: m20k,
            bw_bytes: 0.0,
        }
    }

    /// Eq. 9: scale the single block over the `(src/blk) x (trg/blk)`
    /// grid, capped at `max_parallel_blocks` physical block instances
    /// (the grid beyond that is time-multiplexed, costing latency not
    /// area — the cap is what couples this model to the cost model in
    /// the DSE).
    pub fn estimate(
        &self,
        hw: &HwConfig,
        d: usize,
        src_size: usize,
        trg_size: usize,
        max_parallel_blocks: usize,
        bw_required: f64,
    ) -> ResourceEstimate {
        let single = self.single_block(hw, d);
        let grid = (src_size as f64 / hw.block as f64).ceil()
            * (trg_size as f64 / hw.block as f64).ceil();
        let instances = grid.min(max_parallel_blocks as f64).max(1.0);
        ResourceEstimate {
            alms: single.alms * instances,
            registers: single.registers * instances,
            dsps: single.dsps * instances,
            m20k_blocks: single.m20k_blocks * instances,
            bw_bytes: bw_required,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_single_block_fits_budget() {
        let m = ResourceModel::default();
        let est = m.single_block(&HwConfig::default(), 64);
        assert!(est.fits(&StratixBudget::default()), "{est:?}");
    }

    #[test]
    fn absurd_config_fails_eq10() {
        let m = ResourceModel::default();
        let hw = HwConfig { simd: 64, unroll: 64, ..Default::default() }; // 4096 DSPs
        let est = m.estimate(&hw, 64, 100_000, 100_000, 8, 1e9);
        assert!(!est.fits(&StratixBudget::default()));
    }

    #[test]
    fn estimate_scales_with_instances() {
        let m = ResourceModel::default();
        let hw = HwConfig::default();
        let one = m.estimate(&hw, 32, 64, 64, 8, 0.0); // grid = 1
        let many = m.estimate(&hw, 32, 6_400, 6_400, 8, 0.0); // capped at 8
        assert!((many.dsps / one.dsps - 8.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_reflects_tightest_resource() {
        let budget = StratixBudget::default();
        let est = ResourceEstimate { dsps: 648.0, ..Default::default() };
        assert!((est.max_utilization(&budget) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bigger_tiles_need_more_m20k() {
        let m = ResourceModel::default();
        let small = m.single_block(&HwConfig { block: 32, ..Default::default() }, 32);
        let large = m.single_block(&HwConfig { block: 128, ..Default::default() }, 32);
        assert!(large.m20k_blocks > small.m20k_blocks);
    }
}
