//! Runtime power model for the energy-efficiency evaluation (Fig. 9).
//!
//! The paper measures wall power with an external meter (Poniie
//! PN2000); this reproduction models it instead, calibrated to the
//! wattage ranges the paper reports in §VII-B-b:
//!
//! * sequential CPU implementations (Baseline, TOP): ~20.9-25.6 W
//! * multi-core/BLAS CPU implementations: ~42.5-65.8 W
//! * AccD CPU-FPGA design: ~5-17.1 W on the accelerator side
//!
//! The model is `P = P_idle + P_peak_dyn * utilization`, with the
//! utilization supplied by the execution stats, so energy numbers react
//! to how busy each platform actually was in our runs.

/// Which execution platform a measurement belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    /// Single-core sequential CPU (Baseline / TOP).
    CpuSequential,
    /// Multi-threaded / SIMD BLAS CPU (CBLAS).
    CpuParallel,
    /// The CPU-FPGA heterogeneous design (host share).
    AccdHost,
    /// The CPU-FPGA heterogeneous design (FPGA share).
    AccdFpga,
}

/// Calibrated idle/dynamic wattages per platform.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub cpu_seq_idle: f64,
    pub cpu_seq_dyn: f64,
    pub cpu_par_idle: f64,
    pub cpu_par_dyn: f64,
    pub accd_host_idle: f64,
    pub accd_host_dyn: f64,
    pub fpga_idle: f64,
    pub fpga_dyn: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            // Xeon Silver 4110, one active core: ~19 W idle package +
            // up to ~8 W one-core dynamic => 20.9-25.6 W band.
            cpu_seq_idle: 19.0,
            cpu_seq_dyn: 8.0,
            // All-core AVX BLAS: up to the ~66 W the paper observes.
            cpu_par_idle: 22.0,
            cpu_par_dyn: 44.0,
            // AccD host share: filter work on one core, lighter than a
            // full sequential run because the FPGA does the heavy part.
            accd_host_idle: 3.0,
            accd_host_dyn: 6.0,
            // DE10-Pro: ~5 W board idle, ~12 W kernel dynamic => the
            // 5-17.1 W band of the paper.
            fpga_idle: 5.0,
            fpga_dyn: 12.1,
        }
    }
}

impl PowerModel {
    /// Average watts for a platform at `utilization` in [0, 1].
    pub fn watts(&self, platform: Platform, utilization: f64) -> f64 {
        let u = utilization.clamp(0.0, 1.0);
        match platform {
            Platform::CpuSequential => self.cpu_seq_idle + self.cpu_seq_dyn * u,
            Platform::CpuParallel => self.cpu_par_idle + self.cpu_par_dyn * u,
            Platform::AccdHost => self.accd_host_idle + self.accd_host_dyn * u,
            Platform::AccdFpga => self.fpga_idle + self.fpga_dyn * u,
        }
    }

    /// Energy (joules) of a phase that ran `secs` at `utilization`.
    pub fn joules(&self, platform: Platform, secs: f64, utilization: f64) -> f64 {
        self.watts(platform, utilization) * secs
    }

    /// Combined AccD platform energy: host runs the filter for
    /// `host_secs` (at `host_util`), FPGA runs tiles for `fpga_secs`
    /// busy out of `total_secs` elapsed.
    pub fn accd_joules(
        &self,
        total_secs: f64,
        host_secs: f64,
        host_util: f64,
        fpga_busy_secs: f64,
    ) -> f64 {
        let host = self.joules(Platform::AccdHost, host_secs, host_util)
            + self.joules(Platform::AccdHost, (total_secs - host_secs).max(0.0), 0.0);
        let fpga_util = if total_secs > 0.0 { (fpga_busy_secs / total_secs).min(1.0) } else { 0.0 };
        let fpga = self.joules(Platform::AccdFpga, total_secs, fpga_util);
        host + fpga
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wattage_bands_match_paper_ranges() {
        let m = PowerModel::default();
        // Sequential CPU: 20.9 W (paper's observed TOP lower bound) must
        // be reachable within the band.
        assert!(m.watts(Platform::CpuSequential, 0.0) <= 20.9);
        assert!(m.watts(Platform::CpuSequential, 1.0) >= 20.9);
        // CBLAS band reaches the paper's 65.79 W average.
        assert!(m.watts(Platform::CpuParallel, 1.0) >= 65.0);
        // FPGA band is the paper's 5-17.12 W.
        assert!((m.watts(Platform::AccdFpga, 0.0) - 5.0).abs() < 1e-9);
        assert!(m.watts(Platform::AccdFpga, 1.0) <= 17.2);
    }

    #[test]
    fn utilization_clamps() {
        let m = PowerModel::default();
        assert_eq!(m.watts(Platform::AccdFpga, 2.0), m.watts(Platform::AccdFpga, 1.0));
        assert_eq!(m.watts(Platform::AccdFpga, -1.0), m.watts(Platform::AccdFpga, 0.0));
    }

    #[test]
    fn joules_scale_with_time() {
        let m = PowerModel::default();
        let e1 = m.joules(Platform::CpuSequential, 1.0, 0.5);
        let e2 = m.joules(Platform::CpuSequential, 2.0, 0.5);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
    }

    #[test]
    fn accd_energy_less_than_parallel_cpu_for_same_time() {
        let m = PowerModel::default();
        let t = 10.0;
        let accd = m.accd_joules(t, 3.0, 1.0, 6.0);
        let cblas = m.joules(Platform::CpuParallel, t, 1.0);
        assert!(accd < cblas);
    }
}
