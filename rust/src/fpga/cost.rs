//! Analytical performance model — paper §VI-B Eqs. 5-8.
//!
//! `Latency = Latency_filt + Latency_comp` where the filter term covers
//! the CPU-side grouping/bound work and the comp term the FPGA-side
//! distance tiles.  The model is used twice: (1) by the DSE explorer to
//! rank configurations without running them, and (2) by the device to
//! report modeled-FPGA time next to the measured PJRT wall time.

use crate::config::HwConfig;

/// Inputs describing one algorithm execution for the model.
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    pub src_size: usize,
    pub trg_size: usize,
    pub d: usize,
    pub n_src_grp: usize,
    pub n_trg_grp: usize,
    /// Grouping refinement iterations (paper `n_iteration`).
    pub n_iteration: usize,
    /// Surviving fraction of distance computations after GTI filtering
    /// (paper's `ratio_save`; measured when available, else Eq. 7).
    pub ratio_surviving: f64,
    /// Bytes per scalar (4 for f32).
    pub dtype_bytes: usize,
}

impl WorkloadModel {
    /// Eq. 7 estimate of the surviving ratio when no measurement
    /// exists.  `alpha` is the point-density parameter; larger alpha
    /// (denser data) means less pruning.  The paper's formula yields a
    /// *saving* factor; we clamp its complement into (0, 1].
    pub fn eq7_surviving_ratio(&self, alpha: f64) -> f64 {
        let group_pts = (self.src_size * self.trg_size) as f64
            / (self.n_src_grp.max(1) * self.n_trg_grp.max(1)) as f64;
        let save = (self.n_iteration as f64 / alpha.max(1e-9)) * group_pts.sqrt();
        // Normalize: saving saturates; express survivors as 1/(1+save').
        1.0 / (1.0 + save / (self.src_size as f64).sqrt())
    }
}

/// Latency split the model produces (seconds).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// CPU-side GTI filtering (Eq. 6 first line).
    pub filt_secs: f64,
    /// FPGA-side remaining distance computation (Eq. 6 second line).
    pub comp_secs: f64,
    /// Host<->device transfer at the modeled bandwidth.
    pub xfer_secs: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.filt_secs + self.comp_secs + self.xfer_secs
    }
}

/// The configured analytical model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HwConfig,
    /// Host scalar distance-op throughput (ops/sec) for the filter term.
    /// Calibrated on the Xeon-class host: ~1 GF effective scalar.
    pub cpu_flops: f64,
    /// External memory bandwidth of the accelerator board (bytes/sec).
    /// DE10-Pro DDR4: ~17 GB/s usable.
    pub fpga_bw: f64,
}

impl CostModel {
    pub fn new(hw: HwConfig) -> Self {
        Self { hw, cpu_flops: 1.0e9, fpga_bw: 17.0e9 }
    }

    /// Eq. 6, `Latency_filt`: grouping + bound computation on the CPU.
    /// The dominant term is `n_trg_grp * n_src_grp * d` bound work plus
    /// the sample-bounded grouping refinement.
    pub fn latency_filt(&self, w: &WorkloadModel) -> f64 {
        let bound_ops = (w.n_src_grp * w.n_trg_grp * w.d) as f64;
        let grouping_ops = ((w.src_size + w.trg_size) * w.d) as f64
            * w.n_iteration as f64
            / w.n_iteration.max(1) as f64; // one assignment pass per build
        (bound_ops + grouping_ops) / self.cpu_flops
    }

    /// Eq. 6, `Latency_comp`: surviving distance computations on the
    /// accelerator at `blk^2 * simd * unroll` MACs per cycle.
    pub fn latency_comp(&self, w: &WorkloadModel) -> f64 {
        let surviving =
            w.src_size as f64 * w.trg_size as f64 * w.ratio_surviving * w.d as f64;
        let macs_per_cycle =
            (self.hw.block * self.hw.block) as f64 * self.hw.simd as f64 * self.hw.unroll as f64
                / (self.hw.block * self.hw.block) as f64; // simd*unroll lanes active
        let cycles = surviving / macs_per_cycle.max(1.0);
        cycles / (self.hw.freq_mhz * 1e6)
    }

    /// Eq. 8 bandwidth requirement given total latency.
    pub fn bandwidth(&self, w: &WorkloadModel, latency: f64) -> f64 {
        ((w.src_size + w.trg_size) * w.d * w.dtype_bytes) as f64 / latency.max(1e-12)
    }

    /// Full Eq. 5 evaluation.
    pub fn latency(&self, w: &WorkloadModel) -> LatencyBreakdown {
        let filt = self.latency_filt(w);
        let comp = self.latency_comp(w);
        let bytes = ((w.src_size + w.trg_size) * w.d * w.dtype_bytes) as f64;
        let xfer = bytes / self.fpga_bw;
        LatencyBreakdown { filt_secs: filt, comp_secs: comp, xfer_secs: xfer }
    }

    /// Modeled seconds for `tiles` accelerator tiles of shape
    /// `(tm x tn x d)` — the per-tile form of `Latency_comp` used by
    /// the device's running clock.
    pub fn tile_seconds(&self, tiles: u64, tm: usize, tn: usize, d: usize) -> f64 {
        let macs = tiles as f64 * (tm * tn * d) as f64;
        let lanes = (self.hw.simd * self.hw.unroll) as f64;
        macs / lanes / (self.hw.freq_mhz * 1e6)
    }

    /// Modeled accelerator throughput in *pairs* per second for
    /// dimensionality `d` — the inverse of `tile_seconds(1, 1, 1, d)`.
    /// This is the bridge between the planner's abstract cost units
    /// (pair counts, see `WorkUnit::cost_estimate`) and time.
    pub fn pairs_per_sec(&self, d: usize) -> f64 {
        let lanes = (self.hw.simd * self.hw.unroll) as f64;
        lanes * self.hw.freq_mhz * 1e6 / d.max(1) as f64
    }

    /// Convert cold bytes that would have to cross the DMA link into
    /// the planner's cost units: the pairs the accelerator could have
    /// computed in the time the transfer takes.  This makes the
    /// movement term directly comparable to `WorkUnit::cost_estimate`,
    /// so a warm shard wins exactly when staying saves more modeled
    /// time than the compute imbalance costs.
    pub fn move_penalty_units(&self, dma: &DmaModel, bytes: u64, d: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let secs = dma.transfer_ns(bytes) as f64 * 1e-9;
        (secs * self.pairs_per_sec(d)).round() as u64
    }

    /// Eq. 5 extended over an emulated multi-device pool: `devices`
    /// devices split the surviving tiles evenly, each re-paying the
    /// DMA upload of its input partition (the filter term stays on the
    /// one host CPU).  The DSE machinery uses this to rank device
    /// counts the same way it ranks tile shapes.
    pub fn latency_multi_device(
        &self,
        w: &WorkloadModel,
        dma: &DmaModel,
        devices: usize,
    ) -> LatencyBreakdown {
        let n = devices.max(1) as f64;
        let filt = self.latency_filt(w);
        let comp = self.latency_comp(w) / n;
        let bytes = ((w.src_size + w.trg_size) * w.d * w.dtype_bytes) as f64;
        // Each device uploads its own 1/n slice plus pays the fixed
        // per-transfer latency; uploads run concurrently across
        // devices, so the wall term is one slice, not n.
        let xfer = dma.transfer_ns((bytes / n).ceil() as u64) as f64 * 1e-9;
        LatencyBreakdown { filt_secs: filt, comp_secs: comp, xfer_secs: xfer }
    }
}

/// The modeled host<->device DMA link of one emulated device: a fixed
/// per-transfer setup latency plus per-byte streaming at `gbps`
/// (decimal GB/s, matching how PCIe/DMA link specs are quoted).  The
/// shape mirrors the AWS F1 `fpga_dma` burst-write discipline: every
/// transfer pays the doorbell/descriptor setup once, then streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaModel {
    /// Link streaming rate in decimal gigabytes per second.
    pub gbps: f64,
    /// Fixed per-transfer setup cost (descriptor + doorbell), ns.
    pub latency_ns: u64,
}

impl DmaModel {
    /// Typical PCIe gen3 x8 DMA setup cost.
    pub const DEFAULT_LATENCY_NS: u64 = 2_000;

    pub fn new(gbps: f64) -> Self {
        Self { gbps, latency_ns: Self::DEFAULT_LATENCY_NS }
    }

    /// Modeled nanoseconds to move `bytes` across the link.  Zero
    /// bytes is free: no transfer is issued at all, so no setup cost.
    pub fn transfer_ns(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let stream_ns = (bytes as f64 / self.gbps.max(1e-9)).ceil() as u64;
        self.latency_ns + stream_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl() -> WorkloadModel {
        WorkloadModel {
            src_size: 100_000,
            trg_size: 1_000,
            d: 32,
            n_src_grp: 100,
            n_trg_grp: 10,
            n_iteration: 3,
            ratio_surviving: 0.2,
            dtype_bytes: 4,
        }
    }

    #[test]
    fn more_lanes_cut_comp_latency() {
        let slow = CostModel::new(HwConfig { simd: 1, unroll: 1, ..Default::default() });
        let fast = CostModel::new(HwConfig { simd: 16, unroll: 8, ..Default::default() });
        let w = wl();
        assert!(fast.latency_comp(&w) < slow.latency_comp(&w) / 50.0);
    }

    #[test]
    fn filtering_reduces_comp_term() {
        let m = CostModel::new(HwConfig::default());
        let mut w = wl();
        let full = m.latency_comp(&WorkloadModel { ratio_surviving: 1.0, ..w.clone() });
        w.ratio_surviving = 0.1;
        assert!((m.latency_comp(&w) - full * 0.1).abs() / full < 1e-9);
    }

    #[test]
    fn eq7_monotonic_in_density() {
        let w = wl();
        // Denser data (higher alpha) -> more survivors.
        assert!(w.eq7_surviving_ratio(10.0) > w.eq7_surviving_ratio(1.0));
        let r = w.eq7_surviving_ratio(1.0);
        assert!(r > 0.0 && r <= 1.0);
    }

    #[test]
    fn bandwidth_matches_eq8() {
        let m = CostModel::new(HwConfig::default());
        let w = wl();
        let bw = m.bandwidth(&w, 1.0);
        assert_eq!(bw, ((w.src_size + w.trg_size) * w.d * 4) as f64);
    }

    #[test]
    fn tile_seconds_scales_linearly() {
        let m = CostModel::new(HwConfig::default());
        let one = m.tile_seconds(1, 64, 64, 32);
        let ten = m.tile_seconds(10, 64, 64, 32);
        assert!((ten - 10.0 * one).abs() < 1e-15);
    }

    #[test]
    fn dma_transfer_is_latency_plus_stream_and_zero_is_free() {
        let dma = DmaModel::new(16.0); // 16 GB/s = 16 bytes/ns
        assert_eq!(dma.transfer_ns(0), 0);
        // 16 KiB at 16 B/ns = 1024 ns of streaming + setup.
        assert_eq!(dma.transfer_ns(16 * 1024), DmaModel::DEFAULT_LATENCY_NS + 1024);
        // The fixed latency dominates tiny transfers: 1 byte != free.
        assert!(dma.transfer_ns(1) > DmaModel::DEFAULT_LATENCY_NS);
        // A faster link strictly shrinks the streaming term.
        let fast = DmaModel::new(32.0);
        assert!(fast.transfer_ns(1 << 20) < dma.transfer_ns(1 << 20));
    }

    #[test]
    fn move_penalty_is_zero_for_warm_and_monotonic_in_bytes() {
        let m = CostModel::new(HwConfig::default());
        let dma = DmaModel::new(16.0);
        assert_eq!(m.move_penalty_units(&dma, 0, 8), 0);
        let small = m.move_penalty_units(&dma, 64 * 1024, 8);
        let big = m.move_penalty_units(&dma, 4 << 20, 8);
        assert!(small > 0, "a cold slab must cost something");
        assert!(big > small, "more cold bytes must cost more");
        // Sanity of scale: penalty equals transfer time re-expressed
        // as pairs the accelerator could have computed meanwhile.
        let secs = dma.transfer_ns(4 << 20) as f64 * 1e-9;
        assert_eq!(big, (secs * m.pairs_per_sec(8)).round() as u64);
    }

    #[test]
    fn multi_device_latency_splits_comp_and_xfer_not_filt() {
        let m = CostModel::new(HwConfig::default());
        let dma = DmaModel::new(16.0);
        let w = wl();
        let one = m.latency_multi_device(&w, &dma, 1);
        let four = m.latency_multi_device(&w, &dma, 4);
        assert_eq!(one.filt_secs, four.filt_secs, "filter stays on the host CPU");
        assert!((four.comp_secs - one.comp_secs / 4.0).abs() < 1e-12);
        assert!(four.xfer_secs < one.xfer_secs, "each device uploads a slice");
        assert!(four.total() < one.total(), "DSE must see more devices as faster here");
    }
}
