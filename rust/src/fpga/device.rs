//! Functional accelerator device: tile jobs in, tile results out.
//!
//! Wraps [`crate::runtime::Runtime`] with padding, batching and the
//! simulated-clock bookkeeping.  Every job the GTI filter emits is a
//! dense (source group x candidate target groups) rectangle; the device
//! splits it into manifest-sized tiles, executes them on PJRT, and
//! accumulates both wall-clock and modeled-FPGA time.

use std::sync::Arc;

use super::cost::CostModel;
use crate::config::HwConfig;
use crate::data::Matrix;
use crate::runtime::Runtime;
use crate::util::round_up;
use crate::Result;

/// One dense distance job: a padded source slab against a padded
/// target slab.  `src_rows`/`trg_rows` are the *valid* (unpadded)
/// counts; padding rows' outputs are discarded.
///
/// The target slab is reference-counted: the serving layer coalesces
/// queries whose jobs hit the same candidate target set, so one packed
/// slab is built once per cohort and shared by every job (and query)
/// that streams it — the cross-query analogue of the Fig. 4b slab
/// reuse.
#[derive(Debug, Clone)]
pub struct TileJob {
    /// Row-major `(src_rows_padded, d_padded)` source slab.
    pub src: Vec<f32>,
    pub src_rows: usize,
    /// Row-major `(trg_rows_padded, d_padded)` target slab, shared
    /// between jobs with identical candidate target sets.
    pub trg: std::sync::Arc<Vec<f32>>,
    pub trg_rows: usize,
    pub d: usize,
    pub d_padded: usize,
    pub metric: &'static str,
}

/// Dense distance block result (valid rows/cols only).
#[derive(Debug, Clone)]
pub struct TileResult {
    /// Row-major `(src_rows, trg_rows)` distances.
    pub dist: Vec<f32>,
    pub src_rows: usize,
    pub trg_rows: usize,
}

/// Counters the device accumulates over its lifetime.
#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    pub jobs: u64,
    pub tiles: u64,
    /// Point-pair distances actually computed (incl. padding waste).
    pub padded_pairs: u64,
    /// Valid point-pair distances delivered.
    pub valid_pairs: u64,
    /// Wall-clock seconds spent inside PJRT execution.
    pub wall_secs: f64,
    /// Modeled FPGA seconds (cost model, Eq. 6 comp term).
    pub modeled_secs: f64,
    /// Host<->device traffic in bytes (modeled transfers).
    pub bytes_moved: u64,
}

impl DeviceStats {
    /// Padding efficiency: valid / computed pairs.
    pub fn pad_efficiency(&self) -> f64 {
        if self.padded_pairs == 0 {
            1.0
        } else {
            self.valid_pairs as f64 / self.padded_pairs as f64
        }
    }
}

/// The simulated CPU-attached FPGA accelerator.
pub struct FpgaDevice {
    runtime: Arc<Runtime>,
    cost: CostModel,
    stats: std::sync::Mutex<DeviceStats>,
}

impl FpgaDevice {
    pub fn new(runtime: Arc<Runtime>, hw: HwConfig) -> Self {
        Self {
            runtime,
            cost: CostModel::new(hw),
            stats: std::sync::Mutex::new(DeviceStats::default()),
        }
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    pub fn stats(&self) -> DeviceStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = DeviceStats::default();
    }

    /// Pad a sub-slice of points (rows given by `ids` into `points`)
    /// into an `(rows_padded x d_padded)` tile input buffer.
    pub fn pad_rows(
        points: &Matrix,
        ids: &[u32],
        rows_padded: usize,
        d_padded: usize,
    ) -> Vec<f32> {
        let d = points.cols();
        let mut out = vec![0.0f32; rows_padded * d_padded];
        for (r, &pi) in ids.iter().enumerate() {
            out[r * d_padded..r * d_padded + d].copy_from_slice(points.row(pi as usize));
        }
        out
    }

    /// Pad a contiguous row-major slab (already packed by the layout
    /// optimizer) into a tile input buffer.
    pub fn pad_slab(
        slab: &[f32],
        rows: usize,
        d: usize,
        rows_padded: usize,
        d_padded: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; rows_padded * d_padded];
        for r in 0..rows {
            out[r * d_padded..r * d_padded + d].copy_from_slice(&slab[r * d..(r + 1) * d]);
        }
        out
    }

    /// Execute one dense distance job over a greedy mix of tile-size
    /// variants: large tiles cover the bulk (one PJRT dispatch carries
    /// up to 512x512 pairs), base tiles cover the remainder so padding
    /// waste stays at the base-tile grid.  Returns the valid
    /// `(src_rows x trg_rows)` distance block.
    pub fn distance_block(&self, job: &TileJob) -> Result<TileResult> {
        let manifest = self.runtime.manifest().clone();
        let t = &manifest.tile;
        let sr_pad = round_up(job.src_rows.max(1), t.m);
        let tr_pad = round_up(job.trg_rows.max(1), t.n);
        debug_assert_eq!(job.src.len(), sr_pad * job.d_padded, "src slab not padded to tile grid");
        debug_assert_eq!(job.trg.len(), tr_pad * job.d_padded);

        // Large tiles on ONE axis only: the perf probe (EXPERIMENTS.md
        // §Perf, ablation 3) shows single-large-axis tiles at 3.7-4.4
        // GMAC/s while two-axis 512x512 drops to 3.5 (the 2-D Pallas
        // grid lowers to a slower loop nest on the CPU backend).  The
        // column axis wins end-to-end (scatter of a (64, tn) tile is
        // one contiguous row copy per output row), so columns get the
        // large variants whenever they can fill one; otherwise rows do.
        // ACCD_FORCE_BASE_TILES=1 forces 64x64 everywhere (ablation 3).
        let base_only = |rows: usize| -> Vec<(usize, usize)> {
            let b = manifest.tile.m;
            (0..crate::util::round_up(rows.max(1), b) / b).map(|i| (i * b, b)).collect()
        };
        let force_base = std::env::var_os("ACCD_FORCE_BASE_TILES").is_some();
        let big = *manifest.tile.variants.last().unwrap_or(&manifest.tile.m);
        let (row_segs, col_segs) = if force_base {
            (base_only(job.src_rows), base_only(job.trg_rows))
        } else if job.trg_rows >= big || job.trg_rows >= job.src_rows {
            (base_only(job.src_rows), manifest.segments(job.trg_rows))
        } else {
            (manifest.segments(job.src_rows), base_only(job.trg_rows))
        };
        let mut dist = vec![0.0f32; job.src_rows * job.trg_rows];
        let wall_start = std::time::Instant::now();
        let mut tiles = 0u64;
        let mut mac_tiles = 0.0f64;
        // Scratch buffers for segments that overrun the padded slab.
        let mut a_buf: Vec<f32> = Vec::new();
        let mut b_buf: Vec<f32> = Vec::new();
        for &(ro, tm) in &row_segs {
            if ro >= job.src_rows {
                break; // fully-padding segment
            }
            let valid_m = (job.src_rows - ro).min(tm);
            let a: &[f32] = slab_segment(
                &job.src, sr_pad, job.d_padded, ro, tm, &mut a_buf,
            );
            for &(co, tn) in &col_segs {
                if co >= job.trg_rows {
                    break;
                }
                let valid_n = (job.trg_rows - co).min(tn);
                let b: &[f32] = slab_segment(
                    &job.trg, tr_pad, job.d_padded, co, tn, &mut b_buf,
                );
                let tile =
                    self.runtime.distance_tile_sized(job.metric, tm, tn, job.d_padded, a, b)?;
                tiles += 1;
                mac_tiles += (tm * tn) as f64;
                for r in 0..valid_m {
                    let out_off = (ro + r) * job.trg_rows + co;
                    dist[out_off..out_off + valid_n]
                        .copy_from_slice(&tile[r * tn..r * tn + valid_n]);
                }
            }
        }
        let wall = wall_start.elapsed().as_secs_f64();

        let mut s = self.stats.lock().unwrap();
        s.jobs += 1;
        s.tiles += tiles;
        s.padded_pairs += mac_tiles as u64;
        s.valid_pairs += (job.src_rows * job.trg_rows) as u64;
        s.wall_secs += wall;
        s.modeled_secs += self.cost.tile_seconds(1, 1, 1, 1) * mac_tiles * job.d_padded as f64;
        s.bytes_moved += ((sr_pad + tr_pad) * job.d_padded * 4
            + job.src_rows * job.trg_rows * 4) as u64;
        Ok(TileResult { dist, src_rows: job.src_rows, trg_rows: job.trg_rows })
    }

    /// Fused K-means assignment over all points of a padded slab,
    /// segmented greedily over the tile variants (one PJRT dispatch per
    /// up-to-512-row segment).  Returns (assigned center index,
    /// squared distance) per valid row.
    pub fn kmeans_assign_block(
        &self,
        points_slab: &[f32],
        valid_rows: usize,
        d_padded: usize,
        centers_padded: &[f32],
        k_padded: usize,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let manifest = self.runtime.manifest().clone();
        let rows_pad = round_up(valid_rows.max(1), manifest.tile.m);
        debug_assert_eq!(points_slab.len(), rows_pad * d_padded);
        let mut idx = vec![0i32; valid_rows];
        let mut dist = vec![0.0f32; valid_rows];
        let wall_start = std::time::Instant::now();
        let mut tiles = 0u64;
        let mut mac_rows = 0u64;
        let mut a_buf: Vec<f32> = Vec::new();
        for (ro, tm) in manifest.segments(valid_rows) {
            if ro >= valid_rows {
                break;
            }
            let valid_m = (valid_rows - ro).min(tm);
            let a = slab_segment(points_slab, rows_pad, d_padded, ro, tm, &mut a_buf);
            let (ti, td) =
                self.runtime.kmeans_assign_tile_sized(tm, k_padded, d_padded, a, centers_padded)?;
            tiles += 1;
            mac_rows += tm as u64;
            idx[ro..ro + valid_m].copy_from_slice(&ti[..valid_m]);
            dist[ro..ro + valid_m].copy_from_slice(&td[..valid_m]);
        }
        let wall = wall_start.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.jobs += 1;
        s.tiles += tiles;
        s.padded_pairs += mac_rows * k_padded as u64;
        s.valid_pairs += (valid_rows * k_padded) as u64;
        s.wall_secs += wall;
        s.modeled_secs += self.cost.tile_seconds(1, 1, 1, 1)
            * (mac_rows * k_padded as u64) as f64
            * d_padded as f64;
        s.bytes_moved +=
            ((rows_pad + k_padded) * d_padded * 4 + valid_rows * 8) as u64;
        Ok((idx, dist))
    }

    /// Like [`FpgaDevice::kmeans_assign_block`], but also returns the
    /// squared distance to the *second*-closest center per valid row —
    /// the plan-time seed of the incremental TI path's Hamerly lower
    /// bound.  With a single real center the second slot reports the
    /// padding sentinel's distance (effectively infinite), which is the
    /// correct "no other center" lower bound.
    pub fn kmeans_assign2_block(
        &self,
        points_slab: &[f32],
        valid_rows: usize,
        d_padded: usize,
        centers_padded: &[f32],
        k_padded: usize,
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
        let manifest = self.runtime.manifest().clone();
        let rows_pad = round_up(valid_rows.max(1), manifest.tile.m);
        debug_assert_eq!(points_slab.len(), rows_pad * d_padded);
        let mut idx = vec![0i32; valid_rows];
        let mut dist = vec![0.0f32; valid_rows];
        let mut second = vec![0.0f32; valid_rows];
        let wall_start = std::time::Instant::now();
        let mut tiles = 0u64;
        let mut mac_rows = 0u64;
        let mut a_buf: Vec<f32> = Vec::new();
        for (ro, tm) in manifest.segments(valid_rows) {
            if ro >= valid_rows {
                break;
            }
            let valid_m = (valid_rows - ro).min(tm);
            let a = slab_segment(points_slab, rows_pad, d_padded, ro, tm, &mut a_buf);
            let (ti, td, ts) = self
                .runtime
                .kmeans_assign2_tile_sized(tm, k_padded, d_padded, a, centers_padded)?;
            tiles += 1;
            mac_rows += tm as u64;
            idx[ro..ro + valid_m].copy_from_slice(&ti[..valid_m]);
            dist[ro..ro + valid_m].copy_from_slice(&td[..valid_m]);
            second[ro..ro + valid_m].copy_from_slice(&ts[..valid_m]);
        }
        let wall = wall_start.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.jobs += 1;
        s.tiles += tiles;
        s.padded_pairs += mac_rows * k_padded as u64;
        s.valid_pairs += (valid_rows * k_padded) as u64;
        s.wall_secs += wall;
        s.modeled_secs += self.cost.tile_seconds(1, 1, 1, 1)
            * (mac_rows * k_padded as u64) as f64
            * d_padded as f64;
        s.bytes_moved +=
            ((rows_pad + k_padded) * d_padded * 4 + valid_rows * 12) as u64;
        Ok((idx, dist, second))
    }

    /// N-body acceleration of a padded source slab against a padded
    /// target slab (masses zero on padding rows), segmented greedily
    /// over the tile variants on both axes.  Adds into `acc`
    /// (`valid_i x 3`, source-slab row order).
    #[allow(clippy::too_many_arguments)]
    pub fn nbody_accumulate(
        &self,
        pos_i: &[f32],
        valid_i: usize,
        pos_j: &[f32],
        mass_j: &[f32],
        eps2: f32,
        rmax2: f32,
        acc: &mut [f32],
    ) -> Result<()> {
        let manifest = self.runtime.manifest().clone();
        let base = manifest.tile.nbody;
        let rows_pad = round_up(valid_i.max(1), base);
        debug_assert_eq!(pos_i.len(), rows_pad * 3);
        debug_assert_eq!(pos_j.len() % (base * 3), 0);
        let trg_rows = pos_j.len() / 3;
        let wall_start = std::time::Instant::now();
        let mut tiles = 0u64;
        let mut mac_tiles = 0.0f64;
        let mut i_buf: Vec<f32> = Vec::new();
        let mut j_buf: Vec<f32> = Vec::new();
        let mut m_buf: Vec<f32> = Vec::new();
        for (ro, tm) in manifest.segments(valid_i) {
            if ro >= valid_i {
                break;
            }
            let valid_m = (valid_i - ro).min(tm);
            let pi = slab_segment(pos_i, rows_pad, 3, ro, tm, &mut i_buf);
            for (co, tn) in manifest.segments(trg_rows) {
                if co >= trg_rows {
                    break;
                }
                let pj = slab_segment(pos_j, trg_rows, 3, co, tn, &mut j_buf);
                let mj = slab_segment(mass_j, trg_rows, 1, co, tn, &mut m_buf);
                let a = self.runtime.nbody_accel_sized(tm, tn, pi, pj, mj, eps2, rmax2)?;
                tiles += 1;
                mac_tiles += (tm * tn) as f64;
                for r in 0..valid_m {
                    let i = ro + r;
                    acc[i * 3] += a[r * 3];
                    acc[i * 3 + 1] += a[r * 3 + 1];
                    acc[i * 3 + 2] += a[r * 3 + 2];
                }
            }
        }
        let wall = wall_start.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.jobs += 1;
        s.tiles += tiles;
        s.padded_pairs += mac_tiles as u64;
        s.valid_pairs += (valid_i * trg_rows) as u64;
        s.wall_secs += wall;
        s.modeled_secs += self.cost.tile_seconds(1, 1, 1, 1) * mac_tiles * 4.0;
        s.bytes_moved +=
            ((rows_pad + trg_rows) * 3 * 4 + trg_rows * 4) as u64 + (valid_i * 3 * 4) as u64;
        Ok(())
    }
}

/// Borrow rows `[off, off+edge)` of a `(rows_padded x cols)` row-major
/// slab, zero-padding through a scratch buffer when the segment
/// overruns the slab (defensive; segments normally fit exactly).
fn slab_segment<'a>(
    slab: &'a [f32],
    rows_padded: usize,
    cols: usize,
    off: usize,
    edge: usize,
    scratch: &'a mut Vec<f32>,
) -> &'a [f32] {
    if off + edge <= rows_padded {
        &slab[off * cols..(off + edge) * cols]
    } else {
        scratch.clear();
        scratch.resize(edge * cols, 0.0);
        let avail = rows_padded.saturating_sub(off);
        scratch[..avail * cols].copy_from_slice(&slab[off * cols..rows_padded * cols]);
        &scratch[..]
    }
}
