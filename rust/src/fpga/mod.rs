//! The accelerator: functional execution + analytical DE10-Pro model.
//!
//! The paper's Intel Stratix-10 DE10-Pro is unavailable here, so the
//! "FPGA" is split into two coupled halves (DESIGN.md §Substitutions):
//!
//! * [`device`] — **functional** half: executes the real AOT-compiled
//!   distance kernels through PJRT, so every number the system produces
//!   is computed by the actual accelerator code path.
//! * [`cost`] — **analytical** half: the paper's performance model
//!   (Eqs. 5-8) evaluated on the same tile stream, giving estimated
//!   FPGA latency/bandwidth for the configured (blk, simd, unroll,
//!   frequency) design point.
//! * [`resource`] — the paper's Eq. 9 resource model with a
//!   micro-benchmark calibration table for `Resource_single`.
//! * [`power`] — runtime power model for the energy-efficiency figures
//!   (Fig. 9), calibrated to the wattage ranges the paper reports.

pub mod cost;
pub mod device;
pub mod power;
pub mod resource;

pub use cost::{CostModel, DmaModel, LatencyBreakdown};
pub use device::{FpgaDevice, TileJob, TileResult};
pub use power::{PowerModel, Platform};
pub use resource::{ResourceEstimate, ResourceModel, StratixBudget};
