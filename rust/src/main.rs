//! `accd` — the AccD launcher.
//!
//! Subcommands:
//!
//! * `run <program.dd>` — compile a DDSL program, bind synthetic (or
//!   CSV) datasets to its DSets, and execute the plan on the CPU-FPGA
//!   engine.
//! * `kmeans | knn | nbody` — run one algorithm directly with explicit
//!   parameters, choosing the implementation with `--impl`.
//! * `explore` — run the DSE explorer on a workload description and
//!   print the chosen design point.
//! * `info` — show the artifact manifest and platform.
//!
//! Run `accd <subcommand> --help` (or no args) for usage.

use accd::baselines::{cblas, naive, top};
use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{loader, synthetic, Dataset};
use accd::ddsl::{self, plan::PlanKind};
use accd::dse::{explorer::Workload, Explorer};
use accd::util::cli::Args;

const USAGE: &str = "\
accd — compiler-based acceleration of distance-related algorithms (AccD)

USAGE:
  accd run <program.dd> [--data file.csv] [--impl accd|naive|top|cblas] [--seed N]
  accd kmeans  --n N --d D --k K [--iters I] [--impl ...] [--seed N] [--data file.csv]
  accd knn     --n N --m M --d D --k K [--impl ...] [--seed N]
  accd nbody   --n N --steps S --radius R [--dt T] [--impl ...] [--seed N]
  accd explore --n N --m M --d D [--iters I] [--alpha A]
  accd info

COMMON OPTIONS:
  --config path.json   load AccdConfig overrides
  --artifacts dir      artifact directory (default: artifacts)
  --no-fpga            run the AccD implementation CPU-only
  --json               print the run report as JSON
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    match dispatch(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    let cmd = argv[0].as_str();
    let rest = &argv[1..];
    let value_opts = [
        "n", "m", "d", "k", "iters", "steps", "radius", "dt", "impl", "seed", "config",
        "artifacts", "data", "alpha", "groups",
    ];
    let flags = ["no-fpga", "json", "verbose"];
    let args = Args::parse(rest, &value_opts, &flags).map_err(anyhow::Error::msg)?;

    match cmd {
        "run" => cmd_run(&args),
        "kmeans" => cmd_kmeans(&args),
        "knn" => cmd_knn(&args),
        "nbody" => cmd_nbody(&args),
        "explore" => cmd_explore(&args),
        "info" => cmd_info(&args),
        other => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn load_config(args: &Args) -> anyhow::Result<AccdConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => AccdConfig::load(path)?,
        None => AccdConfig::new(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifact_dir = dir.to_string();
    }
    if args.flag("no-fpga") {
        cfg.use_fpga = false;
    }
    cfg.seed = args.get_u64("seed", cfg.seed).map_err(anyhow::Error::msg)?;
    if let Some(g) = args.get("groups") {
        let g: usize = g.parse().map_err(|_| anyhow::anyhow!("--groups expects an integer"))?;
        cfg.gti.src_groups = g;
        cfg.gti.trg_groups = g;
    }
    Ok(cfg)
}

fn print_report(report: &accd::metrics::RunReport, json: bool) {
    if json {
        println!("{}", report.to_json().to_string());
    } else {
        println!("{}", report.summary());
    }
}

fn cmd_kmeans(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("n", 20_000).map_err(anyhow::Error::msg)?;
    let d = args.get_usize("d", 16).map_err(anyhow::Error::msg)?;
    let k = args.get_usize("k", 64).map_err(anyhow::Error::msg)?;
    let iters = args.get_usize("iters", 20).map_err(anyhow::Error::msg)?;
    let ds = match args.get("data") {
        Some(path) => loader::load_csv(path, &loader::CsvOptions::default())?,
        None => synthetic::clustered(n, d, (n as f64).sqrt() as usize / 2, 0.03, cfg.seed),
    };
    let imp = args.get_or("impl", "accd");
    let report = match imp {
        "accd" => {
            let mut eng = Engine::new(cfg)?;
            eng.kmeans(&ds, k, iters)?.report
        }
        "naive" => naive::kmeans(&ds, k, iters, cfg.seed)?.report,
        "top" => top::kmeans(&ds, k, iters, cfg.seed)?.report,
        "cblas" => cblas::kmeans(&ds, k, iters, cfg.seed)?.report,
        other => anyhow::bail!("unknown --impl {other:?}"),
    };
    print_report(&report, args.flag("json"));
    Ok(())
}

fn cmd_knn(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("n", 20_000).map_err(anyhow::Error::msg)?; // targets
    let m = args.get_usize("m", 5_000).map_err(anyhow::Error::msg)?; // sources
    let d = args.get_usize("d", 8).map_err(anyhow::Error::msg)?;
    let k = args.get_usize("k", 100).map_err(anyhow::Error::msg)?;
    let src = synthetic::clustered(m, d, (m as f64).sqrt() as usize / 2, 0.03, cfg.seed);
    let trg = synthetic::clustered(n, d, (n as f64).sqrt() as usize / 2, 0.03, cfg.seed ^ 1);
    let imp = args.get_or("impl", "accd");
    let report = match imp {
        "accd" => {
            let mut eng = Engine::new(cfg)?;
            eng.knn_join(&src, &trg, k)?.report
        }
        "naive" => naive::knn_join(&src, &trg, k)?.report,
        "top" => top::knn_join(&src, &trg, k, cfg.seed)?.report,
        "cblas" => cblas::knn_join(&src, &trg, k)?.report,
        other => anyhow::bail!("unknown --impl {other:?}"),
    };
    print_report(&report, args.flag("json"));
    Ok(())
}

fn cmd_nbody(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("n", 16_384).map_err(anyhow::Error::msg)?;
    let steps = args.get_usize("steps", 5).map_err(anyhow::Error::msg)?;
    let radius = args.get_f64("radius", 0.1).map_err(anyhow::Error::msg)? as f32;
    let dt = args.get_f64("dt", 1e-3).map_err(anyhow::Error::msg)? as f32;
    let ds = synthetic::uniform(n, 3, cfg.seed);
    let masses = synthetic::equal_masses(n, 1.0);
    let imp = args.get_or("impl", "accd");
    let report = match imp {
        "accd" => {
            let mut eng = Engine::new(cfg)?;
            eng.nbody(&ds, &masses, steps, dt, radius)?.report
        }
        "naive" => naive::nbody(&ds, &masses, steps, dt, radius)?.report,
        "top" => top::nbody(&ds, &masses, steps, dt, radius)?.report,
        other => anyhow::bail!("unknown --impl {other:?} (nbody has no cblas variant)"),
    };
    print_report(&report, args.flag("json"));
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: accd run <program.dd>"))?;
    let src = std::fs::read_to_string(path)?;
    let plan = ddsl::compile_program(&src)?;
    println!(
        "compiled {path}: {:?} | GTI strategy: {} | metric {}{}",
        kind_name(&plan.kind),
        plan.strategy,
        if plan.metric.weighted { "weighted " } else { "" },
        plan.metric.norm,
    );
    let cfg = load_config(args)?;
    let seed = cfg.seed;
    let mut eng = Engine::new(cfg)?;

    // Bind datasets: CSV if provided, synthetic otherwise (shapes from
    // the program's DSet declarations).
    let bind = |name: &str, size: usize, dim: usize, salt: u64| -> Dataset {
        let mut ds = synthetic::clustered(
            size,
            dim,
            (size as f64).sqrt() as usize / 2,
            0.03,
            seed ^ salt,
        );
        ds.name = name.to_string();
        ds
    };
    let report = match &plan.kind {
        PlanKind::KmeansLike { points, centers: _, k, max_iters } => {
            let (pname, psize, pdim) = &plan.bindings[0];
            let _ = points;
            let ds = match args.get("data") {
                Some(p) => loader::load_csv(p, &loader::CsvOptions::default())?,
                None => bind(pname, *psize, *pdim, 0xA),
            };
            eng.kmeans(&ds, *k, *max_iters)?.report
        }
        PlanKind::KnnJoinLike { k, .. } => {
            let (sname, ssize, sdim) = &plan.bindings[0];
            let (tname, tsize, tdim) = &plan.bindings[1];
            let src_ds = bind(sname, *ssize, *sdim, 0xB);
            let trg_ds = bind(tname, *tsize, *tdim, 0xC);
            anyhow::ensure!(sdim == tdim, "source/target dim mismatch");
            let metric = accd::gti::Metric::from_ddsl(&plan.metric.norm);
            eng.knn_join_metric(&src_ds, &trg_ds, *k, metric)?.report
        }
        PlanKind::NbodyLike { radius_expr, max_iters, .. } => {
            let (pname, psize, _) = &plan.bindings[0];
            let mut ds = synthetic::uniform(*psize, 3, seed ^ 0xD);
            ds.name = pname.clone();
            let masses = synthetic::equal_masses(*psize, 1.0);
            // DDSL ranges are integers; interpret as percent of box edge.
            let radius = (*radius_expr as f32) / 100.0;
            eng.nbody(&ds, &masses, *max_iters, 1e-3, radius)?.report
        }
    };
    print_report(&report, args.flag("json"));
    Ok(())
}

fn kind_name(kind: &PlanKind) -> &'static str {
    match kind {
        PlanKind::KmeansLike { .. } => "K-means-like clustering",
        PlanKind::KnnJoinLike { .. } => "KNN-join",
        PlanKind::NbodyLike { .. } => "N-body-like self-join",
    }
}

fn cmd_explore(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 70_187).map_err(anyhow::Error::msg)?;
    let m = args.get_usize("m", 265).map_err(anyhow::Error::msg)?;
    let d = args.get_usize("d", 60).map_err(anyhow::Error::msg)?;
    let iters = args.get_usize("iters", 3).map_err(anyhow::Error::msg)?;
    let alpha = args.get_f64("alpha", 10.0).map_err(anyhow::Error::msg)?;
    let w = Workload { src_size: n, trg_size: m, d, n_iteration: iters, alpha };
    let out = Explorer::default().explore(&w)?;
    println!(
        "explored {} configs ({} infeasible) over {} generations",
        out.evaluated, out.infeasible, out.generations
    );
    println!(
        "best design: src_groups={} trg_groups={} block={} simd={} unroll={}",
        out.best.n_src_grp, out.best.n_trg_grp, out.best.block, out.best.simd, out.best.unroll
    );
    println!("modeled latency: {:.6} s", out.best_latency);
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let rt = accd::runtime::Runtime::load_or_builtin(&cfg.artifact_dir)?;
    println!("platform: {}", rt.platform());
    let m = rt.manifest();
    println!(
        "tile: m={} n={} d_pad={:?} knn_k={} kmeans_k_pad={:?} nbody={}",
        m.tile.m, m.tile.n, m.tile.d_pad, m.tile.knn_k, m.tile.kmeans_k_pad, m.tile.nbody
    );
    println!("artifacts ({}):", m.entries.len());
    for e in &m.entries {
        println!("  {} [{:?}] inputs {:?}", e.name, e.kind, e.inputs);
    }
    Ok(())
}
