//! DDSL abstract syntax tree (paper §III constructs).

/// Scalar/element types supported by DDSL (paper §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    Int,
    Float,
    Double,
}

impl DType {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "int" => Some(Self::Int),
            "float" => Some(Self::Float),
            "double" => Some(Self::Double),
            _ => None,
        }
    }
}

/// A size/dimension expression: literal or reference to a `DVar`.
#[derive(Debug, Clone, PartialEq)]
pub enum SizeExpr {
    Lit(usize),
    Var(String),
}

/// Scalar literal values for `DVar` initializers / assignments.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Bool(bool),
}

/// Definition constructs.
#[derive(Debug, Clone, PartialEq)]
pub enum Decl {
    /// `DVar name type [init];`
    Var { name: String, ty: DType, init: Option<Value> },
    /// `DSet name type size dim;`
    Set { name: String, ty: DType, size: SizeExpr, dim: SizeExpr },
}

/// Distance metric of a `AccD_Comp_Dist` (paper Table I `mtr`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Metric {
    pub weighted: bool,
    /// "L1" or "L2".
    pub norm: String,
}

impl Metric {
    /// Parse the paper's metric strings: `"Unweighted L1"`,
    /// `"Weighted L2"`, plain `"L2"`, ...
    pub fn parse(s: &str) -> Option<Self> {
        let lower = s.to_ascii_lowercase();
        let weighted = lower.contains("weighted") && !lower.contains("unweighted");
        let norm = if lower.contains("l1") {
            "L1"
        } else if lower.contains("l2") || lower.contains("euclid") {
            "L2"
        } else {
            return None;
        };
        Some(Metric { weighted, norm: norm.to_string() })
    }
}

/// Operation and control constructs.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `AccD_Comp_Dist(p1, p2, disMat, idMat, dim, mtr, weightMat);`
    CompDist {
        src: String,
        trg: String,
        dist_mat: String,
        id_mat: String,
        dim: SizeExpr,
        metric: Metric,
        /// `0` for unweighted, or the weight-matrix DSet name.
        weight: Option<String>,
    },
    /// `AccD_Dist_Select(distMat, idMat, range, scope, outMat);`
    DistSelect {
        dist_mat: String,
        id_mat: String,
        /// K (Top-K) or a distance threshold (range search).
        range: SizeExpr,
        /// "smallest" | "largest" | "within".
        scope: String,
        out_mat: String,
    },
    /// `AccD_Update(var, p1, ..., pm, status);`
    Update { target: String, inputs: Vec<String>, status: String },
    /// `AccD_Iter(cond|maxIter) { ... }`
    Iter { cond: IterCond, body: Vec<Stmt> },
    /// `name = value;`
    Assign { name: String, value: Value },
}

/// Iteration exit condition (paper §III-E).
#[derive(Debug, Clone, PartialEq)]
pub enum IterCond {
    /// Loop while the named status variable is true.
    Status(String),
    /// Fixed maximum iteration count.
    MaxIters(usize),
}

/// A full DDSL program.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    pub decls: Vec<Decl>,
    pub body: Vec<Stmt>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_parsing_covers_paper_strings() {
        let m = Metric::parse("Unweighted L1").unwrap();
        assert!(!m.weighted);
        assert_eq!(m.norm, "L1");
        let m = Metric::parse("Weighted L2").unwrap();
        assert!(m.weighted);
        assert_eq!(m.norm, "L2");
        assert_eq!(Metric::parse("Euclidean").unwrap().norm, "L2");
        assert!(Metric::parse("cosine").is_none());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float"), Some(DType::Float));
        assert_eq!(DType::parse("void"), None);
    }
}
